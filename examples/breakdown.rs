//! Online-phase breakdown (Fig 14 in miniature): time each stage of the
//! TARDIS FFN pipeline — folded matmul, predictor, top-K aux, result
//! fixing — on the tardis80 variant, and print the share decomposition.
//!
//! ```sh
//! make artifacts && cargo run --release --example breakdown
//! ```

use anyhow::Result;
use tardis::config::Manifest;
use tardis::runtime::engine::{buffer_to_f32, buffer_to_i32};
use tardis::runtime::Engine;
use tardis::util::stats::Samples;

fn time_stage<F: FnMut() -> Result<()>>(iters: usize, mut f: F) -> Result<f64> {
    let mut s = Samples::new();
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f()?;
        s.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(s.mean())
}

fn main() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_path())?;
    let engine = Engine::cpu()?;
    let v = engine.load_variant(
        &manifest, "tardis80",
        Some(&["ffn_dense", "ffn_folded", "ffn_predictor", "ffn_aux",
               "ffn_fix"]))?;
    let d = manifest.model.d_model;
    let x = engine.upload_f32(&vec![0.1f32; manifest.batch * d],
                              &[manifest.batch, d])?;

    let score = v.exec("ffn_predictor")?.run(&[&x])?;
    let aux = v.exec("ffn_aux")?.run(&[&score[0]])?;
    let iters = 40;

    let t_dense = time_stage(iters, || {
        let o = v.exec("ffn_dense")?.run(&[&x])?;
        buffer_to_f32(&o[0]).map(|_| ())
    })?;
    let t_fold = time_stage(iters, || {
        let o = v.exec("ffn_folded")?.run(&[&x])?;
        buffer_to_f32(&o[0]).map(|_| ())
    })?;
    let t_pred = time_stage(iters, || {
        let o = v.exec("ffn_predictor")?.run(&[&x])?;
        buffer_to_f32(&o[0]).map(|_| ())
    })?;
    let t_aux = time_stage(iters, || {
        let o = v.exec("ffn_aux")?.run(&[&score[0]])?;
        buffer_to_i32(&o[0]).map(|_| ())
    })?;
    let t_fix = time_stage(iters, || {
        let o = v.exec("ffn_fix")?.run(&[&x, &aux[0], &aux[1]])?;
        buffer_to_f32(&o[0]).map(|_| ())
    })?;

    let total = t_fold + t_pred + t_aux + t_fix;
    println!("TARDIS FFN online-phase breakdown (tardis80, K={}):",
             v.spec.fix_capacity);
    println!("  folded matmul  {:7.3} ms  {:5.1}%  (paper ~22%)",
             t_fold, 100.0 * t_fold / total);
    println!("  predictor      {:7.3} ms  {:5.1}%  (paper ~12%)",
             t_pred, 100.0 * t_pred / total);
    println!("  aux (top-K)    {:7.3} ms  {:5.1}%",
             t_aux, 100.0 * t_aux / total);
    println!("  result fixing  {:7.3} ms  {:5.1}%  (paper: dominant)",
             t_fix, 100.0 * t_fix / total);
    println!("  -- total       {:7.3} ms  vs dense FFN {:7.3} ms ({:.2}x)",
             total, t_dense, t_dense / total);
    Ok(())
}
