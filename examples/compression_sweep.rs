//! Compression-ratio sweep on the live runtime: for each exported variant,
//! generate the same prompt and report tokens/s, decode latency, and the
//! modeled 4090 speedup side by side — a minimal Fig 13 you can eyeball.
//!
//! ```sh
//! make artifacts && cargo run --release --example compression_sweep
//! ```

use anyhow::Result;
use tardis::config::Manifest;
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::PjrtModel;
use tardis::coordinator::request::SamplingParams;
use tardis::costmodel;
use tardis::runtime::Engine;
use tardis::server::protocol::encode_text;

fn main() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_path())?;
    let engine = Engine::cpu()?;
    let params = SamplingParams { max_tokens: 64, ..Default::default() };

    println!("{:10} {:>7} {:>9} {:>12} {:>14}",
             "variant", "ratio", "tok/s", "decode ms", "4090 e2e model");
    let mut base_tps = None;
    for v in manifest.variant_names() {
        let variant = engine.load_variant(&manifest, v,
                                          Some(&["decode", "prefill16"]))?;
        let ratio = variant.spec.compression_ratio;
        let model = PjrtModel::new(&engine, variant, manifest.batch,
                                   manifest.model.max_seq,
                                   manifest.model.vocab, vec![16])?;
        let mut ie = InferenceEngine::new(model, EngineConfig::default());
        let t0 = std::time::Instant::now();
        let c = ie.generate_sequential(encode_text("the quick "), params)?;
        let tps = c.tokens.len() as f64 / t0.elapsed().as_secs_f64();
        if base_tps.is_none() {
            base_tps = Some(tps);
        }
        let (_, e2e) = if ratio > 0.0 {
            costmodel::tardis_speedup(&costmodel::FALCON_7B,
                                      &costmodel::RTX_4090, 1, 128, ratio, 0.05)
        } else {
            (1.0, 1.0)
        };
        println!("{:10} {:6.1}% {:9.1} {:12.2} {:13.2}x",
                 v, ratio * 100.0, tps, ie.decode_latency_ms.mean(), e2e);
    }
    Ok(())
}
