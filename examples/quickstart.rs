//! Quickstart: load the TARDIS-folded model, generate text, compare with
//! the dense baseline.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use tardis::config::Manifest;
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::PjrtModel;
use tardis::coordinator::request::SamplingParams;
use tardis::runtime::Engine;
use tardis::server::protocol::{decode_tokens, encode_text};

fn main() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_path())?;
    let engine = Engine::cpu()?;
    println!("platform: {}", engine.platform());
    println!("model: {} ({} layers, d={}, act={})",
             manifest.model.name, manifest.model.n_layers,
             manifest.model.d_model, manifest.model.act);

    let prompt = "the falcon ";
    let params = SamplingParams { max_tokens: 40, ..Default::default() };

    for variant in ["dense", "tardis80"] {
        let v = engine.load_variant(&manifest, variant,
                                    Some(&["decode", "prefill16"]))?;
        let ratio = v.spec.compression_ratio;
        let model = PjrtModel::new(&engine, v, manifest.batch,
                                   manifest.model.max_seq,
                                   manifest.model.vocab, vec![16])?;
        let mut ie = InferenceEngine::new(model, EngineConfig::default());
        let t0 = std::time::Instant::now();
        let c = ie.generate_sequential(encode_text(prompt), params)?;
        let dt = t0.elapsed().as_secs_f64();
        println!();
        println!("[{variant}] (FFN compression {:.1}%)", ratio * 100.0);
        println!("  {}{}", prompt, decode_tokens(&c.tokens));
        println!("  {} tokens, {:.2} tok/s, decode mean {:.2} ms",
                 c.tokens.len(), c.tokens.len() as f64 / dt,
                 ie.decode_latency_ms.mean());
    }
    Ok(())
}
