//! Serving demo: start the TCP server over two variants (dense +
//! tardis80), fire a batch of concurrent clients at it, and report
//! latency/throughput per variant — the paper's deployment story
//! (§7.4's vLLM integration) end to end.
//!
//! PJRT buffers are not Send, so the engine/router stay on the main
//! thread (serve() runs here) while clients drive from a worker pool.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_batch
//! ```

use std::sync::{Arc, Mutex};

use anyhow::Result;
use tardis::config::Manifest;
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::PjrtModel;
use tardis::coordinator::router::Router;
use tardis::runtime::Engine;
use tardis::server::tcp::{client_roundtrip, serve};
use tardis::util::stats::Samples;
use tardis::util::threadpool::ThreadPool;

const N_REQUESTS: usize = 12;

fn main() -> Result<()> {
    let manifest = Manifest::load(&Manifest::default_path())?;
    let engine = Engine::cpu()?;
    let mut replicas = Vec::new();
    for vname in ["dense", "tardis80"] {
        eprintln!("loading {vname} ...");
        let v = engine.load_variant(&manifest, vname,
                                    Some(&["decode", "prefill16"]))?;
        let model = PjrtModel::new(&engine, v, manifest.batch,
                                   manifest.model.max_seq,
                                   manifest.model.vocab, vec![16])?;
        replicas.push((vname.to_string(),
                       InferenceEngine::new(model, EngineConfig::default())));
    }
    let router = Router::new(replicas);

    // pick an ephemeral port
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    drop(listener);

    // clients on a separate thread (plain TCP, Send-safe);
    // the PJRT-backed server loop runs on this thread below.
    let lat: Arc<Mutex<Vec<(String, f64)>>> = Arc::new(Mutex::new(Vec::new()));
    let client_addr = addr.clone();
    let client_lat = Arc::clone(&lat);
    let clients = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(400));
        let pool = ThreadPool::new(6);
        let t0 = std::time::Instant::now();
        pool.map((0..N_REQUESTS).collect::<Vec<_>>(), move |i| {
            let variant = if i % 2 == 0 { "dense" } else { "tardis80" };
            let req = format!(
                r#"{{"op":"generate","prompt":"the {} ","max_tokens":24,"variant":"{variant}"}}"#,
                ["falcon", "river", "market", "engine"][i % 4]
            );
            let t = std::time::Instant::now();
            let resp = client_roundtrip(&client_addr, &req).expect("roundtrip");
            let ms = t.elapsed().as_secs_f64() * 1e3;
            assert!(resp.contains("\"ok\":true"), "{resp}");
            client_lat.lock().unwrap().push((variant.to_string(), ms));
        });
        t0.elapsed().as_secs_f64()
    });

    let served = serve(router, &addr, Some(N_REQUESTS))?;
    let wall = clients.join().expect("clients thread");

    println!();
    println!("served {served} requests in {wall:.2}s \
              ({:.2} req/s, {} tokens total)",
             served as f64 / wall, served * 24);
    for variant in ["dense", "tardis80"] {
        let mut s = Samples::new();
        for (v, ms) in lat.lock().unwrap().iter() {
            if v == variant {
                s.push(*ms);
            }
        }
        println!("  {variant:9} latency: {}", s.summary());
    }
    Ok(())
}
