"""Table/figure regeneration harness (see DESIGN.md experiment index).

Each module regenerates one paper table/figure on the synthetic testbed
and appends its output to ``artifacts/results/<name>.txt``. ``run_all``
executes every bench in dependency order.
"""
