"""Shared bench plumbing: cached models, calibration, folds, and output
capture (every bench writes artifacts/results/<name>.txt and prints)."""

from __future__ import annotations

import contextlib
import io
import sys
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[2]
ARTIFACTS = REPO / "artifacts"
RESULTS = ARTIFACTS / "results"

sys.path.insert(0, str(REPO / "python"))

from compile import evalsuite  # noqa: E402
from compile.baselines import METHODS  # noqa: E402
from compile.model import ModelConfig  # noqa: E402
from compile.tardis import calibration, pipeline  # noqa: E402
from compile.train import MODEL_ZOO, get_or_train  # noqa: E402

_CACHE: dict = {}


def model(name: str = "tiny-gelu"):
    """(cfg, params) for a zoo model, trained/cached under artifacts."""
    if name not in _CACHE:
        _CACHE[name] = get_or_train(name, ARTIFACTS / "weights",
                                    verbose=True)
    return _CACHE[name]


def calib(name: str = "tiny-gelu", dataset: str = "c4-syn", n_samples=8):
    key = ("calib", name, dataset, n_samples)
    if key not in _CACHE:
        cfg, params = model(name)
        _CACHE[key] = calibration.collect(params, cfg, dataset=dataset,
                                          n_samples=n_samples)
    return _CACHE[key]


def fold(name: str = "tiny-gelu", ratio: float | None = None,
         target_t: float | None = None, bits: int = 2, dataset="c4-syn",
         **kw):
    """Folded params + report, cached per configuration."""
    cfg, params = model(name)
    if target_t is None:
        target_t = pipeline.threshold_for_ratio(cfg, ratio, bits)
    key = ("fold", name, round(target_t, 4), bits, dataset,
           tuple(sorted(kw.items())))
    if key not in _CACHE:
        _CACHE[key] = pipeline.fold_model(
            params, cfg, target_t=target_t, bits=bits,
            stats=calib(name, dataset), **kw)
    return _CACHE[key]


def pruned(name: str, method: str, ratio: float):
    key = ("prune", name, method, ratio)
    if key not in _CACHE:
        cfg, params = model(name)
        _CACHE[key] = METHODS[method](params, calib(name), ratio)
    return _CACHE[key]


@contextlib.contextmanager
def bench_output(bench_name: str):
    """Tee stdout to artifacts/results/<bench_name>.txt."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    buf = io.StringIO()
    orig = sys.stdout

    class Tee:
        def write(self, s):
            orig.write(s)
            buf.write(s)

        def flush(self):
            orig.flush()

    sys.stdout = Tee()
    t0 = time.time()
    try:
        yield
    finally:
        sys.stdout = orig
        out = buf.getvalue()
        (RESULTS / f"{bench_name}.txt").write_text(
            out + f"\n[wall time: {time.time() - t0:.1f}s]\n")


def ppl(params, cfg: ModelConfig, dataset: str, **kw) -> float:
    return evalsuite.perplexity(params, cfg, dataset=dataset,
                                max_windows=kw.pop("max_windows", 24), **kw)


def acc(params, cfg: ModelConfig, task: str, **kw) -> float:
    return evalsuite.zero_shot_accuracy(
        params, cfg, task=task, n_items=kw.pop("n_items", 48), **kw)


def fmt_row(cells, widths=None):
    widths = widths or [12] * len(cells)
    return "  ".join(str(c)[:w].ljust(w) for c, w in zip(cells, widths))
