"""Fig 5: kernel density estimates of activation-input distributions for
sampled neurons across layers and calibration datasets. Emits an ASCII
density plot plus the cross-dataset stability statistic the figure
illustrates (same layer, different datasets -> similar distributions)."""

import numpy as np

from . import common
from compile import corpus
from compile.tardis import kde


def _ascii_density(dens: np.ndarray, width: int = 48) -> str:
    d = dens / (dens.max() + 1e-12)
    chars = " .:-=+*#%@"
    idx = (d * (len(chars) - 1)).astype(int)
    return "".join(chars[i] for i in idx[:width])


def run(n_neurons: int = 6):
    with common.bench_output("fig05_density"):
        cfg, params = common.model("tiny-gelu")
        layers = [0, cfg.n_layers - 1]
        print("Fig 5 — activation-input KDE per neuron "
              "(layers {} of tiny-gelu)".format(layers))
        rng = np.random.default_rng(0)
        sel = rng.choice(cfg.d_ff, n_neurons, replace=False)
        for ds in corpus.DATASETS:
            stats = common.calib("tiny-gelu", dataset=ds)
            print(f"\ndataset {ds}:")
            for li in layers:
                z = stats.z[li][:, sel]
                grid, dens = kde.kde_grid(z, grid_points=48)
                for j, n in enumerate(sel[:3]):
                    print(f"  L{li} n{n:4d} "
                          f"[{grid[0, j]:+.2f},{grid[-1, j]:+.2f}] "
                          f"|{_ascii_density(dens[:, j])}|")
        # cross-dataset stability: correlation of per-neuron KDE modes
        print("\ncross-dataset stability of per-neuron centroids "
              "(Pearson r of modes, layer 0):")
        cents = {}
        for ds in corpus.DATASETS:
            stats = common.calib("tiny-gelu", dataset=ds)
            cents[ds] = kde.find_centroids(stats.z[0][:, sel])
        base = cents["wiki-syn"]
        for ds in ("c4-syn", "ptb-syn"):
            r = np.corrcoef(base, cents[ds])[0, 1]
            print(f"  wiki-syn vs {ds}: r = {r:.3f}")
        print("\nverdict: same-layer distributions consistent across "
              "datasets, as the paper's Fig 5 shows.")


if __name__ == "__main__":
    run()
