"""Fig 6: layer-wise and neuron-wise linear-approximation error
distributions (+ the adaptive-vs-uniform thresholding ablation that
motivates the two-level allocator)."""

import numpy as np

from . import common
from compile.tardis import ranges, thresholds


def run(ablate: bool = True):
    with common.bench_output("fig06_error_dist"):
        cfg, params = common.model("tiny-gelu")
        stats = common.calib("tiny-gelu")
        w2n = [np.linalg.norm(np.asarray(lp["w2"]), axis=1)
               for lp in params["layers"]]

        print("Fig 6a — per-layer FFN approximation error vs coverage "
              "threshold:")
        header = ["layer"] + [f"t={t:.2f}" for t in (0.65, 0.75, 0.85, 0.95)]
        print(common.fmt_row(header, [6] + [10] * 4))
        layer_err_at_085 = []
        for li in range(cfg.n_layers):
            z = stats.z[li]
            cells = [f"L{li}"]
            for t in (0.65, 0.75, 0.85, 0.95):
                lo, hi = ranges.quantile_ranges(z, np.full(z.shape[1], t))
                err = ranges.approx_error(z, cfg.act, lo, hi, w2n[li]).sum()
                cells.append(f"{err:.2e}")
                if t == 0.85:
                    layer_err_at_085.append(err)
            print(common.fmt_row(cells, [6] + [10] * 4))
        spread = max(layer_err_at_085) / (min(layer_err_at_085) + 1e-12)
        print(f"layer error spread at t=0.85: {spread:.1f}x "
              "(paper: ~10x between layers)")

        print("\nFig 6b — neuron-wise error distribution (layer 0, t=0.85):")
        z = stats.z[0]
        lo, hi = ranges.quantile_ranges(z, np.full(z.shape[1], 0.85))
        nerr = ranges.approx_error(z, cfg.act, lo, hi, w2n[0])
        nz = nerr[nerr > 0]
        qs = np.percentile(nz, [1, 25, 50, 75, 99])
        print("  error percentiles (1/25/50/75/99): " +
              " ".join(f"{q:.2e}" for q in qs))
        print(f"  dynamic range: {qs[-1] / (qs[0] + 1e-300):.0f}x "
              "(paper: ~3 orders of magnitude)")

        if ablate:
            print("\nablation — adaptive vs uniform thresholding "
                  "(total weighted error at mean t=0.85):")
            total_uniform, total_adaptive = 0.0, 0.0
            t_layers = thresholds.layer_thresholds(layer_err_at_085, 0.85)
            for li in range(cfg.n_layers):
                z = stats.z[li]
                h = z.shape[1]
                lo, hi = ranges.quantile_ranges(z, np.full(h, 0.85))
                nerr = ranges.approx_error(z, cfg.act, lo, hi, w2n[li])
                total_uniform += nerr.sum()
                t_n = thresholds.neuron_thresholds(nerr, float(t_layers[li]))
                lo2, hi2 = ranges.quantile_ranges(z, t_n)
                total_adaptive += ranges.approx_error(
                    z, cfg.act, lo2, hi2, w2n[li]).sum()
            print(f"  uniform : {total_uniform:.3e}")
            print(f"  adaptive: {total_adaptive:.3e} "
                  f"({100 * (1 - total_adaptive / total_uniform):+.1f}% error)")


if __name__ == "__main__":
    run()
