"""Fig 9 + §9 ablations: why single-range approximation (multi-range
folded-matrix blow-up is r^h) and why GLU FFNs defeat folding (the 254x
parameter explosion)."""

from . import common
from compile.tardis import folding


def run():
    with common.bench_output("fig09_blowup"):
        print("Fig 9 — folded matrices needed for r ranges over h neurons "
              "(r^h):\n")
        print(common.fmt_row(["h neurons", "r=2", "r=3"], [10, 14, 14]))
        for h in (1, 2, 4, 8, 16, 10_000):
            print(common.fmt_row(
                [h, f"{2.0**min(h,1020):.3g}", f"{3.0**min(h,640):.3g}"],
                [10, 14, 14]))
        print("\nat h ~ 10^4 (real LLM FFN width) multi-range folding is "
              "astronomically infeasible\n-> TARDIS's single-range design "
              "(§5.1.1).\n")

        print("§9 — GLU-variant folding blow-up (folded quadratic form vs "
              "original 3dh):\n")
        print(common.fmt_row(["model", "d", "h", "blow-up"],
                             [14, 7, 7, 10]))
        for name, d, h in (("llama2-7b", 4096, 11008),
                           ("llama3-8b", 4096, 14336),
                           ("tiny-glu", 128, 512)):
            print(common.fmt_row(
                [name, d, h, f"{folding.glu_fold_blowup(d, h):.0f}x"],
                [14, 7, 7, 10]))
        print("\npaper: 254x for LLaMA-2-7B — folding gated FFNs is a "
              "non-starter; matches our formula's order of magnitude.")


if __name__ == "__main__":
    run()
