"""Fig 11 (+ Fig 2): fine-grained compression-ratio sweep of perplexity
and zero-shot accuracy for TARDIS vs pruning baselines, plus the top-K
fix-capacity ablation (DESIGN.md ablation #3)."""

from . import common
from compile.tardis import pipeline

RATIOS = (0.1, 0.3, 0.5, 0.6, 0.7, 0.8)


def run(capacity_ablation: bool = True):
    with common.bench_output("fig11_sweep"):
        name = "tiny-gelu"
        cfg, params = common.model(name)
        ds, task = "wiki-syn", "agree-syn"
        print("Fig 11 — ratio sweep on tiny-gelu "
              f"(ppl on {ds}, acc on {task})\n")
        print(common.fmt_row(
            ["ratio", "wanda ppl", "ria ppl", "tardis ppl",
             "wanda acc", "tardis acc"], [7, 10, 10, 10, 10, 10]))
        for r in RATIOS:
            wanda = common.pruned(name, "wanda", r)
            ria = common.pruned(name, "ria", r)
            fp, rep = common.fold(name, ratio=r)
            tcfg = cfg.with_mode("tardis_pred_dense")
            print(common.fmt_row([
                f"{int(r*100)}%",
                f"{common.ppl(wanda, cfg, ds):.2f}",
                f"{common.ppl(ria, cfg, ds):.2f}",
                f"{common.ppl(fp, tcfg, ds):.2f}",
                f"{common.acc(wanda, cfg, task)*100:.1f}%",
                f"{common.acc(fp, tcfg, task)*100:.1f}%",
            ], [7, 10, 10, 10, 10, 10]))

        if capacity_ablation:
            print("\nablation — top-K fix capacity at ratio 80% "
                  "(kernel path, K vs quality):")
            fp, rep = common.fold(name, ratio=0.8)
            k_star = pipeline.fix_capacity_for(cfg, rep.mean_oor_rate)
            for k in sorted({4, k_star // 2, k_star, 2 * k_star, 128}):
                k = max(1, min(int(k), cfg.d_ff))
                kcfg = cfg.with_mode("tardis", fix_capacity=k)
                print(f"  K={k:4d}: ppl {common.ppl(fp, kcfg, ds, max_windows=8):.2f}"
                      + ("   <- calibrated capacity" if k == k_star else ""))


if __name__ == "__main__":
    run()
