"""Fig 12 + §7.3: calibration-set size vs perplexity and achieved in-range
coverage (precision of the range assignment), plus offline-pipeline
wall-time (the paper reports 30 min/layer; our vectorized Algorithm 1 is
seconds/layer)."""

import time

from . import common
from compile import evalsuite
from compile.tardis import pipeline


def run(sizes=(1, 2, 4, 8, 16, 32), target_t: float = 0.85):
    with common.bench_output("fig12_calibration"):
        name = "tiny-gelu"
        cfg, params = common.model(name)
        print(f"Fig 12 — calibration-set size sweep (target t={target_t})\n")
        print(common.fmt_row(
            ["samples", "achieved cov", "|cov - t|", "ppl wiki-syn",
             "search s/layer"], [8, 12, 10, 12, 14]))
        for n in sizes:
            stats = common.calib(name, n_samples=n)
            t0 = time.time()
            fp, rep = pipeline.fold_model(params, cfg, target_t=target_t,
                                          stats=stats)
            dt = (time.time() - t0) / cfg.n_layers
            ppl = evalsuite.perplexity(
                fp, cfg.with_mode("tardis_pred_dense"),
                dataset="wiki-syn", max_windows=12)
            print(common.fmt_row([
                n, f"{rep.achieved_coverage:.3f}",
                f"{abs(rep.achieved_coverage - target_t):.3f}",
                f"{ppl:.3f}", f"{dt:.1f}",
            ], [8, 12, 10, 12, 14]))
        print("\npaper: coverage within 1.8% of target from 8 samples; "
              "ppl stable (<0.06 drift) over 8-64 samples.")
        print("paper offline cost: ~30 min/layer; ours (vectorized "
              "Algorithm 1): seconds/layer — see column above.")


if __name__ == "__main__":
    run()
