"""Fig 15: predictor size (quantization bits) vs perplexity, plus the
predictor precision/recall stats behind it."""

from . import common
from compile import evalsuite


def run(bits_list=(2, 3, 4, 8), ratio: float = 0.7):
    with common.bench_output("fig15_predictor"):
        name = "tiny-gelu"
        cfg, params = common.model(name)
        print(f"Fig 15 — predictor bits vs perplexity "
              f"(TARDIS @ {int(ratio*100)}%)\n")
        print(common.fmt_row(
            ["bits", "ppl wiki-syn", "recall", "precision", "size (f32-eq)"],
            [5, 12, 8, 10, 14]))
        rows = []
        for bits in bits_list:
            fp, rep = common.fold(name, ratio=ratio, bits=bits)
            ppl = evalsuite.perplexity(
                fp, cfg.with_mode("tardis_pred_dense"),
                dataset="wiki-syn", max_windows=16)
            ps = rep.layers[0].pred_stats
            size = cfg.d_model * cfg.d_ff * bits / 32.0
            rows.append(ppl)
            print(common.fmt_row(
                [bits, f"{ppl:.3f}", f"{ps.recall:.2f}",
                 f"{ps.precision:.2f}", f"{size:.0f}"],
                [5, 12, 8, 10, 14]))
        print(f"\nppl range over bits: {max(rows) - min(rows):.3f} "
              "(paper: max difference 0.12 — small predictors suffice)")


if __name__ == "__main__":
    run()
