"""Run every python-side table/figure bench in dependency order.

`python -m bench.run_all [--fast]` — --fast trims the expensive grids
(single model, fewer ratios) for smoke runs.
"""

import argparse
import sys
import time

from . import (fig05_density, fig06_error_dist, fig09_blowup,
               fig11_sweep, fig12_calibration, fig15_predictor,
               tab01_skew, tab03_perplexity, tab04_zeroshot,
               tab05_sensitivity, tab06_tab07_precision)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (e.g. tab03,fig11)")
    args = ap.parse_args()

    benches = [
        ("tab01", lambda: tab01_skew.run()),
        ("fig05", lambda: fig05_density.run()),
        ("fig06", lambda: fig06_error_dist.run()),
        ("fig09", lambda: fig09_blowup.run()),
        ("tab03", lambda: tab03_perplexity.run(
            models=("tiny-gelu",) if args.fast else
            ("tiny-gelu", "tiny-relu"))),
        ("tab04", lambda: tab04_zeroshot.run()),
        ("fig11", lambda: fig11_sweep.run(
            capacity_ablation=not args.fast)),
        ("fig12", lambda: fig12_calibration.run(
            sizes=(2, 8) if args.fast else (1, 2, 4, 8, 16, 32))),
        ("tab05", lambda: tab05_sensitivity.run()),
        ("fig15", lambda: fig15_predictor.run(
            bits_list=(2, 8) if args.fast else (2, 3, 4, 8))),
        ("tab0607", lambda: tab06_tab07_precision.run()),
    ]
    only = set(args.only.split(",")) if args.only else None
    t0 = time.time()
    for name, fn in benches:
        if only and name not in only:
            continue
        print(f"\n######## {name} ########", flush=True)
        fn()
    print(f"\nall benches done in {time.time() - t0:.0f}s; outputs in "
          "artifacts/results/", file=sys.stderr)


if __name__ == "__main__":
    main()
