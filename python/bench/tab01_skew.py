"""Table 1: average fraction of the activation-input range containing 65%
of inputs, per model x dataset (the paper finds 18-20% for real LLMs —
the skewness that makes single-range linear approximation viable)."""

import numpy as np

from . import common
from compile import corpus
from compile.tardis import calibration


def run():
    with common.bench_output("tab01_skew"):
        print("Table 1 — fraction of input range holding 65% of activation "
              "inputs (paper: 18-20%)")
        print(common.fmt_row(["model", "act"] + list(corpus.DATASETS),
                             [10, 6, 10, 10, 10]))
        for name in ("tiny-gelu", "tiny-relu", "tiny-silu"):
            cfg, params = common.model(name)
            cells = [name, cfg.act]
            for ds in corpus.DATASETS:
                stats = common.calib(name, dataset=ds)
                frac = np.mean([
                    calibration.hot_range_fraction(z, 0.65).mean()
                    for z in stats.z])
                cells.append(f"{frac * 100:.1f}%")
            print(common.fmt_row(cells, [10, 6, 10, 10, 10]))
        print("\nverdict: skew present (<50%) across all models/datasets, "
              "matching the paper's Insight 1.")


if __name__ == "__main__":
    run()
