"""Table 3: perplexity on three datasets for dense / Wanda / RIA / TARDIS
at 50/70/80% FFN compression (the headline accuracy table). Also covers
Fig 2 (pruning collapse) since the same grid contains those points."""

from . import common
from compile import corpus

RATIOS = (0.5, 0.7, 0.8)
MODELS = ("tiny-gelu", "tiny-relu")


def run(models=MODELS, methods=("wanda", "ria"), datasets=corpus.DATASETS):
    with common.bench_output("tab03_perplexity"):
        print("Table 3 — perplexity (lower is better); "
              "TARDIS evaluated in tardis_pred_dense mode\n")
        for name in models:
            cfg, params = common.model(name)
            print(f"== {name} (act={cfg.act}) ==")
            hdr = ["dataset", "method"] + [f"{int(r*100)}%" for r in RATIOS]
            print(common.fmt_row(hdr, [10, 8, 8, 8, 8]))
            for ds in datasets:
                dense = common.ppl(params, cfg, ds)
                print(common.fmt_row([ds, "dense", f"{dense:.2f}", "", ""],
                                     [10, 8, 8, 8, 8]))
                for m in methods:
                    cells = [ds, m]
                    for r in RATIOS:
                        pp = common.pruned(name, m, r)
                        cells.append(f"{common.ppl(pp, cfg, ds):.2f}")
                    print(common.fmt_row(cells, [10, 8, 8, 8, 8]))
                cells = [ds, "tardis"]
                for r in RATIOS:
                    fp, rep = common.fold(name, ratio=r)
                    cells.append(f"{common.ppl(fp, cfg.with_mode('tardis_pred_dense'), ds):.2f}")
                print(common.fmt_row(cells, [10, 8, 8, 8, 8]))
            print()
        print("verdict target (paper): at 80% TARDIS's ppl is orders of "
              "magnitude below Wanda/RIA;\nat 50% all methods are close "
              "to dense.")


if __name__ == "__main__":
    run()
