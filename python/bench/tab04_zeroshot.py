"""Table 4: zero-shot accuracy on the three synthetic tasks for dense /
Wanda / RIA / TARDIS at 50/70/80% FFN compression."""

from . import common
from compile import corpus

RATIOS = (0.5, 0.7, 0.8)
TASKS = tuple(sorted(corpus.TASKS))


def run(models=("tiny-gelu",), methods=("wanda", "ria")):
    with common.bench_output("tab04_zeroshot"):
        print("Table 4 — zero-shot accuracy (%) (higher is better); "
              "chance = 50%\n")
        for name in models:
            cfg, params = common.model(name)
            print(f"== {name} ==")
            hdr = ["task", "method"] + [f"{int(r*100)}%" for r in RATIOS]
            print(common.fmt_row(hdr, [10, 8, 8, 8, 8]))
            for task in TASKS:
                dense = common.acc(params, cfg, task)
                print(common.fmt_row(
                    [task, "dense", f"{dense*100:.1f}", "", ""],
                    [10, 8, 8, 8, 8]))
                for m in methods:
                    cells = [task, m]
                    for r in RATIOS:
                        pp = common.pruned(name, m, r)
                        cells.append(f"{common.acc(pp, cfg, task)*100:.1f}")
                    print(common.fmt_row(cells, [10, 8, 8, 8, 8]))
                cells = [task, "tardis"]
                for r in RATIOS:
                    fp, _ = common.fold(name, ratio=r)
                    cells.append(
                        f"{common.acc(fp, cfg.with_mode('tardis_pred_dense'), task)*100:.1f}")
                print(common.fmt_row(cells, [10, 8, 8, 8, 8]))
            print()
        print("verdict target (paper): TARDIS holds accuracy at 80% while "
              "pruning collapses toward chance.")


if __name__ == "__main__":
    run()
