"""Table 5: calibration-distribution sensitivity — calibrate on dataset A,
evaluate perplexity on dataset B (paper: cross-calibration costs <0.4)."""

from . import common
from compile import evalsuite


def run(datasets=("wiki-syn", "c4-syn"), ratio: float = 0.7):
    with common.bench_output("tab05_sensitivity"):
        name = "tiny-gelu"
        cfg, params = common.model(name)
        print(f"Table 5 — calibration sensitivity (TARDIS @ {int(ratio*100)}%"
              " compression), perplexity\n")
        print(common.fmt_row(["eval \\ calib"] + list(datasets) + ["diff"],
                             [12, 10, 10, 8]))
        for ev in datasets:
            row = [ev]
            vals = []
            for cal in datasets:
                fp, _ = common.fold(name, ratio=ratio, dataset=cal)
                v = evalsuite.perplexity(
                    fp, cfg.with_mode("tardis_pred_dense"), dataset=ev,
                    max_windows=16)
                vals.append(v)
                row.append(f"{v:.2f}")
            row.append(f"{abs(vals[0] - vals[1]):.2f}")
            print(common.fmt_row(row, [12, 10, 10, 8]))
        print("\npaper: diffs of 0.08 / 0.37 — calibration choice barely "
              "matters.")


if __name__ == "__main__":
    run()
