"""Tables 6 & 7: numerical effects of FFN reordering.

Table 6: fold with different intermediate dtypes -> FFN MSE + perplexity.
Table 7: fold MSE vs FFN scale (x1 / x4 / x8 synthetic enlargement).
"""

import numpy as np

from . import common
from compile import evalsuite
from compile.tardis import folding


def run():
    with common.bench_output("tab06_tab07_precision"):
        name = "tiny-gelu"
        cfg, params = common.model(name)

        print("Table 6 — intermediate dtype during folding "
              "(TARDIS @ t=0.9)\n")
        dense_ppl = common.ppl(params, cfg, "wiki-syn")
        print(common.fmt_row(["dtype", "fold MSE", "ppl wiki-syn"],
                             [10, 12, 12]))
        print(common.fmt_row(["(dense)", "0", f"{dense_ppl:.3f}"],
                             [10, 12, 12]))
        for dt in ("bfloat16", "float16", "float32", "float64"):
            fp, rep = common.fold(name, target_t=0.9, intermediate_dtype=dt)
            ppl = evalsuite.perplexity(fp, cfg.with_mode("tardis_exact"),
                                       dataset="wiki-syn", max_windows=16)
            print(common.fmt_row([dt, f"{rep.fold_mse:.2e}", f"{ppl:.3f}"],
                                 [10, 12, 12]))
        print("\npaper: only bfloat16 shows a visible ppl gap; "
              "f16/f32/f64 within 0.1%.\n")

        print("Table 7 — fold MSE vs FFN scale (intermediate = float64)\n")
        rng = np.random.default_rng(0)
        lp = params["layers"][0]
        w1 = np.asarray(lp["w1"])
        w2 = np.asarray(lp["w2"])
        b1 = np.asarray(lp["b1"])
        d, h = w1.shape
        x = np.asarray(common.calib(name).ffn_in[0][:128])
        print(common.fmt_row(["scale", "d x h", "MSE"], [6, 12, 12]))
        for scale in (1, 4, 8):
            # enlarge by tiling + jitter (paper scales the FFN synthetically)
            w1s = np.tile(w1, (scale, scale)) + \
                rng.normal(0, 1e-3, (d * scale, h * scale)).astype(np.float32)
            w2s = np.tile(w2, (scale, scale)) + \
                rng.normal(0, 1e-3, (h * scale, d * scale)).astype(np.float32)
            b1s = np.tile(b1, scale)
            a = rng.normal(0.3, 0.1, h * scale).astype(np.float32)
            b = rng.normal(0, 0.05, h * scale).astype(np.float32)
            xs = np.tile(x, (1, scale)).astype(np.float32) / scale
            mse = folding.fold_mse(w1s, b1s, w2s,
                                   np.zeros(d * scale, np.float32), a, b,
                                   None, xs, "float64")
            print(common.fmt_row(
                [f"x{scale}", f"{d*scale} x {h*scale}", f"{mse:.2e}"],
                [6, 12, 12]))
        print("\npaper: MSE stays < 1e-6 at x8 — reordering error "
              "negligible at scale.")


if __name__ == "__main__":
    run()
