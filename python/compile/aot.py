"""AOT export: train → fold → lower → artifacts/ (the `make artifacts` entry).

Python runs exactly once here and never on the request path. Outputs:

  artifacts/
    manifest.json            everything rust needs: model config, variants,
                             executables (+ parameter order), weight tables
    <variant>.weights.bin    raw little-endian arrays, offsets in manifest
    <variant>.decode.hlo.txt           batched decode step (B = 8)
    <variant>.prefill<S>.hlo.txt       prefill buckets (batch 1, slot-indexed)
    <variant>.ffn_*.hlo.txt            FFN micro-executables (Figs 13/14)
    weights/<model>.pkl                trained dense checkpoints (cache)

Variants: ``dense`` plus ``tardis@{50,70,80}`` (the paper's headline
ratios). Pruned (Wanda/RIA) variants are *accuracy* baselines evaluated by
the python bench harness — their dense-shaped matmuls have identical
runtime cost, so the rust serving benches only need dense + tardis.
"""

from __future__ import annotations

import argparse
import json
import time
from functools import partial
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from . import hloutil, model as M
from .kernels import ref as kref
from .model import ModelConfig
from .tardis import calibration, pipeline

BATCH = 8
PREFILL_BUCKETS = (16, 64)
TARDIS_RATIOS = (0.5, 0.7, 0.8)

_DT = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32",
       np.dtype(np.int8): "i8"}


def _weights_table(names, arrays, bin_path: Path):
    """Write raw weights and return the manifest parameter table."""
    table = []
    off = 0
    with open(bin_path, "wb") as f:
        for name, arr in zip(names, arrays):
            a = np.asarray(arr)
            if a.dtype == np.float64:
                a = a.astype(np.float32)
            data = np.ascontiguousarray(a).tobytes()
            table.append({"name": name, "dtype": _DT[a.dtype],
                          "shape": list(a.shape), "offset": off,
                          "nbytes": len(data)})
            f.write(data)
            off += len(data)
    return table


def _export_variant(vdir: Path, vname: str, cfg: ModelConfig, params,
                    extra: dict) -> dict:
    """Lower decode/prefill/FFN micro fns for one variant."""
    names = M.param_names(params)
    flat = M.flatten_params(params)
    # The decode/prefill signatures drop b2 for folded layers (absorbed
    # into fold_b, DCE'd by jax) — but the ffn_dense micro-executable
    # still reads it, so the weight *table* keeps every b2.
    extra_names, extra_flat = [], []
    for li, lp in enumerate(params["layers"]):
        if "fold_c" in lp:
            extra_names.append(f"layer{li}.b2")
            extra_flat.append(lp["b2"])
    table = _weights_table(names + extra_names, flat + extra_flat,
                           vdir / f"{vname}.weights.bin")

    kv_spec = jnp.zeros((cfg.n_layers, 2, BATCH, cfg.max_seq, cfg.n_heads,
                         cfg.d_head), jnp.float32)
    execs = {}

    def lower(tag, fn, args):
        path = vdir / f"{vname}.{tag}.hlo.txt"
        hloutil.export_hlo(fn, args, path)
        return str(path.name)

    # --- decode step: (params..., tokens[B], pos[B], kv) -> logits, kv ---
    def decode_fn(*args):
        ps = M.unflatten_params(names, list(args[:-3]), cfg.n_layers)
        tokens, pos, kv = args[-3:]
        return M.decode_step(ps, tokens, pos, kv, cfg)

    execs["decode"] = {
        "file": lower("decode", decode_fn,
                      (*flat, jnp.zeros((BATCH,), jnp.int32),
                       jnp.zeros((BATCH,), jnp.int32), kv_spec)),
        "weight_params": names,
        "inputs": [f"tokens:i32[{BATCH}]", f"pos:i32[{BATCH}]", "kv"],
        "outputs": ["logits", "kv"],
        "flops": hloutil.flop_estimate(
            decode_fn, (*flat, jnp.zeros((BATCH,), jnp.int32),
                        jnp.zeros((BATCH,), jnp.int32), kv_spec)),
    }

    # --- prefill buckets: (params..., tokens[T], kv, slot, pos0) ---
    for T in PREFILL_BUCKETS:
        def prefill_fn(*args, T=T):
            ps = M.unflatten_params(names, list(args[:-4]), cfg.n_layers)
            tokens, kv, slot, pos0 = args[-4:]
            return M.prefill_step(ps, tokens, kv, slot, pos0, cfg)

        execs[f"prefill{T}"] = {
            "file": lower(f"prefill{T}", prefill_fn,
                          (*flat, jnp.zeros((T,), jnp.int32), kv_spec,
                           jnp.int32(0), jnp.int32(0))),
            "weight_params": names,
            "inputs": [f"tokens:i32[{T}]", "kv", "slot:i32", "pos0:i32"],
            "outputs": ["logits", "kv"],
        }

    # --- FFN micro-executables on layer 0 (Figs 13/14 harness) ---
    lp0 = params["layers"][0]
    x_spec = jnp.zeros((BATCH, cfg.d_model), jnp.float32)

    def micro(tag, fn, wkeys, args, inputs, outputs):
        wnames = [f"layer0.{k}" for k in wkeys]
        execs[tag] = {"file": lower(tag, fn, args),
                      "weight_params": wnames, "inputs": inputs,
                      "outputs": outputs}

    micro("ffn_dense",
          lambda w1, b1, w2, b2, x: (
              kref.dense_ffn_ref(x, w1, b1, w2, b2, cfg.act),),
          ("w1", "b1", "w2", "b2"),
          (lp0["w1"], lp0["b1"], lp0["w2"], lp0["b2"], x_spec),
          [f"x:f32[{BATCH},{cfg.d_model}]"], ["y"])

    if "fold_c" in lp0:
        from .kernels import (fix_gather, folded_ffn, predictor_scores,
                              select_topk)
        K = cfg.fix_capacity

        micro("ffn_folded",
              lambda c, b, x: (folded_ffn(x, c, b),),
              ("fold_c", "fold_b"), (lp0["fold_c"], lp0["fold_b"], x_spec),
              [f"x:f32[{BATCH},{cfg.d_model}]"], ["y"])

        micro("ffn_predictor",
              lambda codes, scales, b1, lo, hi, x: (
                  predictor_scores(x, codes, scales, b1, lo, hi,
                                   group_size=cfg.pred_group),),
              ("pred_codes", "pred_scales", "b1", "lo", "hi"),
              (lp0["pred_codes"], lp0["pred_scales"], lp0["b1"],
               lp0["lo"], lp0["hi"], x_spec),
              [f"x:f32[{BATCH},{cfg.d_model}]"], ["score"])

        micro("ffn_aux",
              lambda score: select_topk(score, K),
              (), (jnp.zeros((BATCH, cfg.d_ff), jnp.float32),),
              [f"score:f32[{BATCH},{cfg.d_ff}]"], ["idx", "valid"])

        micro("ffn_fix",
              lambda w1, b1, w2, a, b, x, idx, valid: (
                  fix_gather(x, idx, valid, w1, b1, w2, a, b,
                             act=cfg.act),),
              ("w1", "b1", "w2", "lin_a", "lin_b"),
              (lp0["w1"], lp0["b1"], lp0["w2"], lp0["lin_a"], lp0["lin_b"],
               x_spec, jnp.zeros((BATCH, K), jnp.int32),
               jnp.zeros((BATCH, K), jnp.float32)),
              [f"x:f32[{BATCH},{cfg.d_model}]", f"idx:i32[{BATCH},{K}]",
               f"valid:f32[{BATCH},{K}]"], ["corr"])

    return {
        "name": vname,
        "ffn_mode": cfg.ffn_mode,
        "act": cfg.act,
        "fix_capacity": cfg.fix_capacity if "fold_c" in lp0 else 0,
        "weights_file": f"{vname}.weights.bin",
        "params": table,
        "executables": execs,
        **extra,
    }


def build_artifacts(out_dir: Path, model_name: str = "tiny-gelu",
                    ratios=TARDIS_RATIOS, bits: int = 2,
                    verbose: bool = True) -> dict:
    from .train import get_or_train
    out_dir.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    cfg, params = get_or_train(model_name, out_dir / "weights",
                               verbose=verbose)
    stats = calibration.collect(params, cfg, dataset="c4-syn", n_samples=8)

    variants = []
    if verbose:
        print(f"[aot] exporting dense ({time.time() - t0:.0f}s)")
    variants.append(_export_variant(
        out_dir, "dense", cfg, params,
        {"compression_ratio": 0.0, "target_threshold": 1.0}))

    for ratio in ratios:
        t = pipeline.threshold_for_ratio(cfg, ratio, bits)
        fparams, rep = pipeline.fold_model(params, cfg, target_t=t,
                                           stats=stats, bits=bits)
        K = pipeline.fix_capacity_for(cfg, rep.mean_oor_rate)
        vcfg = cfg.with_mode("tardis", fix_capacity=K)
        vname = f"tardis{int(ratio * 100)}"
        if verbose:
            print(f"[aot] exporting {vname}: t={t:.3f} "
                  f"cov={rep.achieved_coverage:.3f} K={K} "
                  f"ratio={rep.compression_ratio:.3f} "
                  f"({time.time() - t0:.0f}s)")
        variants.append(_export_variant(
            out_dir, vname, vcfg, fparams,
            {"compression_ratio": rep.compression_ratio,
             "target_threshold": t,
             "achieved_coverage": rep.achieved_coverage,
             "predictor_bits": bits}))

    manifest = {
        "model": {"name": cfg.name, "vocab": cfg.vocab,
                  "d_model": cfg.d_model, "n_layers": cfg.n_layers,
                  "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
                  "max_seq": cfg.max_seq, "act": cfg.act},
        "batch": BATCH,
        "prefill_buckets": list(PREFILL_BUCKETS),
        "kv_shape": [cfg.n_layers, 2, BATCH, cfg.max_seq, cfg.n_heads,
                     cfg.d_head],
        "variants": variants,
        "built_unix": int(time.time()),
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if verbose:
        print(f"[aot] wrote manifest with {len(variants)} variants "
              f"in {time.time() - t0:.0f}s")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory")
    ap.add_argument("--model", default="tiny-gelu")
    ap.add_argument("--bits", type=int, default=2)
    ap.add_argument("--ratios", default="0.5,0.7,0.8")
    args = ap.parse_args()
    ratios = tuple(float(r) for r in args.ratios.split(","))
    build_artifacts(Path(args.out), model_name=args.model,
                    ratios=ratios, bits=args.bits)


if __name__ == "__main__":
    main()
