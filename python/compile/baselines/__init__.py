"""Pruning baselines the paper compares against (Wanda, RIA, magnitude).

All baselines prune the FFN blocks only (matching §7.1: "we compress the
FFN blocks ... while keeping the attention blocks intact"). Pruned weights
are zeroed in place; the compression ratio equals the pruning ratio
(paper: "pruned weights considered compressed").
"""

from .magnitude import prune_magnitude
from .ria import prune_ria
from .wanda import prune_wanda

METHODS = {
    "wanda": prune_wanda,
    "ria": prune_ria,
    "magnitude": prune_magnitude,
}

__all__ = ["prune_wanda", "prune_ria", "prune_magnitude", "METHODS"]
