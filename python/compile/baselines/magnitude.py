"""Magnitude pruning: the classic |W| criterion (sanity baseline)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _prune_matrix(w: np.ndarray, ratio: float) -> np.ndarray:
    k = int(round(ratio * w.size))
    if k <= 0:
        return w.copy()
    cut = np.partition(np.abs(w).ravel(), k - 1)[k - 1]
    return np.where(np.abs(w) > cut, w, 0.0)


def prune_magnitude(params: dict, stats, ratio: float) -> dict:
    """stats accepted (and ignored) for a uniform baseline interface."""
    new = {k: v for k, v in params.items() if k != "layers"}
    new["layers"] = []
    for lp in params["layers"]:
        nlp = dict(lp)
        nlp["w1"] = jnp.asarray(_prune_matrix(np.asarray(lp["w1"]), ratio))
        nlp["w2"] = jnp.asarray(_prune_matrix(np.asarray(lp["w2"]), ratio))
        new["layers"].append(nlp)
    return new
