"""RIA pruning (Zhang et al., ICLR 2024): Relative Importance + Activation.

Score(W_ij) = ( |W_ij| / sum_i |W_ij|  +  |W_ij| / sum_j |W_ij| )
              * (||x_i||_2)^alpha ,  alpha = 0.5

i.e. the weight's share of both its input row and output column, scaled by
a softened activation norm. Pruned per output unit like Wanda.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _ria_scores(w: np.ndarray, x_norm: np.ndarray,
                alpha: float = 0.5) -> np.ndarray:
    aw = np.abs(w)
    row_share = aw / (aw.sum(axis=1, keepdims=True) + 1e-12)
    col_share = aw / (aw.sum(axis=0, keepdims=True) + 1e-12)
    return (row_share + col_share) * (x_norm[:, None] ** alpha)


def _prune_matrix(w: np.ndarray, x_norm: np.ndarray, ratio: float,
                  alpha: float = 0.5) -> np.ndarray:
    score = _ria_scores(w, x_norm, alpha)
    k = int(round(ratio * w.shape[0]))
    if k <= 0:
        return w.copy()
    cut = np.partition(score, k - 1, axis=0)[k - 1]
    return np.where(score > cut[None, :], w, 0.0)


def prune_ria(params: dict, stats, ratio: float, alpha: float = 0.5) -> dict:
    new = {k: v for k, v in params.items() if k != "layers"}
    new["layers"] = []
    for li, lp in enumerate(params["layers"]):
        n1 = np.linalg.norm(stats.ffn_in[li], axis=0)
        n2 = np.linalg.norm(stats.act_out[li], axis=0)
        nlp = dict(lp)
        nlp["w1"] = jnp.asarray(
            _prune_matrix(np.asarray(lp["w1"]), n1, ratio, alpha))
        nlp["w2"] = jnp.asarray(
            _prune_matrix(np.asarray(lp["w2"]), n2, ratio, alpha))
        new["layers"].append(nlp)
    return new
