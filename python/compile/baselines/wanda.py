"""Wanda pruning (Sun et al., ICLR 2024): prune by |W| * ||x||_2.

Weight importance is the product of the weight magnitude and the L2 norm
of its input feature across the calibration set; weights are compared and
removed *per output unit* (Wanda's per-output comparison group), which the
paper found essential at LLM scale.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def _prune_matrix(w: np.ndarray, x_norm: np.ndarray, ratio: float
                  ) -> np.ndarray:
    """Zero the lowest-scoring ``ratio`` of each output column.

    w: [in, out]; x_norm: [in] L2 norms of the input features.
    """
    score = np.abs(w) * x_norm[:, None]
    k = int(round(ratio * w.shape[0]))
    if k <= 0:
        return w.copy()
    # indices of the k smallest scores per column
    cut = np.partition(score, k - 1, axis=0)[k - 1]
    mask = score > cut[None, :]
    # keep exactly (in - k) per column even with ties
    out = np.where(mask, w, 0.0)
    return out


def prune_wanda(params: dict, stats, ratio: float) -> dict:
    """Prune FFN W1/W2 of every layer. stats: calibration.CalibStats."""
    new = {k: v for k, v in params.items() if k != "layers"}
    new["layers"] = []
    for li, lp in enumerate(params["layers"]):
        x_in = stats.ffn_in[li]          # [T, d] inputs to W1
        act = stats.act_out[li]          # [T, h] inputs to W2
        n1 = np.linalg.norm(x_in, axis=0)
        n2 = np.linalg.norm(act, axis=0)
        nlp = dict(lp)
        nlp["w1"] = jnp.asarray(
            _prune_matrix(np.asarray(lp["w1"]), n1, ratio))
        nlp["w2"] = jnp.asarray(
            _prune_matrix(np.asarray(lp["w2"]), n2, ratio))
        new["layers"].append(nlp)
    return new
