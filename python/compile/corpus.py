"""Synthetic corpus + downstream-task generators.

The paper evaluates on WikiText-2 / C4 / PTB (language modelling) and
PIQA / Lambada / ARC-Challenge (zero-shot). None of those are available in
this offline environment, so we build three *disjoint synthetic corpora*
from a seeded PCFG-style generator (``wiki-syn``, ``c4-syn``, ``ptb-syn``)
and three synthetic zero-shot tasks that use the same evaluation mechanism
as the paper's benchmarks:

* ``agree-syn``  — two-choice grammatical-agreement (PIQA-like binary choice,
  scored by total sequence log-likelihood of each option),
* ``recall-syn`` — final-word recall where the answer word occurred earlier
  in the context (Lambada-like; exact final-token match),
* ``arith-syn``  — pattern-completion multiple choice (ARC-like).

Everything is byte-level (vocab = 256) and fully deterministic given a seed,
so `make artifacts` is reproducible.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Grammar fragments. Three "dialects" with disjoint-ish vocabulary so that
# cross-calibration (Table 5) actually measures distribution shift.
# ---------------------------------------------------------------------------

_DIALECTS = {
    "wiki-syn": dict(
        nouns_sg=["fox", "engine", "river", "castle", "signal", "garden",
                  "falcon", "matrix", "neuron", "layer", "token", "model"],
        nouns_pl=["foxes", "engines", "rivers", "castles", "signals",
                  "gardens", "falcons", "matrices", "neurons", "layers",
                  "tokens", "models"],
        verbs_sg=["runs", "folds", "sings", "drifts", "glows", "turns",
                  "hums", "waits", "shines", "moves"],
        verbs_pl=["run", "fold", "sing", "drift", "glow", "turn",
                  "hum", "wait", "shine", "move"],
        adjectives=["quick", "linear", "quiet", "bright", "narrow", "dense",
                    "sparse", "folded", "gentle", "hidden"],
        adverbs=["slowly", "quietly", "often", "rarely", "smoothly"],
        preps=["near", "beyond", "under", "above", "inside"],
        determiner_sg=["the", "a", "every", "this"],
        determiner_pl=["the", "some", "many", "these"],
        connectives=["and then", "while", "because", "although", "so"],
        stop=". ",
    ),
    "c4-syn": dict(
        nouns_sg=["server", "packet", "buffer", "thread", "kernel", "cache",
                  "socket", "router", "daemon", "worker", "queue", "shard"],
        nouns_pl=["servers", "packets", "buffers", "threads", "kernels",
                  "caches", "sockets", "routers", "daemons", "workers",
                  "queues", "shards"],
        verbs_sg=["blocks", "drains", "retries", "commits", "spins",
                  "yields", "routes", "batches", "syncs", "halts"],
        verbs_pl=["block", "drain", "retry", "commit", "spin",
                  "yield", "route", "batch", "sync", "halt"],
        adjectives=["busy", "idle", "stale", "warm", "cold", "greedy",
                    "lazy", "atomic", "remote", "local"],
        adverbs=["eventually", "atomically", "lazily", "eagerly", "twice"],
        preps=["across", "behind", "within", "against", "toward"],
        determiner_sg=["the", "one", "each", "that"],
        determiner_pl=["the", "all", "most", "those"],
        connectives=["and", "until", "unless", "whenever", "but"],
        stop=". ",
    ),
    "ptb-syn": dict(
        nouns_sg=["trader", "market", "bond", "index", "price", "share",
                  "broker", "ledger", "profit", "margin", "asset", "yield"],
        nouns_pl=["traders", "markets", "bonds", "indices", "prices",
                  "shares", "brokers", "ledgers", "profits", "margins",
                  "assets", "yields"],
        verbs_sg=["rises", "falls", "trades", "closes", "opens",
                  "settles", "slips", "climbs", "stalls", "rallies"],
        verbs_pl=["rise", "fall", "trade", "close", "open",
                  "settle", "slip", "climb", "stall", "rally"],
        adjectives=["volatile", "steady", "weak", "strong", "junk",
                    "prime", "thin", "broad", "mixed", "flat"],
        adverbs=["sharply", "modestly", "broadly", "barely", "late"],
        preps=["amid", "despite", "after", "before", "over"],
        determiner_sg=["the", "a", "another", "its"],
        determiner_pl=["the", "several", "fewer", "its"],
        connectives=["as", "while", "after", "though", "and"],
        stop=". ",
    ),
}

DATASETS = tuple(_DIALECTS.keys())


@dataclass
class CorpusConfig:
    dataset: str = "wiki-syn"
    seed: int = 0
    n_sentences: int = 4000
    # Probability knobs that shape the byte distribution (and therefore the
    # activation-input distribution TARDIS calibrates on).
    p_adjective: float = 0.5
    p_adverb: float = 0.3
    p_prep_phrase: float = 0.35
    p_connective: float = 0.3
    p_number: float = 0.15


def _sentence(rng: random.Random, d: dict, cfg: CorpusConfig) -> str:
    plural = rng.random() < 0.4
    det = rng.choice(d["determiner_pl"] if plural else d["determiner_sg"])
    noun = rng.choice(d["nouns_pl"] if plural else d["nouns_sg"])
    verb = rng.choice(d["verbs_pl"] if plural else d["verbs_sg"])
    parts = [det]
    if rng.random() < cfg.p_adjective:
        parts.append(rng.choice(d["adjectives"]))
    parts.append(noun)
    parts.append(verb)
    if rng.random() < cfg.p_adverb:
        parts.append(rng.choice(d["adverbs"]))
    if rng.random() < cfg.p_prep_phrase:
        plural2 = rng.random() < 0.4
        parts.append(rng.choice(d["preps"]))
        parts.append(rng.choice(d["determiner_pl"] if plural2
                                else d["determiner_sg"]))
        parts.append(rng.choice(d["nouns_pl"] if plural2 else d["nouns_sg"]))
    if rng.random() < cfg.p_number:
        parts.append(str(rng.randint(2, 99)))
        parts.append(rng.choice(d["nouns_pl"]))
    s = " ".join(parts)
    if rng.random() < cfg.p_connective:
        plural3 = rng.random() < 0.4
        s += " " + rng.choice(d["connectives"]) + " " + \
            rng.choice(d["determiner_pl"] if plural3 else d["determiner_sg"]) \
            + " " + rng.choice(d["nouns_pl"] if plural3 else d["nouns_sg"]) \
            + " " + rng.choice(d["verbs_pl"] if plural3 else d["verbs_sg"])
    return s + d["stop"]


def generate_text(cfg: CorpusConfig) -> str:
    """Deterministic synthetic text for ``cfg.dataset``."""
    if cfg.dataset not in _DIALECTS:
        raise ValueError(f"unknown dataset {cfg.dataset!r}; "
                         f"choose one of {DATASETS}")
    rng = random.Random((cfg.seed, cfg.dataset).__repr__())
    d = _DIALECTS[cfg.dataset]
    return "".join(_sentence(rng, d, cfg) for _ in range(cfg.n_sentences))


def encode(text: str) -> list[int]:
    """Byte-level tokenization (vocab = 256)."""
    return list(text.encode("utf-8"))


def decode(tokens) -> str:
    return bytes(int(t) & 0xFF for t in tokens).decode("utf-8", "replace")


def token_stream(dataset: str, seed: int = 0, n_sentences: int = 4000
                 ) -> list[int]:
    return encode(generate_text(CorpusConfig(dataset=dataset, seed=seed,
                                             n_sentences=n_sentences)))


def train_eval_split(dataset: str, seed: int = 0, n_sentences: int = 6000,
                     eval_frac: float = 0.1) -> tuple[list[int], list[int]]:
    toks = token_stream(dataset, seed=seed, n_sentences=n_sentences)
    cut = int(len(toks) * (1.0 - eval_frac))
    return toks[:cut], toks[cut:]


# ---------------------------------------------------------------------------
# Zero-shot downstream tasks (Table 4 analogues).
# ---------------------------------------------------------------------------

@dataclass
class ChoiceItem:
    """A binary/multi-choice item scored by sequence log-likelihood."""
    context: str
    choices: list[str]
    answer: int
    meta: dict = field(default_factory=dict)


def make_agree_items(n: int, seed: int = 0, dataset: str = "wiki-syn"
                     ) -> list[ChoiceItem]:
    """PIQA-like: choose the grammatical continuation (verb agreement)."""
    rng = random.Random(("agree", seed, dataset).__repr__())
    d = _DIALECTS[dataset]
    items = []
    for _ in range(n):
        plural = rng.random() < 0.5
        det = rng.choice(d["determiner_pl"] if plural else d["determiner_sg"])
        adj = rng.choice(d["adjectives"])
        noun = rng.choice(d["nouns_pl"] if plural else d["nouns_sg"])
        vi = rng.randrange(len(d["verbs_sg"]))
        good = d["verbs_pl"][vi] if plural else d["verbs_sg"][vi]
        bad = d["verbs_sg"][vi] if plural else d["verbs_pl"][vi]
        ctx = f"{det} {adj} {noun}"
        order = rng.random() < 0.5
        choices = [f" {good}.", f" {bad}."] if order else [f" {bad}.", f" {good}."]
        items.append(ChoiceItem(context=ctx, choices=choices,
                                answer=0 if order else 1))
    return items


def make_recall_items(n: int, seed: int = 0, dataset: str = "wiki-syn"
                      ) -> list[ChoiceItem]:
    """Lambada-like: the final word already appeared in the context.

    Context: "the falcon glows . the garden waits . the falcon" → " glows".
    Scored as a 2-choice between the seen verb and a distractor verb.
    """
    rng = random.Random(("recall", seed, dataset).__repr__())
    d = _DIALECTS[dataset]
    items = []
    for _ in range(n):
        noun = rng.choice(d["nouns_sg"])
        vi = rng.randrange(len(d["verbs_sg"]))
        verb = d["verbs_sg"][vi]
        other_noun = rng.choice([x for x in d["nouns_sg"] if x != noun])
        other_verb = rng.choice([v for v in d["verbs_sg"] if v != verb])
        ctx = (f"the {noun} {verb}. the {other_noun} {other_verb}. "
               f"the {noun}")
        order = rng.random() < 0.5
        choices = [f" {verb}.", f" {other_verb}."]
        if not order:
            choices.reverse()
        items.append(ChoiceItem(context=ctx, choices=choices,
                                answer=0 if order else 1))
    return items


def make_arith_items(n: int, seed: int = 0, dataset: str = "wiki-syn"
                     ) -> list[ChoiceItem]:
    """ARC-like pattern completion: count words ("one fox, two foxes, ...")."""
    rng = random.Random(("arith", seed, dataset).__repr__())
    d = _DIALECTS[dataset]
    numbers = ["one", "two", "three", "four", "five", "six"]
    items = []
    for _ in range(n):
        noun_sg = rng.choice(d["nouns_sg"])
        idx = d["nouns_sg"].index(noun_sg)
        noun_pl = d["nouns_pl"][idx]
        k = rng.randint(1, 4)
        seq = [f"one {noun_sg}"] + [f"{numbers[i]} {noun_pl}"
                                    for i in range(1, k + 1)]
        ctx = ", ".join(seq) + f", {numbers[k + 1]}"
        good = f" {noun_pl}."
        bad = f" {rng.choice([x for x in d['nouns_pl'] if x != noun_pl])}."
        order = rng.random() < 0.5
        choices = [good, bad] if order else [bad, good]
        items.append(ChoiceItem(context=ctx, choices=choices,
                                answer=0 if order else 1))
    return items


TASKS = {
    "agree-syn": make_agree_items,
    "recall-syn": make_recall_items,
    "arith-syn": make_arith_items,
}
