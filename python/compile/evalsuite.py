"""Evaluation suite: perplexity and zero-shot accuracy (paper §7.1).

Mirrors lm-evaluation-harness mechanics on our synthetic benchmarks:

* ``perplexity``      — exp(mean NLL) over held-out windows of a corpus.
* ``zero_shot_accuracy`` — for each ChoiceItem, score every choice by the
  sum of its token log-likelihoods given the context and pick the argmax
  (exactly how PIQA/Lambada/ARC-C are scored in the harness).

Both take an ``ffn_mode``-configured ModelConfig, so the same functions
evaluate dense, TARDIS-folded (exact or predictor-driven), and pruned
models.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, forward


@functools.partial(jax.jit, static_argnames=("cfg",))
def _window_nll(params, tokens, cfg: ModelConfig):
    """tokens: [B, S+1] -> (sum NLL, token count)."""
    logits = forward(params, tokens[:, :-1], cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll), nll.size


def perplexity(params, cfg: ModelConfig, dataset: str = "wiki-syn",
               seq: int = 64, max_windows: int = 48, batch: int = 8,
               seed: int = 0) -> float:
    """Held-out perplexity on ``dataset`` (lower is better)."""
    _, ev = corpus.train_eval_split(dataset, seed=seed)
    toks = np.asarray(ev, np.int32)
    n = min((len(toks) - 1) // seq, max_windows)
    wins = np.stack([toks[i * seq:i * seq + seq + 1] for i in range(n)])
    total, count = 0.0, 0
    for i in range(0, n, batch):
        chunk = wins[i:i + batch]
        s, c = _window_nll(params, jnp.asarray(chunk), cfg)
        total += float(s)
        count += int(c)
    return float(np.exp(total / max(count, 1)))


@functools.partial(jax.jit, static_argnames=("cfg",))
def _seq_logprob(params, tokens, start, cfg: ModelConfig):
    """Sum log p(tokens[i] | tokens[<i]) for i >= start. tokens: [S]."""
    logits = forward(params, tokens[None, :-1], cfg)[0]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_lp = jnp.take_along_axis(logp, tokens[1:, None], axis=-1)[:, 0]
    idx = jnp.arange(tok_lp.shape[0])
    return jnp.sum(jnp.where(idx >= start - 1, tok_lp, 0.0))


def _score_choice(params, cfg, context: str, choice: str) -> float:
    ctx = corpus.encode(context)
    full = ctx + corpus.encode(choice)
    full = full[: cfg.max_seq]
    toks = jnp.asarray(np.asarray(full, np.int32))
    return float(_seq_logprob(params, toks, min(len(ctx), len(full) - 1),
                              cfg))


def zero_shot_accuracy(params, cfg: ModelConfig, task: str = "agree-syn",
                       n_items: int = 64, seed: int = 0,
                       dataset: str = "wiki-syn") -> float:
    items = corpus.TASKS[task](n_items, seed=seed, dataset=dataset)
    correct = 0
    for it in items:
        scores = [_score_choice(params, cfg, it.context, ch)
                  for ch in it.choices]
        correct += int(int(np.argmax(scores)) == it.answer)
    return correct / len(items)


def eval_grid(params, cfg: ModelConfig, datasets=("wiki-syn",),
              tasks=("agree-syn",), **kw) -> dict:
    """Convenience: {metric_name: value} over datasets and tasks."""
    out = {}
    for ds in datasets:
        out[f"ppl/{ds}"] = perplexity(params, cfg, dataset=ds, **kw)
    for tk in tasks:
        out[f"acc/{tk}"] = zero_shot_accuracy(params, cfg, task=tk)
    return out
