"""HLO-text lowering helpers (the AOT interchange with rust).

HLO *text* — not serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Outputs are lowered *untupled* (``return_tuple=False``) so the rust runtime
receives one PjRtBuffer per result and can thread the KV cache back into
the next step without a host round-trip.
"""

from __future__ import annotations

from pathlib import Path

import jax
from jax._src.lib import xla_client as xc


def lower_to_hlo_text(fn, example_args, return_tuple: bool = False) -> str:
    """Lower ``jax.jit(fn)`` at the example args' shapes to HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple)
    return comp.as_hlo_text()


def export_hlo(fn, example_args, out_path: Path,
               return_tuple: bool = False) -> Path:
    out_path = Path(out_path)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(lower_to_hlo_text(fn, example_args, return_tuple))
    return out_path


def flop_estimate(fn, example_args) -> float:
    """XLA cost-analysis FLOPs of the lowered module (L2 §Perf metric)."""
    lowered = jax.jit(fn).lower(*example_args)
    try:
        analysis = lowered.compile().cost_analysis()
        if isinstance(analysis, list):
            analysis = analysis[0]
        return float(analysis.get("flops", -1.0))
    except Exception:
        return -1.0
