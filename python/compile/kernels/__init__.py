"""L1 Pallas kernels (interpret=True) + pure-jnp oracles (ref.py)."""

from .folded_ffn import folded_ffn
from .predictor_mm import predictor_scores
from .fix_gather import fix_gather, select_topk

__all__ = ["folded_ffn", "predictor_scores", "fix_gather", "select_topk"]
