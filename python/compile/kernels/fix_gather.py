"""L1 Pallas kernel: top-K selective result fixing.

The paper's CUDA kernel does *selective loading* of the W1 columns / W2
rows belonging to the (few) neurons the predictor flagged out-of-range,
then replaces their linear approximation with the true activation:

    z      = x @ W1[:, idx] + b1[idx]
    delta  = valid * (sigma(z) - (a[idx] * z + b[idx]))
    corr   = delta @ W2[idx, :]

Dynamic sparsity does not fit XLA's static shapes, so we adapt the kernel
to a *static capacity* K (DESIGN.md §Hardware-Adaptation): the model layer
always hands us K indices per row (top-k over the predictor score); rows
flagged fewer than K times pad with valid=0 slots whose contribution is
exactly zero, preserving correctness.

Two implementations:

* :func:`fix_gather` (default) — fully *vectorized* gathers: one batched
  `w1[:, idx]` / `w2[idx, :]` gather plus two einsums. This is the Pallas
  analogue of the paper's memory-coalesced + vectorized-shared-memory CUDA
  kernel, and what the exported decode executables use (perf log in
  EXPERIMENTS.md §Perf: the original per-row loop serialised the whole fix
  path and made TARDIS *slower* than dense on CPU).
* :func:`fix_gather_looped` — the naive one-neuron-at-a-time loop kept for
  the §Perf before/after comparison and as the closest structural analogue
  of a scalar gather loop.

On a real TPU this kernel would use ``PrefetchScalarGridSpec`` so the
scalar core prefetches ``idx`` and drives the W1/W2 block index_maps
directly (documented as the Mosaic deployment plan; interpret mode keeps
the explicit-gather form that CPU PJRT can execute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import activation


# ---------------------------------------------------------------------------
# Vectorized kernel (default).
# ---------------------------------------------------------------------------

def _fix_kernel_vec(x_ref, idx_ref, valid_ref, w1_ref, b1_ref, w2_ref,
                    a_ref, b_ref, o_ref, *, act: str):
    """Whole batch in one program: batched gathers + MXU einsums."""
    sigma = activation(act)
    x = x_ref[...]                                  # [B, d]
    idx = idx_ref[...]                              # [B, K]
    valid = valid_ref[...]                          # [B, K]
    w1g = w1_ref[...][:, idx]                       # [d, B, K] gather
    z = jnp.einsum("bd,dbk->bk", x, w1g,
                   preferred_element_type=jnp.float32)
    z = z + b1_ref[...][idx]
    delta = (sigma(z) - (a_ref[...][idx] * z + b_ref[...][idx])) * valid
    w2g = w2_ref[...][idx, :]                       # [B, K, d] gather
    corr = jnp.einsum("bk,bkd->bd", delta, w2g,
                      preferred_element_type=jnp.float32)
    o_ref[...] = corr.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act",))
def fix_gather(x, idx, valid, w1, b1, w2, a, b, *, act: str = "gelu"):
    """Selective correction (vectorized). x: [B, d], idx: [B, K] int32,
    valid: [B, K] float32 (0/1), w1: [d, h], w2: [h, d] -> corr [B, d]."""
    m, d = x.shape
    _, n_k = idx.shape
    h, d_out = w2.shape
    assert w1.shape == (d, h) and valid.shape == idx.shape
    return pl.pallas_call(
        functools.partial(_fix_kernel_vec, act=act),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m, n_k), lambda i: (0, 0)),
            pl.BlockSpec((m, n_k), lambda i: (0, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, d_out), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m, d_out), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), x.dtype),
        interpret=True,
    )(x, idx, valid, w1, b1, w2, a, b)


# ---------------------------------------------------------------------------
# Looped kernel (perf baseline; EXPERIMENTS.md §Perf "before").
# ---------------------------------------------------------------------------

def _fix_kernel_loop(x_ref, idx_ref, valid_ref, w1_ref, b1_ref, w2_ref,
                     a_ref, b_ref, o_ref, *, n_k: int, act: str):
    """One batch row per grid step: walk K indices with dynamic slices."""
    sigma = activation(act)
    x = x_ref[...]                       # [1, d]
    d_out = o_ref.shape[-1]

    def body(k, acc):
        nid = idx_ref[0, k]
        v = valid_ref[0, k]
        w1col = pl.load(w1_ref, (slice(None), pl.dslice(nid, 1)))  # [d, 1]
        z = jnp.sum(x[0, :] * w1col[:, 0]) + b1_ref[nid]
        delta = (sigma(z) - (a_ref[nid] * z + b_ref[nid])) * v
        w2row = pl.load(w2_ref, (pl.dslice(nid, 1), slice(None)))  # [1, d]
        return acc + delta * w2row[0, :]

    acc0 = jnp.zeros((d_out,), jnp.float32)
    o_ref[0, :] = jax.lax.fori_loop(0, n_k, body, acc0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act",))
def fix_gather_looped(x, idx, valid, w1, b1, w2, a, b, *, act: str = "gelu"):
    """Naive per-neuron loop variant (kept for the perf ablation)."""
    m, d = x.shape
    _, n_k = idx.shape
    h, d_out = w2.shape
    assert w1.shape == (d, h) and valid.shape == idx.shape
    return pl.pallas_call(
        functools.partial(_fix_kernel_loop, n_k=n_k, act=act),
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, n_k), lambda i: (i, 0)),
            pl.BlockSpec((1, n_k), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, d_out), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), x.dtype),
        interpret=True,
    )(x, idx, valid, w1, b1, w2, a, b)


def select_topk(score, k: int):
    """Pick the K worst out-of-range neurons per row from predictor scores.

    Returns (idx [B, K] int32, valid [B, K] float32). valid masks padding
    slots (score == 0 means the neuron was in range — nothing to fix).

    NOTE: implemented with argsort rather than ``jax.lax.top_k`` — top_k
    lowers to a dedicated `topk` HLO instruction that the xla_extension
    0.5.1 text parser predates; argsort lowers to the classic `sort` op,
    which round-trips through HLO text cleanly.
    """
    order = jnp.argsort(-score, axis=-1)[:, :k]          # [B, K]
    vals = jnp.take_along_axis(score, order, axis=-1)
    valid = (vals > 0.0).astype(jnp.float32)
    return order.astype(jnp.int32), valid


def hbm_bytes_moved(d: int, k: int, dtype_bytes: int = 4) -> int:
    """Bytes of original FFN weights touched per row by the fix path —
    the selective-loading saving vs the dense 2*d*h the paper targets."""
    return 2 * d * k * dtype_bytes
