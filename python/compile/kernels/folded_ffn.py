"""L1 Pallas kernel: the folded-FFN speculative matmul  y = x @ C + B.

This is TARDIS's replacement for the whole FFN block on the hot path: a
single ``[B, d] @ [d, d]`` matmul plus bias, versus the original
``[B, d] @ [d, h]``, activation, ``[B, h] @ [h, d]`` (h = 4d).

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel tiles the output
into ``(bm, bn)`` blocks and marches over the contraction dimension in
``bk`` steps; each step stages an x-tile and a C-tile through VMEM and
feeds the MXU via ``jnp.dot`` with a float32 accumulator held in the
output block (the out index_map is independent of the k grid axis, so the
block stays resident across the k-march — the standard Pallas accumulate
pattern). Block sizes default to MXU-friendly 128 but shrink to the
problem size for the tiny models used in tests.

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode lowers the kernel to plain HLO that
both pytest and the rust runtime can run. Real-TPU efficiency is estimated
analytically (see ``vmem_footprint_bytes`` / ``mxu_utilization_estimate``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, pref: int) -> int:
    """Largest block <= pref that divides dim (keeps the grid exact)."""
    b = min(pref, dim)
    while dim % b:
        b -= 1
    return b


def _folded_kernel(x_ref, c_ref, b_ref, o_ref, *, n_k: int):
    """One (bm, bn) output tile; grid = (m/bm, n/bn, k/bk), k innermost."""
    k = pl.program_id(2)
    part = jnp.dot(x_ref[...], c_ref[...],
                   preferred_element_type=jnp.float32).astype(o_ref.dtype)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part + b_ref[...].astype(o_ref.dtype)[None, :]

    @pl.when(k != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def folded_ffn(x, c, bias, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """y = x @ c + bias. x: [B, d], c: [d, d], bias: [d] -> [B, d]."""
    m, k = x.shape
    k2, n = c.shape
    assert k == k2 and bias.shape == (n,), (x.shape, c.shape, bias.shape)
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_folded_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, c, bias)


def vmem_footprint_bytes(bm: int, bn: int, bk: int,
                         dtype_bytes: int = 4) -> int:
    """VMEM bytes resident per grid step: x-tile + C-tile + bias + out."""
    return (bm * bk + bk * bn + bn) * dtype_bytes + bm * bn * 4


def mxu_utilization_estimate(m: int, n: int, k: int,
                             bm: int = 128, bn: int = 128,
                             bk: int = 128) -> float:
    """Fraction of 128x128 MXU lanes busy given tile shapes (padding waste).

    The MXU processes 128x128 tiles; a (bm, bn, bk) block wastes the
    fraction of each dimension that pads up to the systolic array size.
    """
    def eff(b, t=128):
        b = min(b, t)
        return b / t
    return eff(min(bm, m)) * eff(min(bn, n)) * eff(min(bk, k))
