"""L1 Pallas kernel: the k-bit quantized out-of-range predictor.

TARDIS's online phase must know which neurons received activation inputs
outside their linearly-approximated hot range, *without* paying for the
full ``x @ W1`` matmul. The paper compresses W1 with GPTQ to 2 bits; we
store a from-scratch symmetric group quantization (int8 codes + per-group
scales — the *modeled* size is ``bits``/param, see tardis/predictor.py)
and fuse dequantization into the matmul:

    z_hat  = x @ (codes * scale) + b1
    score  = relu(lo - z_hat) + relu(z_hat - hi)

``score > 0``  <=>  the neuron is predicted out-of-range; the magnitude is
how far outside, which the model layer uses to pick the top-K neurons to
fix.

TPU mapping: grid over (batch tiles, neuron tiles); the code tile is
dequantized in VMEM registers right before the MXU dot, so HBM traffic is
``bits/32`` of the float W1 traffic — the entire point of the predictor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, pref: int) -> int:
    b = min(pref, dim)
    while dim % b:
        b -= 1
    return b


def _predictor_kernel(x_ref, codes_ref, scales_ref, b1_ref, lo_ref, hi_ref,
                      score_ref, *, group_size: int):
    x = x_ref[...]                                   # [bm, d]
    codes = codes_ref[...].astype(jnp.float32)       # [d, bn]
    scales = scales_ref[...]                         # [d/g, bn]
    d = codes.shape[0]
    # Dequantize: broadcast each group's scale over its group_size rows.
    s = jnp.repeat(scales, group_size, axis=0)[:d]   # [d, bn]
    w_hat = codes * s
    z_hat = jnp.dot(x, w_hat, preferred_element_type=jnp.float32)
    z_hat = z_hat + b1_ref[...][None, :]
    lo = lo_ref[...][None, :]
    hi = hi_ref[...][None, :]
    score = jnp.maximum(lo - z_hat, 0.0) + jnp.maximum(z_hat - hi, 0.0)
    score_ref[...] = score.astype(score_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group_size", "bm", "bn"))
def predictor_scores(x, codes, scales, b1, lo, hi, *, group_size: int = 32,
                     bm: int = 128, bn: int = 128):
    """x: [B, d], codes: [d, h] int8, scales: [d/g, h] -> score [B, h]."""
    m, d = x.shape
    d2, h = codes.shape
    assert d == d2 and d % group_size == 0, (x.shape, codes.shape, group_size)
    assert scales.shape == (d // group_size, h)
    bm, bn = _block(m, bm), _block(h, bn)
    grid = (m // bm, h // bn)
    return pl.pallas_call(
        functools.partial(_predictor_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),
            pl.BlockSpec((d // group_size, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, h), jnp.float32),
        interpret=True,
    )(x, codes, scales, b1, lo, hi)


def vmem_footprint_bytes(bm: int, bn: int, d: int, group_size: int,
                         bits: int) -> int:
    """Modeled VMEM bytes per grid step with packed codes on a real TPU."""
    return (bm * d * 4                      # x tile (f32)
            + d * bn * bits // 8            # packed code tile
            + (d // group_size) * bn * 4    # scales
            + 3 * bn * 4                    # b1 / lo / hi
            + bm * bn * 4)                  # score out
