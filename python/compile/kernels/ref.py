"""Pure-jnp oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here;
``python/tests`` asserts ``allclose`` between the two over hypothesis-driven
shape/dtype sweeps. The oracles are also used directly by the offline
pipeline (accuracy evaluation doesn't need the kernels' tiling).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Activation functions (shared by L1 kernels, L2 model, offline pipeline).
# ---------------------------------------------------------------------------

def gelu(x):
    """tanh-approximated GELU (the variant used by GPT-2/Falcon)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def relu(x):
    return jnp.maximum(x, 0.0)


def silu(x):
    return x * jax.nn.sigmoid(x)


ACTIVATIONS = {"gelu": gelu, "relu": relu, "silu": silu}


def activation(name: str):
    try:
        return ACTIVATIONS[name]
    except KeyError:
        raise ValueError(f"unknown activation {name!r}; "
                         f"choose one of {sorted(ACTIVATIONS)}") from None


# ---------------------------------------------------------------------------
# Oracle: folded FFN (speculative approximation)  y = x @ C + B
# ---------------------------------------------------------------------------

def folded_ffn_ref(x, c, bias):
    """x: [B, d], c: [d, d], bias: [d] -> [B, d]."""
    return x @ c + bias[None, :]


# ---------------------------------------------------------------------------
# Oracle: k-bit quantized predictor.
#
# W1 is stored as signed integer codes with per-(group, neuron) scales:
#   w_hat[i, n] = codes[i, n] * scales[i // group_size, n]
# The predictor computes z_hat = x @ w_hat + b1 and an out-of-range score
#   score = relu(lo - z_hat) + relu(z_hat - hi)
# score == 0  <=>  the (dequantized) activation input is inside [lo, hi).
# ---------------------------------------------------------------------------

def dequantize_ref(codes, scales, group_size: int):
    """codes: [d, h] int8, scales: [d/group_size, h] -> [d, h] float32."""
    d, h = codes.shape
    s = jnp.repeat(scales, group_size, axis=0)[:d]
    return codes.astype(jnp.float32) * s


def predictor_ref(x, codes, scales, b1, lo, hi, group_size: int):
    """x: [B, d] -> (z_hat [B, h], score [B, h])."""
    w_hat = dequantize_ref(codes, scales, group_size)
    z_hat = x @ w_hat + b1[None, :]
    score = relu(lo[None, :] - z_hat) + relu(z_hat - hi[None, :])
    return z_hat, score


# ---------------------------------------------------------------------------
# Oracle: top-K result fixing (selective correction).
#
# For the K selected neurons per row:  z = x @ W1[:, idx] + b1[idx]
#   correction = valid * (sigma(z) - (a*z + b)) @ W2[idx, :]
# `valid` masks padding slots (top-k always yields K indices; slots whose
# predictor score was 0 contribute nothing, keeping exactness).
# ---------------------------------------------------------------------------

def fix_gather_ref(x, idx, valid, w1, b1, w2, a, b, act: str):
    """x: [B, d], idx: [B, K] int32, valid: [B, K] -> [B, d]."""
    sigma = activation(act)

    def one_row(xr, ir, vr):
        w1g = w1[:, ir]              # [d, K]
        z = xr @ w1g + b1[ir]        # [K]
        delta = (sigma(z) - (a[ir] * z + b[ir])) * vr
        return delta @ w2[ir, :]     # [d]

    return jax.vmap(one_row)(x, idx, valid)


# ---------------------------------------------------------------------------
# Oracle: full dense FFN (the uncompressed baseline the kernels replace).
# ---------------------------------------------------------------------------

def dense_ffn_ref(x, w1, b1, w2, b2, act: str):
    sigma = activation(act)
    return sigma(x @ w1 + b1[None, :]) @ w2 + b2[None, :]


# ---------------------------------------------------------------------------
# Oracle: TARDIS FFN with *exact* (unbounded-capacity) fixing. This is the
# semantic ground truth of the paper's online phase: speculative folded
# matmul, then subtract the linear approximation and re-add the true
# activation for every neuron whose activation input left its hot range.
# ---------------------------------------------------------------------------

def tardis_ffn_exact_ref(x, c, bias, w1, b1, w2, a, b, lo, hi, act: str,
                         out_of_range=None):
    """out_of_range: optional [B, h] bool mask overriding the true range
    test (used to inject *predictor* decisions instead of ground truth)."""
    sigma = activation(act)
    z = x @ w1 + b1[None, :]
    if out_of_range is None:
        out_of_range = (z < lo[None, :]) | (z >= hi[None, :])
    spec = x @ c + bias[None, :]
    delta = jnp.where(out_of_range, sigma(z) - (a[None, :] * z + b[None, :]),
                      0.0)
    return spec + delta @ w2
