"""L2: the JAX transformer (decoder-only LM) with pluggable FFN modes.

This is the compute graph the rust coordinator serves. Everything here is
pure-functional JAX over an explicit parameter pytree so that

* ``train.py`` can differentiate ``loss_fn`` directly,
* the TARDIS offline pipeline can read/replace FFN weights,
* ``aot.py`` can lower ``prefill_step`` / ``decode_step`` to HLO text with
  the parameters as positional inputs (the rust runtime keeps them
  device-resident and threads the KV cache through without host copies).

FFN modes
---------
``dense``             sigma(x W1 + b1) W2 + b2                 (baseline)
``tardis``            folded_ffn + predictor + top-K fix       (the paper's
                      online phase; L1 Pallas kernels on the hot path)
``tardis_exact``      folded matmul + *unbounded* exact fixing (semantic
                      ground truth; used for accuracy tables)
``tardis_pred_dense`` folded matmul + dense fixing driven by the quantized
                      predictor's decisions (isolates predictor error)

The KV cache is one array ``[L, 2, B, S, H, Dh]`` (2 = keys/values; S
before H so single-position scatters write contiguous [H, Dh] rows — see
EXPERIMENTS.md §Perf) and the runtime threads a single buffer per step.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import folded_ffn, predictor_scores, fix_gather, select_topk
from .kernels import ref as kref


@dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny-gelu"
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512           # = 4 * d_model, the paper's h = 4d
    max_seq: int = 256
    act: str = "gelu"
    # TARDIS online knobs (ignored for dense/pruned variants):
    ffn_mode: str = "dense"
    fix_capacity: int = 64    # K: static top-K fix slots per token
    pred_group: int = 32      # predictor quantization group size

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def with_mode(self, mode: str, **kw) -> "ModelConfig":
        return replace(self, ffn_mode=mode, **kw)


# ---------------------------------------------------------------------------
# Parameter initialization.
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    """GPT-2-style init: N(0, 0.02), residual projections scaled by depth."""
    k = iter(jax.random.split(key, 6 + 12 * cfg.n_layers))
    sd = 0.02
    res = sd / np.sqrt(2 * cfg.n_layers)
    d, h, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq

    def norm(shape, scale=sd):
        return jax.random.normal(next(k), shape, jnp.float32) * scale

    params: dict[str, Any] = {
        "embed": norm((v, d)),
        "pos": norm((s, d)),
        "lnf_g": jnp.ones((d,)),
        "lnf_b": jnp.zeros((d,)),
        "head": norm((d, v)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
            "wq": norm((d, d)), "wk": norm((d, d)), "wv": norm((d, d)),
            "wo": norm((d, d), res),
            "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
            "w1": norm((d, h)), "b1": jnp.zeros((h,)),
            "w2": norm((h, d), res), "b2": jnp.zeros((d,)),
        })
    return params


def empty_kv(cfg: ModelConfig, batch: int) -> jnp.ndarray:
    return jnp.zeros((cfg.n_layers, 2, batch, cfg.max_seq, cfg.n_heads,
                      cfg.d_head), jnp.float32)


# ---------------------------------------------------------------------------
# Building blocks.
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def ffn_apply(lp: dict, x, cfg: ModelConfig):
    """Apply the FFN in the configured mode. x: [..., d] -> [..., d]."""
    mode = cfg.ffn_mode
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    if mode == "dense":
        y = kref.dense_ffn_ref(x2, lp["w1"], lp["b1"], lp["w2"], lp["b2"],
                               cfg.act)
    elif mode == "tardis":
        # Hot path: L1 Pallas kernels end to end.
        spec = folded_ffn(x2, lp["fold_c"], lp["fold_b"])
        score = predictor_scores(x2, lp["pred_codes"], lp["pred_scales"],
                                 lp["b1"], lp["lo"], lp["hi"],
                                 group_size=cfg.pred_group)
        idx, valid = select_topk(score, cfg.fix_capacity)
        corr = fix_gather(x2, idx, valid, lp["w1"], lp["b1"], lp["w2"],
                          lp["lin_a"], lp["lin_b"], act=cfg.act)
        y = spec + corr
    elif mode == "tardis_exact":
        y = kref.tardis_ffn_exact_ref(
            x2, lp["fold_c"], lp["fold_b"], lp["w1"], lp["b1"], lp["w2"],
            lp["lin_a"], lp["lin_b"], lp["lo"], lp["hi"], cfg.act)
    elif mode == "tardis_pred_dense":
        _, score = kref.predictor_ref(x2, lp["pred_codes"],
                                      lp["pred_scales"], lp["b1"],
                                      lp["lo"], lp["hi"], cfg.pred_group)
        y = kref.tardis_ffn_exact_ref(
            x2, lp["fold_c"], lp["fold_b"], lp["w1"], lp["b1"], lp["w2"],
            lp["lin_a"], lp["lin_b"], lp["lo"], lp["hi"], cfg.act,
            out_of_range=score > 0.0)
    else:
        raise ValueError(f"unknown ffn_mode {mode!r}")
    return y.reshape(shp)


def _attn_full(lp: dict, x, cfg: ModelConfig):
    """Training-time full-sequence causal attention. x: [B, S, d]."""
    B, S, d = x.shape
    H, Dh = cfg.n_heads, cfg.d_head

    def split(w):
        return (x @ w).reshape(B, S, H, Dh).transpose(0, 2, 1, 3)

    q, k, v = split(lp["wq"]), split(lp["wk"]), split(lp["wv"])
    scores = q @ k.transpose(0, 1, 3, 2) / np.sqrt(Dh)
    mask = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    out = jax.nn.softmax(scores, axis=-1) @ v
    out = out.transpose(0, 2, 1, 3).reshape(B, S, d)
    return out @ lp["wo"]


# ---------------------------------------------------------------------------
# Training / full-sequence forward (no cache).
# ---------------------------------------------------------------------------

def forward(params: dict, tokens, cfg: ModelConfig):
    """tokens: [B, S] int32 -> logits [B, S, V]."""
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :S]
    for lp in params["layers"]:
        x = x + _attn_full(lp, layer_norm(x, lp["ln1_g"], lp["ln1_b"]), cfg)
        x = x + ffn_apply(lp, layer_norm(x, lp["ln2_g"], lp["ln2_b"]), cfg)
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["head"]


def loss_fn(params: dict, tokens, cfg: ModelConfig):
    """Next-token cross entropy. tokens: [B, S+1]."""
    logits = forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Serving-time forward with KV cache (what aot.py lowers for rust).
# ---------------------------------------------------------------------------

def _attn_cached(lp: dict, x, kv_l, pos, cfg: ModelConfig):
    """Cached attention for a block of new tokens in one sequence slot.

    x: [T, d] new-token activations; kv_l: [2, S, H, Dh] this layer+slot's
    cache; pos: [T] absolute positions. Returns (out [T, d], new kv_l).
    """
    T, d = x.shape
    H, Dh, S = cfg.n_heads, cfg.d_head, cfg.max_seq

    def split(w):
        return (x @ w).reshape(T, H, Dh)

    q, k, v = split(lp["wq"]), split(lp["wk"]), split(lp["wv"])
    # Scatter new K/V into the cache at their absolute positions.
    # S-major layout: each scattered position writes a contiguous [H, Dh].
    kv_l = kv_l.at[0, pos, :, :].set(k, mode="drop")
    kv_l = kv_l.at[1, pos, :, :].set(v, mode="drop")
    keys, vals = kv_l[0], kv_l[1]                    # [S, H, Dh]
    scores = jnp.einsum("thd,shd->hts", q, keys) / np.sqrt(Dh)
    key_pos = jnp.arange(S)[None, None, :]           # [1, 1, S]
    visible = key_pos <= pos[None, :, None]          # causal, per new token
    scores = jnp.where(visible, scores, -1e30)
    out = jnp.einsum("hts,shd->thd", jax.nn.softmax(scores, -1), vals)
    return out.reshape(T, d) @ lp["wo"], kv_l


def _block_forward(params, x, kv_slot, pos, cfg):
    """x: [T, d], kv_slot: [L, 2, S, H, Dh], pos: [T]."""
    new_kv = []
    for li, lp in enumerate(params["layers"]):
        a, kv_l = _attn_cached(lp, layer_norm(x, lp["ln1_g"], lp["ln1_b"]),
                               kv_slot[li], pos, cfg)
        x = x + a
        x = x + ffn_apply(lp, layer_norm(x, lp["ln2_g"], lp["ln2_b"]), cfg)
        new_kv.append(kv_l)
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["head"], jnp.stack(new_kv)


def prefill_step(params: dict, tokens, kv, slot, pos0, cfg: ModelConfig):
    """Prefill one sequence slot with a chunk of prompt tokens.

    tokens: [T] int32 — a chunk padded with 0 beyond the real length `n`.
    Returns (logits [T, V], kv'): the caller reads row ``n - 1`` (padding
    rows are pad-query outputs and must be ignored). Pad positions write
    garbage K/V beyond the frontier, but every position is overwritten by
    the chunk/decode step that owns it *before* any query can attend to it
    (queries only see key_pos <= their own position), so the cache stays
    consistent. kv: [L, 2, B, S, H, Dh]; slot, pos0: scalars.
    """
    T = tokens.shape[0]
    pos = pos0 + jnp.arange(T)
    x = params["embed"][tokens] + jnp.take(params["pos"], pos, axis=0)
    kv_slot = kv[:, :, slot]                         # [L, 2, S, H, Dh]
    logits, kv_slot = _block_forward(params, x, kv_slot, pos, cfg)
    kv = kv.at[:, :, slot].set(kv_slot)
    return logits, kv


def _attn_decode_batch(lp: dict, x, kv_l, pos, cfg: ModelConfig):
    """Batched single-token cached attention across all slots.

    x: [B, d] (one new token per slot), kv_l: [2, B, S, H, Dh], pos: [B].
    One einsum per projection instead of a per-slot vmap — this keeps the
    whole decode step as a handful of batch-wide ops, which matters for
    the TARDIS FFN (one kernel launch per layer, not one per slot); see
    EXPERIMENTS.md §Perf.
    """
    B, d = x.shape
    H, Dh, S = cfg.n_heads, cfg.d_head, cfg.max_seq

    def split(w):
        return (x @ w).reshape(B, H, Dh)

    q, k, v = split(lp["wq"]), split(lp["wk"]), split(lp["wv"])
    bidx = jnp.arange(B)
    # (bidx, pos) are adjacent leading axes: the scatter writes one
    # contiguous [H, Dh] row per slot, no layout transpose.
    kv_l = kv_l.at[0, bidx, pos].set(k, mode="drop")
    kv_l = kv_l.at[1, bidx, pos].set(v, mode="drop")
    keys, vals = kv_l[0], kv_l[1]                    # [B, S, H, Dh]
    scores = jnp.einsum("bhd,bshd->bhs", q, keys) / np.sqrt(Dh)
    visible = jnp.arange(S)[None, None, :] <= pos[:, None, None]
    scores = jnp.where(visible, scores, -1e30)
    out = jnp.einsum("bhs,bshd->bhd", jax.nn.softmax(scores, -1), vals)
    return out.reshape(B, d) @ lp["wo"], kv_l


def decode_step(params: dict, tokens, pos, kv, cfg: ModelConfig):
    """One token per active slot. tokens: [B] int32, pos: [B] int32
    (position to write; inactive slots pass pos >= max_seq, dropped by the
    scatter and masked out by causality). Returns (logits [B, V], kv')."""
    x = params["embed"][tokens] + jnp.take(
        params["pos"], jnp.clip(pos, 0, cfg.max_seq - 1), axis=0)
    new_kv = []
    for li, lp in enumerate(params["layers"]):
        a, kv_l = _attn_decode_batch(
            lp, layer_norm(x, lp["ln1_g"], lp["ln1_b"]), kv[li], pos, cfg)
        x = x + a
        x = x + ffn_apply(lp, layer_norm(x, lp["ln2_g"], lp["ln2_b"]), cfg)
        new_kv.append(kv_l)
    x = layer_norm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["head"], jnp.stack(new_kv)


# ---------------------------------------------------------------------------
# Parameter flattening for AOT export (stable ordering shared with rust).
# ---------------------------------------------------------------------------

TARDIS_LAYER_KEYS = ("fold_c", "fold_b", "pred_codes", "pred_scales",
                     "lo", "hi", "lin_a", "lin_b")
DENSE_LAYER_KEYS = ("ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
                    "ln2_g", "ln2_b", "w1", "b1", "w2", "b2")
TOP_KEYS = ("embed", "pos", "lnf_g", "lnf_b", "head")


def _layer_keys(lp: dict) -> list[str]:
    """Parameter keys a layer contributes to the AOT interface.

    Folded layers drop ``b2``: it is absorbed into ``fold_b`` and no
    executable reads it, and jax.jit DCEs unused parameters out of the
    lowered HLO — the flat list must match the executable's signature
    exactly or the rust runtime would feed phantom buffers.
    """
    dense = [k for k in DENSE_LAYER_KEYS
             if not (k == "b2" and "fold_c" in lp)]
    return dense + [k for k in TARDIS_LAYER_KEYS if k in lp]


def param_names(params: dict) -> list[str]:
    """Deterministic flat parameter naming: top-level then per-layer."""
    names = [f"top.{k}" for k in TOP_KEYS]
    for li, lp in enumerate(params["layers"]):
        names += [f"layer{li}.{k}" for k in _layer_keys(lp)]
    return names


def flatten_params(params: dict) -> list[jnp.ndarray]:
    out = [params[k.split(".", 1)[1]] for k in
           (f"top.{t}" for t in TOP_KEYS)]
    for lp in params["layers"]:
        out += [lp[k] for k in _layer_keys(lp)]
    return out


def unflatten_params(names: list[str], arrays: list, n_layers: int) -> dict:
    params: dict[str, Any] = {"layers": [{} for _ in range(n_layers)]}
    for name, arr in zip(names, arrays):
        scope, key = name.split(".", 1)
        if scope == "top":
            params[key] = arr
        else:
            params["layers"][int(scope[5:])][key] = arr
    return params
