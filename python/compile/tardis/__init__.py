"""TARDIS offline pipeline: calibrate → threshold → range-search → fold →
predictor. The output of :func:`pipeline.fold_model` is a parameter pytree
the L2 model can run in ``tardis`` / ``tardis_exact`` modes."""

from .pipeline import FoldReport, fold_model

__all__ = ["fold_model", "FoldReport"]
