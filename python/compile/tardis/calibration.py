"""Calibration: collect per-neuron activation-input statistics (§4.1).

Runs the dense model over a small calibration set and records, for every
FFN layer, the *activation inputs* ``z = ln2(x) @ W1 + b1`` (one column of
``z`` per neuron) plus the FFN block inputs (needed by the Wanda/RIA
baselines). Mirrors the paper's setup: a handful of samples (default 8 x
2048-token in the paper; we scale tokens to our tiny models) is enough
because nothing is backpropagated.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .. import corpus
from ..model import ModelConfig, layer_norm, _attn_full


@dataclass
class CalibStats:
    """Per-layer calibration capture.

    z[l]      : [T, h]  activation inputs (pre-activation) of layer l
    ffn_in[l] : [T, d]  FFN block inputs (post-ln2), for pruning baselines
    act_out[l]: [T, h]  activation outputs sigma(z), for W2 pruning scores
    """
    z: list[np.ndarray]
    ffn_in: list[np.ndarray]
    act_out: list[np.ndarray]
    n_tokens: int


def _capture_forward(params, tokens, cfg: ModelConfig):
    """Dense forward that also returns per-layer (ffn_in, z)."""
    from ..kernels.ref import activation, dense_ffn_ref
    sigma = activation(cfg.act)
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :S]
    caps = []
    for lp in params["layers"]:
        x = x + _attn_full(lp, layer_norm(x, lp["ln1_g"], lp["ln1_b"]), cfg)
        xin = layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        z = xin @ lp["w1"] + lp["b1"][None, None, :]
        caps.append((xin, z))
        x = x + (sigma(z) @ lp["w2"] + lp["b2"][None, None, :])
    return caps


def collect(params, cfg: ModelConfig, dataset: str = "c4-syn",
            n_samples: int = 8, sample_len: int = 256, seed: int = 0,
            max_tokens: int = 4096) -> CalibStats:
    """Run calibration. n_samples windows of sample_len tokens each."""
    from ..kernels.ref import activation
    sigma = activation(cfg.act)
    toks = np.asarray(corpus.token_stream(dataset, seed=seed,
                                          n_sentences=2000), np.int32)
    rng = np.random.default_rng(seed)
    sample_len = min(sample_len, cfg.max_seq)
    starts = rng.integers(0, len(toks) - sample_len, n_samples)
    batch = np.stack([toks[s:s + sample_len] for s in starts])

    caps = jax.jit(_capture_forward, static_argnames=("cfg",))(
        params, jnp.asarray(batch), cfg)
    z_list, in_list, out_list = [], [], []
    total = batch.shape[0] * batch.shape[1]
    keep = min(total, max_tokens)
    sel = rng.choice(total, keep, replace=False) if keep < total \
        else np.arange(total)
    for xin, z in caps:
        zf = np.asarray(z, np.float32).reshape(total, -1)[sel]
        xf = np.asarray(xin, np.float32).reshape(total, -1)[sel]
        z_list.append(zf)
        in_list.append(xf)
        out_list.append(np.asarray(sigma(jnp.asarray(zf)), np.float32))
    return CalibStats(z=z_list, ffn_in=in_list, act_out=out_list,
                      n_tokens=keep)


# ---------------------------------------------------------------------------
# Distribution skewness metric (Table 1 / Fig 5).
# ---------------------------------------------------------------------------

def hot_range_fraction(z: np.ndarray, mass: float = 0.65) -> np.ndarray:
    """Per neuron: length of the shortest interval holding ``mass`` of the
    inputs, relative to the total observed input range (paper Table 1:
    ~18-20% for real LLMs). z: [T, h] -> fractions [h]."""
    zs = np.sort(z, axis=0)
    t, h = zs.shape
    k = max(1, int(np.ceil(mass * t)))
    if k >= t:
        return np.ones(h)
    # window [i, i+k): width of the shortest window containing k samples
    widths = zs[k - 1:, :] - zs[: t - k + 1, :]       # [t-k+1, h]
    shortest = widths.min(axis=0)
    total = zs[-1] - zs[0] + 1e-12
    return shortest / total
