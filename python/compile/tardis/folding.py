"""Constant-folded matrix generation (paper §5.2, Fig 3).

With each neuron's activation replaced by ``phi_n(z) = a_n z + b_n`` on its
hot range, the FFN collapses by matrix associativity:

    sigma(x W1 + b1) W2 + b2
      ~ ((x W1 + b1) * a + b) W2 + b2
      = x (W1 diag(a) W2)  +  (a * b1 + b) W2 + b2
      = x C + B

``C`` is d x d (vs the original 2dh = 8d^2 for h = 4d: the paper's 87.5%
theoretical reduction), and ``B`` absorbs both the activation intercepts
and the original biases. ``intermediate_dtype`` reproduces Table 6: the
fold is computed in the requested precision, then cast back to float32.
"""

from __future__ import annotations

import numpy as np


DTYPES = {
    "bfloat16": None,   # emulated below (numpy has no native bf16)
    "float16": np.float16,
    "float32": np.float32,
    "float64": np.float64,
}


def _to_bf16(x: np.ndarray) -> np.ndarray:
    """Round-to-nearest-even truncation of f32 to bfloat16, kept in f32."""
    u = x.astype(np.float32).view(np.uint32)
    rounding = 0x7FFF + ((u >> 16) & 1)
    return ((u + rounding) & 0xFFFF0000).view(np.float32)


def _cast(x: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        return _to_bf16(np.asarray(x, np.float32))
    return np.asarray(x, DTYPES[dtype])


def fold(w1: np.ndarray, b1: np.ndarray, w2: np.ndarray, b2: np.ndarray,
         a: np.ndarray, b: np.ndarray,
         intermediate_dtype: str = "float32"
         ) -> tuple[np.ndarray, np.ndarray]:
    """Constant-fold one FFN layer. Returns (C [d, d], B [d]) in f32.

    w1: [d, h], b1: [h], w2: [h, d], b2: [d], a/b: [h] per-neuron linear
    coefficients.
    """
    if intermediate_dtype not in DTYPES:
        raise ValueError(f"unknown dtype {intermediate_dtype!r}")
    w1c = _cast(w1, intermediate_dtype)
    w2c = _cast(w2, intermediate_dtype)
    ac = _cast(a, intermediate_dtype)
    bc = _cast(b, intermediate_dtype)
    b1c = _cast(b1, intermediate_dtype)
    if intermediate_dtype == "bfloat16":
        # bf16 storage, f32 accumulate (matches TPU matmul semantics).
        c = (w1c * ac[None, :]) @ w2c
        bias = (ac * b1c + bc) @ w2c
    else:
        c = (w1c * ac[None, :].astype(w1c.dtype)) @ w2c
        bias = (ac * b1c + bc).astype(w2c.dtype) @ w2c
    c = np.asarray(c, np.float32)
    bias = np.asarray(bias, np.float32) + np.asarray(b2, np.float32)
    return c, bias


def fold_mse(w1, b1, w2, b2, a, b, z_samples: np.ndarray,
             x_samples: np.ndarray, intermediate_dtype: str = "float32"
             ) -> float:
    """MSE between folded and unfolded *linear* FFN paths (Tables 6/7).

    Compares x C + B against ((x W1 + b1) * a + b) W2 + b2 computed
    sequentially in f32 — isolating the reassociation/rounding error of the
    fold itself (both sides use the linear activation).
    """
    c, bias = fold(w1, b1, w2, b2, a, b, intermediate_dtype)
    folded = x_samples @ c + bias[None, :]
    z = x_samples @ w1 + b1[None, :]
    seq = (z * a[None, :] + b[None, :]) @ w2 + b2[None, :]
    return float(np.mean((folded - seq) ** 2))


def theoretical_reduction(d: int, h: int) -> float:
    """Paper §3.1: parameter reduction of folding 2dh into d^2."""
    return 1.0 - d * d / (2.0 * d * h)


def glu_fold_blowup(d: int, h: int) -> float:
    """§9 limitation: folding a gated FFN sigma(xW1) .* (xW2) W3 yields a
    quadratic form per output — d*(d+1)/2 parameters per output unit vs the
    3dh of the original GLU, i.e. a multiplicative blow-up. Returns the
    parameter ratio folded/original (>> 1, the paper reports 254x for
    LLaMA-2-7B)."""
    folded = d * (d + 1) / 2.0 * d      # one quadratic form per output dim
    return folded / (3.0 * d * h)
