"""From-scratch Gaussian kernel density estimation (paper §5.1, Alg. 1).

The paper uses cuML's KDE to find each neuron's activation-input centroid
(the mode of the input density) as the seed of the greedy range search.
cuML is unavailable offline, so this is a vectorized numpy implementation:
Scott's-rule bandwidth, density evaluated on a uniform grid, batched over
neurons in chunks to bound memory.
"""

from __future__ import annotations

import numpy as np


def scott_bandwidth(samples: np.ndarray) -> np.ndarray:
    """Scott's rule per neuron. samples: [T, N] -> bw [N]."""
    t = samples.shape[0]
    sd = samples.std(axis=0) + 1e-12
    return 1.06 * sd * t ** (-1.0 / 5.0)


def kde_grid(samples: np.ndarray, grid_points: int = 128,
             max_samples: int = 512, chunk: int = 64,
             seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Gaussian KDE per neuron on a per-neuron uniform grid.

    samples: [T, N] activation inputs for N neurons.
    Returns (grid [G, N], density [G, N]); density integrates to ~1 per
    neuron over its grid span.
    """
    t, n = samples.shape
    if t > max_samples:
        rng = np.random.default_rng(seed)
        samples = samples[rng.choice(t, max_samples, replace=False)]
        t = max_samples
    lo = samples.min(axis=0)
    hi = samples.max(axis=0)
    span = np.maximum(hi - lo, 1e-9)
    grid = lo[None, :] + np.linspace(0.0, 1.0, grid_points)[:, None] \
        * span[None, :]                                     # [G, N]
    bw = scott_bandwidth(samples)                           # [N]
    dens = np.empty((grid_points, n), np.float64)
    for c0 in range(0, n, chunk):
        c1 = min(c0 + chunk, n)
        # [G, T, nc]
        z = (grid[:, None, c0:c1] - samples[None, :, c0:c1]) \
            / bw[None, None, c0:c1]
        k = np.exp(-0.5 * z * z)
        dens[:, c0:c1] = k.mean(axis=1) / (bw[None, c0:c1]
                                           * np.sqrt(2 * np.pi))
    return grid, dens


def find_centroids(samples: np.ndarray, grid_points: int = 128,
                   **kw) -> np.ndarray:
    """Mode of each neuron's input density (Alg. 1 line 13). -> [N]."""
    grid, dens = kde_grid(samples, grid_points=grid_points, **kw)
    idx = dens.argmax(axis=0)
    return grid[idx, np.arange(samples.shape[1])]
