"""The TARDIS offline pipeline (paper Fig 7): model + calibration set +
threshold t  →  folded matrices, ranges, predictor, and a report.

Steps per FFN layer (§5):
  1. calibrate          — capture activation inputs z = ln2(x) W1 + b1
  2. layer thresholds   — error-aware allocation of t across layers
  3. neuron thresholds  — same within the layer
  4. greedy range search— Algorithm 1 (vectorized) → lo/hi/a/b per neuron
  5. constant folding   — C = W1 diag(a) W2, B = (a b1 + b) W2 + b2
  6. predictor          — k-bit quantized W1

The returned parameter pytree contains the original dense weights *plus*
the tardis keys, so the same pytree runs in any ffn_mode. Compression-
ratio accounting (paper §7.1) counts C+B, the predictor, and the expected
resident original weights for fixing against the dense FFN size.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from ..model import ModelConfig
from . import calibration, folding, kde, predictor, ranges, thresholds


@dataclass
class LayerReport:
    threshold: float
    coverage: float           # achieved mean in-range fraction
    mean_err: float
    oor_rate: float           # 1 - coverage (true out-of-range rate)
    pred_stats: predictor.PredictorStats | None = None


@dataclass
class FoldReport:
    target_threshold: float
    bits: int
    layers: list[LayerReport] = field(default_factory=list)
    compression_ratio: float = 0.0
    achieved_coverage: float = 0.0
    wall_time_s: float = 0.0
    fold_mse: float = 0.0

    @property
    def mean_oor_rate(self) -> float:
        return float(np.mean([l.oor_rate for l in self.layers]))


def compression_ratio(cfg: ModelConfig, mean_oor: float, bits: int,
                      group_size: int | None = None) -> float:
    """Paper §7.1 accounting, per FFN layer, in f32-param equivalents:

      kept = C (d^2) + B (d) + predictor (bits/32 * dh + f16 scales)
             + resident original neuron weights for fixing
               (out-of-range rate * 2dh, cf. §5.4 Memory Footprint)
      ratio = 1 - kept / (2dh + h + d)
    """
    d, h = cfg.d_model, cfg.d_ff
    g = group_size or cfg.pred_group
    orig = 2.0 * d * h + h + d
    pred_sz = d * h * bits / 32.0 + (d // g) * h / 2.0
    kept = d * d + d + pred_sz + mean_oor * (2.0 * d * h + h)
    return 1.0 - kept / orig


def threshold_for_ratio(cfg: ModelConfig, target_ratio: float, bits: int,
                        slack: float = 0.0) -> float:
    """Invert the ratio accounting: coverage threshold t giving the ratio.

    Assumes achieved out-of-range rate ~ (1 - t) (validated in Fig 12: the
    range search hits its coverage target within <2%).
    """
    lo_t, hi_t = 0.50, 0.999
    for _ in range(40):
        mid = 0.5 * (lo_t + hi_t)
        r = compression_ratio(cfg, (1.0 - mid) * (1.0 + slack), bits)
        if r < target_ratio:
            lo_t = mid
        else:
            hi_t = mid
    return 0.5 * (lo_t + hi_t)


def fold_model(params: dict, cfg: ModelConfig, target_t: float,
               dataset: str = "c4-syn", n_samples: int = 8,
               bits: int = 2, intermediate_dtype: str = "float32",
               seed: int = 0, stats: calibration.CalibStats | None = None,
               n_steps: int = 64) -> tuple[dict, FoldReport]:
    """Run the offline pipeline; returns (augmented params, report)."""
    t0 = time.time()
    if stats is None:
        stats = calibration.collect(params, cfg, dataset=dataset,
                                    n_samples=n_samples, seed=seed)
    L = cfg.n_layers
    w2norms = [np.linalg.norm(np.asarray(lp["w2"]), axis=1)
               for lp in params["layers"]]

    # ---- layer-level thresholds (error at uniform target as proxy) ----
    layer_err = []
    for li in range(L):
        z = stats.z[li]
        lo, hi = ranges.quantile_ranges(z, np.full(z.shape[1], target_t))
        layer_err.append(float(ranges.approx_error(
            z, cfg.act, lo, hi, w2norms[li]).sum()))
    t_layers = thresholds.layer_thresholds(layer_err, target_t)

    report = FoldReport(target_threshold=target_t, bits=bits)
    new_params = {k: v for k, v in params.items() if k != "layers"}
    new_params["layers"] = []

    for li, lp in enumerate(params["layers"]):
        z = stats.z[li].astype(np.float64)
        h = z.shape[1]
        # ---- neuron-level thresholds ----
        lo_q, hi_q = ranges.quantile_ranges(z, np.full(h, t_layers[li]))
        nerr = ranges.approx_error(z, cfg.act, lo_q, hi_q, w2norms[li])
        t_neurons = thresholds.neuron_thresholds(nerr, float(t_layers[li]))
        # ---- Algorithm 1 ----
        centroids = kde.find_centroids(z.astype(np.float32), seed=seed)
        spec = ranges.greedy_search(z, cfg.act, t_neurons, centroids,
                                    w2norms[li], n_steps=n_steps)
        # ---- constant folding ----
        w1 = np.asarray(lp["w1"], np.float32)
        b1 = np.asarray(lp["b1"], np.float32)
        w2 = np.asarray(lp["w2"], np.float32)
        b2 = np.asarray(lp["b2"], np.float32)
        c, bias = folding.fold(w1, b1, w2, b2,
                               spec.a.astype(np.float32),
                               spec.b.astype(np.float32),
                               intermediate_dtype)
        report.fold_mse += folding.fold_mse(
            w1, b1, w2, b2, spec.a.astype(np.float32),
            spec.b.astype(np.float32), stats.z[li][:256],
            stats.ffn_in[li][:256], intermediate_dtype) / L
        # ---- predictor ----
        qp = predictor.quantize(w1, bits=bits, group_size=cfg.pred_group)
        pstats = predictor.evaluate(qp, stats.ffn_in[li][:512], w1, b1,
                                    spec.lo.astype(np.float32),
                                    spec.hi.astype(np.float32))
        nlp = dict(lp)
        nlp.update({
            "fold_c": jnp.asarray(c),
            "fold_b": jnp.asarray(bias),
            "pred_codes": jnp.asarray(qp.codes),
            "pred_scales": jnp.asarray(qp.scales),
            "lo": jnp.asarray(spec.lo, jnp.float32),
            "hi": jnp.asarray(spec.hi, jnp.float32),
            "lin_a": jnp.asarray(spec.a, jnp.float32),
            "lin_b": jnp.asarray(spec.b, jnp.float32),
        })
        new_params["layers"].append(nlp)
        report.layers.append(LayerReport(
            threshold=float(t_layers[li]),
            coverage=float(spec.coverage.mean()),
            mean_err=float(spec.err.mean()),
            oor_rate=float(1.0 - spec.coverage.mean()),
            pred_stats=pstats,
        ))

    report.achieved_coverage = float(
        np.mean([l.coverage for l in report.layers]))
    report.compression_ratio = compression_ratio(
        cfg, report.mean_oor_rate, bits)
    report.wall_time_s = time.time() - t0
    return new_params, report


def fix_capacity_for(cfg: ModelConfig, mean_oor: float,
                     safety: float = 2.0) -> int:
    """Static top-K capacity from the calibration out-of-range rate."""
    k = int(np.ceil(mean_oor * cfg.d_ff * safety))
    return int(np.clip(k, 4, cfg.d_ff))
