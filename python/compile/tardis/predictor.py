"""Out-of-range predictor (paper §5.3): a k-bit quantized copy of W1.

The online phase must know which neurons' activation inputs left their hot
range. Computing that exactly needs the full ``x @ W1`` — the very matmul
folding eliminated — so TARDIS instead keeps a heavily *quantized* W1
(GPTQ 2-bit in the paper; a from-scratch symmetric group quantizer here)
that is just accurate enough to answer the binary in/out question.

Size accounting models the deployed format: ``bits`` per code plus one
float16 scale per (group, neuron); the int8 ``codes`` array here is the
unpacked working representation the interpret-mode kernel consumes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class QuantizedPredictor:
    codes: np.ndarray     # [d, h] int8 (values in [-qmax, qmax])
    scales: np.ndarray    # [d/group, h] float32
    bits: int
    group_size: int

    @property
    def size_params_f32(self) -> float:
        """Size in float32-parameter equivalents (for ratio accounting)."""
        d, h = self.codes.shape
        return d * h * self.bits / 32.0 + self.scales.size / 2.0

    def dequantize(self) -> np.ndarray:
        s = np.repeat(self.scales, self.group_size, axis=0)
        return self.codes.astype(np.float32) * s[: self.codes.shape[0]]


def quantize(w1: np.ndarray, bits: int = 2, group_size: int = 32
             ) -> QuantizedPredictor:
    """Symmetric per-(group, neuron) quantization of W1 [d, h]."""
    if not 2 <= bits <= 8:
        raise ValueError(f"bits must be in [2, 8], got {bits}")
    d, h = w1.shape
    if d % group_size:
        raise ValueError(f"d={d} not divisible by group_size={group_size}")
    qmax = float(2 ** (bits - 1) - 1)
    g = w1.reshape(d // group_size, group_size, h)
    absmax = np.abs(g).max(axis=1)                      # [d/g, h]
    scales = np.maximum(absmax / qmax, 1e-12).astype(np.float32)
    codes = np.clip(np.rint(g / scales[:, None, :]), -qmax, qmax)
    return QuantizedPredictor(
        codes=codes.reshape(d, h).astype(np.int8),
        scales=scales, bits=bits, group_size=group_size)


def predict_out_of_range(pred: QuantizedPredictor, x: np.ndarray,
                         b1: np.ndarray, lo: np.ndarray, hi: np.ndarray
                         ) -> np.ndarray:
    """Predicted out-of-range mask [T, h] from FFN inputs x [T, d]."""
    z_hat = x @ pred.dequantize() + b1[None, :]
    return (z_hat < lo[None, :]) | (z_hat >= hi[None, :])


@dataclass
class PredictorStats:
    precision: float      # flagged & truly-out / flagged
    recall: float         # flagged & truly-out / truly-out
    flag_rate: float      # fraction of (token, neuron) pairs flagged
    true_oor_rate: float  # ground-truth out-of-range rate


def evaluate(pred: QuantizedPredictor, x: np.ndarray, w1: np.ndarray,
             b1: np.ndarray, lo: np.ndarray, hi: np.ndarray
             ) -> PredictorStats:
    z = x @ w1 + b1[None, :]
    truth = (z < lo[None, :]) | (z >= hi[None, :])
    flagged = predict_out_of_range(pred, x, b1, lo, hi)
    tp = float((flagged & truth).sum())
    return PredictorStats(
        precision=tp / max(float(flagged.sum()), 1.0),
        recall=tp / max(float(truth.sum()), 1.0),
        flag_rate=float(flagged.mean()),
        true_oor_rate=float(truth.mean()),
    )
