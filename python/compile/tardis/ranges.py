"""Range search: Algorithm 1, vectorized across neurons.

For each neuron we find one input interval ``[lo, hi)`` and a linear fit
``y = a*z + b`` of the activation on that interval, such that at least the
neuron's coverage threshold of calibration inputs land inside. The search
is the paper's greedy expansion — start at the KDE centroid, repeatedly
extend the cheaper side — but evaluated for *all h neurons of a layer at
once* with closed-form least-squares statistics, which turns the paper's
30-minutes-per-layer loop into seconds (EXPERIMENTS.md §7.3).

Error metric (paper §5.1): per-neuron L2 distance between true and
approximated FFN contribution, i.e. the activation-space SSE scaled by
``||W2[n, :]||_2``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..kernels.ref import activation as act_fn


@dataclass
class RangeSpec:
    """Per-neuron linear approximation of one FFN layer."""
    lo: np.ndarray        # [h] inclusive lower bound
    hi: np.ndarray        # [h] exclusive upper bound
    a: np.ndarray         # [h] slope
    b: np.ndarray         # [h] intercept
    coverage: np.ndarray  # [h] fraction of calibration inputs in range
    err: np.ndarray       # [h] weighted SSE of the fit (importance score)


def linfit_masked(z: np.ndarray, y: np.ndarray, mask: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Closed-form least squares per neuron over masked samples.

    z, y, mask: [T, h]. Returns (a, b, sse) each [h]. Neurons with < 2
    in-range samples degrade to (a=0, b=mean y) with sse over the mask.
    """
    m = mask.astype(np.float64)
    n = m.sum(axis=0)
    sx = (z * m).sum(axis=0)
    sy = (y * m).sum(axis=0)
    sxx = (z * z * m).sum(axis=0)
    sxy = (z * y * m).sum(axis=0)
    syy = (y * y * m).sum(axis=0)
    denom = n * sxx - sx * sx
    ok = (n >= 2) & (np.abs(denom) > 1e-12)
    a = np.where(ok, (n * sxy - sx * sy) / np.where(ok, denom, 1.0), 0.0)
    b = np.where(n > 0, (sy - a * sx) / np.maximum(n, 1.0), 0.0)
    sse = (syy + a * a * sxx + n * b * b
           - 2 * a * sxy - 2 * b * sy + 2 * a * b * sx)
    return a, b, np.maximum(sse, 0.0)


def quantile_ranges(z: np.ndarray, t_n: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Cheap proxy ranges: the shortest window holding t_n mass per neuron.

    Used for the *error estimation* passes of the adaptive thresholding
    (the paper's ``estimate_error_layers`` / ``estimate_error_neurons``);
    the final ranges come from :func:`greedy_search`.
    """
    t, h = z.shape
    zs = np.sort(z, axis=0)
    lo = np.empty(h)
    hi = np.empty(h)
    for n in range(h):
        k = int(np.ceil(np.clip(t_n[n], 0.0, 1.0) * t))
        k = min(max(k, 2), t)
        widths = zs[k - 1:, n] - zs[: t - k + 1, n]
        i = int(widths.argmin())
        lo[n] = zs[i, n]
        hi[n] = zs[i + k - 1, n]
    # Exclusive upper bound: nudge past the last included sample.
    span = zs[-1] - zs[0]
    return lo, hi + 1e-6 * (span + 1.0)


def approx_error(z: np.ndarray, act: str, lo: np.ndarray, hi: np.ndarray,
                 w2norm: np.ndarray) -> np.ndarray:
    """Weighted in-range SSE of the best linear fit (importance score)."""
    y = act_fn(act)(z)
    mask = (z >= lo[None, :]) & (z < hi[None, :])
    _, _, sse = linfit_masked(z, np.asarray(y), mask)
    return sse * (w2norm ** 2)


def greedy_search(z: np.ndarray, act: str, t_n: np.ndarray,
                  centroids: np.ndarray, w2norm: np.ndarray,
                  n_steps: int = 64, max_iters: int | None = None
                  ) -> RangeSpec:
    """Algorithm 1, all neurons of a layer simultaneously.

    z: [T, h] calibration activation inputs; t_n: [h] coverage thresholds;
    centroids: [h] KDE modes; w2norm: [h] L2 norms of W2 rows.
    """
    t, h = z.shape
    y = np.asarray(act_fn(act)(z), np.float64)
    z = z.astype(np.float64)
    zmin, zmax = z.min(axis=0), z.max(axis=0)
    step = np.maximum((zmax - zmin) / n_steps, 1e-9)
    lo = np.clip(centroids - 0.5 * step, zmin, zmax)
    hi = np.clip(centroids + 0.5 * step, zmin, zmax)
    max_iters = max_iters or (2 * n_steps + 8)

    coverage = np.zeros(h)
    for _ in range(max_iters):
        inr = (z >= lo[None, :]) & (z < hi[None, :])
        coverage = inr.mean(axis=0)
        active = coverage < t_n
        if not active.any():
            break
        lo_l = np.where(active, lo - step, lo)
        hi_r = np.where(active, hi + step, hi)
        # Candidate error when extending left vs right (Alg. 1 l.20-25).
        m_l = (z >= lo_l[None, :]) & (z < hi[None, :])
        m_r = (z >= lo[None, :]) & (z < hi_r[None, :])
        _, _, sse_l = linfit_masked(z, y, m_l)
        _, _, sse_r = linfit_masked(z, y, m_r)
        go_left = sse_l <= sse_r
        # Never expand past the data (the other side keeps making progress).
        go_left = np.where(lo - step < zmin - step, False, go_left)
        go_left = np.where(hi + step > zmax + step, True, go_left)
        lo = np.where(active & go_left, lo - step, lo)
        hi = np.where(active & ~go_left, hi + step, hi)

    inr = (z >= lo[None, :]) & (z < hi[None, :])
    coverage = inr.mean(axis=0)
    a, b, sse = linfit_masked(z, y, inr)
    return RangeSpec(lo=lo, hi=hi, a=a, b=b, coverage=coverage,
                     err=sse * (w2norm ** 2))
