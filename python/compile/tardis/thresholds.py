"""Two-level adaptive thresholding (paper §5.1, "Adaptive Thresholding").

The paper formulates per-layer coverage allocation as

    minimize  sum_i E_i * t_i   s.t.  sum_i t_i = t * L

(and the same one level down, per neuron). As stated this LP is bang-bang
(it would park every layer at a bound), which contradicts the paper's
description of a *graded* allocation, so we solve the bounded, regularized
form: thresholds move away from the uniform target ``t`` proportionally to
how unimportant (low-error) a component is, subject to box bounds and the
exact sum constraint — i.e. the projection of the LP's descent direction
onto the feasible simplex slab. Components with higher approximation error
get stricter (lower) linear coverage, exactly the behaviour the paper
motivates with Insight 2.
"""

from __future__ import annotations

import numpy as np


def error_aware_thresholds(errors: np.ndarray, target: float,
                           lo: float = 0.5, hi: float = 0.995,
                           strength: float = 0.5) -> np.ndarray:
    """Allocate coverage thresholds t_i with mean exactly ``target``.

    errors : per-component empirical approximation error E_i (>= 0)
    target : user threshold t (mean coverage)
    lo, hi : box bounds on each t_i
    strength : fraction of the lo..hi half-width the allocation may use

    Returns t of the same shape as errors with t.mean() == target (up to
    clipping feasibility) and t monotone non-increasing in E_i.
    """
    e = np.asarray(errors, np.float64)
    n = e.size
    if n == 1:
        return np.full(1, np.clip(target, lo, hi))
    target = float(np.clip(target, lo, hi))
    # Rank-based importance in [-1, 1]: -1 = most error (most important).
    order = np.argsort(np.argsort(e))          # ranks 0..n-1, high = big E
    u = 1.0 - 2.0 * order / (n - 1)            # +1 for smallest error
    halfw = strength * min(target - lo, hi - target)
    t = target + halfw * u
    # Iterative re-centering under clipping keeps the mean exact.
    for _ in range(8):
        t = np.clip(t, lo, hi)
        gap = target - t.mean()
        if abs(gap) < 1e-12:
            break
        free = (t > lo + 1e-12) & (t < hi - 1e-12) if gap < 0 else \
               (t < hi - 1e-12)
        if not free.any():
            break
        t[free] += gap * n / free.sum()
    return np.clip(t, lo, hi)


def layer_thresholds(layer_errors: list[float], target: float,
                     **kw) -> np.ndarray:
    """Paper's layer-level allocation: one t_i per FFN layer."""
    return error_aware_thresholds(np.asarray(layer_errors), target, **kw)


def neuron_thresholds(neuron_errors: np.ndarray, layer_target: float,
                      **kw) -> np.ndarray:
    """Paper's neuron-level allocation within one layer."""
    return error_aware_thresholds(neuron_errors, layer_target, **kw)
