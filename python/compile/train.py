"""Training loop for the tiny LMs (pure JAX Adam, deterministic).

We cannot download Falcon/BLOOM/GPT-2, so `make artifacts` trains three
small decoder-only LMs from scratch on the synthetic corpus — one per
activation family the paper evaluates (GELU / ReLU / SiLU). Training is a
build-time step and its outputs are cached under ``artifacts/weights``.
"""

from __future__ import annotations

import functools
import pickle
import time
from dataclasses import dataclass
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, forward, init_params, loss_fn


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 350
    batch: int = 16
    seq: int = 64
    lr: float = 3e-3
    warmup: int = 30
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    dataset: str = "wiki-syn"
    log_every: int = 50


def make_batches(tokens: np.ndarray, tc: TrainConfig):
    """Deterministic random windows of length seq+1."""
    rng = np.random.default_rng(tc.seed)
    n = len(tokens) - tc.seq - 1
    for _ in range(tc.steps):
        starts = rng.integers(0, n, tc.batch)
        yield np.stack([tokens[s:s + tc.seq + 1] for s in starts])


def _lr_at(step, tc: TrainConfig):
    warm = jnp.minimum(step / max(tc.warmup, 1), 1.0)
    # cosine decay to 10%
    prog = jnp.clip((step - tc.warmup) / max(tc.steps - tc.warmup, 1), 0, 1)
    return tc.lr * warm * (0.55 + 0.45 * jnp.cos(jnp.pi * prog))


@functools.partial(jax.jit, static_argnames=("cfg", "tc"))
def train_step(params, opt_state, tokens, step, cfg: ModelConfig,
               tc: TrainConfig):
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, cfg)
    # global-norm clip
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, tc.grad_clip / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
    m, v = opt_state
    lr = _lr_at(step, tc)
    t = step + 1

    def upd(m_, v_, g):
        m_ = tc.beta1 * m_ + (1 - tc.beta1) * g
        v_ = tc.beta2 * v_ + (1 - tc.beta2) * g * g
        return m_, v_

    new_m = jax.tree_util.tree_map(lambda m_, g: tc.beta1 * m_ +
                                   (1 - tc.beta1) * g, m, grads)
    new_v = jax.tree_util.tree_map(lambda v_, g: tc.beta2 * v_ +
                                   (1 - tc.beta2) * g * g, v, grads)
    mhat = jax.tree_util.tree_map(lambda m_: m_ / (1 - tc.beta1 ** t), new_m)
    vhat = jax.tree_util.tree_map(lambda v_: v_ / (1 - tc.beta2 ** t), new_v)
    params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + tc.eps),
        params, mhat, vhat)
    return params, (new_m, new_v), loss


def train(cfg: ModelConfig, tc: TrainConfig, verbose: bool = True):
    """Train from scratch; returns (params, loss_history)."""
    toks_train, _ = corpus.train_eval_split(tc.dataset, seed=tc.seed)
    toks = np.asarray(toks_train, np.int32)
    params = init_params(cfg, jax.random.PRNGKey(tc.seed))
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    opt_state = (zeros, jax.tree_util.tree_map(jnp.zeros_like, params))
    hist = []
    t0 = time.time()
    for step, batch in enumerate(make_batches(toks, tc)):
        params, opt_state, loss = train_step(
            params, opt_state, jnp.asarray(batch), step, cfg, tc)
        hist.append(float(loss))
        if verbose and (step % tc.log_every == 0 or step == tc.steps - 1):
            print(f"[train {cfg.name}] step {step:4d} "
                  f"loss {float(loss):.4f} ({time.time() - t0:.0f}s)")
    return params, hist


def eval_perplexity(params, cfg: ModelConfig, tokens: np.ndarray,
                    seq: int = 64, max_windows: int = 64) -> float:
    """Perplexity over non-overlapping windows of the eval stream."""
    n = (len(tokens) - 1) // seq
    n = min(n, max_windows)
    tok = np.stack([tokens[i * seq:i * seq + seq + 1] for i in range(n)])
    nll = float(loss_fn(params, jnp.asarray(tok, jnp.int32), cfg))
    return float(np.exp(nll))


def save_params(params, path: Path):
    path.parent.mkdir(parents=True, exist_ok=True)
    host = jax.tree_util.tree_map(np.asarray, params)
    with open(path, "wb") as f:
        pickle.dump(host, f)


def load_params(path: Path):
    with open(path, "rb") as f:
        host = pickle.load(f)
    return jax.tree_util.tree_map(jnp.asarray, host)


MODEL_ZOO = {
    "tiny-gelu": ModelConfig(name="tiny-gelu", act="gelu"),
    "tiny-relu": ModelConfig(name="tiny-relu", act="relu"),
    "tiny-silu": ModelConfig(name="tiny-silu", act="silu"),
}


def get_or_train(name: str, cache_dir: Path, tc: TrainConfig | None = None,
                 verbose: bool = True):
    """Load cached weights or train + cache. Returns (cfg, params)."""
    cfg = MODEL_ZOO[name]
    tc = tc or TrainConfig()
    path = cache_dir / f"{name}.pkl"
    if path.exists():
        return cfg, load_params(path)
    params, hist = train(cfg, tc, verbose=verbose)
    save_params(params, path)
    (cache_dir / f"{name}.loss.txt").write_text(
        "\n".join(f"{i} {v:.5f}" for i, v in enumerate(hist)))
    return cfg, params


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="tiny-gelu", choices=MODEL_ZOO)
    ap.add_argument("--cache", default="../artifacts/weights")
    ap.add_argument("--steps", type=int, default=TrainConfig.steps)
    args = ap.parse_args()
    cfg, params = get_or_train(args.model, Path(args.cache),
                               TrainConfig(steps=args.steps))
    _, ev = corpus.train_eval_split("wiki-syn")
    print("eval ppl:", eval_perplexity(params, cfg, np.asarray(ev)))
