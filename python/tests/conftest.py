"""Shared fixtures: a small trained model is expensive, so tests that need
real weights reuse the artifacts/weights cache when present and otherwise
fall back to a random-init model (distributional tests only need shapes)."""

import sys
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from compile.model import ModelConfig, init_params  # noqa: E402

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


@pytest.fixture(scope="session")
def tiny_cfg():
    return ModelConfig(name="tiny-gelu", act="gelu")


@pytest.fixture(scope="session")
def random_params(tiny_cfg):
    return init_params(tiny_cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def trained(tiny_cfg):
    """(cfg, params) with trained weights if cached, else random."""
    from compile.train import load_params
    path = ARTIFACTS / "weights" / "tiny-gelu.pkl"
    if path.exists():
        return tiny_cfg, load_params(path)
    return tiny_cfg, init_params(tiny_cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def calib_stats(trained):
    from compile.tardis import calibration
    cfg, params = trained
    return calibration.collect(params, cfg, dataset="c4-syn", n_samples=4,
                               max_tokens=1024)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
