"""Calibration capture + the skewness statistics behind Table 1 / Fig 5."""

import numpy as np

from compile.tardis import calibration


def test_collect_shapes(trained, calib_stats):
    cfg, params = trained
    s = calib_stats
    assert len(s.z) == cfg.n_layers
    assert len(s.ffn_in) == cfg.n_layers
    for z, xin, act in zip(s.z, s.ffn_in, s.act_out):
        assert z.shape == (s.n_tokens, cfg.d_ff)
        assert xin.shape == (s.n_tokens, cfg.d_model)
        assert act.shape == z.shape
        assert np.isfinite(z).all()


def test_act_out_is_activation_of_z(trained, calib_stats):
    import jax.numpy as jnp
    from compile.kernels.ref import activation
    cfg, _ = trained
    sigma = activation(cfg.act)
    for z, act in zip(calib_stats.z, calib_stats.act_out):
        np.testing.assert_allclose(np.asarray(sigma(jnp.asarray(z[:32]))),
                                   act[:32], rtol=1e-5, atol=1e-5)


def test_hot_range_fraction_uniform_vs_skewed():
    rng = np.random.default_rng(0)
    uniform = rng.uniform(-1, 1, (2000, 4))
    skewed = rng.standard_t(2, (2000, 4))  # heavy tails, tight core
    f_u = calibration.hot_range_fraction(uniform, 0.65)
    f_s = calibration.hot_range_fraction(skewed, 0.65)
    # uniform: 65% of mass needs ~65% of the range; skewed: much less
    assert np.all(f_u > 0.55)
    assert np.all(f_s < 0.35)


def test_hot_range_fraction_on_real_activations(trained, calib_stats):
    """Insight 1 (Table 1): trained-FFN activation inputs are skewed —
    65% of inputs occupy well under half the observed range."""
    fracs = [calibration.hot_range_fraction(z, 0.65).mean()
             for z in calib_stats.z]
    assert all(f < 0.5 for f in fracs), fracs


def test_hot_range_fraction_edge_cases():
    ones = np.ones((100, 3))
    f = calibration.hot_range_fraction(ones, 0.65)
    assert np.all(f <= 1.0)
    tiny = np.random.default_rng(1).normal(0, 1, (3, 2))
    f2 = calibration.hot_range_fraction(tiny, 0.99)
    assert np.all((f2 >= 0) & (f2 <= 1.0 + 1e-9))
