"""Corpus generators, task generators, evaluation suite, and baselines."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import corpus, evalsuite
from compile.baselines import METHODS, prune_magnitude, prune_ria, prune_wanda

SETTINGS = dict(max_examples=10, deadline=None)


# ---------------------------------------------------------------------------
# corpus
# ---------------------------------------------------------------------------

def test_corpora_deterministic():
    a = corpus.token_stream("wiki-syn", seed=3, n_sentences=50)
    b = corpus.token_stream("wiki-syn", seed=3, n_sentences=50)
    assert a == b


def test_corpora_differ_across_datasets_and_seeds():
    a = corpus.token_stream("wiki-syn", seed=0, n_sentences=50)
    b = corpus.token_stream("c4-syn", seed=0, n_sentences=50)
    c = corpus.token_stream("wiki-syn", seed=1, n_sentences=50)
    assert a != b and a != c


def test_tokens_are_bytes():
    toks = corpus.token_stream("ptb-syn", n_sentences=20)
    assert all(0 <= t < 256 for t in toks)
    assert corpus.encode(corpus.decode(toks)) == toks


def test_unknown_dataset_raises():
    with pytest.raises(ValueError):
        corpus.generate_text(corpus.CorpusConfig(dataset="nope"))


def test_split_disjoint_and_ordered():
    tr, ev = corpus.train_eval_split("wiki-syn", n_sentences=200)
    assert len(tr) > len(ev) > 0
    whole = corpus.token_stream("wiki-syn", n_sentences=200)
    assert tr + ev == whole


@settings(**SETTINGS)
@given(task=st.sampled_from(sorted(corpus.TASKS)),
       seed=st.integers(0, 1000))
def test_task_items_well_formed(task, seed):
    items = corpus.TASKS[task](8, seed=seed)
    assert len(items) == 8
    for it in items:
        assert len(it.choices) == 2
        assert 0 <= it.answer < 2
        assert it.choices[0] != it.choices[1]
        assert len(it.context) > 0


def test_recall_items_contain_the_answer_in_context():
    for it in corpus.make_recall_items(16, seed=1):
        answer_word = it.choices[it.answer].strip(" .")
        assert answer_word in it.context


# ---------------------------------------------------------------------------
# evalsuite
# ---------------------------------------------------------------------------

def test_perplexity_of_trained_model_beats_uniform(trained):
    cfg, params = trained
    ppl = evalsuite.perplexity(params, cfg, dataset="wiki-syn",
                               max_windows=8)
    assert ppl < 256  # uniform byte model has ppl 256
    assert ppl > 1.0


def test_perplexity_worse_on_shifted_distribution(trained):
    cfg, params = trained
    ppl_in = evalsuite.perplexity(params, cfg, dataset="wiki-syn",
                                  max_windows=8)
    ppl_out = evalsuite.perplexity(params, cfg, dataset="ptb-syn",
                                   max_windows=8)
    assert ppl_out > ppl_in  # trained on wiki-syn


def test_zero_shot_accuracy_above_chance(trained):
    cfg, params = trained
    acc = evalsuite.zero_shot_accuracy(params, cfg, task="agree-syn",
                                       n_items=32)
    assert acc >= 0.6, acc  # binary task; chance = 0.5


# ---------------------------------------------------------------------------
# pruning baselines
# ---------------------------------------------------------------------------

def _sparsity(w):
    w = np.asarray(w)
    return float((w == 0).mean())


@settings(**SETTINGS)
@given(ratio=st.sampled_from([0.25, 0.5, 0.8]),
       method=st.sampled_from(sorted(METHODS)))
def test_pruning_hits_target_sparsity(trained, calib_stats, ratio, method):
    cfg, params = trained
    pruned = METHODS[method](params, calib_stats, ratio)
    for lp, orig in zip(pruned["layers"], params["layers"]):
        s1 = _sparsity(lp["w1"])
        assert abs(s1 - ratio) < 0.05, (method, ratio, s1)
        # attention untouched (paper compresses FFN only)
        np.testing.assert_array_equal(lp["wq"], orig["wq"])


def test_wanda_keeps_high_scoring_weights(trained, calib_stats):
    cfg, params = trained
    pruned = prune_wanda(params, calib_stats, 0.5)
    w_orig = np.asarray(params["layers"][0]["w1"])
    w_new = np.asarray(pruned["layers"][0]["w1"])
    norms = np.linalg.norm(calib_stats.ffn_in[0], axis=0)
    score = np.abs(w_orig) * norms[:, None]
    # per column, the kept set must be the top-scoring half (up to ties)
    col = 7
    kept = w_new[:, col] != 0
    thresh = np.median(score[:, col])
    assert score[kept, col].min() >= thresh * 0.99


def test_pruned_model_quality_degrades_monotonically(trained, calib_stats):
    cfg, params = trained
    ppls = []
    for ratio in (0.0, 0.5, 0.8):
        p = prune_wanda(params, calib_stats, ratio) if ratio else params
        ppls.append(evalsuite.perplexity(p, cfg, dataset="wiki-syn",
                                         max_windows=6))
    assert ppls[0] <= ppls[1] <= ppls[2], ppls


def test_magnitude_ignores_stats(trained, calib_stats):
    cfg, params = trained
    a = prune_magnitude(params, calib_stats, 0.5)
    b = prune_magnitude(params, None, 0.5)
    np.testing.assert_array_equal(a["layers"][0]["w1"], b["layers"][0]["w1"])


def test_ria_differs_from_wanda(trained, calib_stats):
    cfg, params = trained
    w = prune_wanda(params, calib_stats, 0.5)
    r = prune_ria(params, calib_stats, 0.5)
    assert not np.array_equal(np.asarray(w["layers"][0]["w1"]),
                              np.asarray(r["layers"][0]["w1"]))
