"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes/dtypes; every kernel must match its oracle to
float32 tolerance across the sweep (interpret=True lowers to the same HLO
the rust runtime executes, so this is also the runtime's numerics gate).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (fix_gather, folded_ffn, predictor_scores,
                             select_topk)
from compile.kernels import ref
from compile.kernels.folded_ffn import (mxu_utilization_estimate,
                                        vmem_footprint_bytes)

SETTINGS = dict(max_examples=12, deadline=None)


def _arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# folded_ffn
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 3, 8, 16]),
    d=st.sampled_from([8, 32, 128]),
    n=st.sampled_from([8, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_folded_ffn_matches_ref(m, d, n, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, m, d)
    c = _arr(rng, d, n, scale=0.1)
    b = _arr(rng, n)
    out = folded_ffn(x, c, b)
    np.testing.assert_allclose(out, ref.folded_ffn_ref(x, c, b),
                               rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    bm=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_folded_ffn_blocking_invariant(bm, bk, seed):
    """Different tilings must not change the numerics."""
    rng = np.random.default_rng(seed)
    x = _arr(rng, 16, 128)
    c = _arr(rng, 128, 128, scale=0.1)
    b = _arr(rng, 128)
    base = folded_ffn(x, c, b)
    tiled = folded_ffn(x, c, b, bm=bm, bk=bk, bn=64)
    np.testing.assert_allclose(base, tiled, rtol=2e-5, atol=2e-5)


def test_vmem_and_mxu_estimators():
    # 128-aligned tiles fill the MXU completely
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    # small tiles waste lanes proportionally
    assert abs(mxu_utilization_estimate(8, 128, 128) - 8 / 128) < 1e-9
    fp = vmem_footprint_bytes(128, 128, 128)
    assert fp == (128 * 128 + 128 * 128 + 128) * 4 + 128 * 128 * 4
    assert fp < 16 * 2**20, "tile set must fit VMEM"


# ---------------------------------------------------------------------------
# predictor
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 4, 8]),
    d=st.sampled_from([32, 64, 128]),
    h=st.sampled_from([64, 256]),
    g=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_predictor_matches_ref(m, d, h, g, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, m, d)
    codes = jnp.asarray(rng.integers(-127, 128, (d, h)), jnp.int8)
    scales = jnp.asarray(np.abs(rng.standard_normal((d // g, h))) * 0.01,
                         jnp.float32)
    b1 = _arr(rng, h, scale=0.1)
    lo = -jnp.abs(_arr(rng, h))
    hi = jnp.abs(_arr(rng, h))
    out = predictor_scores(x, codes, scales, b1, lo, hi, group_size=g)
    _, want = ref.predictor_ref(x, codes, scales, b1, lo, hi, g)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_predictor_score_semantics(rng):
    """score == 0 iff z_hat inside [lo, hi)."""
    d, h, g = 32, 64, 16
    x = _arr(rng, 4, d)
    codes = jnp.asarray(rng.integers(-127, 128, (d, h)), jnp.int8)
    scales = jnp.asarray(np.abs(rng.standard_normal((d // g, h))) * 0.01,
                         jnp.float32)
    b1 = jnp.zeros((h,), jnp.float32)
    lo = jnp.full((h,), -1e9, jnp.float32)
    hi = jnp.full((h,), 1e9, jnp.float32)
    score = predictor_scores(x, codes, scales, b1, lo, hi, group_size=g)
    assert float(jnp.max(score)) == 0.0  # everything in the huge range


# ---------------------------------------------------------------------------
# fix_gather + select_topk
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    act=st.sampled_from(["gelu", "relu", "silu"]),
    k=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fix_gather_matches_ref(act, k, seed):
    rng = np.random.default_rng(seed)
    B, d, h = 4, 32, 128
    x = _arr(rng, B, d)
    w1 = _arr(rng, d, h, scale=0.2)
    w2 = _arr(rng, h, d, scale=0.2)
    b1 = _arr(rng, h, scale=0.1)
    a = _arr(rng, h, scale=0.3)
    b = _arr(rng, h, scale=0.1)
    score = jnp.abs(_arr(rng, B, h)) * jnp.asarray(
        rng.random((B, h)) < 0.2, jnp.float32)
    idx, valid = select_topk(score, k)
    out = fix_gather(x, idx, valid, w1, b1, w2, a, b, act=act)
    want = ref.fix_gather_ref(x, idx, valid, w1, b1, w2, a, b, act)
    np.testing.assert_allclose(out, want, rtol=3e-4, atol=3e-4)


def test_select_topk_picks_largest(rng):
    score = jnp.asarray([[0.0, 5.0, 1.0, 0.0, 3.0]], jnp.float32)
    idx, valid = select_topk(score, 3)
    assert set(np.asarray(idx[0]).tolist()) == {1, 4, 2}
    assert valid.tolist() == [[1.0, 1.0, 1.0]]


def test_select_topk_masks_padding(rng):
    score = jnp.asarray([[0.0, 2.0, 0.0, 0.0]], jnp.float32)
    idx, valid = select_topk(score, 3)
    assert int(idx[0, 0]) == 1
    # only one real out-of-range neuron; the rest are padding
    assert valid[0].tolist() == [1.0, 0.0, 0.0]


def test_fix_gather_zero_valid_is_noop(rng):
    B, d, h, k = 2, 16, 32, 4
    x = _arr(rng, B, d)
    out = fix_gather(
        x, jnp.zeros((B, k), jnp.int32), jnp.zeros((B, k), jnp.float32),
        _arr(rng, d, h), _arr(rng, h), _arr(rng, h, d),
        _arr(rng, h), _arr(rng, h), act="gelu")
    np.testing.assert_allclose(out, np.zeros((B, d)), atol=1e-7)


# ---------------------------------------------------------------------------
# TARDIS FFN semantics: folded + exact fixing == dense when every neuron
# is fixed; == pure linear when none are.
# ---------------------------------------------------------------------------

def test_tardis_exact_full_fix_equals_dense(rng):
    from compile.tardis import folding
    B, d, h = 4, 32, 128
    x = _arr(rng, B, d)
    w1, b1 = _arr(rng, d, h, scale=0.2), _arr(rng, h, scale=0.1)
    w2, b2 = _arr(rng, h, d, scale=0.2), _arr(rng, d, scale=0.1)
    a, b = _arr(rng, h, scale=0.3), _arr(rng, h, scale=0.1)
    c, bias = folding.fold(np.asarray(w1), np.asarray(b1), np.asarray(w2),
                           np.asarray(b2), np.asarray(a), np.asarray(b))
    # empty hot range => every neuron out-of-range => exact fixing
    lo = jnp.full((h,), 1e9, jnp.float32)
    hi = jnp.full((h,), 1e9, jnp.float32)
    got = ref.tardis_ffn_exact_ref(x, jnp.asarray(c), jnp.asarray(bias),
                                   w1, b1, w2, a, b, lo, hi, "gelu")
    want = ref.dense_ffn_ref(x, w1, b1, w2, b2, "gelu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_tardis_exact_no_fix_is_pure_linear(rng):
    from compile.tardis import folding
    B, d, h = 4, 32, 128
    x = _arr(rng, B, d)
    w1, b1 = _arr(rng, d, h, scale=0.2), _arr(rng, h, scale=0.1)
    w2, b2 = _arr(rng, h, d, scale=0.2), _arr(rng, d, scale=0.1)
    a, b = _arr(rng, h, scale=0.3), _arr(rng, h, scale=0.1)
    c, bias = folding.fold(np.asarray(w1), np.asarray(b1), np.asarray(w2),
                           np.asarray(b2), np.asarray(a), np.asarray(b))
    lo = jnp.full((h,), -1e9, jnp.float32)
    hi = jnp.full((h,), 1e9, jnp.float32)
    got = ref.tardis_ffn_exact_ref(x, jnp.asarray(c), jnp.asarray(bias),
                                   w1, b1, w2, a, b, lo, hi, "gelu")
    want = x @ jnp.asarray(c) + jnp.asarray(bias)[None, :]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
