"""L2 model invariants: causality, cache consistency, FFN-mode agreement,
parameter flattening contract (the AOT interface rust depends on)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (DENSE_LAYER_KEYS, ModelConfig, TOP_KEYS,
                           decode_step, empty_kv, flatten_params, forward,
                           init_params, loss_fn, param_names, prefill_step,
                           unflatten_params)

SETTINGS = dict(max_examples=6, deadline=None)


@pytest.fixture(scope="module")
def small():
    cfg = ModelConfig(name="t", d_model=32, n_layers=2, n_heads=2, d_ff=128,
                      max_seq=32, vocab=64)
    return cfg, init_params(cfg, jax.random.PRNGKey(1))


def test_forward_shapes(small):
    cfg, params = small
    toks = jnp.zeros((3, 10), jnp.int32)
    assert forward(params, toks, cfg).shape == (3, 10, cfg.vocab)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_causality(small, seed):
    """Changing token t must not change logits before t."""
    cfg, params = small
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
    base = forward(params, toks, cfg)
    poked = forward(params, toks.at[0, 7].set(0), cfg)
    np.testing.assert_allclose(base[0, :7], poked[0, :7], atol=1e-5)
    assert not np.allclose(base[0, 7:], poked[0, 7:], atol=1e-5)


def test_prefill_matches_forward(small):
    cfg, params = small
    rng = np.random.default_rng(0)
    seq = jnp.asarray(rng.integers(0, cfg.vocab, 9), jnp.int32)
    kv = empty_kv(cfg, 4)
    logits, kv = prefill_step(params, seq, kv, 2, 0, cfg)
    full = forward(params, seq[None], cfg)[0]
    np.testing.assert_allclose(logits[len(seq) - 1], full[-1], atol=1e-3)


def test_decode_matches_forward_token_by_token(small):
    cfg, params = small
    rng = np.random.default_rng(2)
    seq = np.asarray(rng.integers(0, cfg.vocab, 6), np.int32)
    kv = empty_kv(cfg, 2)
    # prefill first 3 tokens into slot 1
    _, kv = prefill_step(params, jnp.asarray(seq[:3]), kv, 1, 0, cfg)
    # feed the rest through decode
    for i in range(3, 6):
        tokens = jnp.zeros((2,), jnp.int32).at[1].set(int(seq[i]))
        pos = jnp.full((2,), cfg.max_seq, jnp.int32).at[1].set(i)
        logits, kv = decode_step(params, tokens, pos, kv, cfg)
    full = forward(params, jnp.asarray(seq)[None], cfg)[0]
    np.testing.assert_allclose(logits[1], full[-1], atol=1e-3)


def test_decode_slots_are_isolated(small):
    """Activity in slot 0 must not change slot 1's logits."""
    cfg, params = small
    rng = np.random.default_rng(3)
    seq = jnp.asarray(rng.integers(0, cfg.vocab, 4), jnp.int32)
    kv_a = empty_kv(cfg, 2)
    _, kv_a = prefill_step(params, seq, kv_a, 1, 0, cfg)
    kv_b = empty_kv(cfg, 2)
    _, kv_b = prefill_step(params, seq, kv_b, 1, 0, cfg)
    # slot 0 busy in run B only
    other = jnp.asarray(rng.integers(0, cfg.vocab, 4), jnp.int32)
    _, kv_b = prefill_step(params, other, kv_b, 0, 0, cfg)
    tok = jnp.asarray([5, 7], jnp.int32)
    pos_a = jnp.asarray([cfg.max_seq, 4], jnp.int32)
    pos_b = jnp.asarray([4, 4], jnp.int32)
    la, _ = decode_step(params, tok, pos_a, kv_a, cfg)
    lb, _ = decode_step(params, tok, pos_b, kv_b, cfg)
    np.testing.assert_allclose(la[1], lb[1], atol=1e-4)


def test_padded_prefill_rows_do_not_corrupt(small):
    """Pad tokens beyond the real chunk must not affect the real rows or
    subsequent decodes (the rust scheduler pads chunks to buckets)."""
    cfg, params = small
    rng = np.random.default_rng(4)
    seq = jnp.asarray(rng.integers(0, cfg.vocab, 5), jnp.int32)
    kv1 = empty_kv(cfg, 1)
    l1, kv1 = prefill_step(params, seq, kv1, 0, 0, cfg)
    # same prompt padded to 12 with zeros
    padded = jnp.concatenate([seq, jnp.zeros((7,), jnp.int32)])
    kv2 = empty_kv(cfg, 1)
    l2, kv2 = prefill_step(params, padded, kv2, 0, 0, cfg)
    np.testing.assert_allclose(l1[4], l2[4], atol=1e-4)
    # next decode at pos 5 must agree (overwrites the garbage K/V at 5)
    tok = jnp.asarray([3], jnp.int32)
    pos = jnp.asarray([5], jnp.int32)
    d1, _ = decode_step(params, tok, pos, kv1, cfg)
    d2, _ = decode_step(params, tok, pos, kv2, cfg)
    np.testing.assert_allclose(d1[0], d2[0], atol=1e-4)


def test_loss_decreases_on_training_signal(small):
    cfg, params = small
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 17)), jnp.int32)
    l0 = loss_fn(params, toks, cfg)
    g = jax.grad(loss_fn)(params, toks, cfg)
    params2 = jax.tree_util.tree_map(lambda p, gi: p - 0.5 * gi, params, g)
    l1 = loss_fn(params2, toks, cfg)
    assert float(l1) < float(l0)


def test_param_flattening_roundtrip(small):
    cfg, params = small
    names = param_names(params)
    flat = flatten_params(params)
    assert len(names) == len(flat) == len(TOP_KEYS) + \
        cfg.n_layers * len(DENSE_LAYER_KEYS)
    back = unflatten_params(names, flat, cfg.n_layers)
    for k in TOP_KEYS:
        np.testing.assert_array_equal(params[k], back[k])
    for lp, bp in zip(params["layers"], back["layers"]):
        for k in DENSE_LAYER_KEYS:
            np.testing.assert_array_equal(lp[k], bp[k])


def test_tardis_mode_requires_tardis_params(small):
    cfg, params = small
    toks = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(KeyError):
        forward(params, toks, cfg.with_mode("tardis_exact"))


def test_unknown_ffn_mode_raises(small):
    cfg, params = small
    toks = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError):
        forward(params, toks, cfg.with_mode("bogus"))


def test_tardis_topk_close_to_exact(trained, calib_stats):
    """The capacity-K kernel path must track the exact-fix semantics."""
    from compile.tardis import pipeline
    cfg, params = trained
    fp, rep = pipeline.fold_model(params, cfg, target_t=0.9,
                                  stats=calib_stats)
    K = pipeline.fix_capacity_for(cfg, rep.mean_oor_rate, safety=3.0)
    rng = np.random.default_rng(6)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 16)), jnp.int32)
    exact = forward(fp, toks, cfg.with_mode("tardis_exact"))
    topk = forward(fp, toks, cfg.with_mode("tardis", fix_capacity=K))
    # same argmax on most positions (predictor noise allows a few flips)
    agree = np.mean(np.argmax(exact[0], -1) == np.argmax(topk[0], -1))
    assert agree >= 0.75, agree
