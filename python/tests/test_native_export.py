"""Native-convention manifest export: determinism, structure, and
consistency of the per-neuron ranges + quantized proxy the rust backend
round-trips (see rust/tests/manifest_roundtrip.rs for the rust side)."""

import json
from pathlib import Path

import numpy as np
import pytest

from compile.native_export import NativeExportConfig, export

REPO = Path(__file__).resolve().parents[2]
FIXTURE = REPO / "rust" / "tests" / "data" / "native_manifest"


@pytest.fixture(scope="module")
def small_cfg():
    return NativeExportConfig(calib_tokens=256)


@pytest.fixture(scope="module")
def exported(tmp_path_factory, small_cfg):
    out = tmp_path_factory.mktemp("native_export")
    manifest = export(out, small_cfg, verbose=False)
    return out, manifest


def _param(manifest, variant, name):
    v = next(v for v in manifest["variants"] if v["name"] == variant)
    return next(p for p in v["params"] if p["name"] == name)


def _read(out_dir, manifest, variant, name):
    p = _param(manifest, variant, name)
    v = next(v for v in manifest["variants"] if v["name"] == variant)
    blob = (out_dir / v["weights_file"]).read_bytes()
    dt = {"f32": np.float32, "i8": np.int8}[p["dtype"]]
    n = p["nbytes"] // np.dtype(dt).itemsize
    return np.frombuffer(blob, dt, count=n,
                         offset=p["offset"]).reshape(p["shape"])


def test_export_is_deterministic(tmp_path, small_cfg):
    a = tmp_path / "a"
    b = tmp_path / "b"
    ma = export(a, small_cfg, verbose=False)
    mb = export(b, small_cfg, verbose=False)
    assert ma == mb
    blob = ma["variants"][1]["weights_file"]
    assert (a / blob).read_bytes() == (b / blob).read_bytes()
    assert (a / "manifest.json").read_bytes() == \
        (b / "manifest.json").read_bytes()


def test_manifest_structure(exported, small_cfg):
    out, m = exported
    assert [v["name"] for v in m["variants"]] == ["dense", "tardis80"]
    t = m["variants"][1]
    assert t["predictor"] == "quantized"
    assert t["predictor_bits"] == small_cfg.bits
    assert t["predictor_group"] == small_cfg.group
    assert t["top_k"] == small_cfg.top_k
    assert 0.0 < t["compression_ratio"] < 1.0
    # offsets are contiguous and sized by dtype * shape
    off = 0
    for p in t["params"]:
        assert p["offset"] == off
        elems = int(np.prod(p["shape"]))
        assert elems * {"f32": 4, "i32": 4, "i8": 1}[p["dtype"]] \
            == p["nbytes"]
        off += p["nbytes"]
    blob = out / t["weights_file"]
    assert blob.stat().st_size == off
    # dense variant shares the blob but declares no fold keys
    d = m["variants"][0]
    assert d["weights_file"] == t["weights_file"]
    assert "fold_ratio" not in d


def test_per_neuron_ranges_are_calibrated(exported, small_cfg):
    out, m = exported
    h = small_cfg.d_ff
    for li in range(small_cfg.n_layers):
        lo = _read(out, m, "tardis80", f"layers.{li}.tardis.lo")
        hi = _read(out, m, "tardis80", f"layers.{li}.tardis.hi")
        a = _read(out, m, "tardis80", f"layers.{li}.tardis.lin_a")
        assert lo.shape == (h,) and hi.shape == (h,)
        assert (lo < hi).all()
        # per-neuron, not uniform: the whole point of the calibration
        assert np.unique(lo).size > h // 2
        assert np.unique(a).size > h // 2
        # ranges really cover ~the target mass of fresh calibration-like
        # activations
        w1 = _read(out, m, "tardis80", f"layers.{li}.w1")
        b1 = _read(out, m, "tardis80", f"layers.{li}.b1")
        rng = np.random.default_rng(7)
        x = rng.normal(0.0, 1.0, (512, small_cfg.d_model)).astype(np.float32)
        z = x @ w1 + b1[None, :]
        cov = ((z >= lo[None, :]) & (z < hi[None, :])).mean()
        assert cov > small_cfg.coverage - 0.1, cov


def test_fold_prefix_is_best_fit_first(exported, small_cfg):
    # After the error-ascending reorder, a fresh error estimate over the
    # exported order should be (weakly) increasing on average: the folded
    # prefix approximates strictly better than the kept tail.
    out, m = exported
    from compile.kernels.ref import activation
    from compile.tardis.ranges import linfit_masked
    w1 = _read(out, m, "tardis80", "layers.0.w1")
    b1 = _read(out, m, "tardis80", "layers.0.b1")
    w2 = _read(out, m, "tardis80", "layers.0.w2")
    lo = _read(out, m, "tardis80", "layers.0.tardis.lo")
    hi = _read(out, m, "tardis80", "layers.0.tardis.hi")
    rng = np.random.default_rng(11)
    x = rng.normal(0.0, 1.0, (512, small_cfg.d_model)).astype(np.float32)
    z = (x @ w1 + b1[None, :]).astype(np.float64)
    y = np.asarray(activation("gelu")(z), np.float64)
    mask = (z >= lo[None, :]) & (z < hi[None, :])
    _, _, sse = linfit_masked(z, y, mask)
    err = sse * (np.linalg.norm(w2, axis=1) ** 2)
    nf = int(round(small_cfg.fold_ratio * small_cfg.d_ff))
    assert err[:nf].mean() < err[nf:].mean()


def test_quantized_proxy_consistency(exported, small_cfg):
    out, m = exported
    qmax = 2 ** (small_cfg.bits - 1) - 1
    for li in range(small_cfg.n_layers):
        codes = _read(out, m, "tardis80", f"layers.{li}.tardis.pred_codes")
        scales = _read(out, m, "tardis80", f"layers.{li}.tardis.pred_scales")
        w1 = _read(out, m, "tardis80", f"layers.{li}.w1")
        d, h = w1.shape
        assert codes.shape == (d, h)
        assert scales.shape == (d // small_cfg.group, h)
        assert codes.min() >= -qmax and codes.max() <= qmax
        deq = codes.astype(np.float32) * np.repeat(
            scales, small_cfg.group, axis=0)
        # reconstruction error bounded by half a step per element
        step = np.repeat(scales, small_cfg.group, axis=0)
        assert (np.abs(deq - w1) <= 0.5 * step + 1e-7).all()


def test_committed_fixture_is_loadable():
    # The golden fixture rust round-trips must stay parseable and
    # structurally sound (bytes are asserted in rust against the blob).
    assert FIXTURE.exists(), "golden fixture missing"
    m = json.loads((FIXTURE / "manifest.json").read_text())
    t = next(v for v in m["variants"] if v["name"] == "tardis80")
    assert t["predictor"] == "quantized"
    blob = FIXTURE / t["weights_file"]
    total = sum(p["nbytes"] for p in t["params"])
    assert blob.stat().st_size == total
    lo = _read(FIXTURE, m, "tardis80", "layers.0.tardis.lo")
    hi = _read(FIXTURE, m, "tardis80", "layers.0.tardis.hi")
    assert (lo < hi).all()
