"""Offline pipeline components: thresholds, KDE, ranges, folding, predictor,
and the end-to-end fold_model contract."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import ModelConfig
from compile.tardis import (calibration, folding, kde, pipeline, predictor,
                            ranges, thresholds)

SETTINGS = dict(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# thresholds
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(2, 64),
    target=st.floats(0.55, 0.98),
    seed=st.integers(0, 2**31 - 1),
)
def test_thresholds_mean_and_monotonicity(n, target, seed):
    rng = np.random.default_rng(seed)
    errors = np.abs(rng.standard_normal(n)) * 10.0 ** rng.integers(-8, 0)
    t = thresholds.error_aware_thresholds(errors, target)
    assert abs(t.mean() - target) < 1e-6, "sum constraint violated"
    assert (t >= 0.5 - 1e-9).all() and (t <= 0.995 + 1e-9).all()
    # monotone: larger error -> no larger threshold
    order = np.argsort(errors)
    assert (np.diff(t[order]) <= 1e-9).all()


def test_thresholds_uniform_when_equal_errors():
    t = thresholds.error_aware_thresholds(np.ones(8), 0.85)
    # rank-based: ties get spread, but the mean must hold exactly
    assert abs(t.mean() - 0.85) < 1e-9


def test_thresholds_single_component():
    t = thresholds.error_aware_thresholds(np.array([3.0]), 0.9)
    assert t.shape == (1,) and abs(t[0] - 0.9) < 1e-9


# ---------------------------------------------------------------------------
# KDE
# ---------------------------------------------------------------------------

def test_kde_finds_the_mode():
    rng = np.random.default_rng(1)
    # bimodal with the heavy mode at +2
    z = np.concatenate([
        rng.normal(2.0, 0.2, (800, 3)),
        rng.normal(-1.0, 0.2, (200, 3)),
    ])
    c = kde.find_centroids(z)
    assert np.all(np.abs(c - 2.0) < 0.4), c


def test_kde_density_positive_and_normalized_ish():
    rng = np.random.default_rng(2)
    z = rng.normal(0, 1, (500, 4))
    grid, dens = kde.kde_grid(z, grid_points=64)
    assert (dens >= 0).all()
    # trapezoid-ish integral over the grid span should be close to 1
    dx = grid[1] - grid[0]              # per-neuron grid step [4]
    mass = (dens[:-1] * dx[None, :]).sum(axis=0)
    assert np.all((mass > 0.7) & (mass < 1.1)), mass


# ---------------------------------------------------------------------------
# ranges
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1),
       t=st.floats(0.6, 0.95))
def test_greedy_search_meets_coverage(seed, t):
    rng = np.random.default_rng(seed)
    z = rng.normal(0, 1, (400, 8))
    spec = ranges.greedy_search(
        z, "gelu", np.full(8, t), kde.find_centroids(z.astype(np.float32)),
        np.ones(8))
    assert (spec.coverage >= t - 0.01).all(), spec.coverage
    assert (spec.lo < spec.hi).all()


def test_linfit_exact_on_linear_data():
    rng = np.random.default_rng(3)
    z = rng.normal(0, 1, (200, 4))
    y = 2.5 * z - 0.7
    a, b, sse = ranges.linfit_masked(z, y, np.ones_like(z, bool))
    assert np.allclose(a, 2.5) and np.allclose(b, -0.7)
    assert np.all(sse < 1e-9)


def test_linfit_handles_empty_mask():
    z = np.zeros((10, 2))
    y = np.zeros((10, 2))
    a, b, sse = ranges.linfit_masked(z, y, np.zeros_like(z, bool))
    assert np.all(np.isfinite(a)) and np.all(np.isfinite(b))
    assert np.all(sse >= 0)


def test_quantile_ranges_cover_requested_mass():
    rng = np.random.default_rng(4)
    z = rng.normal(0, 1, (1000, 6))
    lo, hi = ranges.quantile_ranges(z, np.full(6, 0.8))
    cov = ((z >= lo) & (z < hi)).mean(axis=0)
    assert np.all(cov >= 0.79), cov


def test_relu_ranges_are_cheap():
    """ReLU's negative half-line is exactly linear: a hot range there must
    fit with ~zero error (the OPT-6.7B observation in §7.2)."""
    rng = np.random.default_rng(5)
    z = -np.abs(rng.normal(0, 1, (300, 4)))  # all negative
    spec = ranges.greedy_search(
        z, "relu", np.full(4, 0.9), kde.find_centroids(z.astype(np.float32)),
        np.ones(4))
    assert np.all(spec.err < 1e-8), spec.err
    assert np.allclose(spec.a, 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# folding
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_folding_is_exact_in_f64(seed):
    """x C + B must equal the sequential linear path (Table 7's point)."""
    rng = np.random.default_rng(seed)
    d, h = 16, 64
    w1 = rng.standard_normal((d, h)).astype(np.float32) * 0.2
    b1 = rng.standard_normal(h).astype(np.float32) * 0.1
    w2 = rng.standard_normal((h, d)).astype(np.float32) * 0.2
    b2 = rng.standard_normal(d).astype(np.float32) * 0.1
    a = rng.standard_normal(h).astype(np.float32) * 0.5
    b = rng.standard_normal(h).astype(np.float32) * 0.1
    x = rng.standard_normal((32, d)).astype(np.float32)
    mse = folding.fold_mse(w1, b1, w2, b2, a, b, None, x, "float64")
    assert mse < 1e-10, mse


def test_folding_dtype_error_ordering():
    """Table 6's shape: bf16 fold error >> f32/f64 fold error."""
    rng = np.random.default_rng(7)
    d, h = 32, 128
    w1 = rng.standard_normal((d, h)).astype(np.float32) * 0.2
    b1 = rng.standard_normal(h).astype(np.float32) * 0.1
    w2 = rng.standard_normal((h, d)).astype(np.float32) * 0.2
    b2 = np.zeros(d, np.float32)
    a = rng.standard_normal(h).astype(np.float32) * 0.5
    b = np.zeros(h, np.float32)
    x = rng.standard_normal((64, d)).astype(np.float32)
    mses = {dt: folding.fold_mse(w1, b1, w2, b2, a, b, None, x, dt)
            for dt in ("bfloat16", "float16", "float32", "float64")}
    assert mses["bfloat16"] > mses["float16"] > mses["float64"]
    assert mses["float32"] <= mses["float16"]


def test_theoretical_reduction_matches_paper():
    # h = 4d -> 87.5% (paper §3.1)
    assert abs(folding.theoretical_reduction(128, 512) - 0.875) < 1e-9


def test_glu_blowup_is_large():
    # §9: folding a gated FFN explodes parameters (254x for LLaMA-2-7B)
    assert folding.glu_fold_blowup(4096, 11008) > 50


def test_bf16_cast_roundtrip_error_bounded():
    x = np.float32(1.0 + 2**-9)
    y = folding._to_bf16(np.asarray([x]))[0]
    assert abs(y - x) <= 2**-8  # bf16 has 8 total mantissa bits


# ---------------------------------------------------------------------------
# predictor
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(bits=st.sampled_from([2, 3, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_error_scales_with_bits(bits, seed):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((64, 32)).astype(np.float32)
    q = predictor.quantize(w, bits=bits, group_size=16)
    err = np.abs(q.dequantize() - w).max()
    qmax = 2 ** (bits - 1) - 1
    # symmetric quantization: error bounded by half a step per group
    assert err <= np.abs(w).max() / qmax + 1e-6


def test_more_bits_never_hurt():
    rng = np.random.default_rng(11)
    w = rng.standard_normal((64, 32)).astype(np.float32)
    errs = [np.abs(predictor.quantize(w, bits=b, group_size=16)
                   .dequantize() - w).mean() for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_predictor_size_accounting():
    q = predictor.quantize(np.ones((128, 512), np.float32), bits=2,
                           group_size=32)
    # 2-bit codes + f16 scales, in f32-equivalents
    assert q.size_params_f32 == 128 * 512 * 2 / 32 + (128 // 32) * 512 / 2


def test_predictor_rejects_bad_args():
    w = np.ones((64, 32), np.float32)
    with pytest.raises(ValueError):
        predictor.quantize(w, bits=1)
    with pytest.raises(ValueError):
        predictor.quantize(w, bits=4, group_size=48)


def test_predictor_recall_reasonable(trained, calib_stats):
    """On real weights the 2-bit predictor must catch most true
    out-of-range events (the paper's whole accuracy story rests on it)."""
    cfg, params = trained
    w1 = np.asarray(params["layers"][0]["w1"])
    b1 = np.asarray(params["layers"][0]["b1"])
    z = calib_stats.z[0]
    lo, hi = ranges.quantile_ranges(z, np.full(z.shape[1], 0.85))
    q = predictor.quantize(w1, bits=2, group_size=32)
    stats = predictor.evaluate(q, calib_stats.ffn_in[0][:256], w1, b1,
                               lo.astype(np.float32), hi.astype(np.float32))
    assert stats.recall > 0.55, stats
    assert stats.true_oor_rate < 0.35, stats


# ---------------------------------------------------------------------------
# end-to-end pipeline
# ---------------------------------------------------------------------------

def test_fold_model_contract(trained, calib_stats):
    cfg, params = trained
    fp, rep = pipeline.fold_model(params, cfg, target_t=0.85,
                                  stats=calib_stats)
    assert len(rep.layers) == cfg.n_layers
    assert abs(rep.achieved_coverage - 0.85) < 0.05, rep.achieved_coverage
    for lp in fp["layers"]:
        assert lp["fold_c"].shape == (cfg.d_model, cfg.d_model)
        assert lp["fold_b"].shape == (cfg.d_model,)
        assert lp["pred_codes"].dtype == np.int8
        assert np.all(np.asarray(lp["lo"]) < np.asarray(lp["hi"]))
    assert 0.3 < rep.compression_ratio < 0.95
    assert rep.fold_mse < 1e-6


def test_threshold_for_ratio_inverts_accounting():
    cfg = ModelConfig()
    for ratio in (0.5, 0.7, 0.8):
        t = pipeline.threshold_for_ratio(cfg, ratio, bits=2)
        got = pipeline.compression_ratio(cfg, 1.0 - t, bits=2)
        assert abs(got - ratio) < 0.01, (ratio, t, got)


def test_fix_capacity_scales_with_oor():
    cfg = ModelConfig()
    k_low = pipeline.fix_capacity_for(cfg, 0.01)
    k_high = pipeline.fix_capacity_for(cfg, 0.30)
    assert k_low < k_high <= cfg.d_ff
