//! Coordinator micro-benchmarks: the L3 contribution in isolation (mock
//! model, zero compute) — scheduler iteration rate, batcher assembly,
//! sampler throughput, slot allocator churn, queue admission, JSON
//! protocol parse/render. These bound the coordinator overhead per decode
//! step (it must stay far below the model step time; see EXPERIMENTS.md
//! §Perf).
//!
//! Also: a bursty-arrival workload that compares scheduling policies on
//! time-to-first-token and decode occupancy — the seed's single-prefill
//! FIFO baseline vs the StepPlan multi-prefill pipeline (FIFO and
//! shortest-prompt-first). A mock model with a fixed per-call cost makes
//! the numbers wall-clock-meaningful without PJRT artifacts.
//!
//! Run: `cargo bench --bench coordinator`.

use std::time::Duration;

use tardis::bench::{black_box, Bench};
use tardis::coordinator::batcher::Batcher;
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::kv::SlotAllocator;
use tardis::coordinator::model::MockModel;
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::sampler::sample;
use tardis::coordinator::scheduler::{PolicyKind, SchedulerConfig};
use tardis::server::protocol::{parse_request, render_error};
use tardis::util::rng::Rng;
use tardis::util::stats::Samples;

const BURSTS: usize = 4;
const BURST_SIZE: usize = 8;
/// Wall-clock spacing between bursts: arrival times are identical across
/// scheduler configs (pacing by iteration count would hand configs that
/// do more work per iteration a different offered load).
const BURST_GAP: Duration = Duration::from_millis(10);

/// Deterministic mixed-length prompt set: roughly half short prompts
/// (single chunk) and half long multi-chunk prompts — the regime where
/// single-prefill FIFO serializes short prompts behind long ones.
fn bursty_prompts() -> Vec<Vec<i32>> {
    let mut rng = Rng::new(0x7A2D15);
    (0..BURSTS * BURST_SIZE)
        .map(|_| {
            let len = if rng.bool(0.5) {
                4 + rng.usize_below(10)
            } else {
                100 + rng.usize_below(60)
            };
            (0..len).map(|i| 1 + (i % 200) as i32).collect()
        })
        .collect()
}

/// Drive one engine through the bursty arrival schedule; returns
/// (mean TTFT ms, p95 TTFT ms, mean decode occupancy).
fn run_bursty(cfg: EngineConfig) -> (f64, f64, f64) {
    let mut model = MockModel::new(8, 512, 256, vec![16, 64]);
    model.spin_per_call = Duration::from_micros(150);
    let mut ie = InferenceEngine::new(model, cfg);
    let prompts = bursty_prompts();
    let mut next = 0usize;
    let t0 = std::time::Instant::now();
    while next < prompts.len() || !ie.is_idle() {
        // Burst b (all BURST_SIZE requests at once) arrives at t0 + b*gap.
        while next < prompts.len()
            && t0.elapsed() >= BURST_GAP * (next / BURST_SIZE) as u32
        {
            ie.submit(
                prompts[next].clone(),
                SamplingParams { max_tokens: 24, ..Default::default() },
            )
            .unwrap();
            next += 1;
        }
        if ie.is_idle() {
            // Drained before the next burst is due: idle-wait instead of
            // spinning through no-op iterations.
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        ie.step().unwrap();
    }
    let done = ie.take_completions();
    assert_eq!(done.len(), BURSTS * BURST_SIZE);
    let mut ttft = Samples::new();
    for c in &done {
        ttft.push(c.first_token_ms);
    }
    (ttft.mean(), ttft.percentile(95.0), ie.stats.mean_occupancy())
}

fn main() {
    let mut b = Bench::new("coordinator");

    // Full engine loop on a zero-cost model: requests/s through the
    // scheduler with continuous batching (1000 tokens per iteration call).
    b.run("engine_loop/64req_x16tok", || {
        let model = MockModel::new(8, 128, 256, vec![16, 64]);
        let mut ie = InferenceEngine::new(model, EngineConfig {
            queue_capacity: 128,
            ..Default::default()
        });
        for i in 0..64 {
            ie.submit(vec![1 + (i % 200) as i32; 9],
                      SamplingParams { max_tokens: 16, ..Default::default() })
                .unwrap();
        }
        let done = ie.run_to_completion().unwrap();
        assert_eq!(done.len(), 64);
    });

    // Batcher input assembly (hot per decode step).
    let mut batcher = Batcher::new(64, 4096);
    for s in 0..48 {
        batcher.occupy(s, s as u64, s * 3, 7);
    }
    b.run("batcher/decode_inputs_64slots", || {
        let (t, p) = batcher.decode_inputs();
        black_box((t, p));
    });

    // Sampler over a vocab-50k logits row (greedy and temperature).
    let mut rng = Rng::new(7);
    let logits: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
    let greedy = SamplingParams::default();
    b.run("sampler/greedy_50k", || {
        black_box(sample(&logits, &greedy, &mut rng));
    });
    let stochastic = SamplingParams {
        temperature: 0.8,
        top_k: 40,
        ..Default::default()
    };
    b.run("sampler/topk40_t0.8_50k", || {
        black_box(sample(&logits, &stochastic, &mut rng));
    });

    // Slot allocator churn.
    let mut alloc = SlotAllocator::new(64);
    b.run("kv/alloc_release_x64", || {
        let slots: Vec<_> = (0..64).map(|_| alloc.alloc().unwrap()).collect();
        for s in slots {
            alloc.release(s);
        }
    });

    // Wire protocol.
    let line = r#"{"op":"generate","prompt":"the quick brown fox","max_tokens":64,"temperature":0.7,"top_k":40,"variant":"tardis80"}"#;
    b.run("protocol/parse_generate", || {
        black_box(parse_request(line).unwrap());
    });
    b.run("protocol/render_error", || {
        black_box(render_error("queue full (backpressure)"));
    });

    b.report();

    // -- bursty arrivals: scheduling policy comparison ---------------------
    // Not a Bench::run case (each config is one long deterministic run,
    // not a tight loop): the table is the result. The seed baseline is
    // SchedulerConfig::single_prefill() — one prefill job in flight, one
    // chunk per iteration, FIFO admission.
    println!();
    println!(
        "bursty arrivals — {} requests in {} bursts {}ms apart (≈half \
         4-13 tok prompts, half 100-159 tok), 24 generated tokens each, \
         150µs/model-call mock:",
        BURSTS * BURST_SIZE,
        BURSTS,
        BURST_GAP.as_millis()
    );
    let cases: Vec<(&str, EngineConfig)> = vec![
        (
            "seed fifo (1 prefill)",
            EngineConfig {
                scheduler: SchedulerConfig::single_prefill(),
                ..Default::default()
            },
        ),
        ("stepplan fifo (2 prefill)", EngineConfig::default()),
        (
            "stepplan spf (2 prefill)",
            EngineConfig {
                scheduler: SchedulerConfig::with_policy(
                    PolicyKind::ShortestPromptFirst,
                ),
                ..Default::default()
            },
        ),
    ];
    println!("  {:28} {:>14} {:>13} {:>11}",
             "config", "ttft mean ms", "ttft p95 ms", "occupancy");
    let mut rows = Vec::new();
    for (name, cfg) in cases {
        let (mean, p95, occ) = run_bursty(cfg);
        println!("  {name:28} {mean:>14.2} {p95:>13.2} {occ:>11.2}");
        rows.push((name, mean, occ));
    }
    let (_, seed_ttft, seed_occ) = rows[0];
    for (name, mean, occ) in rows.iter().skip(1) {
        println!(
            "  {name}: ttft {:+.1}% occupancy {:+.1}% vs seed baseline",
            (mean / seed_ttft - 1.0) * 100.0,
            (occ / seed_occ - 1.0) * 100.0
        );
    }
}
