//! Coordinator micro-benchmarks: the L3 contribution in isolation (mock
//! model, zero compute) — scheduler iteration rate, batcher assembly,
//! sampler throughput, slot allocator churn, queue admission, JSON
//! protocol parse/render. These bound the coordinator overhead per decode
//! step (it must stay far below the model step time; see EXPERIMENTS.md
//! §Perf).
//!
//! Run: `cargo bench --bench coordinator`.

use tardis::bench::{black_box, Bench};
use tardis::coordinator::batcher::Batcher;
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::kv::SlotAllocator;
use tardis::coordinator::model::MockModel;
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::sampler::sample;
use tardis::server::protocol::{parse_request, render_error};
use tardis::util::rng::Rng;

fn main() {
    let mut b = Bench::new("coordinator");

    // Full engine loop on a zero-cost model: requests/s through the
    // scheduler with continuous batching (1000 tokens per iteration call).
    b.run("engine_loop/64req_x16tok", || {
        let model = MockModel::new(8, 128, 256, vec![16, 64]);
        let mut ie = InferenceEngine::new(model, EngineConfig {
            queue_capacity: 128,
            ..Default::default()
        });
        for i in 0..64 {
            ie.submit(vec![1 + (i % 200) as i32; 9],
                      SamplingParams { max_tokens: 16, ..Default::default() })
                .unwrap();
        }
        let done = ie.run_to_completion().unwrap();
        assert_eq!(done.len(), 64);
    });

    // Batcher input assembly (hot per decode step).
    let mut batcher = Batcher::new(64, 4096);
    for s in 0..48 {
        batcher.occupy(s, s as u64, s * 3, 7);
    }
    b.run("batcher/decode_inputs_64slots", || {
        let (t, p) = batcher.decode_inputs();
        black_box((t, p));
    });

    // Sampler over a vocab-50k logits row (greedy and temperature).
    let mut rng = Rng::new(7);
    let logits: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
    let greedy = SamplingParams::default();
    b.run("sampler/greedy_50k", || {
        black_box(sample(&logits, &greedy, &mut rng));
    });
    let stochastic = SamplingParams {
        temperature: 0.8,
        top_k: 40,
        ..Default::default()
    };
    b.run("sampler/topk40_t0.8_50k", || {
        black_box(sample(&logits, &stochastic, &mut rng));
    });

    // Slot allocator churn.
    let mut alloc = SlotAllocator::new(64);
    b.run("kv/alloc_release_x64", || {
        let slots: Vec<_> = (0..64).map(|_| alloc.alloc().unwrap()).collect();
        for s in slots {
            alloc.release(s);
        }
    });

    // Wire protocol.
    let line = r#"{"op":"generate","prompt":"the quick brown fox","max_tokens":64,"temperature":0.7,"top_k":40,"variant":"tardis80"}"#;
    b.run("protocol/parse_generate", || {
        black_box(parse_request(line).unwrap());
    });
    b.run("protocol/render_error", || {
        black_box(render_error("queue full (backpressure)"));
    });

    b.report();
}
