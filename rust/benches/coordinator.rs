//! Coordinator micro-benchmarks: the L3 contribution in isolation (mock
//! model, zero compute) — scheduler iteration rate, batcher assembly,
//! sampler throughput, block allocator churn, queue admission, JSON
//! protocol parse/render. These bound the coordinator overhead per decode
//! step (it must stay far below the model step time; see EXPERIMENTS.md
//! §Perf).
//!
//! Also: a bursty-arrival workload that compares scheduling planners on
//! time-to-first-token, decode jitter, and occupancy — the seed's
//! single-prefill FIFO baseline and the segregated (prefill-only /
//! decode-only alternating) planner vs the mixed chunked-prefill
//! planner, with and without a `max_step_tokens` budget and under paged
//! block pressure. A mock model with a fixed per-call cost makes the
//! numbers wall-clock-meaningful without PJRT artifacts. The table also
//! lands in `BENCH_native_ffn.json` under `"coordinator"` (merged, so
//! `bench-decode` results are preserved), and
//! `TARDIS_ASSERT_MIXED_TTFT=1` turns the mixed-vs-segregated TTFT win
//! into a hard exit code for CI.
//!
//! And: a shared-prefix workload (one long system prompt, short unique
//! tails — ~86-94% prompt overlap) comparing the radix prefix cache on
//! vs off at the same block-pool size, on TTFT and pool pressure.
//! `TARDIS_ASSERT_PREFIX_TTFT=1` gates the sharing win the same way.
//!
//! Run: `cargo bench --bench coordinator`.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use tardis::bench::{black_box, Bench};
use tardis::coordinator::batcher::Batcher;
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::health::FaultPlan;
use tardis::coordinator::kv::BlockAllocator;
use tardis::coordinator::model::MockModel;
use tardis::coordinator::request::SamplingParams;
use tardis::coordinator::router::{
    FrontDoor, FrontDoorConfig, FrontEnd, ReplicaFactory, SubmitOutcome,
};
use tardis::coordinator::sampler::sample;
use tardis::coordinator::scheduler::{PolicyKind, SchedulerConfig};
use tardis::server::protocol::{parse_request, render_error};
use tardis::util::json::Json;
use tardis::util::rng::Rng;
use tardis::util::stats::Samples;

const BURSTS: usize = 4;
const BURST_SIZE: usize = 8;
/// Wall-clock spacing between bursts: arrival times are identical across
/// scheduler configs (pacing by iteration count would hand configs that
/// do more work per iteration a different offered load).
const BURST_GAP: Duration = Duration::from_millis(10);

/// Deterministic mixed-length prompt set: roughly half short prompts
/// (single chunk) and half long multi-chunk prompts — the regime where
/// prefill-only iterations stall decodes and single-prefill FIFO
/// serializes short prompts behind long ones.
fn bursty_prompts() -> Vec<Vec<i32>> {
    let mut rng = Rng::new(0x7A2D15);
    (0..BURSTS * BURST_SIZE)
        .map(|_| {
            let len = if rng.bool(0.5) {
                4 + rng.usize_below(10)
            } else {
                100 + rng.usize_below(60)
            };
            (0..len).map(|i| 1 + (i % 200) as i32).collect()
        })
        .collect()
}

struct BurstyResult {
    ttft_mean_ms: f64,
    ttft_p95_ms: f64,
    occupancy: f64,
    /// p95 of the wall-clock gap between consecutive decode-bearing
    /// iterations: how long in-flight decodes stall behind prefill work.
    jitter_p95_ms: f64,
    jitter_sd_ms: f64,
    preemptions: u64,
    mixed_ratio: f64,
}

/// Drive one engine through the bursty arrival schedule. `kv` overrides
/// the mock's paged layout (None = degenerate one-block-per-slot).
fn run_bursty(cfg: EngineConfig, kv: Option<(usize, usize)>) -> BurstyResult {
    let mut model = MockModel::new(8, 512, 256, vec![16, 64]);
    if let Some((blocks, block_size)) = kv {
        model = model.with_kv_layout(blocks, block_size);
    }
    model.spin_per_call = Duration::from_micros(150);
    let mut ie = InferenceEngine::new(model, cfg);
    let prompts = bursty_prompts();
    let mut next = 0usize;
    let mut decode_gaps = Samples::new();
    let mut last_decode: Option<std::time::Instant> = None;
    let t0 = std::time::Instant::now();
    while next < prompts.len() || !ie.is_idle() {
        // Burst b (all BURST_SIZE requests at once) arrives at t0 + b*gap.
        while next < prompts.len()
            && t0.elapsed() >= BURST_GAP * (next / BURST_SIZE) as u32
        {
            ie.submit(
                prompts[next].clone(),
                SamplingParams { max_tokens: 24, ..Default::default() },
            )
            .unwrap();
            next += 1;
        }
        if ie.is_idle() {
            // Drained before the next burst is due: idle-wait instead of
            // spinning through no-op iterations.
            std::thread::sleep(Duration::from_micros(100));
            last_decode = None; // an idle gap is not scheduling jitter
            continue;
        }
        let out = ie.step().unwrap();
        if out.decoded_slots > 0 {
            let now = std::time::Instant::now();
            if let Some(prev) = last_decode {
                decode_gaps.push(now.duration_since(prev).as_secs_f64() * 1e3);
            }
            last_decode = Some(now);
        }
    }
    let done = ie.take_completions();
    assert_eq!(done.len(), BURSTS * BURST_SIZE);
    let mut ttft = Samples::new();
    for c in &done {
        ttft.push(c.first_token_ms);
    }
    BurstyResult {
        ttft_mean_ms: ttft.mean(),
        ttft_p95_ms: ttft.percentile(95.0),
        occupancy: ie.stats.mean_occupancy(),
        jitter_p95_ms: decode_gaps.percentile(95.0),
        jitter_sd_ms: decode_gaps.stddev(),
        preemptions: ie.stats.preemptions,
        mixed_ratio: ie.stats.mixed_step_ratio().unwrap_or(0.0),
    }
}

const SHARED_REQUESTS: usize = 32;
/// Tokens every prompt has in common. 90 = 5 full 16-token blocks plus
/// a 10-token partial tail, so hits exercise both the full-block walk
/// and the copy-on-write path (each finished request caches a 6th block
/// whose first 10 tokens are shared).
const SHARED_PREFIX: usize = 90;

/// One long system prompt plus a short unique tail per request: the
/// high-overlap regime (~86-94% of each prompt is shared) that prefix
/// caching targets.
fn shared_prefix_prompts() -> Vec<Vec<i32>> {
    let mut rng = Rng::new(0x51AED);
    let system: Vec<i32> = (0..SHARED_PREFIX).map(|i| 1 + (i % 200) as i32).collect();
    (0..SHARED_REQUESTS)
        .map(|_| {
            let mut p = system.clone();
            let tail = 6 + rng.usize_below(10);
            p.extend((0..tail).map(|_| 1 + rng.below(200) as i32));
            p
        })
        .collect()
}

struct PrefixResult {
    ttft_mean_ms: f64,
    ttft_p95_ms: f64,
    hit_tokens: u64,
    shared_blocks: u64,
    cow_copies: u64,
    evictions: u64,
    preemptions: u64,
    max_blocks_used: usize,
}

/// Drive the shared-prefix arrival schedule (everything queued at once)
/// with the radix cache on or off, over the same 64-block pool.
fn run_shared_prefix(sharing: bool) -> PrefixResult {
    let mut model = MockModel::new(8, 512, 256, vec![16, 64]).with_kv_layout(64, 16);
    model.spin_per_call = Duration::from_micros(150);
    let cfg = EngineConfig { prefix_cache: sharing, ..Default::default() };
    let mut ie = InferenceEngine::new(model, cfg);
    for p in shared_prefix_prompts() {
        ie.submit(p, SamplingParams { max_tokens: 16, ..Default::default() })
            .unwrap();
    }
    let done = ie.run_to_completion().unwrap();
    assert_eq!(done.len(), SHARED_REQUESTS);
    let mut ttft = Samples::new();
    for c in &done {
        ttft.push(c.first_token_ms);
    }
    PrefixResult {
        ttft_mean_ms: ttft.mean(),
        ttft_p95_ms: ttft.percentile(95.0),
        hit_tokens: ie.stats.prefix_hit_tokens,
        shared_blocks: ie.stats.prefix_shared_blocks,
        cow_copies: ie.stats.cow_copies,
        evictions: ie.stats.prefix_evictions,
        preemptions: ie.stats.preemptions,
        max_blocks_used: ie.stats.max_blocks_used,
    }
}

const FRONT_REQUESTS: usize = 48;

struct FrontDoorResult {
    served: usize,
    lost: usize,
    wall_ms: f64,
    throughput_rps: f64,
    shed: u64,
    replays: u64,
    replica_failures: u64,
    replica_restarts: u64,
    journal_appends: u64,
    journal_bytes: u64,
    journal_errors: u64,
}

/// Drive the fault-tolerant front door (2 worker-thread replicas, tight
/// per-replica cap, journal on) through a firehose of requests —
/// optionally killing one replica mid-flight — and account for every
/// admission. `lost` must be 0 in both modes: sheds are re-submitted
/// until admitted, and killed-replica work replays onto the survivor.
fn run_front_door(chaos: bool) -> FrontDoorResult {
    let journal = std::env::temp_dir().join(format!(
        "tardis-bench-front-{}-{}",
        if chaos { "chaos" } else { "clean" },
        std::process::id()
    ));
    let _ = std::fs::remove_file(&journal);
    let factory = || -> ReplicaFactory<MockModel> {
        Box::new(|| {
            let mut m = MockModel::new(8, 512, 256, vec![16, 64]);
            m.spin_per_call = Duration::from_micros(150);
            Ok(InferenceEngine::new(m, EngineConfig::default()))
        })
    };
    let cfg = FrontDoorConfig {
        queue_cap: 8,
        journal: Some(journal.clone()),
        fault_plan: if chaos {
            FaultPlan::parse("kill:1@20").unwrap()
        } else {
            FaultPlan::default()
        },
        probe_base: Duration::from_millis(5),
        ..Default::default()
    };
    let mut front = FrontDoor::new(
        vec![("mock".to_string(), factory()), ("mock".to_string(), factory())],
        cfg,
    )
    .unwrap();
    let mut rng = Rng::new(0xF90D);
    let prompts: Vec<Vec<i32>> = (0..FRONT_REQUESTS)
        .map(|_| {
            let len = 4 + rng.usize_below(40);
            (0..len).map(|i| 1 + (i % 200) as i32).collect()
        })
        .collect();
    let t0 = Instant::now();
    let mut next = 0usize;
    while next < prompts.len() {
        let outcome = front.submit_front(
            None,
            prompts[next].clone(),
            SamplingParams { max_tokens: 16, ..Default::default() },
            false,
        );
        match outcome {
            SubmitOutcome::Admitted { .. } => next += 1,
            SubmitOutcome::Shed { .. } => {
                // Backpressure: make progress, then re-offer.
                front.pump(Duration::from_millis(1)).unwrap();
            }
            SubmitOutcome::Rejected(e) => panic!("unexpected rejection: {e}"),
        }
    }
    let replies = front.drain(Duration::from_secs(60)).unwrap();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let served = replies.iter().filter(|r| r.result.is_ok()).count();
    let snap = front.front_snapshot();
    let _ = std::fs::remove_file(&journal);
    FrontDoorResult {
        served,
        lost: FRONT_REQUESTS - served,
        wall_ms,
        throughput_rps: served as f64 / (wall_ms / 1e3),
        shed: snap.front.shed,
        replays: snap.front.replays,
        replica_failures: snap.front.replica_failures,
        replica_restarts: snap.front.replica_restarts,
        journal_appends: snap.front.journal_appends,
        journal_bytes: snap.front.journal_bytes,
        journal_errors: snap.front.journal_errors,
    }
}

/// Merge the bursty and shared-prefix tables into BENCH_native_ffn.json
/// (or $TARDIS_BENCH_JSON) under the `"coordinator"` key — one write, so
/// neither table clobbers the other — preserving whatever `bench-decode`
/// wrote at the top level.
fn write_bench_json(
    rows: &[(&str, &BurstyResult)],
    prefix: &[(&str, &PrefixResult)],
    fd: &[(&str, &FrontDoorResult)],
) {
    let path = std::env::var("TARDIS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_native_ffn.json".to_string());
    let mut root = match std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
    {
        Some(Json::Obj(map)) => map,
        _ => BTreeMap::new(),
    };
    let mut cases = BTreeMap::new();
    for (name, r) in rows {
        let mut o = BTreeMap::new();
        o.insert("ttft_mean_ms".to_string(), Json::Num(r.ttft_mean_ms));
        o.insert("ttft_p95_ms".to_string(), Json::Num(r.ttft_p95_ms));
        o.insert("occupancy".to_string(), Json::Num(r.occupancy));
        o.insert("decode_jitter_p95_ms".to_string(), Json::Num(r.jitter_p95_ms));
        o.insert("decode_jitter_sd_ms".to_string(), Json::Num(r.jitter_sd_ms));
        o.insert("preemptions".to_string(), Json::Num(r.preemptions as f64));
        o.insert("mixed_step_ratio".to_string(), Json::Num(r.mixed_ratio));
        cases.insert(name.to_string(), Json::Obj(o));
    }
    // Start from the existing coordinator object: `bench-trace` owns the
    // sibling `slo` key and must survive a rerun of this suite.
    let mut coord = match root.get("coordinator") {
        Some(Json::Obj(map)) => map.clone(),
        _ => BTreeMap::new(),
    };
    coord.insert(
        "workload".to_string(),
        Json::Str(format!(
            "{} requests in {BURSTS} bursts {}ms apart, 24 tokens each, \
             150us/model-call mock",
            BURSTS * BURST_SIZE,
            BURST_GAP.as_millis()
        )),
    );
    coord.insert("cases".to_string(), Json::Obj(cases));
    let mut pcases = BTreeMap::new();
    for (name, r) in prefix {
        let mut o = BTreeMap::new();
        o.insert("ttft_mean_ms".to_string(), Json::Num(r.ttft_mean_ms));
        o.insert("ttft_p95_ms".to_string(), Json::Num(r.ttft_p95_ms));
        o.insert("prefix_hit_tokens".to_string(), Json::Num(r.hit_tokens as f64));
        o.insert(
            "prefix_shared_blocks".to_string(),
            Json::Num(r.shared_blocks as f64),
        );
        o.insert("cow_copies".to_string(), Json::Num(r.cow_copies as f64));
        o.insert("prefix_evictions".to_string(), Json::Num(r.evictions as f64));
        o.insert("preemptions".to_string(), Json::Num(r.preemptions as f64));
        o.insert(
            "max_blocks_used".to_string(),
            Json::Num(r.max_blocks_used as f64),
        );
        pcases.insert(name.to_string(), Json::Obj(o));
    }
    let mut pshare = BTreeMap::new();
    pshare.insert(
        "workload".to_string(),
        Json::Str(format!(
            "{SHARED_REQUESTS} requests, {SHARED_PREFIX}-token shared prefix + \
             6-15 token unique tails, 16 tokens each, 64x16 block pool, \
             150us/model-call mock"
        )),
    );
    pshare.insert("cases".to_string(), Json::Obj(pcases));
    coord.insert("prefix_sharing".to_string(), Json::Obj(pshare));
    let mut fcases = BTreeMap::new();
    for (name, r) in fd {
        let mut o = BTreeMap::new();
        o.insert("served".to_string(), Json::Num(r.served as f64));
        o.insert("lost".to_string(), Json::Num(r.lost as f64));
        o.insert("wall_ms".to_string(), Json::Num(r.wall_ms));
        o.insert("throughput_rps".to_string(), Json::Num(r.throughput_rps));
        o.insert("shed".to_string(), Json::Num(r.shed as f64));
        o.insert("replays".to_string(), Json::Num(r.replays as f64));
        o.insert(
            "replica_failures".to_string(),
            Json::Num(r.replica_failures as f64),
        );
        o.insert(
            "replica_restarts".to_string(),
            Json::Num(r.replica_restarts as f64),
        );
        o.insert(
            "journal_appends".to_string(),
            Json::Num(r.journal_appends as f64),
        );
        o.insert("journal_bytes".to_string(), Json::Num(r.journal_bytes as f64));
        o.insert(
            "journal_errors".to_string(),
            Json::Num(r.journal_errors as f64),
        );
        fcases.insert(name.to_string(), Json::Obj(o));
    }
    let mut fdoor = BTreeMap::new();
    fdoor.insert(
        "workload".to_string(),
        Json::Str(format!(
            "{FRONT_REQUESTS} requests firehosed at 2 worker-thread mock \
             replicas (cap 8 each, journal on), 16 tokens each, \
             150us/model-call mock; chaos case kills replica 1 at step 20"
        )),
    );
    fdoor.insert("cases".to_string(), Json::Obj(fcases));
    coord.insert("front_door".to_string(), Json::Obj(fdoor));
    root.insert("coordinator".to_string(), Json::Obj(coord));
    let body = format!("{}\n", Json::Obj(root));
    match std::fs::write(&path, body) {
        Ok(()) => println!("merged coordinator results into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let mut b = Bench::new("coordinator");

    // Full engine loop on a zero-cost model: requests/s through the
    // scheduler with continuous batching (1000 tokens per iteration call).
    b.run("engine_loop/64req_x16tok", || {
        let model = MockModel::new(8, 128, 256, vec![16, 64]);
        let mut ie = InferenceEngine::new(model, EngineConfig {
            queue_capacity: 128,
            ..Default::default()
        });
        for i in 0..64 {
            ie.submit(vec![1 + (i % 200) as i32; 9],
                      SamplingParams { max_tokens: 16, ..Default::default() })
                .unwrap();
        }
        let done = ie.run_to_completion().unwrap();
        assert_eq!(done.len(), 64);
    });

    // Batcher input assembly (hot per decode step).
    let mut batcher = Batcher::new(64, 4096);
    for s in 0..48 {
        batcher.occupy(s, s as u64, s * 3, 7);
    }
    let planned: Vec<usize> = (0..48).collect();
    b.run("batcher/decode_inputs_64slots", || {
        let (t, p) = batcher.decode_inputs_for(&planned);
        black_box((t, p));
    });

    // Sampler over a vocab-50k logits row (greedy and temperature).
    let mut rng = Rng::new(7);
    let logits: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
    let greedy = SamplingParams::default();
    b.run("sampler/greedy_50k", || {
        black_box(sample(&logits, &greedy, &mut rng));
    });
    let stochastic = SamplingParams {
        temperature: 0.8,
        top_k: 40,
        ..Default::default()
    };
    b.run("sampler/topk40_t0.8_50k", || {
        black_box(sample(&logits, &stochastic, &mut rng));
    });

    // Block allocator churn (slots and KV blocks share the type).
    let mut alloc = BlockAllocator::new(64);
    b.run("kv/alloc_release_x64", || {
        let blocks: Vec<_> = (0..64).map(|_| alloc.alloc().unwrap()).collect();
        for blk in blocks {
            alloc.release(blk);
        }
    });

    // Wire protocol.
    let line = r#"{"op":"generate","prompt":"the quick brown fox","max_tokens":64,"temperature":0.7,"top_k":40,"variant":"tardis80"}"#;
    b.run("protocol/parse_generate", || {
        black_box(parse_request(line).unwrap());
    });
    b.run("protocol/render_error", || {
        black_box(render_error("queue full (backpressure)"));
    });

    b.report();

    // -- bursty arrivals: planner comparison -------------------------------
    // Not a Bench::run case (each config is one long deterministic run,
    // not a tight loop): the table is the result. The seed baseline is
    // SchedulerConfig::single_prefill() — segregated, one prefill job in
    // flight, one chunk per iteration, FIFO admission.
    println!();
    println!(
        "bursty arrivals — {} requests in {} bursts {}ms apart (≈half \
         4-13 tok prompts, half 100-159 tok), 24 generated tokens each, \
         150µs/model-call mock:",
        BURSTS * BURST_SIZE,
        BURSTS,
        BURST_GAP.as_millis()
    );
    let budgeted = SchedulerConfig {
        max_step_tokens: 24,
        ..Default::default()
    };
    let cases: Vec<(&str, EngineConfig, Option<(usize, usize)>)> = vec![
        (
            "seed fifo (1 prefill, segregated)",
            EngineConfig {
                scheduler: SchedulerConfig::single_prefill(),
                ..Default::default()
            },
            None,
        ),
        (
            "segregated fifo (2 prefill)",
            EngineConfig {
                scheduler: SchedulerConfig::segregated(),
                ..Default::default()
            },
            None,
        ),
        ("mixed fifo", EngineConfig::default(), None),
        (
            "mixed spf",
            EngineConfig {
                scheduler: SchedulerConfig::with_policy(
                    PolicyKind::ShortestPromptFirst,
                ),
                ..Default::default()
            },
            None,
        ),
        (
            "mixed fifo, 24-tok budget",
            EngineConfig { scheduler: budgeted.clone(), ..Default::default() },
            None,
        ),
        (
            "mixed fifo, paged pressure",
            EngineConfig { scheduler: budgeted, ..Default::default() },
            // 48 blocks x 16 tokens = 768 cached tokens across 8 slots:
            // four long requests alone fill the pool, so decodes preempt
            // and swap under the long-prompt bursts.
            Some((48, 16)),
        ),
    ];
    println!(
        "  {:34} {:>12} {:>11} {:>10} {:>12} {:>8} {:>7}",
        "config", "ttft mean", "ttft p95", "occupancy", "jitter p95", "preempt", "mixed"
    );
    let mut rows: Vec<(&str, BurstyResult)> = Vec::new();
    for (name, cfg, kv) in cases {
        let r = run_bursty(cfg, kv);
        println!(
            "  {name:34} {:>9.2} ms {:>8.2} ms {:>10.2} {:>9.2} ms {:>8} {:>6.0}%",
            r.ttft_mean_ms,
            r.ttft_p95_ms,
            r.occupancy,
            r.jitter_p95_ms,
            r.preemptions,
            r.mixed_ratio * 100.0,
        );
        rows.push((name, r));
    }
    let seed_ttft = rows[0].1.ttft_mean_ms;
    let seg_ttft = rows[1].1.ttft_mean_ms;
    for (name, r) in rows.iter().skip(1) {
        println!(
            "  {name}: ttft {:+.1}% vs seed baseline",
            (r.ttft_mean_ms / seed_ttft - 1.0) * 100.0
        );
    }

    // -- shared-prefix workload: radix cache on vs off ---------------------
    println!();
    println!(
        "shared-prefix workload — {SHARED_REQUESTS} requests, \
         {SHARED_PREFIX}-token shared system prompt + 6-15 token unique \
         tails, 16 generated tokens each, 64x16 block pool, \
         150µs/model-call mock:"
    );
    println!(
        "  {:12} {:>12} {:>11} {:>8} {:>8} {:>6} {:>6} {:>8} {:>8}",
        "config", "ttft mean", "ttft p95", "hit tok", "shr blk", "cow", "evict", "preempt",
        "max blk"
    );
    let prefix_rows: Vec<(&str, PrefixResult)> = vec![
        ("sharing off", run_shared_prefix(false)),
        ("sharing on", run_shared_prefix(true)),
    ];
    for (name, r) in &prefix_rows {
        println!(
            "  {name:12} {:>9.2} ms {:>8.2} ms {:>8} {:>8} {:>6} {:>6} {:>8} {:>8}",
            r.ttft_mean_ms,
            r.ttft_p95_ms,
            r.hit_tokens,
            r.shared_blocks,
            r.cow_copies,
            r.evictions,
            r.preemptions,
            r.max_blocks_used,
        );
    }
    println!(
        "  sharing on: ttft {:+.1}% vs sharing off",
        (prefix_rows[1].1.ttft_mean_ms / prefix_rows[0].1.ttft_mean_ms - 1.0) * 100.0
    );

    // -- fault-tolerant front door: clean vs chaos -------------------------
    println!();
    println!(
        "front door — {FRONT_REQUESTS} requests firehosed at 2 \
         worker-thread replicas (cap 8 each, admission journal on), 16 \
         generated tokens each, 150µs/model-call mock; the chaos case \
         kills replica 1 at its 20th step:"
    );
    println!(
        "  {:24} {:>7} {:>5} {:>10} {:>9} {:>6} {:>8} {:>6} {:>8} {:>11}",
        "config", "served", "lost", "wall", "req/s", "shed", "replays", "fails",
        "restarts", "journal"
    );
    let fd_rows: Vec<(&str, FrontDoorResult)> = vec![
        ("clean", run_front_door(false)),
        ("chaos (kill replica 1)", run_front_door(true)),
    ];
    for (name, r) in &fd_rows {
        println!(
            "  {name:24} {:>7} {:>5} {:>7.1} ms {:>9.1} {:>6} {:>8} {:>6} {:>8} \
             {:>8} B",
            r.served,
            r.lost,
            r.wall_ms,
            r.throughput_rps,
            r.shed,
            r.replays,
            r.replica_failures,
            r.replica_restarts,
            r.journal_bytes,
        );
    }

    // CI chaos lane: no admitted request may be lost, in either mode.
    // Without the env var a violation still prints loudly, but only the
    // lane turns it into an exit code.
    let lost: usize = fd_rows.iter().map(|(_, r)| r.lost).sum();
    if std::env::var("TARDIS_ASSERT_ZERO_LOST").is_ok() {
        if lost > 0 {
            eprintln!("FAIL: front door lost {lost} admitted requests");
            std::process::exit(1);
        }
        println!(
            "zero-lost check: every admitted request completed in both the \
             clean and chaos runs"
        );
    } else if lost > 0 {
        eprintln!("WARNING: front door lost {lost} admitted requests");
    }

    write_bench_json(
        &rows.iter().map(|(n, r)| (*n, r)).collect::<Vec<_>>(),
        &prefix_rows.iter().map(|(n, r)| (*n, r)).collect::<Vec<_>>(),
        &fd_rows.iter().map(|(n, r)| (*n, r)).collect::<Vec<_>>(),
    );

    // CI lane: the mixed planner must not lose to the segregated
    // baseline on bursty-arrival TTFT (same concurrency, same offered
    // load). The gate is deliberately generous — mixed must stay under
    // 1.2x the segregated mean, with one re-measure of both configs —
    // so it catches real planner regressions (mixed should be *well*
    // below 1.0x here) without letting shared-runner wall-clock jitter
    // turn unrelated PRs red.
    if std::env::var("TARDIS_ASSERT_MIXED_TTFT").is_ok() {
        const SLACK: f64 = 1.2;
        assert_eq!(rows[2].0, "mixed fifo");
        let mut mixed_ttft = rows[2].1.ttft_mean_ms;
        let mut seg_best = seg_ttft;
        if mixed_ttft >= seg_best * SLACK {
            eprintln!(
                "mixed TTFT {mixed_ttft:.2} ms >= {SLACK}x segregated \
                 {seg_best:.2} ms; re-measuring both once (noisy-runner guard)"
            );
            let seg2 = run_bursty(
                EngineConfig {
                    scheduler: SchedulerConfig::segregated(),
                    ..Default::default()
                },
                None,
            );
            let mixed2 = run_bursty(EngineConfig::default(), None);
            // Loosen in BOTH directions: best mixed, slowest baseline —
            // min() on the baseline would tighten the gate when the
            // first segregated run was the anomalously fast one.
            mixed_ttft = mixed_ttft.min(mixed2.ttft_mean_ms);
            seg_best = seg_best.max(seg2.ttft_mean_ms);
        }
        if mixed_ttft >= seg_best * SLACK {
            eprintln!(
                "FAIL: mixed planner TTFT {mixed_ttft:.2} ms exceeds {SLACK}x \
                 the segregated baseline {seg_best:.2} ms"
            );
            std::process::exit(1);
        }
        println!(
            "mixed-TTFT check: {mixed_ttft:.2} ms within {SLACK}x of segregated \
             {seg_best:.2} ms (expect well under 1.0x)"
        );
    }

    // CI lane: at the same pool size, prefix sharing must beat the
    // unshared run on mean TTFT over the high-overlap workload. The
    // sharing run skips ~90 of ~100 prompt tokens per request, so its
    // honest win is several-fold; requiring only a 10% margin (with one
    // re-measure of both configs, loosened in both directions) keeps
    // shared-runner jitter from turning unrelated PRs red.
    if std::env::var("TARDIS_ASSERT_PREFIX_TTFT").is_ok() {
        const MARGIN: f64 = 0.9;
        let mut on_ttft = prefix_rows[1].1.ttft_mean_ms;
        let mut off_ttft = prefix_rows[0].1.ttft_mean_ms;
        if on_ttft >= off_ttft * MARGIN {
            eprintln!(
                "sharing TTFT {on_ttft:.2} ms >= {MARGIN}x unshared \
                 {off_ttft:.2} ms; re-measuring both once (noisy-runner guard)"
            );
            let off2 = run_shared_prefix(false);
            let on2 = run_shared_prefix(true);
            // Loosen in BOTH directions: best shared run, slowest
            // unshared baseline.
            on_ttft = on_ttft.min(on2.ttft_mean_ms);
            off_ttft = off_ttft.max(off2.ttft_mean_ms);
        }
        if on_ttft >= off_ttft * MARGIN {
            eprintln!(
                "FAIL: prefix sharing TTFT {on_ttft:.2} ms is not under \
                 {MARGIN}x the unshared baseline {off_ttft:.2} ms"
            );
            std::process::exit(1);
        }
        println!(
            "prefix-TTFT check: {on_ttft:.2} ms under {MARGIN}x of unshared \
             {off_ttft:.2} ms (expect a several-fold win)"
        );
    }
}
