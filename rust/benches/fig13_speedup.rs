//! Fig 13: FFN-level and end-to-end inference speedup vs compression
//! ratio, on the vLLM-like (continuous batching) and HF-like (sequential)
//! runtimes.
//!
//! Paper protocol (§7.4): generate starting from 8 prompt tokens, produce
//! 192 output tokens; report FFN speedup and end-to-end speedup per
//! compression ratio. We additionally print the analytic I/O-bound
//! prediction for the paper's 4090 testbed next to our measured
//! (compute-bound CPU) numbers so the shape comparison is explicit.
//!
//! Run: `cargo bench --bench fig13_speedup` (needs `make artifacts`).

use tardis::bench::Bench;
use tardis::config::Manifest;
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::PjrtModel;
use tardis::coordinator::request::SamplingParams;
use tardis::costmodel;
use tardis::runtime::Engine;

const PROMPT_TOKENS: usize = 8;
const GEN_TOKENS: usize = 192;

fn main() {
    let path = Manifest::default_path();
    if !path.exists() {
        eprintln!("SKIP fig13: no artifacts at {} (run `make artifacts`)",
                  path.display());
        return;
    }
    let manifest = Manifest::load(&path).expect("manifest");
    let engine = Engine::cpu().expect("cpu client");
    let mut b = Bench::new("fig13_speedup");
    b.opts.min_iters = 3;
    b.opts.max_iters = 5;
    b.opts.warmup_iters = 1;

    let variants = ["dense", "tardis50", "tardis70", "tardis80"];
    let prompt: Vec<i32> = (0..PROMPT_TOKENS).map(|i| 97 + i as i32).collect();
    let params = SamplingParams { max_tokens: GEN_TOKENS, ..Default::default() };

    // -- FFN-level microbenches: dense FFN vs full TARDIS FFN pipeline --
    let mut ffn_rows = Vec::new();
    for vname in &variants {
        let execs: &[&str] = if *vname == "dense" {
            &["ffn_dense"]
        } else {
            &["ffn_dense", "ffn_folded", "ffn_predictor", "ffn_aux", "ffn_fix"]
        };
        let v = engine.load_variant(&manifest, vname, Some(execs)).expect("load");
        let d = manifest.model.d_model;
        let x = engine
            .upload_f32(&vec![0.1f32; manifest.batch * d], &[manifest.batch, d])
            .expect("x");
        if *vname == "dense" {
            b.run("ffn/dense", || {
                let out = v.exec("ffn_dense").unwrap().run(&[&x]).unwrap();
                let _ = tardis::runtime::engine::buffer_to_f32(&out[0]).unwrap();
            });
            ffn_rows.push((vname.to_string(), 0.0,
                           b.mean_ms("ffn/dense").unwrap()));
        } else {
            let name = format!("ffn/{vname}");
            // the full online FFN path: folded mm + predictor + top-k + fix
            b.run(&name, || {
                let spec = v.exec("ffn_folded").unwrap().run(&[&x]).unwrap();
                let score = v.exec("ffn_predictor").unwrap().run(&[&x]).unwrap();
                let aux = v.exec("ffn_aux").unwrap().run(&[&score[0]]).unwrap();
                let corr = v
                    .exec("ffn_fix")
                    .unwrap()
                    .run(&[&x, &aux[0], &aux[1]])
                    .unwrap();
                let _ = tardis::runtime::engine::buffer_to_f32(&spec[0]).unwrap();
                let _ = tardis::runtime::engine::buffer_to_f32(&corr[0]).unwrap();
            });
            ffn_rows.push((vname.to_string(), v.spec.compression_ratio,
                           b.mean_ms(&name).unwrap()));
        }
    }

    // -- end-to-end: vLLM-like (batched, 4 concurrent) + HF-like (seq) --
    let mut e2e_rows = Vec::new();
    for vname in &variants {
        let v = engine
            .load_variant(&manifest, vname,
                          Some(&["decode", "prefill16", "prefill64"]))
            .expect("load");
        let ratio = v.spec.compression_ratio;
        let model = PjrtModel::new(&engine, v, manifest.batch,
                                   manifest.model.max_seq,
                                   manifest.model.vocab,
                                   manifest.prefill_buckets.clone())
            .expect("model");
        let mut ie = InferenceEngine::new(model, EngineConfig::default());

        // HF-like: one sequential request.
        let name_hf = format!("e2e_hf/{vname}");
        b.run(&name_hf, || {
            ie.model.reset_kv().unwrap();
            let _ = ie.generate_sequential(prompt.clone(), params).unwrap();
        });

        // vLLM-like: 4 concurrent requests (continuous batching amortizes
        // each decode step across requests).
        let name_vllm = format!("e2e_vllm/{vname}");
        b.run(&name_vllm, || {
            ie.model.reset_kv().unwrap();
            for r in 0..4 {
                let mut p = prompt.clone();
                p[0] += r;
                ie.submit(p, params).unwrap();
            }
            let done = ie.run_to_completion().unwrap();
            assert_eq!(done.len(), 4);
        });
        e2e_rows.push((vname.to_string(), ratio,
                       b.mean_ms(&name_hf).unwrap(),
                       b.mean_ms(&name_vllm).unwrap() / 4.0));
    }

    // -- the figure --
    println!();
    println!("Fig 13 — speedup vs compression ratio ({PROMPT_TOKENS} prompt + {GEN_TOKENS} generated tokens)");
    println!("{:10} {:>7} {:>10} {:>10} {:>10} {:>12} {:>12}",
             "variant", "ratio", "ffn x", "hf x", "vllm x",
             "4090 ffn x", "4090 e2e x");
    let ffn_base = ffn_rows[0].2;
    let hf_base = e2e_rows[0].2;
    let vllm_base = e2e_rows[0].3;
    for i in 0..ffn_rows.len() {
        let (name, ratio, ffn_ms) = &ffn_rows[i];
        let (_, _, hf_ms, vllm_ms) = &e2e_rows[i];
        let (model_ffn, model_e2e) = if *ratio > 0.0 {
            costmodel::tardis_speedup(&costmodel::FALCON_7B,
                                      &costmodel::RTX_4090, 1, 128, *ratio,
                                      0.05)
        } else {
            (1.0, 1.0)
        };
        println!("{:10} {:6.1}% {:9.2}x {:9.2}x {:9.2}x {:11.2}x {:11.2}x",
                 name, ratio * 100.0, ffn_base / ffn_ms, hf_base / hf_ms,
                 vllm_base / vllm_ms, model_ffn, model_e2e);
    }
    println!("(paper @80%: FFN 1.86x, HF 1.39x, vLLM 1.59x on an RTX 4090 —");
    println!(" our testbed is a single-core CPU where FFN weight I/O is not");
    println!(" the bottleneck; the '4090' columns give the analytic I/O-bound");
    println!(" prediction from the same cost model that reproduces Fig 1b.)");
    b.report();
}
