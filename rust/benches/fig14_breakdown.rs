//! Fig 14: performance breakdown of the TARDIS FFN online phase.
//!
//! Paper (§7.5, threshold 0.85): result fixing dominates, predictor ~12%,
//! folded matmul ~22%, the rest is auxiliary ops (mask generation / index
//! conversion). We time the four micro-executables separately and print
//! the same share decomposition for each tardis variant.
//!
//! Run: `cargo bench --bench fig14_breakdown` (needs `make artifacts`).

use tardis::bench::Bench;
use tardis::config::Manifest;
use tardis::runtime::engine::buffer_to_f32;
use tardis::runtime::Engine;

fn main() {
    let path = Manifest::default_path();
    if !path.exists() {
        eprintln!("SKIP fig14: no artifacts at {} (run `make artifacts`)",
                  path.display());
        return;
    }
    let manifest = Manifest::load(&path).expect("manifest");
    let engine = Engine::cpu().expect("cpu client");
    let mut b = Bench::new("fig14_breakdown");

    for vname in ["tardis50", "tardis70", "tardis80"] {
        let Ok(v) = engine.load_variant(
            &manifest, vname,
            Some(&["ffn_folded", "ffn_predictor", "ffn_aux", "ffn_fix"]))
        else {
            eprintln!("SKIP {vname}: not in manifest");
            continue;
        };
        let d = manifest.model.d_model;
        let x = engine
            .upload_f32(&vec![0.1f32; manifest.batch * d], &[manifest.batch, d])
            .expect("x");

        // Stage inputs once so each stage is timed in isolation.
        let score = v.exec("ffn_predictor").unwrap().run(&[&x]).unwrap();
        let aux = v.exec("ffn_aux").unwrap().run(&[&score[0]]).unwrap();

        let t_folded = b
            .run(&format!("{vname}/folded_matmul"), || {
                let out = v.exec("ffn_folded").unwrap().run(&[&x]).unwrap();
                let _ = buffer_to_f32(&out[0]).unwrap();
            })
            .summary
            .mean;
        let t_pred = b
            .run(&format!("{vname}/predictor"), || {
                let out = v.exec("ffn_predictor").unwrap().run(&[&x]).unwrap();
                let _ = buffer_to_f32(&out[0]).unwrap();
            })
            .summary
            .mean;
        let t_aux = b
            .run(&format!("{vname}/aux_topk"), || {
                let out = v.exec("ffn_aux").unwrap().run(&[&score[0]]).unwrap();
                let _ = tardis::runtime::engine::buffer_to_i32(&out[0]).unwrap();
            })
            .summary
            .mean;
        let t_fix = b
            .run(&format!("{vname}/result_fixing"), || {
                let out = v
                    .exec("ffn_fix")
                    .unwrap()
                    .run(&[&x, &aux[0], &aux[1]])
                    .unwrap();
                let _ = buffer_to_f32(&out[0]).unwrap();
            })
            .summary
            .mean;

        let total = t_folded + t_pred + t_aux + t_fix;
        println!();
        println!("Fig 14 — {vname} (fix capacity K = {}):", v.spec.fix_capacity);
        println!("  folded matmul  {:5.1}%   (paper ~22%)", 100.0 * t_folded / total);
        println!("  predictor      {:5.1}%   (paper ~12%)", 100.0 * t_pred / total);
        println!("  result fixing  {:5.1}%   (paper: dominant)", 100.0 * t_fix / total);
        println!("  auxiliary ops  {:5.1}%", 100.0 * t_aux / total);
    }
    b.report();
}
