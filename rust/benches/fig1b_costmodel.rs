//! Fig 1b: theoretical inference-time breakdown (I/O vs compute, MHA vs
//! FFN) for Falcon-7B on an RTX 4090 with the SharedGPT mean workload
//! (91 prompt + 178 generated tokens). Pure analytic model — also
//! asserts the paper's headline cell (FFN I/O ~78.2%) within tolerance,
//! and prints the sweep over batch sizes / prompt lengths that the
//! paper's §2.2 argument rests on.
//!
//! Run: `cargo bench --bench fig1b_costmodel`.

use tardis::costmodel::*;

fn main() {
    println!("== bench suite: fig1b_costmodel ==");
    let b = inference_breakdown(&FALCON_7B, &RTX_4090, 1, 91, 178);
    println!("Fig 1b — Falcon-7B, RTX 4090, 91 prompt + 178 generated:");
    println!("  MHA I/O     {:5.1}%", b.attn_io * 100.0);
    println!("  MHA compute {:5.1}%", b.attn_compute * 100.0);
    println!("  FFN I/O     {:5.1}%  (paper: 78.2%)", b.ffn_io * 100.0);
    println!("  FFN compute {:5.1}%", b.ffn_compute * 100.0);
    assert!((b.ffn_io - 0.782).abs() < 0.05,
            "FFN I/O share {:.3} deviates from the paper's 0.782", b.ffn_io);

    println!();
    println!("sensitivity: FFN-I/O share vs batch size (decode, ctx 128):");
    for batch in [1usize, 4, 16, 64, 256] {
        let d = decode_step(&FALCON_7B, &RTX_4090, batch, 128);
        let tot = d.attn.io_s + d.attn.compute_s + d.ffn.io_s + d.ffn.compute_s;
        println!("  batch {:4}: ffn io {:5.1}%  ffn compute {:5.1}%",
                 batch, 100.0 * d.ffn.io_s / tot,
                 100.0 * d.ffn.compute_s / tot);
    }
    println!("(large batches amortize weight I/O — exactly why the paper's");
    println!(" speedup concentrates in the auto-regressive decode regime.)");

    println!();
    println!("FFN parameter share per model family (paper Table 2):");
    for m in [&FALCON_7B, &TINY_GELU] {
        println!("  {:10} total {:>6.2}B  ffn share {:4.1}%",
                 m.name, m.total_params() / 1e9,
                 m.ffn_param_fraction() * 100.0);
    }
}
