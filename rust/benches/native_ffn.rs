//! Native FFN fold benchmark: dense vs TARDIS-folded forward at several
//! fold ratios (TINY_GELU shape), plus full decode steps through the
//! NativeModel, cross-validated against `costmodel::tardis_speedup`.
//!
//! Run: `cargo bench --bench native_ffn`

use std::sync::Arc;

use tardis::bench::{black_box, Bench};
use tardis::config::{FfnMode, NativeModelConfig, TardisFfnConfig};
use tardis::coordinator::model::{NativeModel, StepModel};
use tardis::costmodel;
use tardis::ffn::linalg::norm;
use tardis::ffn::{DenseFfn, FoldedFfn};
use tardis::util::rng::Rng;

fn tiny_dense(rng: &mut Rng, d: usize, h: usize) -> DenseFfn {
    let scale = 1.0 / (d as f64).sqrt();
    DenseFfn::new(
        Arc::new((0..d * h).map(|_| (rng.normal() * scale) as f32).collect()),
        Arc::new(vec![0.0; h]),
        Arc::new((0..h * d).map(|_| (rng.normal() * scale) as f32).collect()),
        Arc::new(vec![0.0; d]),
        d,
        h,
    )
}

fn main() {
    let mut b = Bench::new("native_ffn");
    let spec = costmodel::TINY_GELU;
    let (d, h) = (spec.d_model, spec.d_ff);
    let batch = 4;
    let mut rng = Rng::new(0xBEEF);

    // ---- FFN-level: dense vs folded forward ----------------------------
    let dense = tiny_dense(&mut rng, d, h);
    let x_dir: Vec<f32> = (0..batch * d).map(|_| rng.normal() as f32).collect();
    let mk_rows = |radius: f32| {
        let mut x = x_dir.clone();
        for row in x.chunks_mut(d) {
            let n = norm(row).max(1e-6);
            for v in row.iter_mut() {
                *v *= radius / n;
            }
        }
        x
    };

    let xd = mk_rows(1.0);
    b.run("ffn/dense", || {
        black_box(dense.forward(None, &xd, batch));
    });

    let mut measured: Vec<(f64, f64)> = Vec::new(); // (ratio, speedup)
    for pct in [50u32, 70, 80] {
        let cfg = TardisFfnConfig {
            fold_ratio: pct as f64 / 100.0,
            ..TardisFfnConfig::default()
        };
        let mut folded = FoldedFfn::new(dense.clone(), &cfg);
        // rows inside the provable radius: the folded path dominates
        let xf = mk_rows(0.9 * folded.predictor.safe_radius());
        let case = format!("ffn/tardis{pct}");
        b.run(&case, || {
            black_box(folded.forward(None, &xf, batch));
        });
        let (dm, fm) = (
            b.mean_ms("ffn/dense").unwrap(),
            b.mean_ms(&case).unwrap(),
        );
        measured.push((folded.compression_ratio(), dm / fm));
    }

    // ---- model-level: full decode steps --------------------------------
    let model_cfg = NativeModelConfig::tiny_gelu();
    let mut decode_means: Vec<(String, f64)> = Vec::new();
    for (name, mode) in [
        ("dense".to_string(), FfnMode::Dense),
        (
            "tardis80".to_string(),
            FfnMode::Tardis(TardisFfnConfig::with_ratio(0.8)),
        ),
    ] {
        let mut model = NativeModel::new(model_cfg.clone(), &mode);
        let tokens: Vec<i32> = (0..model_cfg.batch as i32).collect();
        // warm up the KV cache and the online predictor
        for s in 0..8 {
            let pos = vec![s; model_cfg.batch];
            model.decode(&tokens, &pos).unwrap();
        }
        let mut s = 8i32;
        let case = format!("decode/{name}");
        b.run(&case, || {
            let pos = vec![s % model_cfg.max_seq as i32; model_cfg.batch];
            black_box(model.decode(&tokens, &pos).unwrap());
            s += 1;
        });
        decode_means.push((name, b.mean_ms(&case).unwrap()));
        if let Some(t) = model.ffn_telemetry() {
            println!(
                "  [{case}] fallback rate {:.2}%",
                t.fallback_rate().unwrap_or(0.0) * 100.0
            );
        }
    }

    // ---- cross-validation against the analytic cost model --------------
    println!();
    println!("fold ratio vs costmodel (TINY_GELU on cpu-1core):");
    for (ratio, speedup) in &measured {
        let (ffn_t, e2e_t) = costmodel::tardis_speedup(
            &spec,
            &costmodel::CPU_1CORE,
            batch,
            64,
            *ratio,
            0.0,
        );
        println!(
            "  compression {:5.1}%: measured ffn {speedup:5.2}x, \
             theory ffn {ffn_t:5.2}x (e2e {e2e_t:5.2}x)",
            ratio * 100.0
        );
    }
    if decode_means.len() == 2 {
        println!(
            "decode-step speedup tardis80 vs dense: {:.2}x",
            decode_means[0].1 / decode_means[1].1
        );
    }
    b.report();
}
