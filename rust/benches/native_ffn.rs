//! Native FFN benchmark, three levels deep:
//!
//! 1. kernel — blocked packed GEMM vs the pre-PR scalar kernel
//!    ([`tardis::ffn::kernels::matmul_naive`]) at the TINY_GELU
//!    up-projection shape, batch and single-row (decode) cases, in
//!    GFLOP/s;
//! 2. FFN — dense vs TARDIS-folded forward at several fold ratios;
//! 3. model — full decode steps through the NativeModel, dense vs
//!    tardis80, cross-validated against `costmodel::tardis_speedup`,
//!    plus single-stream self-speculative decode (forced-fold drafts,
//!    k=4) vs plain, with acceptance rate, merged under
//!    `decode.speculative`.
//!
//! Besides the human-readable table, the run merges its report into
//! `BENCH_native_ffn.json` (override the path with `TARDIS_BENCH_JSON`)
//! under the `"native_ffn"` key — sibling suites (`bench-decode`'s
//! top-level record, `coordinator`) are preserved — so the perf
//! trajectory is tracked across PRs: GFLOP/s per dispatch path,
//! packed/naive ratio, tokens/s, measured dense-vs-tardis ratio,
//! fallback rate, scratch-arena misses.
//!
//! Run: `cargo bench --bench native_ffn`

use std::collections::BTreeMap;
use std::sync::Arc;

use tardis::bench::{black_box, Bench};
use tardis::config::{FfnMode, NativeModelConfig, TardisFfnConfig};
use tardis::coordinator::engine_loop::{EngineConfig, InferenceEngine};
use tardis::coordinator::model::{NativeModel, StepModel};
use tardis::coordinator::request::SamplingParams;
use tardis::costmodel;
use tardis::ffn::kernels::{
    matmul, matmul_naive, matmul_q, norm, Epilogue, KernelDispatch, PackedMatrix, Scratch,
};
use tardis::ffn::{DenseFfn, FoldedFfn, QuantizedProxy};
use tardis::util::json::Json;
use tardis::util::rng::Rng;

fn tiny_dense(rng: &mut Rng, d: usize, h: usize) -> DenseFfn {
    let scale = 1.0 / (d as f64).sqrt();
    DenseFfn::new(
        Arc::new((0..d * h).map(|_| (rng.normal() * scale) as f32).collect()),
        Arc::new(vec![0.0; h]),
        Arc::new((0..h * d).map(|_| (rng.normal() * scale) as f32).collect()),
        Arc::new(vec![0.0; d]),
        d,
        h,
    )
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn gflops(rows: usize, k: usize, m: usize, mean_ms: f64) -> f64 {
    2.0 * (rows * k * m) as f64 / (mean_ms * 1e-3) / 1e9
}

fn main() {
    let mut b = Bench::new("native_ffn");
    let spec = costmodel::TINY_GELU;
    let (d, h) = (spec.d_model, spec.d_ff);
    let batch = 4;
    let mut rng = Rng::new(0xBEEF);
    let mut report = BTreeMap::new();
    let isa = KernelDispatch::active().name();
    report.insert("isa".to_string(), Json::Str(isa.to_string()));
    {
        let mut shape = BTreeMap::new();
        shape.insert("d_model".to_string(), num(d as f64));
        shape.insert("d_ff".to_string(), num(h as f64));
        shape.insert("batch".to_string(), num(batch as f64));
        report.insert("shape".to_string(), Json::Obj(shape));
    }

    // ---- kernel-level: packed blocked GEMM vs pre-PR scalar kernel -----
    let x: Vec<f32> = (0..batch * d).map(|_| rng.normal() as f32).collect();
    let wraw: Vec<f32> = (0..d * h).map(|_| rng.normal() as f32).collect();
    let bias: Vec<f32> = (0..h).map(|_| rng.normal() as f32).collect();
    let packed = PackedMatrix::pack(&wraw, d, h);
    let mut y = vec![0f32; batch * h];
    b.run("gemm/naive_b4", || {
        black_box(matmul_naive(&x, batch, d, &wraw, h, Some(&bias)));
    });
    b.run("gemm/packed_b4", || {
        matmul(None, &x, batch, &packed, Epilogue::Bias(&bias), &mut y);
        black_box(&y);
    });
    b.run("gemm/naive_b1", || {
        black_box(matmul_naive(&x[..d], 1, d, &wraw, h, Some(&bias)));
    });
    b.run("gemm/packed_b1", || {
        matmul(None, &x[..d], 1, &packed, Epilogue::Bias(&bias), &mut y[..h]);
        black_box(&y);
    });
    // fused k-bit dequant GEMM: the quantized-proxy inner loop at the
    // decode (rows=1) shape, codes consumed in packed-panel form
    let proxy = QuantizedProxy::quantize(&wraw, d, h, h, 4, 32);
    b.run("gemm/fused_q4_b1", || {
        matmul_q(None, &x[..d], 1, proxy.panels(), Epilogue::Bias(&bias), &mut y[..h]);
        black_box(&y);
    });
    let naive4 = gflops(batch, d, h, b.mean_ms("gemm/naive_b4").unwrap());
    let packed4 = gflops(batch, d, h, b.mean_ms("gemm/packed_b4").unwrap());
    let naive1 = gflops(1, d, h, b.mean_ms("gemm/naive_b1").unwrap());
    let packed1 = gflops(1, d, h, b.mean_ms("gemm/packed_b1").unwrap());
    let fusedq1 = gflops(1, d, h, b.mean_ms("gemm/fused_q4_b1").unwrap());
    let io_bytes = ((d + h) * 4) as f64;
    let f32_bytes = packed.resident_bytes() as f64 + io_bytes;
    let q_bytes = proxy.resident_bytes() as f64 + io_bytes;
    let q_gbps = q_bytes / (b.mean_ms("gemm/fused_q4_b1").unwrap() * 1e-3) / 1e9;
    println!(
        "gemm [{batch}x{d}]x[{d}x{h}] ({isa} path): naive {naive4:.2} GFLOP/s, \
         packed {packed4:.2} GFLOP/s ({:.2}x); rows=1: naive {naive1:.2}, \
         packed {packed1:.2} ({:.2}x); fused q4 {fusedq1:.2} GFLOP/s \
         ({:.0} B/token, {:.2}x fewer than f32, {q_gbps:.2} GB/s)",
        packed4 / naive4,
        packed1 / naive1,
        q_bytes,
        f32_bytes / q_bytes,
    );
    {
        let mut g = BTreeMap::new();
        g.insert("naive_gflops_b4".to_string(), num(naive4));
        g.insert("packed_gflops_b4".to_string(), num(packed4));
        g.insert("packed_vs_naive_b4".to_string(), num(packed4 / naive4));
        g.insert("naive_gflops_b1".to_string(), num(naive1));
        g.insert("packed_gflops_b1".to_string(), num(packed1));
        g.insert("packed_vs_naive_b1".to_string(), num(packed1 / naive1));
        g.insert("fused_q4_gflops_b1".to_string(), num(fusedq1));
        g.insert("fused_q4_bytes_per_token".to_string(), num(q_bytes));
        g.insert("fused_q4_bytes_ratio".to_string(), num(f32_bytes / q_bytes));
        g.insert("fused_q4_gbps".to_string(), num(q_gbps));
        report.insert("gemm".to_string(), Json::Obj(g));
    }

    // ---- FFN-level: dense vs folded forward ----------------------------
    let dense = tiny_dense(&mut rng, d, h);
    let x_dir: Vec<f32> = (0..batch * d).map(|_| rng.normal() as f32).collect();
    let mk_rows = |radius: f32| {
        let mut x = x_dir.clone();
        for row in x.chunks_mut(d) {
            let n = norm(row).max(1e-6);
            for v in row.iter_mut() {
                *v *= radius / n;
            }
        }
        x
    };

    let mut scratch = Scratch::new();
    let xd = mk_rows(1.0);
    b.run("ffn/dense", || {
        let y = dense.forward(None, &mut scratch, &xd, batch);
        black_box(&y);
        scratch.give(y);
    });

    let mut measured: Vec<(f64, f64)> = Vec::new(); // (ratio, speedup)
    let mut ffn_cases: Vec<Json> = Vec::new();
    for pct in [50u32, 70, 80] {
        let cfg = TardisFfnConfig {
            fold_ratio: pct as f64 / 100.0,
            ..TardisFfnConfig::default()
        };
        let mut folded = FoldedFfn::new(dense.clone(), &cfg);
        // rows inside the provable radius: the folded path dominates
        let xf = mk_rows(0.9 * folded.predictor.safe_radius());
        let case = format!("ffn/tardis{pct}");
        b.run(&case, || {
            let y = folded.forward(None, &mut scratch, &xf, batch);
            black_box(&y);
            scratch.give(y);
        });
        let (dm, fm) = (
            b.mean_ms("ffn/dense").unwrap(),
            b.mean_ms(&case).unwrap(),
        );
        measured.push((folded.compression_ratio(), dm / fm));
        let mut c = BTreeMap::new();
        c.insert("case".to_string(), Json::Str(format!("tardis{pct}")));
        c.insert("compression".to_string(), num(folded.compression_ratio()));
        c.insert("speedup_vs_dense".to_string(), num(dm / fm));
        ffn_cases.push(Json::Obj(c));
    }
    report.insert("ffn".to_string(), Json::Arr(ffn_cases));
    let ffn_misses = scratch.misses;

    // ---- model-level: full decode steps --------------------------------
    let model_cfg = NativeModelConfig::tiny_gelu();
    let mut decode_means: Vec<(String, f64)> = Vec::new();
    let mut decode_json = BTreeMap::new();
    for (name, mode) in [
        ("dense".to_string(), FfnMode::Dense),
        (
            "tardis80".to_string(),
            FfnMode::Tardis(TardisFfnConfig::with_ratio(0.8)),
        ),
    ] {
        let mut model = NativeModel::new(model_cfg.clone(), &mode);
        let tokens: Vec<i32> = (0..model_cfg.batch as i32).collect();
        // warm up the KV cache, the online predictor and the scratch arena
        for s in 0..8 {
            let pos = vec![s; model_cfg.batch];
            model.decode(&tokens, &pos).unwrap();
        }
        let warm_misses = model.scratch_misses();
        let mut s = 8i32;
        let case = format!("decode/{name}");
        b.run(&case, || {
            let pos = vec![s % model_cfg.max_seq as i32; model_cfg.batch];
            black_box(model.decode(&tokens, &pos).unwrap());
            s += 1;
        });
        // The dense path's buffer usage is deterministic, so its arena
        // must be silent once warm. (The tardis path can pool one extra
        // buffer the first time the router produces a new batch mix, so
        // it is reported rather than asserted.)
        if name == "dense" {
            assert_eq!(
                model.scratch_misses(),
                warm_misses,
                "steady-state dense decode allocated scratch buffers"
            );
        }
        decode_json.insert(
            format!("scratch_misses_{name}"),
            num(model.scratch_misses() as f64),
        );
        let mean = b.mean_ms(&case).unwrap();
        let toks_per_s = model_cfg.batch as f64 / (mean * 1e-3);
        decode_means.push((name.clone(), mean));
        decode_json.insert(format!("{name}_ms"), num(mean));
        decode_json.insert(format!("tokens_per_s_{name}"), num(toks_per_s));
        if let Some(t) = model.ffn_telemetry() {
            let rate = t.fallback_rate().unwrap_or(0.0);
            println!("  [{case}] fallback rate {:.2}%", rate * 100.0);
            decode_json.insert(format!("fallback_rate_{name}"), num(rate));
        }
    }
    if decode_means.len() == 2 {
        let ratio = decode_means[0].1 / decode_means[1].1;
        println!("decode-step speedup tardis80 vs dense: {ratio:.2}x");
        decode_json.insert("dense_vs_tardis".to_string(), num(ratio));
    }
    // ---- model-level: single-stream self-speculative decode ------------
    // One greedy request through the full engine, plain vs drafting k
    // tokens per step through the forced-fold path; recorded under
    // decode.speculative (k, acceptance, tokens/s per variant).
    let spec_k = 4usize;
    let mut spec_json = BTreeMap::new();
    spec_json.insert("k".to_string(), num(spec_k as f64));
    let mut spec_rows = Vec::new();
    for (name, mode) in [
        ("dense".to_string(), FfnMode::Dense),
        (
            "tardis80".to_string(),
            FfnMode::Tardis(TardisFfnConfig::with_ratio(0.8)),
        ),
    ] {
        let run = |k: usize| {
            let model = NativeModel::new(model_cfg.clone(), &mode);
            let ecfg = EngineConfig {
                speculate_k: k,
                prefix_cache: false,
                ..Default::default()
            };
            let mut e = InferenceEngine::new(model, ecfg);
            let prompt: Vec<i32> = (0..8i32)
                .map(|t| (5 * t + 2) % model_cfg.vocab as i32)
                .collect();
            let warm = SamplingParams { max_tokens: 8, ..Default::default() };
            e.generate_sequential(prompt.clone(), warm).unwrap();
            let params = SamplingParams { max_tokens: 48, ..Default::default() };
            let t0 = std::time::Instant::now();
            let c = e.generate_sequential(prompt, params).unwrap();
            let dt = t0.elapsed().as_secs_f64();
            (c.tokens.len() as f64 / dt, e.stats.spec_acceptance())
        };
        let (plain_tok_s, _) = run(0);
        let (spec_tok_s, acceptance) = run(spec_k);
        println!(
            "  [decode/speculative/{name}] plain {plain_tok_s:.1} tok/s, \
             k={spec_k} speculative {spec_tok_s:.1} tok/s ({:.2}x), \
             acceptance {:.1}%",
            spec_tok_s / plain_tok_s,
            acceptance.unwrap_or(0.0) * 100.0,
        );
        let mut o = BTreeMap::new();
        o.insert("variant".to_string(), Json::Str(name));
        if let Some(a) = acceptance {
            o.insert("acceptance".to_string(), num(a));
        }
        o.insert("plain_tokens_per_s".to_string(), num(plain_tok_s));
        o.insert("spec_tokens_per_s".to_string(), num(spec_tok_s));
        o.insert(
            "speedup_vs_plain".to_string(),
            num(spec_tok_s / plain_tok_s),
        );
        spec_rows.push(Json::Obj(o));
    }
    spec_json.insert("variants".to_string(), Json::Arr(spec_rows));
    decode_json.insert("speculative".to_string(), Json::Obj(spec_json));

    decode_json.insert("ffn_scratch_misses".to_string(), num(ffn_misses as f64));
    report.insert("decode".to_string(), Json::Obj(decode_json));

    // ---- cross-validation against the analytic cost model --------------
    println!();
    println!("fold ratio vs costmodel (TINY_GELU on cpu-1core):");
    for (ratio, speedup) in &measured {
        let (ffn_t, e2e_t) = costmodel::tardis_speedup(
            &spec,
            &costmodel::CPU_1CORE,
            batch,
            64,
            *ratio,
            0.0,
        );
        println!(
            "  compression {:5.1}%: measured ffn {speedup:5.2}x, \
             theory ffn {ffn_t:5.2}x (e2e {e2e_t:5.2}x)",
            ratio * 100.0
        );
    }
    b.report();

    // Merge under the "native_ffn" key: bench-decode owns the top
    // level and the coordinator bench owns "coordinator"; clobbering
    // the file would erase their latest records.
    let path = std::env::var("TARDIS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_native_ffn.json".to_string());
    let mut root = match std::fs::read_to_string(&path)
        .ok()
        .and_then(|text| Json::parse(&text).ok())
    {
        Some(Json::Obj(map)) => map,
        _ => BTreeMap::new(),
    };
    root.insert("native_ffn".to_string(), Json::Obj(report));
    let json = Json::Obj(root).to_string();
    match std::fs::write(&path, format!("{json}\n")) {
        Ok(()) => println!("merged native_ffn results into {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
