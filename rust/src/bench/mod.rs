//! Criterion-lite: the micro-benchmark harness behind `cargo bench`
//! (criterion itself is not in the offline vendor set).
//!
//! Usage in a `harness = false` bench target:
//! ```no_run
//! use tardis::bench::Bench;
//! let mut b = Bench::new("fig13_speedup");
//! b.run("decode/dense", || { /* one iteration */ });
//! b.report();
//! ```
//! Each case is warmed up, then timed for a minimum number of iterations
//! *and* a minimum wall-clock window; mean/p50/p99 are reported and the
//! raw rows are appended to `target/bench_results.csv` for EXPERIMENTS.md.

use std::time::{Duration, Instant};

use crate::util::stats::{Samples, Summary};

pub struct BenchOpts {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 2000,
            min_time: Duration::from_millis(300),
        }
    }
}

pub struct CaseResult {
    pub name: String,
    pub summary: Summary,
    /// iterations per second from the mean
    pub rate: f64,
}

pub struct Bench {
    pub suite: String,
    pub opts: BenchOpts,
    pub results: Vec<CaseResult>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        println!("== bench suite: {suite} ==");
        Bench { suite: suite.to_string(), opts: BenchOpts::default(), results: Vec::new() }
    }

    pub fn with_opts(suite: &str, opts: BenchOpts) -> Self {
        println!("== bench suite: {suite} ==");
        Bench { suite: suite.to_string(), opts, results: Vec::new() }
    }

    /// Time one case; `f` runs a single iteration.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &CaseResult {
        for _ in 0..self.opts.warmup_iters {
            f();
        }
        let mut samples = Samples::new();
        let started = Instant::now();
        let mut iters = 0usize;
        while (iters < self.opts.min_iters || started.elapsed() < self.opts.min_time)
            && iters < self.opts.max_iters
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e3); // ms
            iters += 1;
        }
        let summary = samples.summary();
        let rate = if summary.mean > 0.0 {
            1000.0 / summary.mean
        } else {
            f64::NAN
        };
        println!(
            "{:40} mean {:9.4} ms  p50 {:9.4}  p99 {:9.4}  ({} iters, {:.1}/s)",
            name, summary.mean, summary.p50, summary.p99, summary.n, rate
        );
        self.results.push(CaseResult { name: name.to_string(), summary, rate });
        self.results.last().unwrap()
    }

    /// Mean time in ms of the most recent case with this name.
    pub fn mean_ms(&self, name: &str) -> Option<f64> {
        self.results.iter().rev().find(|r| r.name == name).map(|r| r.summary.mean)
    }

    /// Append rows to target/bench_results.csv and print a footer.
    pub fn report(&self) {
        let path = std::path::Path::new("target").join("bench_results.csv");
        let mut rows = String::new();
        let header_needed = !path.exists();
        if header_needed {
            rows.push_str("suite,case,n,mean_ms,p50_ms,p99_ms,rate_per_s\n");
        }
        for r in &self.results {
            rows.push_str(&format!(
                "{},{},{},{:.6},{:.6},{:.6},{:.3}\n",
                self.suite, r.name, r.summary.n, r.summary.mean,
                r.summary.p50, r.summary.p99, r.rate
            ));
        }
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        use std::io::Write;
        if let Ok(mut f) =
            std::fs::OpenOptions::new().create(true).append(true).open(&path)
        {
            let _ = f.write_all(rows.as_bytes());
        }
        println!(
            "== {}: {} cases, rows appended to {} ==",
            self.suite,
            self.results.len(),
            path.display()
        );
    }
}

/// Prevent the optimizer from discarding a value (std-only black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_a_case() {
        let mut b = Bench::with_opts(
            "selftest",
            BenchOpts {
                warmup_iters: 1,
                min_iters: 5,
                max_iters: 10,
                min_time: Duration::from_millis(1),
            },
        );
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(r.summary.n >= 5);
        assert!(r.summary.mean >= 0.0);
        assert!(b.mean_ms("spin").is_some());
    }
}
