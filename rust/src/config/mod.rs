//! Manifest + configuration loading, and the backend axis.
//!
//! `artifacts/manifest.json` is the contract between the python compile
//! path and the rust runtime: the model shape, the KV-cache layout, and
//! for each compression variant the HLO executables, their input
//! signatures, and the weight table into `<variant>.weights.bin`.
//!
//! The std-only side of this module defines the serving stack's backend
//! matrix ([`BackendKind`]: mock / native / pjrt), the native model shape
//! ([`NativeModelConfig`]) and the per-variant TARDIS fold parameters
//! ([`TardisFfnConfig`]: fold ratio, linear-range bounds, predictor
//! threshold) — shared by the manifest parser, the CLI and the native
//! backend, so "which backend" is a first-class configuration axis
//! instead of a cfg-gated special case.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Backend axis.
// ---------------------------------------------------------------------------

/// Which step-model implementation the serving stack runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust tiny GELU transformer with dense or TARDIS FFNs
    /// (std-only, no artifacts).
    #[default]
    Native,
    /// Deterministic mock (scheduler tests and protocol experiments).
    Mock,
    /// PJRT runtime over exported artifacts (`--features pjrt`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "native" => Some(BackendKind::Native),
            "mock" => Some(BackendKind::Mock),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Mock => "mock",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Which out-of-range predictor routes work around the fold (paper
/// §5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PredictorKind {
    /// Per-row 1-D input-norm proxy (provable Cauchy–Schwarz radius +
    /// online learning). Cheap, but blind to direction-dependent
    /// outliers.
    #[default]
    Norm,
    /// k-bit quantized `W_up` proxy GEMM with *per-neuron* in/out
    /// decisions against the calibrated ranges and top-K result fixing
    /// (the paper's predictor).
    Quantized,
}

impl PredictorKind {
    pub fn parse(s: &str) -> Option<PredictorKind> {
        match s {
            "norm" => Some(PredictorKind::Norm),
            "quantized" => Some(PredictorKind::Quantized),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PredictorKind::Norm => "norm",
            PredictorKind::Quantized => "quantized",
        }
    }
}

/// Per-variant TARDIS fold parameters (the knobs the python pipeline
/// calibrates). `linear_lo`/`linear_hi` are the *uniform fallback*
/// range used when no per-neuron calibration accompanies the weights;
/// a manifest with `tardis.lo`/`tardis.hi` parameter arrays overrides
/// them per neuron (see `docs/manifest.md`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TardisFfnConfig {
    /// Fraction of hidden units folded into the `d×d` map.
    pub fold_ratio: f64,
    /// Approximated linear range `[lo, hi)` of the activation.
    pub linear_lo: f32,
    pub linear_hi: f32,
    /// Online outlier predictor margin (see
    /// [`crate::ffn::OutlierPredictor`]); 1.0 = fold only norms at or
    /// below observed/provable in-range norms.
    pub predictor_threshold: f32,
    /// Which predictor routes around the fold.
    pub predictor: PredictorKind,
    /// Bit width of the quantized `W_up` proxy (2..=8).
    pub predictor_bits: u8,
    /// Reduction-dimension rows sharing one quantization scale.
    pub predictor_group: usize,
    /// Result-fixing capacity: rows with at most this many predicted
    /// out-of-range neurons are fixed per neuron; beyond it the whole
    /// row falls back to the dense path.
    pub top_k: usize,
}

impl TardisFfnConfig {
    pub fn with_ratio(fold_ratio: f64) -> TardisFfnConfig {
        TardisFfnConfig { fold_ratio, ..TardisFfnConfig::default() }
    }

    pub fn with_predictor(self, predictor: PredictorKind) -> TardisFfnConfig {
        TardisFfnConfig { predictor, ..self }
    }
}

impl Default for TardisFfnConfig {
    fn default() -> Self {
        TardisFfnConfig {
            fold_ratio: 0.8,
            linear_lo: -6.0,
            linear_hi: 6.0,
            predictor_threshold: 1.05,
            predictor: PredictorKind::Norm,
            predictor_bits: 4,
            predictor_group: 32,
            top_k: 8,
        }
    }
}

/// FFN execution mode of a native variant.
#[derive(Debug, Clone, PartialEq)]
pub enum FfnMode {
    /// Pure GELU dense FFN (baseline).
    Dense,
    /// Folded partially-linear FFN with online outlier fallback.
    Tardis(TardisFfnConfig),
    /// Dense math with the same partial linearization as the fold — the
    /// semantic reference the folded path must reproduce (tests).
    TardisReference(TardisFfnConfig),
}

impl FfnMode {
    pub fn name(&self) -> &'static str {
        match self {
            FfnMode::Dense => "dense",
            FfnMode::Tardis(_) => "tardis",
            FfnMode::TardisReference(_) => "tardis_reference",
        }
    }
}

/// Resolve a native variant name to its FFN mode: `dense`,
/// `tardis<PCT>` (e.g. `tardis80` = fold ratio 0.80) or
/// `tardis-ref<PCT>` (the unfolded reference at the same linearization).
pub fn native_ffn_mode(name: &str) -> Option<FfnMode> {
    if name == "dense" {
        return Some(FfnMode::Dense);
    }
    if let Some(pct) = name.strip_prefix("tardis-ref") {
        let p: u32 = pct.parse().ok()?;
        if p == 0 || p > 100 {
            return None;
        }
        return Some(FfnMode::TardisReference(TardisFfnConfig::with_ratio(
            p as f64 / 100.0,
        )));
    }
    if let Some(pct) = name.strip_prefix("tardis") {
        let p: u32 = pct.parse().ok()?;
        if p == 0 || p > 100 {
            return None;
        }
        return Some(FfnMode::Tardis(TardisFfnConfig::with_ratio(
            p as f64 / 100.0,
        )));
    }
    None
}

/// The native variants the CLI serves/benches by default.
pub fn builtin_native_variants() -> Vec<(String, FfnMode)> {
    ["dense", "tardis50", "tardis70", "tardis80"]
        .iter()
        .map(|n| (n.to_string(), native_ffn_mode(n).expect("builtin")))
        .collect()
}

/// Shape + execution knobs of the native backend. Defaults to the
/// costmodel's `TINY_GELU` shape so every native path runs without
/// artifacts.
#[derive(Debug, Clone, PartialEq)]
pub struct NativeModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    /// Decode batch (KV slots).
    pub batch: usize,
    pub prefill_buckets: Vec<usize>,
    /// Weight synthesis seed.
    pub seed: u64,
    /// Worker threads for matmuls (0 = serial).
    pub threads: usize,
    /// Tokens per paged-KV block (clamped to `1..=max_seq`).
    pub kv_block_size: usize,
    /// Physical KV blocks in the pool. 0 = auto: enough for every slot
    /// to span the full context (`batch * ceil(max_seq / block_size)`,
    /// i.e. no block pressure). Smaller pools oversubscribe the cache
    /// and rely on the engine's preemption/swap machinery.
    pub kv_blocks: usize,
}

impl NativeModelConfig {
    pub fn tiny_gelu() -> NativeModelConfig {
        NativeModelConfig {
            vocab: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            max_seq: 256,
            batch: 4,
            prefill_buckets: vec![16, 64],
            seed: 0x7A9D15,
            threads: 0,
            kv_block_size: 16,
            kv_blocks: 0,
        }
    }

    /// Resolved paged-KV geometry as `(num_blocks, block_size)`.
    pub fn resolved_kv_layout(&self) -> (usize, usize) {
        let block_size = self.kv_block_size.clamp(1, self.max_seq.max(1));
        let per_slot = self.max_seq.div_ceil(block_size);
        let num_blocks = if self.kv_blocks == 0 {
            self.batch * per_slot
        } else {
            self.kv_blocks
        };
        (num_blocks.max(1), block_size)
    }

    pub fn head_dim(&self) -> usize {
        assert!(
            self.n_heads > 0 && self.d_model % self.n_heads == 0,
            "d_model {} not divisible by n_heads {}",
            self.d_model,
            self.n_heads
        );
        self.d_model / self.n_heads
    }
}

impl Default for NativeModelConfig {
    fn default() -> Self {
        NativeModelConfig::tiny_gelu()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub act: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I8,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "i8" => DType::I8,
            other => bail!("unknown dtype {other:?} in manifest"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ExecSpec {
    pub file: String,
    pub weight_params: Vec<String>,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    pub ffn_mode: String,
    pub fix_capacity: usize,
    pub compression_ratio: f64,
    pub weights_file: String,
    pub params: Vec<ParamEntry>,
    pub executables: BTreeMap<String, ExecSpec>,
    /// TARDIS fold parameters, when the variant declares a `fold_ratio`
    /// (optional manifest keys: `fold_ratio`, `linear_lo`, `linear_hi`,
    /// `predictor_threshold`).
    pub tardis: Option<TardisFfnConfig>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub batch: usize,
    pub prefill_buckets: Vec<usize>,
    pub kv_shape: Vec<usize>,
    pub variants: Vec<VariantSpec>,
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest missing key {key:?}"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?.as_usize().ok_or_else(|| anyhow!("{key:?} not a usize"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(req(j, key)?
        .as_str()
        .ok_or_else(|| anyhow!("{key:?} not a string"))?
        .to_string())
}

fn str_list(j: &Json, key: &str) -> Result<Vec<String>> {
    Ok(req(j, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("{key:?} not an array"))?
        .iter()
        .filter_map(|v| v.as_str().map(str::to_string))
        .collect())
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let dir = path
            .parent()
            .ok_or_else(|| anyhow!("manifest has no parent dir"))?
            .to_path_buf();

        let m = req(&j, "model")?;
        let model = ModelInfo {
            name: req_str(m, "name")?,
            vocab: req_usize(m, "vocab")?,
            d_model: req_usize(m, "d_model")?,
            n_layers: req_usize(m, "n_layers")?,
            n_heads: req_usize(m, "n_heads")?,
            d_ff: req_usize(m, "d_ff")?,
            max_seq: req_usize(m, "max_seq")?,
            act: req_str(m, "act")?,
        };

        let kv_shape = req(&j, "kv_shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("kv_shape not an array"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect::<Vec<_>>();
        let prefill_buckets = req(&j, "prefill_buckets")?
            .as_arr()
            .ok_or_else(|| anyhow!("prefill_buckets not an array"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect::<Vec<_>>();

        let mut variants = Vec::new();
        for v in req(&j, "variants")?
            .as_arr()
            .ok_or_else(|| anyhow!("variants not an array"))?
        {
            let mut params = Vec::new();
            for p in req(v, "params")?
                .as_arr()
                .ok_or_else(|| anyhow!("params not an array"))?
            {
                params.push(ParamEntry {
                    name: req_str(p, "name")?,
                    dtype: DType::parse(&req_str(p, "dtype")?)?,
                    shape: req(p, "shape")?
                        .as_arr()
                        .ok_or_else(|| anyhow!("shape not an array"))?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                    offset: req_usize(p, "offset")?,
                    nbytes: req_usize(p, "nbytes")?,
                });
            }
            let mut executables = BTreeMap::new();
            for (tag, e) in req(v, "executables")?
                .as_obj()
                .ok_or_else(|| anyhow!("executables not an object"))?
            {
                executables.insert(
                    tag.clone(),
                    ExecSpec {
                        file: req_str(e, "file")?,
                        weight_params: str_list(e, "weight_params")?,
                        inputs: str_list(e, "inputs")?,
                        outputs: str_list(e, "outputs")?,
                    },
                );
            }
            let tardis = match v.get("fold_ratio").and_then(Json::as_f64) {
                None => None,
                Some(r) => {
                    let d = TardisFfnConfig::default();
                    let predictor = match v.get("predictor").and_then(Json::as_str) {
                        None => d.predictor,
                        Some(s) => PredictorKind::parse(s).ok_or_else(|| {
                            anyhow!("unknown predictor {s:?} (norm|quantized)")
                        })?,
                    };
                    Some(TardisFfnConfig {
                        fold_ratio: r,
                        linear_lo: v
                            .get("linear_lo")
                            .and_then(Json::as_f64)
                            .map(|x| x as f32)
                            .unwrap_or(d.linear_lo),
                        linear_hi: v
                            .get("linear_hi")
                            .and_then(Json::as_f64)
                            .map(|x| x as f32)
                            .unwrap_or(d.linear_hi),
                        predictor_threshold: v
                            .get("predictor_threshold")
                            .and_then(Json::as_f64)
                            .map(|x| x as f32)
                            .unwrap_or(d.predictor_threshold),
                        predictor,
                        predictor_bits: match v
                            .get("predictor_bits")
                            .and_then(Json::as_usize)
                        {
                            None => d.predictor_bits,
                            Some(b) if (2..=8).contains(&b) => b as u8,
                            Some(b) => {
                                bail!("predictor_bits {b} not in 2..=8")
                            }
                        },
                        predictor_group: v
                            .get("predictor_group")
                            .and_then(Json::as_usize)
                            .unwrap_or(d.predictor_group),
                        top_k: v
                            .get("top_k")
                            .and_then(Json::as_usize)
                            .unwrap_or(d.top_k),
                    })
                }
            };
            variants.push(VariantSpec {
                name: req_str(v, "name")?,
                ffn_mode: req_str(v, "ffn_mode")?,
                fix_capacity: req_usize(v, "fix_capacity")?,
                compression_ratio: req(v, "compression_ratio")?
                    .as_f64()
                    .ok_or_else(|| anyhow!("compression_ratio not a number"))?,
                weights_file: req_str(v, "weights_file")?,
                params,
                executables,
                tardis,
            });
        }

        Ok(Manifest {
            dir,
            model,
            batch: req_usize(&j, "batch")?,
            prefill_buckets,
            kv_shape,
            variants,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "variant {name:?} not in manifest (have: {})",
                    self.variants
                        .iter()
                        .map(|v| v.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn variant_names(&self) -> Vec<&str> {
        self.variants.iter().map(|v| v.name.as_str()).collect()
    }

    /// Default artifacts location: `$TARDIS_ARTIFACTS` or `artifacts/`.
    pub fn default_path() -> PathBuf {
        std::env::var("TARDIS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
            .join("manifest.json")
    }
}

impl VariantSpec {
    pub fn param(&self, name: &str) -> Result<&ParamEntry> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow!("param {name:?} not in weight table"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("i8").unwrap().size(), 1);
        assert!(DType::parse("f16").is_err());
    }

    #[test]
    fn parses_minimal_manifest() {
        let tmp = std::env::temp_dir().join("tardis_manifest_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let path = tmp.join("manifest.json");
        std::fs::write(
            &path,
            r#"{
              "model": {"name":"m","vocab":256,"d_model":8,"n_layers":1,
                        "n_heads":2,"d_ff":32,"max_seq":16,"act":"gelu"},
              "batch": 2,
              "prefill_buckets": [4],
              "kv_shape": [1,2,2,2,16,4],
              "variants": [
                {"name":"dense","ffn_mode":"dense","fix_capacity":0,
                 "compression_ratio":0.0,"weights_file":"dense.weights.bin",
                 "params":[{"name":"top.embed","dtype":"f32","shape":[256,8],
                            "offset":0,"nbytes":8192}],
                 "executables":{"decode":{"file":"d.hlo.txt",
                   "weight_params":["top.embed"],
                   "inputs":["tokens:i32[2]"],"outputs":["logits","kv"]}}}
              ]
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.model.d_model, 8);
        assert_eq!(m.batch, 2);
        assert_eq!(m.variant_names(), vec!["dense"]);
        let v = m.variant("dense").unwrap();
        assert_eq!(v.param("top.embed").unwrap().nbytes, 8192);
        assert!(v.tardis.is_none(), "no fold_ratio key => no tardis config");
        assert!(m.variant("nope").is_err());
        assert!(v.param("nope").is_err());
    }

    #[test]
    fn parses_variant_tardis_fields() {
        let tmp = std::env::temp_dir().join("tardis_manifest_test_fold");
        std::fs::create_dir_all(&tmp).unwrap();
        let path = tmp.join("manifest.json");
        std::fs::write(
            &path,
            r#"{
              "model": {"name":"m","vocab":256,"d_model":8,"n_layers":1,
                        "n_heads":2,"d_ff":32,"max_seq":16,"act":"gelu"},
              "batch": 2,
              "prefill_buckets": [4],
              "kv_shape": [1,2,2,2,16,4],
              "variants": [
                {"name":"tardis80","ffn_mode":"tardis","fix_capacity":8,
                 "compression_ratio":0.8,"weights_file":"t.weights.bin",
                 "fold_ratio":0.8,"linear_lo":-4.0,"linear_hi":4.5,
                 "params":[],"executables":{}}
              ]
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&path).unwrap();
        let t = m.variant("tardis80").unwrap().tardis.expect("tardis cfg");
        assert!((t.fold_ratio - 0.8).abs() < 1e-12);
        assert!((t.linear_lo + 4.0).abs() < 1e-6);
        assert!((t.linear_hi - 4.5).abs() < 1e-6);
        // unspecified keys fall back to the defaults
        let d = TardisFfnConfig::default();
        assert!((t.predictor_threshold - d.predictor_threshold).abs() < 1e-6);
        assert_eq!(t.predictor, d.predictor);
        assert_eq!(t.predictor_bits, d.predictor_bits);
        assert_eq!(t.predictor_group, d.predictor_group);
        assert_eq!(t.top_k, d.top_k);
    }

    #[test]
    fn parses_variant_predictor_fields() {
        let tmp = std::env::temp_dir().join("tardis_manifest_test_pred");
        std::fs::create_dir_all(&tmp).unwrap();
        let path = tmp.join("manifest.json");
        std::fs::write(
            &path,
            r#"{
              "model": {"name":"m","vocab":256,"d_model":8,"n_layers":1,
                        "n_heads":2,"d_ff":32,"max_seq":16,"act":"gelu"},
              "batch": 2,
              "prefill_buckets": [4],
              "kv_shape": [1,2,2,2,16,4],
              "variants": [
                {"name":"tardis80","ffn_mode":"tardis","fix_capacity":6,
                 "compression_ratio":0.8,"weights_file":"t.weights.bin",
                 "fold_ratio":0.8,"predictor":"quantized",
                 "predictor_bits":3,"predictor_group":8,"top_k":6,
                 "params":[],"executables":{}}
              ]
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&path).unwrap();
        let t = m.variant("tardis80").unwrap().tardis.expect("tardis cfg");
        assert_eq!(t.predictor, PredictorKind::Quantized);
        assert_eq!(t.predictor_bits, 3);
        assert_eq!(t.predictor_group, 8);
        assert_eq!(t.top_k, 6);
        // out-of-range bit widths are a load error, not a silent wrap
        std::fs::write(
            &path,
            r#"{
              "model": {"name":"m","vocab":256,"d_model":8,"n_layers":1,
                        "n_heads":2,"d_ff":32,"max_seq":16,"act":"gelu"},
              "batch": 2,
              "prefill_buckets": [4],
              "kv_shape": [1,2,2,2,16,4],
              "variants": [
                {"name":"t","ffn_mode":"tardis","fix_capacity":0,
                 "compression_ratio":0.8,"weights_file":"t.weights.bin",
                 "fold_ratio":0.8,"predictor_bits":260,
                 "params":[],"executables":{}}
              ]
            }"#,
        )
        .unwrap();
        assert!(Manifest::load(&path).is_err());
        // a bogus predictor name is a load error, not a silent default
        std::fs::write(
            &path,
            r#"{
              "model": {"name":"m","vocab":256,"d_model":8,"n_layers":1,
                        "n_heads":2,"d_ff":32,"max_seq":16,"act":"gelu"},
              "batch": 2,
              "prefill_buckets": [4],
              "kv_shape": [1,2,2,2,16,4],
              "variants": [
                {"name":"t","ffn_mode":"tardis","fix_capacity":0,
                 "compression_ratio":0.8,"weights_file":"t.weights.bin",
                 "fold_ratio":0.8,"predictor":"psychic",
                 "params":[],"executables":{}}
              ]
            }"#,
        )
        .unwrap();
        assert!(Manifest::load(&path).is_err());
    }

    #[test]
    fn predictor_kind_roundtrip() {
        for k in [PredictorKind::Norm, PredictorKind::Quantized] {
            assert_eq!(PredictorKind::parse(k.name()), Some(k));
        }
        assert_eq!(PredictorKind::parse("oracle"), None);
        assert_eq!(PredictorKind::default(), PredictorKind::Norm);
    }

    #[test]
    fn backend_kind_roundtrip() {
        for k in [BackendKind::Native, BackendKind::Mock, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("gpu"), None);
        assert_eq!(BackendKind::default(), BackendKind::Native);
    }

    #[test]
    fn native_variant_names_resolve() {
        assert_eq!(native_ffn_mode("dense"), Some(FfnMode::Dense));
        match native_ffn_mode("tardis80") {
            Some(FfnMode::Tardis(t)) => {
                assert!((t.fold_ratio - 0.8).abs() < 1e-12)
            }
            other => panic!("unexpected {other:?}"),
        }
        match native_ffn_mode("tardis-ref65") {
            Some(FfnMode::TardisReference(t)) => {
                assert!((t.fold_ratio - 0.65).abs() < 1e-12)
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(native_ffn_mode("tardis0"), None);
        assert_eq!(native_ffn_mode("tardis101"), None);
        assert_eq!(native_ffn_mode("mock"), None);
        let builtins = builtin_native_variants();
        assert_eq!(builtins.len(), 4);
        assert_eq!(builtins[0].0, "dense");
    }

    #[test]
    fn native_config_defaults_to_tiny_gelu() {
        let c = NativeModelConfig::default();
        assert_eq!(c.d_model, 128);
        assert_eq!(c.d_ff, 512);
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.vocab, 256);
        // auto paged pool: no block pressure by default
        assert_eq!(c.resolved_kv_layout(), (4 * 16, 16));
    }

    #[test]
    fn kv_layout_resolution() {
        let mut c = NativeModelConfig::tiny_gelu();
        c.kv_blocks = 24;
        assert_eq!(c.resolved_kv_layout(), (24, 16));
        // block size clamps to the context length
        c.kv_block_size = 4096;
        assert_eq!(c.resolved_kv_layout(), (24, 256));
        c.kv_block_size = 0;
        assert_eq!(c.resolved_kv_layout().1, 1);
    }
}
