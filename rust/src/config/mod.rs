//! Manifest + configuration loading.
//!
//! `artifacts/manifest.json` is the contract between the python compile
//! path and the rust runtime: the model shape, the KV-cache layout, and
//! for each compression variant the HLO executables, their input
//! signatures, and the weight table into `<variant>.weights.bin`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub act: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I8,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "i8" => DType::I8,
            other => bail!("unknown dtype {other:?} in manifest"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ExecSpec {
    pub file: String,
    pub weight_params: Vec<String>,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    pub ffn_mode: String,
    pub fix_capacity: usize,
    pub compression_ratio: f64,
    pub weights_file: String,
    pub params: Vec<ParamEntry>,
    pub executables: BTreeMap<String, ExecSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub batch: usize,
    pub prefill_buckets: Vec<usize>,
    pub kv_shape: Vec<usize>,
    pub variants: Vec<VariantSpec>,
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest missing key {key:?}"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?.as_usize().ok_or_else(|| anyhow!("{key:?} not a usize"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(req(j, key)?
        .as_str()
        .ok_or_else(|| anyhow!("{key:?} not a string"))?
        .to_string())
}

fn str_list(j: &Json, key: &str) -> Result<Vec<String>> {
    Ok(req(j, key)?
        .as_arr()
        .ok_or_else(|| anyhow!("{key:?} not an array"))?
        .iter()
        .filter_map(|v| v.as_str().map(str::to_string))
        .collect())
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let dir = path
            .parent()
            .ok_or_else(|| anyhow!("manifest has no parent dir"))?
            .to_path_buf();

        let m = req(&j, "model")?;
        let model = ModelInfo {
            name: req_str(m, "name")?,
            vocab: req_usize(m, "vocab")?,
            d_model: req_usize(m, "d_model")?,
            n_layers: req_usize(m, "n_layers")?,
            n_heads: req_usize(m, "n_heads")?,
            d_ff: req_usize(m, "d_ff")?,
            max_seq: req_usize(m, "max_seq")?,
            act: req_str(m, "act")?,
        };

        let kv_shape = req(&j, "kv_shape")?
            .as_arr()
            .ok_or_else(|| anyhow!("kv_shape not an array"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect::<Vec<_>>();
        let prefill_buckets = req(&j, "prefill_buckets")?
            .as_arr()
            .ok_or_else(|| anyhow!("prefill_buckets not an array"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect::<Vec<_>>();

        let mut variants = Vec::new();
        for v in req(&j, "variants")?
            .as_arr()
            .ok_or_else(|| anyhow!("variants not an array"))?
        {
            let mut params = Vec::new();
            for p in req(v, "params")?
                .as_arr()
                .ok_or_else(|| anyhow!("params not an array"))?
            {
                params.push(ParamEntry {
                    name: req_str(p, "name")?,
                    dtype: DType::parse(&req_str(p, "dtype")?)?,
                    shape: req(p, "shape")?
                        .as_arr()
                        .ok_or_else(|| anyhow!("shape not an array"))?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                    offset: req_usize(p, "offset")?,
                    nbytes: req_usize(p, "nbytes")?,
                });
            }
            let mut executables = BTreeMap::new();
            for (tag, e) in req(v, "executables")?
                .as_obj()
                .ok_or_else(|| anyhow!("executables not an object"))?
            {
                executables.insert(
                    tag.clone(),
                    ExecSpec {
                        file: req_str(e, "file")?,
                        weight_params: str_list(e, "weight_params")?,
                        inputs: str_list(e, "inputs")?,
                        outputs: str_list(e, "outputs")?,
                    },
                );
            }
            variants.push(VariantSpec {
                name: req_str(v, "name")?,
                ffn_mode: req_str(v, "ffn_mode")?,
                fix_capacity: req_usize(v, "fix_capacity")?,
                compression_ratio: req(v, "compression_ratio")?
                    .as_f64()
                    .ok_or_else(|| anyhow!("compression_ratio not a number"))?,
                weights_file: req_str(v, "weights_file")?,
                params,
                executables,
            });
        }

        Ok(Manifest {
            dir,
            model,
            batch: req_usize(&j, "batch")?,
            prefill_buckets,
            kv_shape,
            variants,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "variant {name:?} not in manifest (have: {})",
                    self.variants
                        .iter()
                        .map(|v| v.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn variant_names(&self) -> Vec<&str> {
        self.variants.iter().map(|v| v.name.as_str()).collect()
    }

    /// Default artifacts location: `$TARDIS_ARTIFACTS` or `artifacts/`.
    pub fn default_path() -> PathBuf {
        std::env::var("TARDIS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
            .join("manifest.json")
    }
}

impl VariantSpec {
    pub fn param(&self, name: &str) -> Result<&ParamEntry> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow!("param {name:?} not in weight table"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("i8").unwrap().size(), 1);
        assert!(DType::parse("f16").is_err());
    }

    #[test]
    fn parses_minimal_manifest() {
        let tmp = std::env::temp_dir().join("tardis_manifest_test");
        std::fs::create_dir_all(&tmp).unwrap();
        let path = tmp.join("manifest.json");
        std::fs::write(
            &path,
            r#"{
              "model": {"name":"m","vocab":256,"d_model":8,"n_layers":1,
                        "n_heads":2,"d_ff":32,"max_seq":16,"act":"gelu"},
              "batch": 2,
              "prefill_buckets": [4],
              "kv_shape": [1,2,2,2,16,4],
              "variants": [
                {"name":"dense","ffn_mode":"dense","fix_capacity":0,
                 "compression_ratio":0.0,"weights_file":"dense.weights.bin",
                 "params":[{"name":"top.embed","dtype":"f32","shape":[256,8],
                            "offset":0,"nbytes":8192}],
                 "executables":{"decode":{"file":"d.hlo.txt",
                   "weight_params":["top.embed"],
                   "inputs":["tokens:i32[2]"],"outputs":["logits","kv"]}}}
              ]
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.model.d_model, 8);
        assert_eq!(m.batch, 2);
        assert_eq!(m.variant_names(), vec!["dense"]);
        let v = m.variant("dense").unwrap();
        assert_eq!(v.param("top.embed").unwrap().nbytes, 8192);
        assert!(m.variant("nope").is_err());
        assert!(v.param("nope").is_err());
    }
}
