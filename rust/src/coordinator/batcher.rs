//! Continuous batching: tracks which request occupies which KV slot and
//! assembles the per-iteration decode inputs (one token per active slot,
//! sentinel (0, max_seq) for idle slots, which the executable masks out).

use super::request::RequestId;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlotState {
    pub req: RequestId,
    /// Next KV position to write (== tokens already in the cache).
    pub next_pos: usize,
    /// The token to feed at the next decode step.
    pub pending_token: i32,
}

#[derive(Debug)]
pub struct Batcher {
    slots: Vec<Option<SlotState>>,
    max_seq: usize,
}

impl Batcher {
    pub fn new(batch: usize, max_seq: usize) -> Self {
        Batcher { slots: vec![None; batch], max_seq }
    }

    pub fn batch(&self) -> usize {
        self.slots.len()
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.active() == 0
    }

    pub fn occupy(&mut self, slot: usize, req: RequestId, next_pos: usize, pending_token: i32) {
        assert!(self.slots[slot].is_none(), "slot {slot} already occupied");
        self.slots[slot] = Some(SlotState { req, next_pos, pending_token });
    }

    pub fn vacate(&mut self, slot: usize) -> Option<SlotState> {
        self.slots[slot].take()
    }

    pub fn slot_of(&self, req: RequestId) -> Option<usize> {
        self.slots
            .iter()
            .position(|s| s.map(|st| st.req) == Some(req))
    }

    pub fn state(&self, slot: usize) -> Option<&SlotState> {
        self.slots[slot].as_ref()
    }

    /// After sampling, feed the next token and advance the position.
    pub fn advance(&mut self, slot: usize, token: i32) {
        let st = self.slots[slot].as_mut().expect("advance on empty slot");
        st.next_pos += 1;
        st.pending_token = token;
    }

    /// Decode-step inputs for the planned `selected` slots only; every
    /// other slot — idle, prefilling, or stalled waiting for a KV block —
    /// gets the sentinel (token 0, pos = max_seq) the model masks out, so
    /// an unplanned slot's cache is never advanced.
    pub fn decode_inputs_for(&self, selected: &[usize]) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = vec![0i32; self.slots.len()];
        let mut pos = vec![self.max_seq as i32; self.slots.len()];
        for &slot in selected {
            if let Some(st) = &self.slots[slot] {
                tokens[slot] = st.pending_token;
                pos[slot] = st.next_pos as i32;
            }
        }
        (tokens, pos)
    }

    /// Build the decode-step inputs for every occupied slot (the
    /// all-planned special case of [`Self::decode_inputs_for`]).
    pub fn decode_inputs(&self) -> (Vec<i32>, Vec<i32>) {
        let occupied: Vec<usize> =
            (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect();
        self.decode_inputs_for(&occupied)
    }

    /// Slots that took part in a decode step (active, in-range).
    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.slots.len())
            .filter(|&i| {
                self.slots[i]
                    .map(|st| st.next_pos < self.max_seq)
                    .unwrap_or(false)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::property;

    #[test]
    fn occupy_advance_vacate() {
        let mut b = Batcher::new(4, 32);
        assert!(b.is_idle());
        b.occupy(2, 77, 5, 9);
        assert_eq!(b.active(), 1);
        assert_eq!(b.slot_of(77), Some(2));
        let (toks, pos) = b.decode_inputs();
        assert_eq!(toks, vec![0, 0, 9, 0]);
        assert_eq!(pos, vec![32, 32, 5, 32]);
        b.advance(2, 11);
        let (toks, pos) = b.decode_inputs();
        assert_eq!(toks[2], 11);
        assert_eq!(pos[2], 6);
        let st = b.vacate(2).unwrap();
        assert_eq!(st.req, 77);
        assert!(b.is_idle());
    }

    #[test]
    fn decode_inputs_for_masks_unplanned_slots() {
        let mut b = Batcher::new(4, 32);
        b.occupy(1, 7, 5, 9);
        b.occupy(3, 8, 2, 4);
        // slot 3 occupied but not planned (e.g. stalled on a KV block)
        let (toks, pos) = b.decode_inputs_for(&[1]);
        assert_eq!(toks, vec![0, 9, 0, 0]);
        assert_eq!(pos, vec![32, 5, 32, 32]);
        let (toks, pos) = b.decode_inputs_for(&[1, 3]);
        assert_eq!(toks, vec![0, 9, 0, 4]);
        assert_eq!(pos, vec![32, 5, 32, 2]);
    }

    #[test]
    #[should_panic(expected = "already occupied")]
    fn double_occupy_panics() {
        let mut b = Batcher::new(2, 8);
        b.occupy(0, 1, 0, 0);
        b.occupy(0, 2, 0, 0);
    }

    #[test]
    fn prop_inputs_consistent() {
        property("decode inputs match slot states", 150, |rng| {
            let n = 1 + rng.usize_below(8);
            let max_seq = 16 + rng.usize_below(64);
            let mut b = Batcher::new(n, max_seq);
            let mut occupied = vec![false; n];
            for step in 0..50 {
                let slot = rng.usize_below(n);
                if occupied[slot] {
                    if rng.bool(0.3) {
                        b.vacate(slot);
                        occupied[slot] = false;
                    } else {
                        b.advance(slot, rng.below(255) as i32);
                    }
                } else if rng.bool(0.6) {
                    b.occupy(slot, step as u64, rng.usize_below(max_seq), rng.below(255) as i32);
                    occupied[slot] = true;
                }
                let (toks, pos) = b.decode_inputs();
                prop_assert!(toks.len() == n && pos.len() == n);
                for i in 0..n {
                    if occupied[i] {
                        let st = b.state(i).unwrap();
                        prop_assert!(pos[i] == st.next_pos as i32);
                        prop_assert!(toks[i] == st.pending_token);
                    } else {
                        prop_assert!(pos[i] == max_seq as i32, "idle slot {i} pos {}", pos[i]);
                    }
                }
                prop_assert!(b.active() == occupied.iter().filter(|&&o| o).count());
            }
            Ok(())
        });
    }
}
