//! The serving engine: queue → scheduler plan → step-model → sampler,
//! one iteration at a time (so callers — CLI, server, benches — control
//! pacing and can interleave with I/O).
//!
//! This is the "vLLM-like" runtime of Fig 13: continuous batching over a
//! **paged KV cache**, driven by the [`StepPlan`] a pluggable
//! [`crate::coordinator::scheduler::SchedulerPolicy`] emits each
//! iteration. The engine owns two deterministic allocators — decode
//! slots (batch rows) and fixed-size KV blocks — plus a per-slot
//! [`BlockTable`] it mirrors into the model via
//! [`StepModel::kv_map`]. A mixed plan carries admissions, prefill
//! chunks and the decode batch in one iteration; under block pressure
//! the scheduler preempts the lowest-priority decode, whose cache is
//! saved to the host swap pool ([`StepModel::kv_save`]) and restored
//! bitwise on re-admission. The "HF-like" sequential baseline is
//! [`InferenceEngine::generate_sequential`], which runs one request at a
//! time with batch occupancy 1 — the difference between the two is the
//! serving-system contribution the paper piggybacks on.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::util::rng::Rng;
use crate::util::stats::Samples;

use super::batcher::Batcher;
use super::kv::{BlockAllocator, BlockTable, KvLayout, PrefixMatch, RadixCache};
use super::model::{KvSwap, StepModel};
use super::queue::{AdmissionQueue, QueueFull};
use super::request::{FinishReason, Request, RequestId, RequestState, SamplingParams};
use super::sampler::{argmax, sample};
use super::scheduler::{Abort, Admission, ChunkSpec, DecodeBatch, DecodeSlotView, Preemption};
use super::scheduler::{PrefillView, QueuedRequest, Resume, SchedView, Scheduler};
use super::scheduler::{SchedulerConfig, StepOutcome, StepPlan, SwappedView};

/// Typed admission failure, so callers (the front door's shed path) can
/// tell retryable backpressure from a permanently bad request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The admission queue is at capacity — back off and retry.
    Backpressure { queue_depth: usize, capacity: usize },
    /// Malformed request; retrying can never succeed.
    Invalid(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure { .. } => write!(f, "queue full (backpressure)"),
            SubmitError::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// An injected one-shot step failure (the chaos harness's kill switch);
/// see [`crate::coordinator::health::FaultPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepFault {
    /// `step()` panics — exercises the worker's `catch_unwind` isolation.
    Panic,
    /// `step()` returns an error.
    Error,
}

/// The engine's request-latency clock. `Wall` (default) reads real time
/// relative to engine construction. `Virtual` is a replay clock advanced
/// only by [`InferenceEngine::advance_clock_us`], which makes every
/// µs stamp — and therefore TTFT/TPOT, EDF deadlines and goodput —
/// bitwise reproducible across runs of the same trace.
#[derive(Debug, Clone, Copy)]
enum Clock {
    Wall(Instant),
    Virtual(u64),
}

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub queue_capacity: usize,
    pub scheduler: SchedulerConfig,
    /// Share KV blocks across requests with common prompt prefixes
    /// (radix cache + copy-on-write). Takes effect only on backends
    /// whose [`StepModel::supports_block_sharing`] is true.
    pub prefix_cache: bool,
    /// Self-speculative decoding: draft up to this many tokens per
    /// decode step through the all-folded forced FFN path and verify
    /// them with one batched multi-row forward, retiring the longest
    /// agreeing prefix plus the verify's own token (0 = off). Greedy
    /// token-match acceptance keeps accepted streams bitwise identical
    /// to plain decode; requests sampling at temperature > 0 simply
    /// decode one token at a time. Takes effect only on backends whose
    /// [`StepModel::supports_speculation`] is true.
    pub speculate_k: usize,
    /// Adapt each request's draft window to its observed acceptance:
    /// shrink toward 1 when a step rejects most drafts, recover toward
    /// `speculate_k` when every draft lands — and let degraded-tier
    /// requests (whose verify path IS the forced fold, so drafts always
    /// agree) grow to `2 * speculate_k`.
    pub speculate_adaptive: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            queue_capacity: 64,
            scheduler: SchedulerConfig::default(),
            prefix_cache: true,
            speculate_k: 0,
            speculate_adaptive: false,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub iterations: u64,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    pub tokens_generated: u64,
    pub admitted: u64,
    pub finished: u64,
    /// Decodes evicted under KV block pressure (cache swapped to host).
    pub preemptions: u64,
    /// Swapped requests restored into fresh blocks.
    pub resumes: u64,
    /// Prefill jobs aborted back to the queue under block pressure
    /// (last-resort deadlock breaker; they re-prefill from scratch).
    pub prefill_aborts: u64,
    /// Iterations whose plan carried prefill chunks *and* a decode batch
    /// (the chunked-prefill co-scheduling case).
    pub mixed_steps: u64,
    /// Summed decode-batch occupancy over all decode steps (streaming —
    /// a long-running server's stats stay O(1) in time and space; the
    /// continuous-batching win is the mean, `occupancy_sum/decode_steps`)
    pub occupancy_sum: u64,
    /// High-water mark of concurrently in-flight prefill jobs.
    pub max_concurrent_prefills: usize,
    /// High-water mark of KV blocks in use.
    pub max_blocks_used: usize,
    /// Cumulative TARDIS row routing (0/0 unless the model runs a
    /// partially-linear FFN; see [`StepModel::ffn_telemetry`]).
    pub ffn_folded_rows: u64,
    pub ffn_fallback_rows: u64,
    /// Fallback fraction of the most recent step that routed any rows.
    pub ffn_last_step_fallback_rate: Option<f64>,
    /// Prompt tokens whose prefill was skipped via prefix-cache hits.
    pub prefix_hit_tokens: u64,
    /// Cached blocks mapped into admitted requests' tables (cumulative).
    pub prefix_shared_blocks: u64,
    /// Copy-on-write block copies (partial-tail hits diverging).
    pub cow_copies: u64,
    /// Cold cache leaves evicted to satisfy block allocation.
    pub prefix_evictions: u64,
    /// Draft tokens proposed by the speculative decode loop.
    pub spec_drafted: u64,
    /// Drafted tokens the verify forward accepted (token-match).
    pub spec_accepted: u64,
    /// Decode steps that carried at least one draft token.
    pub spec_steps: u64,
}

impl EngineStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.occupancy_sum as f64 / self.decode_steps as f64
    }

    /// Fraction of decode steps that carried prefill chunks in the same
    /// iteration; `None` before the first decode step.
    pub fn mixed_step_ratio(&self) -> Option<f64> {
        if self.decode_steps == 0 {
            None
        } else {
            Some(self.mixed_steps as f64 / self.decode_steps as f64)
        }
    }

    /// Cumulative fraction of FFN rows routed to the dense fallback
    /// path; `None` until a partially-linear model routed any row.
    pub fn ffn_fallback_rate(&self) -> Option<f64> {
        let total = self.ffn_folded_rows + self.ffn_fallback_rows;
        if total == 0 {
            None
        } else {
            Some(self.ffn_fallback_rows as f64 / total as f64)
        }
    }

    /// Fraction of drafted tokens the verify accepted; `None` until the
    /// speculative loop drafted anything.
    pub fn spec_acceptance(&self) -> Option<f64> {
        if self.spec_drafted == 0 {
            None
        } else {
            Some(self.spec_accepted as f64 / self.spec_drafted as f64)
        }
    }
}

/// Point-in-time engine state for the server's `stats` op and for tests.
#[derive(Debug, Clone)]
pub struct EngineSnapshot {
    pub policy: &'static str,
    pub queue_depth: usize,
    pub queue_pressure: f64,
    pub active_slots: usize,
    pub inflight_prefills: usize,
    pub slots_total: usize,
    /// Physical KV blocks in the pool.
    pub kv_blocks_total: usize,
    /// KV blocks currently allocated to block tables.
    pub kv_blocks_used: usize,
    /// `kv_blocks_used / kv_blocks_total`.
    pub block_utilization: f64,
    /// Requests currently swapped out awaiting re-admission.
    pub swapped: usize,
    /// Cumulative preemption count.
    pub preemptions: u64,
    /// Fraction of decode steps that also carried prefill chunks.
    pub mixed_step_ratio: Option<f64>,
    pub mean_occupancy: f64,
    pub tokens_generated: u64,
    pub admitted: u64,
    pub finished: u64,
    pub iterations: u64,
    /// Cumulative fraction of FFN rows routed to the dense fallback path
    /// (None unless the backend runs a partially-linear FFN).
    pub ffn_fallback_rate: Option<f64>,
    /// Same fraction over the most recent step that routed any rows.
    pub ffn_last_step_fallback_rate: Option<f64>,
    /// Blocks currently indexed by the radix prefix cache.
    pub prefix_cached_blocks: usize,
    /// Cached blocks reclaimable right now by cold-leaf eviction.
    pub prefix_evictable_blocks: usize,
    /// Cumulative prefix-cache counters (see [`EngineStats`]).
    pub prefix_hit_tokens: u64,
    pub prefix_shared_blocks: u64,
    pub cow_copies: u64,
    pub prefix_evictions: u64,
}

/// A finished request handed back to the caller.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    pub reason: FinishReason,
    /// Time spent waiting in the admission queue (enqueue → slot
    /// admission). Distinct from `first_token_ms`, which also includes
    /// the prefill itself. Preemption does not reset it.
    pub queue_ms: f64,
    pub first_token_ms: f64,
    pub total_ms: f64,
    /// Engine-clock TTFT in µs (enqueue → first token). On the virtual
    /// replay clock this is bitwise deterministic; on the wall clock it
    /// tracks `first_token_ms`. `None` if no token was produced.
    pub ttft_us: Option<u64>,
    /// Engine-clock total latency in µs (enqueue → finish).
    pub total_us: Option<u64>,
    /// Whether the request ran degraded (forced-fold FFN) — stamped at
    /// the submission boundary by overload admission control.
    pub degraded: bool,
    /// Prompt tokens served from the prefix cache (prefill skipped).
    pub prefix_hit_tokens: usize,
}

/// An in-flight prefill: the prompt is written to the cache chunk by
/// chunk; `next` counts tokens already written (a prefix-cache hit
/// starts `next` at the hit length — those tokens never run prefill).
struct PrefillJob {
    req: Request,
    slot: usize,
    next: usize,
    /// The hit's tail block is shared and only partially covered: it
    /// must be copy-on-write'd before the first suffix chunk appends.
    cow_pending: bool,
}

/// A preempted request parked in the host swap pool: its saved cache,
/// plus the batcher state needed to re-occupy a slot on resume.
struct SwappedRequest {
    req: Request,
    swap: KvSwap,
    next_pos: usize,
    pending_token: i32,
}

/// The concurrently in-flight prefill jobs, keyed by KV slot (sorted, so
/// every traversal is deterministic).
#[derive(Default)]
pub struct PrefillSet {
    jobs: BTreeMap<usize, PrefillJob>,
}

impl PrefillSet {
    fn insert(&mut self, job: PrefillJob) {
        debug_assert!(!self.jobs.contains_key(&job.slot), "slot {} already prefilling", job.slot);
        self.jobs.insert(job.slot, job);
    }

    fn remove(&mut self, slot: usize) -> Option<PrefillJob> {
        self.jobs.remove(&slot)
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

pub struct InferenceEngine<M: StepModel> {
    pub model: M,
    cfg: EngineConfig,
    queue: AdmissionQueue,
    /// Decode slots (batch rows).
    slots: BlockAllocator,
    /// KV blocks (paged cache units).
    blocks: BlockAllocator,
    layout: KvLayout,
    /// Per-slot block tables, mirrored into the model via `kv_map`.
    tables: Vec<BlockTable>,
    batcher: Batcher,
    scheduler: Scheduler,
    /// requests currently decoding, by slot
    active: HashMap<usize, Request>,
    /// concurrently in-flight multi-chunk prefills, by slot
    prefilling: PrefillSet,
    /// preempted requests awaiting re-admission, FIFO by eviction time
    swapped: VecDeque<SwappedRequest>,
    completions: VecDeque<Completion>,
    next_id: RequestId,
    rngs: HashMap<RequestId, Rng>,
    /// Radix index over cached prefix blocks (empty while `sharing` is
    /// off; each indexed block holds one cache reference).
    prefix: RadixCache,
    /// `cfg.prefix_cache && model.supports_block_sharing()`.
    sharing: bool,
    /// Pinned prefix matches for queued requests, refreshed every
    /// admissible iteration so the planner's hit discounts stay valid
    /// (pinned blocks cannot be evicted out from under an admission).
    queue_pins: HashMap<RequestId, PrefixMatch>,
    /// Set when an idle plan coincided with held pins (the pins may be
    /// starving decode growth); suppresses repinning until a step does
    /// work again.
    pins_suspended: bool,
    /// One-shot injected step faults by iteration number (chaos
    /// harness); consumed when fired.
    step_faults: Vec<(u64, StepFault)>,
    /// Source of the µs stamps on [`Request`] / [`Completion`].
    clock: Clock,
    /// `cfg.speculate_k`, zeroed when the backend lacks speculation
    /// support — the engine-wide draft ceiling.
    spec_k: usize,
    /// Per-slot adaptive draft window (equal to `spec_k` when adaptation
    /// is off); reset at admission/resume, updated per speculative step.
    spec_win: Vec<usize>,
    pub stats: EngineStats,
    pub decode_latency_ms: Samples,
}

impl<M: StepModel> InferenceEngine<M> {
    pub fn new(model: M, cfg: EngineConfig) -> Self {
        let batch = model.batch();
        let max_seq = model.max_seq();
        let layout = model.kv_layout();
        let sharing = cfg.prefix_cache && model.supports_block_sharing();
        let spec_k = if model.supports_speculation() { cfg.speculate_k } else { 0 };
        InferenceEngine {
            queue: AdmissionQueue::new(cfg.queue_capacity),
            slots: BlockAllocator::new(batch),
            blocks: BlockAllocator::new(layout.num_blocks),
            tables: (0..batch).map(|_| BlockTable::new(layout.block_size)).collect(),
            layout,
            batcher: Batcher::new(batch, max_seq),
            scheduler: Scheduler::new(cfg.scheduler.clone()),
            active: HashMap::new(),
            prefilling: PrefillSet::default(),
            swapped: VecDeque::new(),
            completions: VecDeque::new(),
            next_id: 1,
            rngs: HashMap::new(),
            prefix: RadixCache::new(layout.block_size),
            sharing,
            queue_pins: HashMap::new(),
            pins_suspended: false,
            step_faults: Vec::new(),
            clock: Clock::Wall(Instant::now()),
            spec_k,
            spec_win: vec![spec_k; batch],
            stats: EngineStats::default(),
            decode_latency_ms: Samples::new(),
            model,
            cfg,
        }
    }

    /// Whether prefix sharing is live (configured on *and* supported by
    /// the backend).
    pub fn prefix_sharing(&self) -> bool {
        self.sharing
    }

    pub fn queue_pressure(&self) -> f64 {
        self.queue.pressure()
    }

    /// Engine-clock reading in µs: elapsed wall time since construction,
    /// or the virtual replay clock's current value.
    pub fn now_us(&self) -> u64 {
        match self.clock {
            Clock::Wall(epoch) => epoch.elapsed().as_micros() as u64,
            Clock::Virtual(now) => now,
        }
    }

    /// Switch to the deterministic virtual clock (starting at 0). Time
    /// then advances only via [`Self::advance_clock_us`] — the trace
    /// harness charges a modeled cost per step, so latency stamps and
    /// goodput become bitwise-reproducible functions of the trace.
    pub fn enable_virtual_clock(&mut self) {
        self.clock = Clock::Virtual(0);
    }

    /// Advance the virtual clock; no-op on the wall clock.
    pub fn advance_clock_us(&mut self, us: u64) {
        if let Clock::Virtual(now) = &mut self.clock {
            *now = now.saturating_add(us);
        }
    }

    /// The longest sequence a request can reach: the model's context,
    /// clamped to what the block pool can hold — so a lone request can
    /// always grow to its finish without deadlocking on blocks.
    fn max_request_seq(&self) -> usize {
        self.model.max_seq().min(self.layout.capacity_tokens())
    }

    pub fn snapshot(&self) -> EngineSnapshot {
        let kv_total = self.blocks.capacity();
        let kv_used = self.blocks.used();
        let evictable =
            if self.sharing { self.prefix.evictable_blocks(&self.blocks) } else { 0 };
        EngineSnapshot {
            policy: self.scheduler.policy_name(),
            queue_depth: self.queue.len(),
            queue_pressure: self.queue.pressure(),
            active_slots: self.active.len(),
            inflight_prefills: self.prefilling.len(),
            slots_total: self.slots.capacity(),
            kv_blocks_total: kv_total,
            kv_blocks_used: kv_used,
            block_utilization: kv_used as f64 / kv_total.max(1) as f64,
            swapped: self.swapped.len(),
            preemptions: self.stats.preemptions,
            mixed_step_ratio: self.stats.mixed_step_ratio(),
            mean_occupancy: self.stats.mean_occupancy(),
            tokens_generated: self.stats.tokens_generated,
            admitted: self.stats.admitted,
            finished: self.stats.finished,
            iterations: self.stats.iterations,
            ffn_fallback_rate: self.stats.ffn_fallback_rate(),
            ffn_last_step_fallback_rate: self.stats.ffn_last_step_fallback_rate,
            prefix_cached_blocks: self.prefix.len(),
            prefix_evictable_blocks: evictable,
            prefix_hit_tokens: self.stats.prefix_hit_tokens,
            prefix_shared_blocks: self.stats.prefix_shared_blocks,
            cow_copies: self.stats.cow_copies,
            prefix_evictions: self.stats.prefix_evictions,
        }
    }

    /// Submit a request; fails with backpressure when the queue is full.
    pub fn submit(&mut self, prompt: Vec<i32>, params: SamplingParams) -> Result<RequestId> {
        self.try_submit(prompt, params).map_err(|e| anyhow!("{e}"))
    }

    /// [`submit`](Self::submit) with a typed error, so the front door
    /// can shed on backpressure and reject invalid requests outright.
    pub fn try_submit(
        &mut self,
        prompt: Vec<i32>,
        params: SamplingParams,
    ) -> Result<RequestId, SubmitError> {
        let max_prompt = self.max_request_seq().saturating_sub(1);
        if prompt.is_empty() || prompt.len() > max_prompt {
            return Err(SubmitError::Invalid(format!(
                "prompt length {} not in 1..={max_prompt}",
                prompt.len()
            )));
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut req = Request::new(id, prompt, params);
        req.enqueued_us = self.now_us();
        self.queue.push(req).map_err(|QueueFull(_)| {
            self.next_id -= 1;
            SubmitError::Backpressure {
                queue_depth: self.queue.len(),
                capacity: self.queue.capacity(),
            }
        })?;
        Ok(id)
    }

    /// Arm a one-shot injected fault that fires when `step()` runs
    /// iteration number `iteration` (1-based, matching
    /// `stats.iterations`).
    pub fn inject_step_fault(&mut self, iteration: u64, fault: StepFault) {
        self.step_faults.push((iteration, fault));
    }

    /// Pop any completions produced so far.
    pub fn take_completions(&mut self) -> Vec<Completion> {
        self.completions.drain(..).collect()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
            && self.active.is_empty()
            && self.prefilling.is_empty()
            && self.swapped.is_empty()
    }

    /// Run one scheduler iteration: build a [`StepPlan`] from the current
    /// state and execute it. Returns what the plan actually did.
    pub fn step(&mut self) -> Result<StepOutcome> {
        self.stats.iterations += 1;
        if let Some(pos) =
            self.step_faults.iter().position(|&(it, _)| it == self.stats.iterations)
        {
            let (it, fault) = self.step_faults.swap_remove(pos);
            match fault {
                StepFault::Panic => panic!("injected fault: panic at iteration {it}"),
                StepFault::Error => {
                    return Err(anyhow!("injected fault: step error at iteration {it}"))
                }
            }
        }
        let before = self.model.ffn_telemetry();
        let plan = self.make_plan();
        let outcome = self.execute_plan(plan)?;
        if let Some(t) = self.model.ffn_telemetry() {
            let prev = before.unwrap_or_default();
            self.stats.ffn_folded_rows = t.folded_rows;
            self.stats.ffn_fallback_rows = t.fallback_rows;
            let folded = t.folded_rows.saturating_sub(prev.folded_rows);
            let fallback = t.fallback_rows.saturating_sub(prev.fallback_rows);
            if folded + fallback > 0 {
                self.stats.ffn_last_step_fallback_rate =
                    Some(fallback as f64 / (folded + fallback) as f64);
            }
        }
        if outcome.did_work() {
            self.pins_suspended = false;
        } else if !self.is_idle() && !self.queue_pins.is_empty() {
            // An idle plan while work exists means the pinned prefix
            // blocks may be what's starving it (pins make their blocks
            // non-evictable). Drop them and stop repinning until some
            // step makes progress; affected requests fall back to full
            // prefill cost, which always fits an otherwise-empty pool.
            self.drop_queue_pins();
            self.pins_suspended = true;
        } else if !self.is_idle() && self.sharing && !self.prefix.is_empty() {
            // Still idle with no pins left to drop: the cache itself can
            // wedge the pool. A live table sharing a trie *descendant*
            // keeps the trunk above it out of the all-free evictable set
            // even at refcount 1, so those blocks are dead weight no
            // allocation can reclaim — and with a single starved prefill
            // the PR-5 abort breaker (which needs two) never fires.
            // Prune cache references coldest-leaf-first until a block
            // actually frees or the cache empties; an empty cache
            // restores the pre-sharing invariants (any single prompt
            // fits the pool).
            let before = self.blocks.available();
            while self.prefix.prune_one(&mut self.blocks).is_some() {
                self.stats.prefix_evictions += 1;
                if self.blocks.available() > before {
                    break;
                }
            }
        }
        Ok(outcome)
    }

    /// Drive until every submitted request has finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_completions())
    }

    // -- internals ----------------------------------------------------------

    /// Tokens the next prefill chunk for `remaining` prompt tokens runs.
    fn next_chunk_len(&self, remaining: usize) -> usize {
        remaining.min(self.model.bucket_for(remaining))
    }

    fn make_plan(&mut self) -> StepPlan {
        let free_slots = self.slots.free_list();
        // Snapshotting (and policy-ranking) the queue is only worth it
        // when an admission could actually happen this iteration; under
        // a deep backlog with full slots this keeps the per-step cost
        // independent of queue depth.
        let concurrency = self.scheduler.config().max_concurrent_prefills.max(1);
        let admissible = !free_slots.is_empty() && self.prefilling.len() < concurrency;
        if admissible {
            self.refresh_queue_pins();
        }
        let queued: Vec<QueuedRequest> = if admissible {
            self.queue
                .iter()
                .enumerate()
                .map(|(arrival, r)| {
                    let (hit_tokens, hit_blocks, cow) = self
                        .queue_pins
                        .get(&r.id)
                        .map_or((0, 0, false), |p| (p.hit_tokens, p.blocks.len(), p.cow));
                    QueuedRequest {
                        id: r.id,
                        prompt_len: r.prompt.len(),
                        priority: r.params.priority,
                        arrival,
                        deadline_us: r.deadline_us(),
                        first_chunk: self.next_chunk_len(r.prompt.len() - hit_tokens),
                        hit_tokens,
                        hit_blocks,
                        cow,
                    }
                })
                .collect()
        } else {
            Vec::new()
        };
        let inflight = self.prefill_views();
        let decoding = self.decode_views();
        let swapped: Vec<SwappedView> = self
            .swapped
            .iter()
            .map(|s| SwappedView {
                request: s.req.id,
                priority: s.req.params.priority,
                tokens: s.next_pos,
            })
            .collect();
        // The planner may budget against cold cache leaves: they are
        // reclaimed on demand (`alloc_block` evicts), and pinning keeps
        // the hits it was promised out of the evictable set.
        let evictable =
            if self.sharing { self.prefix.evictable_blocks(&self.blocks) } else { 0 };
        let view = SchedView {
            queued: &queued,
            free_slots: &free_slots,
            inflight: &inflight,
            decoding: &decoding,
            swapped: &swapped,
            free_blocks: self.blocks.available() + evictable,
            block_size: self.layout.block_size,
            can_preempt: self.model.supports_preemption(),
        };
        self.scheduler.plan(&view)
    }

    /// Drop every queued-request pin, releasing the cache's promise
    /// refs. The free list keeps blocks sorted, so release order cannot
    /// perturb future allocation (bitwise history invariance).
    fn drop_queue_pins(&mut self) {
        for (_, pin) in self.queue_pins.drain() {
            for &b in &pin.blocks {
                self.blocks.release(b);
            }
        }
    }

    /// Re-match every queued request against the radix cache in queue
    /// order, pinning hit blocks (one `retain` each) so eviction cannot
    /// invalidate the discounts the planner is about to budget. Pins
    /// are consumed by [`Self::admit`] and rebuilt next admissible
    /// iteration — so a request enqueued behind a sibling picks up the
    /// sibling's blocks as soon as its chunks land in the cache.
    fn refresh_queue_pins(&mut self) {
        self.drop_queue_pins();
        if !self.sharing || self.pins_suspended {
            return;
        }
        for r in self.queue.iter() {
            let m = self.prefix.match_and_pin(&mut self.blocks, &r.prompt);
            if m.is_hit() {
                self.queue_pins.insert(r.id, m);
            }
        }
    }

    /// Scheduler-facing prefill snapshot, slot-sorted (the `PrefillSet`
    /// is keyed by slot).
    fn prefill_views(&self) -> Vec<PrefillView> {
        self.prefilling
            .jobs
            .values()
            .map(|j| {
                let remaining = j.req.prompt.len() - j.next;
                PrefillView {
                    request: j.req.id,
                    slot: j.slot,
                    remaining,
                    written: j.next,
                    blocks_held: self.tables[j.slot].blocks().len(),
                    next_chunk: self.next_chunk_len(remaining),
                    cow_pending: j.cow_pending,
                }
            })
            .collect()
    }

    /// Scheduler-facing decode snapshot, slot-ascending, with the block
    /// pressure each slot exerts this iteration.
    fn decode_views(&self) -> Vec<DecodeSlotView> {
        self.batcher
            .active_slots()
            .into_iter()
            .map(|slot| {
                let st = self.batcher.state(slot).expect("active slot state");
                let req = &self.active[&slot];
                // Preempting this slot only reclaims blocks it holds
                // alone; shared prefix blocks stay pinned by the cache
                // and their other referents.
                let owned = self.tables[slot]
                    .blocks()
                    .iter()
                    .filter(|&&b| self.blocks.ref_count(b) == 1)
                    .count();
                DecodeSlotView {
                    slot,
                    request: req.id,
                    priority: req.params.priority,
                    blocks_held: owned,
                    next_pos: st.next_pos,
                    table_blocks: self.tables[slot].blocks().len(),
                    spec_window: self.spec_window_for(slot, st.next_pos, req),
                }
            })
            .collect()
    }

    /// Draft tokens the engine wants the planner to grant `slot` this
    /// step: 0 when speculation is off (engine-wide or for this request
    /// — non-greedy sampling consumes RNG per token, so drafting would
    /// change the stream), otherwise the slot's adaptive window clamped
    /// to the sequence-length and max-tokens room actually left.
    fn spec_window_for(&self, slot: usize, next_pos: usize, req: &Request) -> usize {
        if self.spec_k == 0 || req.params.temperature > 0.0 {
            return 0;
        }
        // The verify writes rows at next_pos..=next_pos+w, all < max_seq.
        let room = self.max_request_seq().saturating_sub(next_pos + 1);
        // Tokens the request can still emit beyond the guaranteed one.
        let want = req
            .params
            .max_tokens
            .saturating_sub(req.generated.len())
            .saturating_sub(1);
        self.spec_win[slot].min(room).min(want)
    }

    fn execute_plan(&mut self, plan: StepPlan) -> Result<StepOutcome> {
        let mut outcome = StepOutcome {
            admitted: plan.admissions.len(),
            prefill_chunks: plan.prefill_chunks.len(),
            decoded_slots: plan
                .decode
                .as_ref()
                .map(|d| d.slots.len())
                .unwrap_or(0),
            decoded_tokens: 0,
            preempted: plan.preemptions.len(),
            resumed: plan.resumes.len(),
            aborted: plan.aborts.len(),
        };
        self.model.plan_begin(&plan);
        for p in &plan.preemptions {
            self.preempt(p)?;
        }
        for a in &plan.aborts {
            self.abort_prefill(a)?;
        }
        for r in &plan.resumes {
            self.resume(r)?;
        }
        for adm in &plan.admissions {
            self.admit(adm)?;
        }
        self.stats.max_concurrent_prefills = self
            .stats
            .max_concurrent_prefills
            .max(self.prefilling.len());
        for chunk in &plan.prefill_chunks {
            self.run_prefill_chunk(chunk)?;
        }
        if let Some(batch) = &plan.decode {
            outcome.decoded_tokens = self.do_decode_step(batch)?;
        }
        if plan.is_mixed() {
            self.stats.mixed_steps += 1;
        }
        self.stats.max_blocks_used = self.stats.max_blocks_used.max(self.blocks.used());
        self.model.plan_end(&outcome);
        Ok(outcome)
    }

    /// Allocate one KV block, evicting cold prefix-cache leaves on
    /// demand when the free list is empty (the planner already counted
    /// them as free).
    fn alloc_block(&mut self, slot: usize) -> Result<usize> {
        loop {
            if let Some(b) = self.blocks.alloc() {
                return Ok(b);
            }
            if self.prefix.evict_one(&mut self.blocks).is_none() {
                return Err(anyhow!(
                    "scheduler bug: KV block pool exhausted growing slot {slot}"
                ));
            }
            self.stats.prefix_evictions += 1;
        }
    }

    /// Grow `slot`'s block table to `target_blocks` and mirror the new
    /// mapping into the model.
    fn grow_table(&mut self, slot: usize, target_blocks: usize) -> Result<()> {
        let mut grew = false;
        while self.tables[slot].blocks().len() < target_blocks {
            let b = self.alloc_block(slot)?;
            self.tables[slot].push_block(b);
            grew = true;
        }
        if grew {
            self.model.kv_map(slot, &self.tables[slot]);
        }
        Ok(())
    }

    /// Release `slot`'s blocks back to the pool and clear its mapping.
    fn release_kv(&mut self, slot: usize) {
        for b in self.tables[slot].clear() {
            self.blocks.release(b);
        }
        self.model.kv_map(slot, &self.tables[slot]);
    }

    /// Evict a decoding request: save its cache to the swap pool, free
    /// its blocks and slot. Its RNG stream stays put, so the resumed
    /// request samples exactly the tokens it would have uninterrupted.
    fn preempt(&mut self, p: &Preemption) -> Result<()> {
        let mut req = self.active.remove(&p.slot).ok_or_else(|| {
            anyhow!("scheduler bug: preemption of idle slot {}", p.slot)
        })?;
        ensure!(
            req.id == p.request,
            "scheduler bug: slot {} runs request {} not {}",
            p.slot,
            req.id,
            p.request
        );
        let st = self.batcher.vacate(p.slot).expect("decoding slot occupied");
        let swap = self.model.kv_save(p.slot, st.next_pos)?;
        self.release_kv(p.slot);
        self.slots.release(p.slot);
        self.model.set_slot_degrade(p.slot, false);
        req.state = RequestState::Preempted;
        self.stats.preemptions += 1;
        self.swapped.push_back(SwappedRequest {
            req,
            swap,
            next_pos: st.next_pos,
            pending_token: st.pending_token,
        });
        Ok(())
    }

    /// Abort an in-flight prefill back to the queue front (last-resort
    /// deadlock breaker): release its blocks and slot, and let it
    /// re-prefill from scratch later. No token was sampled yet and its
    /// RNG reseeds identically on re-admission, so the eventual stream
    /// is unchanged.
    fn abort_prefill(&mut self, a: &Abort) -> Result<()> {
        let job = self.prefilling.remove(a.slot).ok_or_else(|| {
            anyhow!("scheduler bug: abort of idle slot {}", a.slot)
        })?;
        ensure!(
            job.req.id == a.request,
            "scheduler bug: slot {} runs request {} not {}",
            a.slot,
            job.req.id,
            a.request
        );
        let mut req = job.req;
        self.release_kv(a.slot);
        self.slots.release(a.slot);
        self.model.set_slot_degrade(a.slot, false);
        self.rngs.remove(&req.id);
        req.state = RequestState::Queued;
        req.prefix_hit = 0; // it will re-match (or not) on re-admission
        self.queue.requeue_front(req);
        self.stats.prefill_aborts += 1;
        Ok(())
    }

    /// Re-admit a swapped request: fresh blocks (possibly different
    /// physical ids), bitwise cache restore, back into the decode batch.
    fn resume(&mut self, r: &Resume) -> Result<()> {
        let idx = self
            .swapped
            .iter()
            .position(|s| s.req.id == r.request)
            .ok_or_else(|| {
                anyhow!("scheduler bug: resume of unswapped request {}", r.request)
            })?;
        let SwappedRequest { mut req, swap, next_pos, pending_token } =
            self.swapped.remove(idx).expect("indexed swap entry");
        ensure!(
            self.slots.claim(r.slot),
            "scheduler bug: resume into unavailable slot {}",
            r.slot
        );
        self.grow_table(r.slot, self.layout.blocks_to_resume(next_pos))?;
        self.model.kv_restore(r.slot, &swap)?;
        self.model.set_slot_degrade(r.slot, req.params.degrade);
        req.state = RequestState::Decoding { slot: r.slot };
        self.spec_win[r.slot] = self.spec_k;
        self.batcher.occupy(r.slot, req.id, next_pos, pending_token);
        self.active.insert(r.slot, req);
        self.stats.resumes += 1;
        Ok(())
    }

    /// Move a queued request into the decode slot the plan assigned it.
    fn admit(&mut self, adm: &Admission) -> Result<()> {
        let mut req = self.queue.take(adm.request).ok_or_else(|| {
            anyhow!("scheduler bug: admission of unqueued request {}", adm.request)
        })?;
        ensure!(
            self.slots.claim(adm.slot),
            "scheduler bug: admission into unavailable slot {}",
            adm.slot
        );
        debug_assert!(
            self.tables[adm.slot].blocks().is_empty(),
            "slot {} admitted with a live block table",
            adm.slot
        );
        // Consume the request's prefix pin: the pinned blocks (and their
        // promise refs) move into the block table, and prefill starts
        // past the hit — those tokens never run a chunk.
        let pin = self.queue_pins.remove(&adm.request).unwrap_or_default();
        if pin.is_hit() {
            for &b in &pin.blocks {
                self.tables[adm.slot].push_block(b);
            }
            self.model.kv_map(adm.slot, &self.tables[adm.slot]);
            req.prefix_hit = pin.hit_tokens;
            self.stats.prefix_hit_tokens += pin.hit_tokens as u64;
            self.stats.prefix_shared_blocks += pin.blocks.len() as u64;
        }
        req.state = RequestState::Prefilling { slot: adm.slot, next: pin.hit_tokens };
        req.admitted_at = Some(Instant::now());
        self.model.set_slot_degrade(adm.slot, req.params.degrade);
        self.rngs.insert(req.id, Rng::new(req.params.seed ^ req.id));
        self.stats.admitted += 1;
        self.prefilling.insert(PrefillJob {
            req,
            slot: adm.slot,
            next: pin.hit_tokens,
            cow_pending: pin.cow,
        });
        Ok(())
    }

    /// Copy-on-write the partially-covered tail block of a prefix hit
    /// before the first suffix chunk appends into it: the hit cells
    /// move to a block this request owns alone, the shared original
    /// keeps serving the cache. (Full-block hits never append into
    /// shared blocks, so this is the only COW site.)
    fn cow_tail_block(&mut self, job: &mut PrefillJob) -> Result<()> {
        let bs = self.layout.block_size;
        let (idx, cells) = (job.next / bs, job.next % bs);
        debug_assert!(cells > 0, "COW flagged on a block-aligned hit");
        let shared = self.tables[job.slot].blocks()[idx];
        let fresh = self.alloc_block(job.slot)?;
        self.model.kv_copy_block(shared, fresh, cells)?;
        self.tables[job.slot].replace_block(idx, fresh);
        self.blocks.release(shared);
        self.model.kv_map(job.slot, &self.tables[job.slot]);
        job.cow_pending = false;
        self.stats.cow_copies += 1;
        Ok(())
    }

    /// Run one prompt chunk for the prefill job in `spec.slot`; on the
    /// final chunk, sample the first token and hand the request to the
    /// decode batcher.
    fn run_prefill_chunk(&mut self, spec: &ChunkSpec) -> Result<()> {
        let mut job = self.prefilling.remove(spec.slot).ok_or_else(|| {
            anyhow!("scheduler bug: prefill chunk for idle slot {}", spec.slot)
        })?;
        ensure!(
            job.req.id == spec.request,
            "scheduler bug: slot {} runs request {} not {}",
            spec.slot,
            job.req.id,
            spec.request
        );
        if job.cow_pending {
            self.cow_tail_block(&mut job)?;
        }
        let remaining = job.req.prompt.len() - job.next;
        let bucket = self.model.bucket_for(remaining);
        let take = remaining.min(bucket);
        self.grow_table(spec.slot, self.layout.blocks_for(job.next + take))?;
        let mut chunk = job.req.prompt[job.next..job.next + take].to_vec();
        chunk.resize(bucket, 0); // pad; the model overwrites before reads
        let logits = self.model.prefill(bucket, &chunk, take, job.slot, job.next)?;
        self.stats.prefill_chunks += 1;
        job.next += take;
        if self.sharing {
            // Index every full prompt block written so far: a sibling
            // request admitted next iteration hits them immediately.
            self.prefix.insert(
                &mut self.blocks,
                &job.req.prompt[..job.next],
                self.tables[spec.slot].blocks(),
            );
        }
        if job.next < job.req.prompt.len() {
            job.req.state = RequestState::Prefilling { slot: job.slot, next: job.next };
            self.prefilling.insert(job);
            return Ok(());
        }
        // Prompt complete: sample the first generated token from the
        // prefill logits and move to decoding.
        let now_us = self.now_us();
        let PrefillJob { mut req, slot, .. } = job;
        let rng = self.rngs.get_mut(&req.id).expect("rng");
        let tok = sample(&logits, &req.params, rng);
        req.record_token(tok);
        req.first_token_us.get_or_insert(now_us);
        self.stats.tokens_generated += 1;
        if let Some(reason) = req.stop_reason(self.max_request_seq()) {
            self.finish(req, slot, reason, false);
            return Ok(());
        }
        req.state = RequestState::Decoding { slot };
        self.spec_win[slot] = self.spec_k;
        self.batcher.occupy(slot, req.id, req.prompt.len(), tok);
        self.active.insert(slot, req);
        Ok(())
    }

    /// Run the plan's decode batch, plain or speculative, and return the
    /// number of tokens actually retired.
    fn do_decode_step(&mut self, batch: &DecodeBatch) -> Result<usize> {
        debug_assert_eq!(batch.slots.len(), batch.draft.len(), "ragged decode batch");
        // Grow the tables of planned slots to cover every write of this
        // step — the base token plus any granted draft window (the
        // scheduler budgeted these allocations).
        for (i, &slot) in batch.slots.iter().enumerate() {
            let next_pos = self
                .batcher
                .state(slot)
                .ok_or_else(|| {
                    anyhow!("scheduler bug: decode batch names idle slot {slot}")
                })?
                .next_pos;
            let w = batch.draft.get(i).copied().unwrap_or(0);
            self.grow_table(slot, self.layout.blocks_for(next_pos + 1 + w))?;
            // Decode writes only land in blocks the slot owns alone:
            // partial prompt tails are never cache-indexed and resume
            // restores into fresh blocks, so no COW is needed here.
            debug_assert!(
                self.blocks.ref_count(
                    self.tables[slot].blocks()[next_pos / self.layout.block_size]
                ) == 1,
                "decode write into a shared KV block (slot {slot})"
            );
        }
        if batch.draft.iter().sum::<usize>() == 0 {
            self.plain_decode_step(batch)
        } else {
            self.speculative_decode_step(batch)
        }
    }

    fn plain_decode_step(&mut self, batch: &DecodeBatch) -> Result<usize> {
        // Only the planned slots feed real inputs; occupied-but-unplanned
        // slots (stalled on a block) are masked so their cache state
        // cannot advance.
        let (tokens, pos) = self.batcher.decode_inputs_for(&batch.slots);
        let t0 = Instant::now();
        let logits = self.model.decode(&tokens, &pos)?;
        self.decode_latency_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += batch.slots.len() as u64;
        let vocab = self.model.vocab();
        let max_seq = self.max_request_seq();
        let now_us = self.now_us();
        // The plan's slot list is sorted: sampling order (and therefore
        // per-request RNG consumption) is deterministic, not HashMap
        // iteration order.
        for &slot in &batch.slots {
            let Some(req) = self.active.get_mut(&slot) else {
                return Err(anyhow!("scheduler bug: decode batch names idle slot {slot}"));
            };
            let row = &logits[slot * vocab..(slot + 1) * vocab];
            let rng = self.rngs.get_mut(&req.id).expect("rng");
            let tok = sample(row, &req.params, rng);
            req.record_token(tok);
            req.first_token_us.get_or_insert(now_us);
            self.stats.tokens_generated += 1;
            self.batcher.advance(slot, tok);
            if let Some(reason) = req.stop_reason(max_seq) {
                let req = self.active.remove(&slot).expect("req");
                self.finish(req, slot, reason, true);
            }
        }
        Ok(batch.slots.len())
    }

    /// One self-speculative decode step. `draft[i]` forced-fold draft
    /// forwards propose greedy tokens for slot `slots[i]`; one batched
    /// multi-row verify forward recomputes positions
    /// `next_pos..=next_pos + draft[i]` exactly — overwriting the
    /// approximate K/V rows the drafts wrote — and the longest agreeing
    /// prefix plus the verify's own next token retire atomically.
    /// Speculation is greedy-gated, and greedy sampling consumes no RNG,
    /// so retired streams are bitwise identical to plain decode.
    fn speculative_decode_step(&mut self, batch: &DecodeBatch) -> Result<usize> {
        let n_slots = batch.slots.len();
        let batch_n = self.model.batch();
        let model_seq = self.model.max_seq();
        let vocab = self.model.vocab();
        let t0 = Instant::now();

        // -- draft phase: one batched forced-fold forward per round -----
        // cur[i] = (token, pos) the next draft round feeds for slot i.
        let mut cur: Vec<(i32, usize)> = Vec::with_capacity(n_slots);
        for &slot in &batch.slots {
            let st = self.batcher.state(slot).ok_or_else(|| {
                anyhow!("scheduler bug: decode batch names idle slot {slot}")
            })?;
            cur.push((st.pending_token, st.next_pos));
        }
        let mut drafts: Vec<Vec<i32>> = vec![Vec::new(); n_slots];
        let max_w = batch.draft.iter().copied().max().unwrap_or(0);
        for round in 0..max_w {
            let mut tokens = vec![0i32; batch_n];
            let mut pos = vec![model_seq as i32; batch_n];
            for (i, &slot) in batch.slots.iter().enumerate() {
                if batch.draft[i] > round {
                    tokens[slot] = cur[i].0;
                    pos[slot] = cur[i].1 as i32;
                }
            }
            let logits = self.model.decode_draft(&tokens, &pos)?;
            for (i, &slot) in batch.slots.iter().enumerate() {
                if batch.draft[i] > round {
                    let t = argmax(&logits[slot * vocab..(slot + 1) * vocab]);
                    drafts[i].push(t);
                    cur[i] = (t, cur[i].1 + 1);
                }
            }
        }

        // -- verify phase: one batched multi-row forward ----------------
        // Per slot: the pending token at next_pos, then its drafts —
        // slot-ascending, positions consecutive.
        let mut vtokens = Vec::new();
        let mut vslots = Vec::new();
        let mut vpos = Vec::new();
        let mut row0 = Vec::with_capacity(n_slots);
        for (i, &slot) in batch.slots.iter().enumerate() {
            let st = self.batcher.state(slot).expect("planned slot state");
            row0.push(vtokens.len());
            vtokens.push(st.pending_token);
            vslots.push(slot);
            vpos.push(st.next_pos as i32);
            for (j, &dt) in drafts[i].iter().enumerate() {
                vtokens.push(dt);
                vslots.push(slot);
                vpos.push((st.next_pos + 1 + j) as i32);
            }
        }
        let logits = self.model.decode_multi(&vtokens, &vslots, &vpos)?;
        self.decode_latency_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        self.stats.decode_steps += 1;
        self.stats.spec_steps += 1;
        self.stats.occupancy_sum += n_slots as u64;

        // -- retirement: atomic per slot, sorted slot order -------------
        let max_seq = self.max_request_seq();
        let now_us = self.now_us();
        let mut retired_total = 0usize;
        for (i, &slot) in batch.slots.iter().enumerate() {
            let w = drafts[i].len();
            self.stats.spec_drafted += w as u64;
            let mut matched = 0usize;
            let mut finish_reason = None;
            let degrade;
            {
                let Some(req) = self.active.get_mut(&slot) else {
                    return Err(anyhow!(
                        "scheduler bug: decode batch names idle slot {slot}"
                    ));
                };
                degrade = req.params.degrade;
                let rng = self.rngs.get_mut(&req.id).expect("rng");
                for r in 0..=w {
                    let row = &logits[(row0[i] + r) * vocab..(row0[i] + r + 1) * vocab];
                    // Greedy (speculation is gated on temperature 0), so
                    // `sample` is argmax and consumes no RNG.
                    let tok = sample(row, &req.params, rng);
                    req.record_token(tok);
                    req.first_token_us.get_or_insert(now_us);
                    self.stats.tokens_generated += 1;
                    self.batcher.advance(slot, tok);
                    retired_total += 1;
                    if let Some(reason) = req.stop_reason(max_seq) {
                        finish_reason = Some(reason);
                        break;
                    }
                    if r < w {
                        if tok == drafts[i][r] {
                            // The draft agreed: row r+1's input was this
                            // very token, so its logits are valid too.
                            matched += 1;
                        } else {
                            break;
                        }
                    }
                }
            }
            self.stats.spec_accepted += matched as u64;
            if self.cfg.speculate_adaptive && w > 0 {
                // Back off toward 1 when most drafts miss, recover when
                // a whole window lands; degraded requests verify through
                // the forced fold itself (drafts always agree), so their
                // ceiling doubles.
                let cap = if degrade { self.spec_k * 2 } else { self.spec_k }.max(1);
                let win = &mut self.spec_win[slot];
                if matched == w {
                    *win = (*win + 1).min(cap);
                } else if matched * 2 < w {
                    *win = win.saturating_sub(1).max(1);
                }
            }
            if let Some(reason) = finish_reason {
                let req = self.active.remove(&slot).expect("req");
                self.finish(req, slot, reason, true);
            } else {
                // Roll the block table back to exactly what the retired
                // tokens need: the rejected tail's cells are unreachable
                // (attention reads only 0..=pos) but its surplus blocks
                // must return to the pool before the next plan.
                let next_pos =
                    self.batcher.state(slot).expect("planned slot state").next_pos;
                self.truncate_kv(slot, next_pos);
            }
        }
        Ok(retired_total)
    }

    /// Shrink `slot`'s block table to what `tokens` resident KV entries
    /// need, releasing surplus (speculative-growth) blocks and mirroring
    /// the new mapping into the model.
    fn truncate_kv(&mut self, slot: usize, tokens: usize) {
        let popped = self.tables[slot].truncate(self.layout.blocks_for(tokens));
        if popped.is_empty() {
            return;
        }
        for b in popped {
            debug_assert!(
                self.blocks.ref_count(b) == 1,
                "speculative growth block {b} is shared"
            );
            self.blocks.release(b);
        }
        self.model.kv_map(slot, &self.tables[slot]);
    }

    fn finish(&mut self, mut req: Request, slot: usize, reason: FinishReason, in_batcher: bool) {
        req.finish(reason);
        req.finished_us = Some(self.now_us());
        if in_batcher {
            self.batcher.vacate(slot);
        }
        self.release_kv(slot);
        self.slots.release(slot);
        self.model.set_slot_degrade(slot, false);
        self.rngs.remove(&req.id);
        self.stats.finished += 1;
        self.completions.push_back(Completion {
            id: req.id,
            prompt: req.prompt.clone(),
            tokens: req.generated.clone(),
            reason,
            queue_ms: req
                .admitted_at
                .map(|t| t.duration_since(req.enqueued_at).as_secs_f64() * 1e3)
                .unwrap_or(f64::NAN),
            first_token_ms: req
                .first_token_at
                .map(|t| t.duration_since(req.enqueued_at).as_secs_f64() * 1e3)
                .unwrap_or(f64::NAN),
            total_ms: req
                .finished_at
                .map(|t| t.duration_since(req.enqueued_at).as_secs_f64() * 1e3)
                .unwrap_or(f64::NAN),
            ttft_us: req.first_token_us.map(|t| t.saturating_sub(req.enqueued_us)),
            total_us: req.finished_us.map(|t| t.saturating_sub(req.enqueued_us)),
            degraded: req.params.degrade,
            prefix_hit_tokens: req.prefix_hit,
        });
    }

    /// HF-like sequential baseline: run a single request start-to-finish
    /// with batch occupancy 1 (no continuous batching). Used by Fig 13 to
    /// compare runtimes.
    pub fn generate_sequential(
        &mut self,
        prompt: Vec<i32>,
        params: SamplingParams,
    ) -> Result<Completion> {
        if !self.is_idle() {
            return Err(anyhow!("sequential generation requires an idle engine"));
        }
        let id = self.submit(prompt, params)?;
        let completions = self.run_to_completion()?;
        completions
            .into_iter()
            .find(|c| c.id == id)
            .ok_or_else(|| anyhow!("request {id} did not complete"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::model::MockModel;
    use crate::coordinator::scheduler::PolicyKind;

    fn engine(batch: usize) -> InferenceEngine<MockModel> {
        InferenceEngine::new(MockModel::new(batch, 64, 16, vec![4, 8]), EngineConfig::default())
    }

    #[test]
    fn single_request_generates_expected_tokens() {
        let mut e = engine(2);
        // prompt [1,2,3]: last tok 3 at pos 2 -> first gen (3+2)%16 = 5
        // then 5 at pos 3 -> 8; 8 at pos 4 -> 12
        let params = SamplingParams { max_tokens: 3, ..Default::default() };
        let id = e.submit(vec![1, 2, 3], params).unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].tokens, vec![5, 8, 12]);
        assert_eq!(done[0].reason, FinishReason::Length);
    }

    #[test]
    fn multi_chunk_prefill_matches_single_chunk() {
        // a 7-token prompt must split into 4+3 chunks with buckets [4,8]?
        // bucket_for(7)=8 so single chunk; force multi-chunk via buckets [4]
        let model = MockModel::new(1, 64, 16, vec![4]);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        let prompt = vec![1, 2, 3, 4, 5, 6, 7];
        let params = SamplingParams { max_tokens: 1, ..Default::default() };
        let id = e.submit(prompt.clone(), params).unwrap();
        let done = e.run_to_completion().unwrap();
        // last tok 7 at pos 6 -> (7+6)%16 = 13
        assert_eq!(done[0].tokens, vec![13]);
        assert_eq!(done[0].id, id);
        assert!(e.stats.prefill_chunks >= 2);
    }

    #[test]
    fn concurrent_requests_share_decode_steps() {
        let mut e = engine(4);
        let n = 4;
        for i in 0..n {
            let params = SamplingParams { max_tokens: 8, ..Default::default() };
            e.submit(vec![1 + i as i32, 2, 3], params).unwrap();
        }
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), n);
        // Continuous batching: far fewer decode steps than tokens.
        let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(tokens, 8 * n);
        assert!(
            (e.stats.decode_steps as usize) < tokens,
            "decode steps {} should be < total tokens {tokens}",
            e.stats.decode_steps
        );
        assert!(e.stats.mean_occupancy() > 1.5, "occupancy {}", e.stats.mean_occupancy());
    }

    #[test]
    fn mixed_iterations_carry_prefill_and_decode() {
        // Long prompts keep prefilling while earlier requests decode: the
        // default mixed planner must overlap them in single iterations.
        let model = MockModel::new(4, 64, 16, vec![4]);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        for i in 0..4 {
            let params = SamplingParams { max_tokens: 12, ..Default::default() };
            e.submit(vec![1 + i; 12], params).unwrap();
        }
        e.run_to_completion().unwrap();
        assert!(e.stats.mixed_steps > 0, "no mixed iterations despite prefill+decode overlap");
        assert!(e.stats.mixed_step_ratio().unwrap() > 0.0);
    }

    #[test]
    fn more_requests_than_slots_queue_up() {
        let mut e = engine(2);
        for i in 0..6 {
            let params = SamplingParams { max_tokens: 4, ..Default::default() };
            e.submit(vec![1 + i, 2], params).unwrap();
        }
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 6);
        assert!(e.is_idle());
    }

    #[test]
    fn backpressure_propagates() {
        let model = MockModel::new(1, 64, 16, vec![4]);
        let mut e = InferenceEngine::new(
            model,
            EngineConfig { queue_capacity: 2, ..Default::default() },
        );
        e.submit(vec![1], SamplingParams::default()).unwrap();
        e.submit(vec![2], SamplingParams::default()).unwrap();
        assert!(e.submit(vec![3], SamplingParams::default()).is_err());
    }

    #[test]
    fn rejects_overlong_prompt() {
        let mut e = engine(2);
        assert!(e.submit(vec![1; 64], SamplingParams::default()).is_err());
        assert!(e.submit(vec![1; 63], SamplingParams::default()).is_ok());
    }

    #[test]
    fn prompt_limit_respects_block_pool() {
        // 3 blocks of 8 tokens = 24-token effective context, though the
        // model's max_seq is 64.
        let model = MockModel::new(2, 64, 16, vec![4, 8]).with_kv_layout(3, 8);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        assert!(e.submit(vec![1; 24], SamplingParams::default()).is_err());
        assert!(e.submit(vec![1; 23], SamplingParams::default()).is_ok());
    }

    #[test]
    fn context_overflow_finishes_request() {
        let model = MockModel::new(1, 16, 8, vec![4]);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        let params = SamplingParams { max_tokens: 1000, ..Default::default() };
        e.submit(vec![1, 2, 3, 4], params).unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done[0].reason, FinishReason::ContextOverflow);
        assert_eq!(done[0].tokens.len() + 4, 16);
    }

    #[test]
    fn overflow_clamps_to_block_pool_capacity() {
        // Pool capacity 2*4 = 8 tokens < max_seq 16: a request stops at
        // the pool limit instead of deadlocking on blocks.
        let model = MockModel::new(1, 16, 8, vec![4]).with_kv_layout(2, 4);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        let params = SamplingParams { max_tokens: 1000, ..Default::default() };
        e.submit(vec![1, 2, 3], params).unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done[0].reason, FinishReason::ContextOverflow);
        assert_eq!(done[0].tokens.len() + 3, 8);
    }

    #[test]
    fn blocks_released_on_finish() {
        let model = MockModel::new(2, 64, 16, vec![4, 8]).with_kv_layout(16, 4);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        for i in 0..4 {
            let params = SamplingParams { max_tokens: 4, ..Default::default() };
            e.submit(vec![1 + i; 9], params).unwrap();
        }
        e.run_to_completion().unwrap();
        // Finished requests keep only their cache-indexed full prompt
        // blocks alive (2 per distinct 9-token prompt at block size 4);
        // everything else returns to the pool.
        let s = e.snapshot();
        assert_eq!(s.prefix_cached_blocks, 8);
        assert_eq!(
            e.blocks.used(),
            s.prefix_cached_blocks,
            "finished requests leak KV blocks"
        );
        assert!(e.stats.max_blocks_used > 0);
        assert_eq!(s.kv_blocks_total, 16);
        assert_eq!(s.kv_blocks_used, s.prefix_cached_blocks);
        // Nothing references the cached blocks: all of them are cold
        // leaves an allocation could reclaim.
        assert_eq!(s.prefix_evictable_blocks, s.prefix_cached_blocks);
    }

    #[test]
    fn blocks_fully_released_when_sharing_is_off() {
        let model = MockModel::new(2, 64, 16, vec![4, 8]).with_kv_layout(16, 4);
        let cfg = EngineConfig { prefix_cache: false, ..Default::default() };
        let mut e = InferenceEngine::new(model, cfg);
        assert!(!e.prefix_sharing());
        for i in 0..4 {
            let params = SamplingParams { max_tokens: 4, ..Default::default() };
            e.submit(vec![1 + i; 9], params).unwrap();
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.blocks.used(), 0, "finished requests leak KV blocks");
        let s = e.snapshot();
        assert_eq!(s.prefix_cached_blocks, 0);
        assert_eq!(s.kv_blocks_used, 0);
        assert_eq!(s.block_utilization, 0.0);
    }

    #[test]
    fn block_pressure_preempts_and_restores_exactly() {
        // 2 slots but a pool of only 6 4-token blocks: two 9-token
        // prompts decoding 12 tokens each grow to 6 blocks apiece at the
        // tail (12 demanded, 6 exist), so someone must swap out and come
        // back — with an unchanged token stream.
        let reference = {
            let model = MockModel::new(2, 64, 16, vec![4, 8]);
            let mut e = InferenceEngine::new(model, EngineConfig::default());
            for i in 0..2 {
                let params = SamplingParams { max_tokens: 12, ..Default::default() };
                e.submit(vec![1 + i; 9], params).unwrap();
            }
            let mut done = e.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            assert_eq!(e.stats.preemptions, 0, "reference run must not preempt");
            done
        };
        let model = MockModel::new(2, 64, 16, vec![4, 8]).with_kv_layout(6, 4);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        for i in 0..2 {
            let params = SamplingParams { max_tokens: 12, ..Default::default() };
            e.submit(vec![1 + i; 9], params).unwrap();
        }
        let mut done = e.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        assert!(e.stats.preemptions > 0, "pool pressure must preempt");
        assert_eq!(e.stats.resumes, e.stats.preemptions, "every preempted request resumed");
        // 12-token tails on a 6-block pool force cold cached prompt
        // blocks out; whatever survives is all the pool still holds.
        assert!(e.stats.prefix_evictions > 0, "pool pressure must evict cache leaves");
        assert_eq!(e.blocks.used(), e.snapshot().prefix_cached_blocks);
        for (a, b) in reference.iter().zip(&done) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "preemption changed request {} output", a.id);
        }
        assert!(e.snapshot().preemptions > 0);
    }

    #[test]
    fn sequential_equals_batched_output() {
        let mut e1 = engine(4);
        let params = SamplingParams { max_tokens: 5, ..Default::default() };
        let c1 = e1.generate_sequential(vec![2, 4, 6], params).unwrap();
        let mut e2 = engine(4);
        let params = SamplingParams { max_tokens: 5, ..Default::default() };
        let id = e2.submit(vec![2, 4, 6], params).unwrap();
        // add noise requests around it
        let noise = SamplingParams { max_tokens: 5, ..Default::default() };
        e2.submit(vec![9, 9], noise).unwrap();
        let done = e2.run_to_completion().unwrap();
        let c2 = done.iter().find(|c| c.id == id).unwrap();
        assert_eq!(c1.tokens, c2.tokens, "batching must not change outputs");
    }

    fn spec_engine(batch: usize, k: usize, miss_period: usize) -> InferenceEngine<MockModel> {
        let model = MockModel::new(batch, 64, 16, vec![4, 8]).with_draft_misses(miss_period);
        let cfg = EngineConfig { speculate_k: k, ..Default::default() };
        InferenceEngine::new(model, cfg)
    }

    #[test]
    fn speculative_stream_matches_plain_decode() {
        // Drafts diverge from the verifier every 3rd position, so both
        // full acceptance and mid-window rejection are exercised — the
        // retired stream must still be bitwise the plain stream.
        let reference = {
            let mut e = engine(2);
            for i in 0..3 {
                let params = SamplingParams { max_tokens: 10, ..Default::default() };
                e.submit(vec![1 + i, 5, 9], params).unwrap();
            }
            let mut done = e.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            done
        };
        let mut e = spec_engine(2, 4, 3);
        for i in 0..3 {
            let params = SamplingParams { max_tokens: 10, ..Default::default() };
            e.submit(vec![1 + i, 5, 9], params).unwrap();
        }
        let mut done = e.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        assert!(e.stats.spec_steps > 0, "speculation never engaged");
        assert!(e.stats.spec_drafted > 0);
        let acc = e.stats.spec_acceptance().unwrap();
        assert!((0.0..=1.0).contains(&acc), "acceptance {acc}");
        for (a, b) in reference.iter().zip(&done) {
            assert_eq!(a.tokens, b.tokens, "speculation changed request {} output", a.id);
            assert_eq!(a.reason, b.reason);
        }
    }

    #[test]
    fn speculation_retires_multiple_tokens_per_step() {
        // Perfectly agreeing drafts (miss period 0): every verify accepts
        // the whole window, so the decode-step count must drop well below
        // the token count.
        let mut e = spec_engine(1, 4, 0);
        let params = SamplingParams { max_tokens: 16, ..Default::default() };
        e.submit(vec![3, 1], params).unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 16);
        assert!((e.stats.spec_acceptance().unwrap() - 1.0).abs() < 1e-9);
        assert!(
            e.stats.decode_steps < 8,
            "16 tokens should need far fewer than 16 decode steps, got {}",
            e.stats.decode_steps
        );
    }

    #[test]
    fn sampled_requests_bypass_speculation() {
        // temperature > 0 consumes RNG per token; speculation is greedy
        // only, so sampled requests must take the plain path untouched.
        let mut e = spec_engine(1, 4, 0);
        let params = SamplingParams { max_tokens: 6, temperature: 0.8, ..Default::default() };
        e.submit(vec![2, 7], params).unwrap();
        e.run_to_completion().unwrap();
        assert_eq!(e.stats.spec_steps, 0, "sampled request must not speculate");
        assert_eq!(e.stats.spec_drafted, 0);
        assert!(e.stats.spec_acceptance().is_none());
    }

    #[test]
    fn speculative_rollback_conserves_blocks_under_pressure() {
        // Tight pool + draft misses: rejected tails truncate KV and the
        // pool must balance to zero once everything finishes.
        let reference = {
            let model = MockModel::new(2, 64, 16, vec![4, 8]).with_kv_layout(6, 4);
            let cfg = EngineConfig { prefix_cache: false, ..Default::default() };
            let mut e = InferenceEngine::new(model, cfg);
            for i in 0..2 {
                let params = SamplingParams { max_tokens: 12, ..Default::default() };
                e.submit(vec![1 + i; 9], params).unwrap();
            }
            let mut done = e.run_to_completion().unwrap();
            done.sort_by_key(|c| c.id);
            done
        };
        let model = MockModel::new(2, 64, 16, vec![4, 8])
            .with_kv_layout(6, 4)
            .with_draft_misses(3);
        let cfg =
            EngineConfig { prefix_cache: false, speculate_k: 4, ..Default::default() };
        let mut e = InferenceEngine::new(model, cfg);
        for i in 0..2 {
            let params = SamplingParams { max_tokens: 12, ..Default::default() };
            e.submit(vec![1 + i; 9], params).unwrap();
        }
        let mut done = e.run_to_completion().unwrap();
        done.sort_by_key(|c| c.id);
        assert!(e.stats.spec_steps > 0);
        assert_eq!(e.blocks.used(), 0, "speculative rollback leaked KV blocks");
        for (a, b) in reference.iter().zip(&done) {
            assert_eq!(a.tokens, b.tokens, "speculation under pressure changed outputs");
        }
    }

    #[test]
    fn adaptive_k_backs_off_and_recovers() {
        // Frequent misses (every 2nd token) shrink the per-slot window;
        // adaptive engines still match the plain stream bitwise.
        let reference = {
            let mut e = engine(1);
            let params = SamplingParams { max_tokens: 14, ..Default::default() };
            e.submit(vec![4, 2], params).unwrap();
            e.run_to_completion().unwrap()
        };
        let model = MockModel::new(1, 64, 16, vec![4, 8]).with_draft_misses(2);
        let cfg = EngineConfig {
            speculate_k: 8,
            speculate_adaptive: true,
            ..Default::default()
        };
        let mut e = InferenceEngine::new(model, cfg);
        let params = SamplingParams { max_tokens: 14, ..Default::default() };
        e.submit(vec![4, 2], params).unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(reference[0].tokens, done[0].tokens);
        let acc = e.stats.spec_acceptance().unwrap();
        assert!(acc < 1.0, "miss period 2 must reject some drafts, acceptance {acc}");
        assert!(e.spec_win[0] < 8, "window should have backed off from 8");
    }

    #[test]
    fn queue_ms_measures_admission_not_first_token() {
        // One slow-prefill request hogs the engine while a second waits
        // in the queue: its queue_ms must be <= first_token_ms, and both
        // must be finite.
        let mut model = MockModel::new(1, 64, 16, vec![4]);
        model.spin_per_call = std::time::Duration::from_millis(2);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        e.submit(vec![1; 12], params).unwrap();
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        e.submit(vec![2; 12], params).unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        for c in &done {
            assert!(c.queue_ms.is_finite(), "queue_ms {}", c.queue_ms);
            assert!(c.first_token_ms.is_finite());
            assert!(c.queue_ms <= c.first_token_ms + 1e-9,
                    "queue {} > first token {}", c.queue_ms, c.first_token_ms);
        }
        // The second request waited for the first's 3-chunk prefill and
        // 2 decode steps (batch=1 serializes): its prefill alone takes
        // ~3 spins, so queue time must be clearly below first-token time.
        let second = done.iter().find(|c| c.prompt[0] == 2).unwrap();
        assert!(
            second.first_token_ms > second.queue_ms,
            "first token {} should exceed queue {}",
            second.first_token_ms,
            second.queue_ms
        );
    }

    #[test]
    fn snapshot_reports_live_state() {
        let mut e = engine(2);
        for i in 0..4 {
            let params = SamplingParams { max_tokens: 4, ..Default::default() };
            e.submit(vec![1 + i, 2, 3], params).unwrap();
        }
        let s = e.snapshot();
        assert_eq!(s.queue_depth, 4);
        assert_eq!(s.policy, "fifo");
        assert_eq!(s.slots_total, 2);
        assert_eq!(s.active_slots, 0);
        // degenerate layout: one block per slot, spanning max_seq
        assert_eq!(s.kv_blocks_total, 2);
        e.run_to_completion().unwrap();
        let s = e.snapshot();
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.finished, 4);
        assert_eq!(s.swapped, 0);
        assert_eq!(s.preemptions, 0);
        assert!(s.tokens_generated >= 16);
    }

    #[test]
    fn fallback_rate_flows_into_snapshot() {
        use crate::config::{FfnMode, NativeModelConfig, TardisFfnConfig};
        use crate::coordinator::model::NativeModel;
        // Mock backend: no partially-linear FFN, no rate.
        let mut e = engine(2);
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        e.submit(vec![1, 2], params).unwrap();
        e.run_to_completion().unwrap();
        assert!(e.snapshot().ffn_fallback_rate.is_none());
        // Native tardis backend: rate is reported after any routed row.
        let cfg = NativeModelConfig {
            vocab: 32,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            max_seq: 32,
            batch: 2,
            prefill_buckets: vec![4],
            seed: 5,
            threads: 0,
            kv_block_size: 8,
            kv_blocks: 0,
        };
        let model = NativeModel::new(cfg, &FfnMode::Tardis(TardisFfnConfig::with_ratio(0.8)));
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        e.submit(vec![1, 2, 3], params).unwrap();
        e.run_to_completion().unwrap();
        let s = e.snapshot();
        let rate = s.ffn_fallback_rate.expect("tardis backend reports a rate");
        assert!((0.0..=1.0).contains(&rate), "rate {rate}");
        assert!(s.ffn_last_step_fallback_rate.is_some());
        assert!(e.stats.ffn_folded_rows + e.stats.ffn_fallback_rows > 0);
    }

    #[test]
    fn prefix_hit_skips_prefill_for_shared_prompt() {
        let model = MockModel::new(2, 64, 16, vec![4, 8]).with_kv_layout(16, 4);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        assert!(e.prefix_sharing());
        let prompt: Vec<i32> = (1..=13).collect();
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        e.submit(prompt.clone(), params).unwrap();
        e.run_to_completion().unwrap();
        let mark = e.model.prefill_log.len();
        e.submit(prompt.clone(), params).unwrap();
        let second = e.run_to_completion().unwrap();
        // The repeat request maps the 3 cached full blocks (12 tokens)
        // and runs exactly one chunk, for the final prompt token.
        let tail = &e.model.prefill_log[mark..];
        assert_eq!(tail.len(), 1, "hit-covered tokens must not run prefill chunks");
        assert_eq!(tail[0].1, 12, "suffix prefill must start at the hit length");
        assert_eq!(e.stats.prefix_hit_tokens, 12);
        assert_eq!(e.stats.prefix_shared_blocks, 3);
        assert_eq!(e.stats.cow_copies, 0);
        assert_eq!(second[0].prefix_hit_tokens, 12);
        // Bitwise guarantee: the shared run emits exactly the stream an
        // unshared engine produces for the same submission history.
        let reference = {
            let model = MockModel::new(2, 64, 16, vec![4, 8]).with_kv_layout(16, 4);
            let cfg = EngineConfig { prefix_cache: false, ..Default::default() };
            let mut e = InferenceEngine::new(model, cfg);
            e.submit(prompt.clone(), params).unwrap();
            e.run_to_completion().unwrap();
            e.submit(prompt, params).unwrap();
            let done = e.run_to_completion().unwrap();
            assert_eq!(e.stats.prefix_hit_tokens, 0);
            done
        };
        assert_eq!(second[0].tokens, reference[0].tokens);
    }

    #[test]
    fn partial_hit_copies_on_write_and_matches_unshared_stream() {
        let prompt_a: Vec<i32> = vec![5, 5, 5, 5, 7, 7, 7, 7, 9];
        let prompt_b: Vec<i32> = vec![5, 5, 5, 5, 7, 7, 3, 3, 3];
        let run = |share: bool| {
            let model = MockModel::new(2, 64, 16, vec![4, 8]).with_kv_layout(16, 4);
            let cfg = EngineConfig { prefix_cache: share, ..Default::default() };
            let mut e = InferenceEngine::new(model, cfg);
            let params = SamplingParams { max_tokens: 4, ..Default::default() };
            e.submit(prompt_a.clone(), params).unwrap();
            e.run_to_completion().unwrap();
            e.submit(prompt_b.clone(), params).unwrap();
            let done = e.run_to_completion().unwrap();
            (done[0].tokens.clone(), e.stats.clone(), done[0].prefix_hit_tokens)
        };
        let (shared_tokens, stats, hit) = run(true);
        // B matches A's [5,5,5,5] block in full and [7,7,7,7] for two of
        // four tokens: a 6-token partial hit that must COW before the
        // suffix appends into the shared tail block.
        assert_eq!(hit, 6);
        assert_eq!(stats.prefix_hit_tokens, 6);
        assert_eq!(stats.prefix_shared_blocks, 2);
        assert_eq!(stats.cow_copies, 1);
        let (unshared_tokens, stats, _) = run(false);
        assert_eq!(stats.cow_copies, 0);
        assert_eq!(shared_tokens, unshared_tokens, "COW divergence changed the stream");
    }

    #[test]
    fn degrade_mark_armed_at_admission_and_cleared_at_finish() {
        let mut e = engine(2);
        let params = SamplingParams { max_tokens: 2, degrade: true, ..Default::default() };
        e.submit(vec![1, 2], params).unwrap();
        let done = e.run_to_completion().unwrap();
        assert!(done[0].degraded);
        assert_eq!(e.model.degrade_log.first(), Some(&(0, true)));
        assert_eq!(e.model.degrade_log.last(), Some(&(0, false)));
        // On a backend with no partially-linear FFN the flag is inert:
        // the stream matches a full-quality run exactly.
        let mut r = engine(2);
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        r.submit(vec![1, 2], params).unwrap();
        let full = r.run_to_completion().unwrap();
        assert!(!full[0].degraded);
        assert_eq!(done[0].tokens, full[0].tokens);
    }

    #[test]
    fn degraded_stream_matches_standalone_forced_fold() {
        use crate::config::{FfnMode, NativeModelConfig, TardisFfnConfig};
        use crate::coordinator::model::NativeModel;
        let cfg = NativeModelConfig {
            vocab: 32,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            max_seq: 32,
            batch: 2,
            prefill_buckets: vec![4],
            seed: 5,
            threads: 0,
            kv_block_size: 8,
            kv_blocks: 0,
        };
        let mode = FfnMode::Tardis(TardisFfnConfig::with_ratio(0.8));
        let params = SamplingParams { max_tokens: 6, degrade: true, ..Default::default() };
        // Standalone forced-fold run: the degraded request alone.
        let solo = {
            let model = NativeModel::new(cfg.clone(), &mode);
            let mut e = InferenceEngine::new(model, EngineConfig::default());
            let id = e.submit(vec![1, 2, 3], params).unwrap();
            let done = e.run_to_completion().unwrap();
            done.into_iter().find(|c| c.id == id).unwrap()
        };
        // Same request co-batched with a full-quality neighbor: only its
        // own rows are forced, and its stream must not change.
        let model = NativeModel::new(cfg, &mode);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        let id = e.submit(vec![1, 2, 3], params).unwrap();
        let noise = SamplingParams { max_tokens: 6, ..Default::default() };
        e.submit(vec![9, 8, 7], noise).unwrap();
        let done = e.run_to_completion().unwrap();
        let batched = done.iter().find(|c| c.id == id).unwrap();
        assert!(solo.degraded && batched.degraded);
        let neighbor = done.iter().find(|c| c.id != id).unwrap();
        assert!(!neighbor.degraded);
        assert_eq!(solo.tokens, batched.tokens, "co-batching changed a degraded stream");
    }

    #[test]
    fn virtual_clock_stamps_are_deterministic() {
        let run = || {
            let mut e = engine(2);
            e.enable_virtual_clock();
            let params = SamplingParams { max_tokens: 3, ..Default::default() };
            e.advance_clock_us(100); // enqueue at t=100µs
            e.submit(vec![1, 2, 3], params).unwrap();
            while !e.is_idle() {
                e.step().unwrap();
                e.advance_clock_us(50); // modeled per-step cost
            }
            e.take_completions().remove(0)
        };
        let (a, b) = (run(), run());
        let ttft = a.ttft_us.expect("first token stamped");
        let total = a.total_us.expect("finish stamped");
        assert_eq!(a.ttft_us, b.ttft_us, "virtual TTFT must be bitwise reproducible");
        assert_eq!(a.total_us, b.total_us);
        assert!(total >= ttft, "total {total} < ttft {ttft}");
        assert!(!a.degraded);
        // Wall-clock mode still stamps (non-deterministically).
        let mut e = engine(2);
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        e.submit(vec![1, 2], params).unwrap();
        let done = e.run_to_completion().unwrap();
        assert!(done[0].ttft_us.is_some());
        assert!(done[0].total_us.is_some());
    }

    #[test]
    fn non_fifo_policy_selected_via_config() {
        let mut cfg = EngineConfig::default();
        cfg.scheduler.policy = PolicyKind::ShortestPromptFirst;
        cfg.scheduler.max_concurrent_prefills = 1; // serialize admissions
        cfg.scheduler.chunk_budget = 1;
        let model = MockModel::new(1, 64, 16, vec![4]);
        let mut e = InferenceEngine::new(model, cfg);
        // Long prompt first, short prompt second: SPF admits the short
        // one first, so it finishes first despite arriving later.
        let params = SamplingParams { max_tokens: 1, ..Default::default() };
        let long = e.submit(vec![1; 20], params).unwrap();
        let params = SamplingParams { max_tokens: 1, ..Default::default() };
        let short = e.submit(vec![2, 3], params).unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done[0].id, short);
        assert_eq!(done[1].id, long);
    }
}
