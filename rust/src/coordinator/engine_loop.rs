//! The serving engine: queue → scheduler plan → step-model → sampler,
//! one iteration at a time (so callers — CLI, server, benches — control
//! pacing and can interleave with I/O).
//!
//! This is the "vLLM-like" runtime of Fig 13: continuous batching with
//! slot-level admission, driven by the [`StepPlan`] a pluggable
//! [`crate::coordinator::scheduler::SchedulerPolicy`] emits each
//! iteration. Several prefill jobs ride in flight concurrently (the
//! [`PrefillSet`]), so one long prompt no longer serializes every prompt
//! behind it. The "HF-like" sequential baseline is
//! [`InferenceEngine::generate_sequential`], which runs one request at a
//! time with batch occupancy 1 — the difference between the two is the
//! serving-system contribution the paper piggybacks on.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::util::rng::Rng;
use crate::util::stats::Samples;

use super::batcher::Batcher;
use super::kv::SlotAllocator;
use super::model::StepModel;
use super::queue::{AdmissionQueue, QueueFull};
use super::request::{FinishReason, Request, RequestId, RequestState,
                     SamplingParams};
use super::sampler::sample;
use super::scheduler::{Admission, ChunkSpec, DecodeBatch, PrefillView,
                       QueuedRequest, SchedView, Scheduler, SchedulerConfig,
                       StepOutcome, StepPlan};

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub queue_capacity: usize,
    pub scheduler: SchedulerConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            queue_capacity: 64,
            scheduler: SchedulerConfig::default(),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub iterations: u64,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    pub tokens_generated: u64,
    pub admitted: u64,
    pub finished: u64,
    /// Summed decode-batch occupancy over all decode steps (streaming —
    /// a long-running server's stats stay O(1) in time and space; the
    /// continuous-batching win is the mean, `occupancy_sum/decode_steps`)
    pub occupancy_sum: u64,
    /// High-water mark of concurrently in-flight prefill jobs.
    pub max_concurrent_prefills: usize,
    /// Cumulative TARDIS row routing (0/0 unless the model runs a
    /// partially-linear FFN; see [`StepModel::ffn_telemetry`]).
    pub ffn_folded_rows: u64,
    pub ffn_fallback_rows: u64,
    /// Fallback fraction of the most recent step that routed any rows.
    pub ffn_last_step_fallback_rate: Option<f64>,
}

impl EngineStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.occupancy_sum as f64 / self.decode_steps as f64
    }

    /// Cumulative fraction of FFN rows routed to the dense fallback
    /// path; `None` until a partially-linear model routed any row.
    pub fn ffn_fallback_rate(&self) -> Option<f64> {
        let total = self.ffn_folded_rows + self.ffn_fallback_rows;
        if total == 0 {
            None
        } else {
            Some(self.ffn_fallback_rows as f64 / total as f64)
        }
    }
}

/// Point-in-time engine state for the server's `stats` op and for tests.
#[derive(Debug, Clone)]
pub struct EngineSnapshot {
    pub policy: &'static str,
    pub queue_depth: usize,
    pub queue_pressure: f64,
    pub active_slots: usize,
    pub inflight_prefills: usize,
    pub slots_total: usize,
    pub mean_occupancy: f64,
    pub tokens_generated: u64,
    pub admitted: u64,
    pub finished: u64,
    pub iterations: u64,
    /// Cumulative fraction of FFN rows routed to the dense fallback path
    /// (None unless the backend runs a partially-linear FFN).
    pub ffn_fallback_rate: Option<f64>,
    /// Same fraction over the most recent step that routed any rows.
    pub ffn_last_step_fallback_rate: Option<f64>,
}

/// A finished request handed back to the caller.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    pub reason: FinishReason,
    /// Time spent waiting in the admission queue (enqueue → slot
    /// admission). Distinct from `first_token_ms`, which also includes
    /// the prefill itself.
    pub queue_ms: f64,
    pub first_token_ms: f64,
    pub total_ms: f64,
}

/// An in-flight prefill: the prompt is written to the cache chunk by
/// chunk; `next` counts tokens already written.
struct PrefillJob {
    req: Request,
    slot: usize,
    next: usize,
}

/// The concurrently in-flight prefill jobs, keyed by KV slot (sorted, so
/// every traversal is deterministic). Replaces the seed's single
/// `Option<PrefillJob>` — the scheduler may interleave chunks of several
/// prompts.
#[derive(Default)]
pub struct PrefillSet {
    jobs: BTreeMap<usize, PrefillJob>,
}

impl PrefillSet {
    fn insert(&mut self, job: PrefillJob) {
        debug_assert!(!self.jobs.contains_key(&job.slot),
                      "slot {} already prefilling", job.slot);
        self.jobs.insert(job.slot, job);
    }

    fn remove(&mut self, slot: usize) -> Option<PrefillJob> {
        self.jobs.remove(&slot)
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Scheduler-facing view, slot-sorted.
    fn views(&self) -> Vec<PrefillView> {
        self.jobs
            .values()
            .map(|j| PrefillView {
                request: j.req.id,
                slot: j.slot,
                remaining: j.req.prompt.len() - j.next,
            })
            .collect()
    }
}

pub struct InferenceEngine<M: StepModel> {
    pub model: M,
    cfg: EngineConfig,
    queue: AdmissionQueue,
    slots: SlotAllocator,
    batcher: Batcher,
    scheduler: Scheduler,
    /// requests currently decoding, by slot
    active: HashMap<usize, Request>,
    /// concurrently in-flight multi-chunk prefills, by slot
    prefilling: PrefillSet,
    completions: VecDeque<Completion>,
    next_id: RequestId,
    rngs: HashMap<RequestId, Rng>,
    pub stats: EngineStats,
    pub decode_latency_ms: Samples,
}

impl<M: StepModel> InferenceEngine<M> {
    pub fn new(model: M, cfg: EngineConfig) -> Self {
        let batch = model.batch();
        let max_seq = model.max_seq();
        InferenceEngine {
            queue: AdmissionQueue::new(cfg.queue_capacity),
            slots: SlotAllocator::new(batch),
            batcher: Batcher::new(batch, max_seq),
            scheduler: Scheduler::new(cfg.scheduler.clone()),
            active: HashMap::new(),
            prefilling: PrefillSet::default(),
            completions: VecDeque::new(),
            next_id: 1,
            rngs: HashMap::new(),
            stats: EngineStats::default(),
            decode_latency_ms: Samples::new(),
            model,
            cfg,
        }
    }

    pub fn queue_pressure(&self) -> f64 {
        self.queue.pressure()
    }

    pub fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            policy: self.scheduler.policy_name(),
            queue_depth: self.queue.len(),
            queue_pressure: self.queue.pressure(),
            active_slots: self.active.len(),
            inflight_prefills: self.prefilling.len(),
            slots_total: self.slots.capacity(),
            mean_occupancy: self.stats.mean_occupancy(),
            tokens_generated: self.stats.tokens_generated,
            admitted: self.stats.admitted,
            finished: self.stats.finished,
            iterations: self.stats.iterations,
            ffn_fallback_rate: self.stats.ffn_fallback_rate(),
            ffn_last_step_fallback_rate: self.stats.ffn_last_step_fallback_rate,
        }
    }

    /// Submit a request; fails with backpressure when the queue is full.
    pub fn submit(&mut self, prompt: Vec<i32>, params: SamplingParams)
                  -> Result<RequestId> {
        let max_prompt = self.model.max_seq().saturating_sub(1);
        if prompt.is_empty() || prompt.len() > max_prompt {
            return Err(anyhow!(
                "prompt length {} not in 1..={max_prompt}", prompt.len()));
        }
        let id = self.next_id;
        self.next_id += 1;
        let req = Request::new(id, prompt, params);
        self.queue
            .push(req)
            .map_err(|QueueFull(_)| anyhow!("queue full (backpressure)"))?;
        Ok(id)
    }

    /// Pop any completions produced so far.
    pub fn take_completions(&mut self) -> Vec<Completion> {
        self.completions.drain(..).collect()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
            && self.prefilling.is_empty()
    }

    /// Run one scheduler iteration: build a [`StepPlan`] from the current
    /// state and execute it. Returns what the plan actually did.
    pub fn step(&mut self) -> Result<StepOutcome> {
        self.stats.iterations += 1;
        let before = self.model.ffn_telemetry();
        let plan = self.make_plan();
        let outcome = self.execute_plan(plan);
        if let Some(t) = self.model.ffn_telemetry() {
            let prev = before.unwrap_or_default();
            self.stats.ffn_folded_rows = t.folded_rows;
            self.stats.ffn_fallback_rows = t.fallback_rows;
            let folded = t.folded_rows.saturating_sub(prev.folded_rows);
            let fallback = t.fallback_rows.saturating_sub(prev.fallback_rows);
            if folded + fallback > 0 {
                self.stats.ffn_last_step_fallback_rate =
                    Some(fallback as f64 / (folded + fallback) as f64);
            }
        }
        outcome
    }

    /// Drive until every submitted request has finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_completions())
    }

    // -- internals ----------------------------------------------------------

    fn make_plan(&mut self) -> StepPlan {
        let free_slots = self.slots.free_slots();
        // Snapshotting (and policy-ranking) the queue is only worth it
        // when an admission could actually happen this iteration; under
        // a deep backlog with full slots this keeps the per-step cost
        // independent of queue depth.
        let concurrency =
            self.scheduler.config().max_concurrent_prefills.max(1);
        let admissible =
            !free_slots.is_empty() && self.prefilling.len() < concurrency;
        let queued: Vec<QueuedRequest> = if admissible {
            self.queue
                .iter()
                .enumerate()
                .map(|(arrival, r)| QueuedRequest {
                    id: r.id,
                    prompt_len: r.prompt.len(),
                    priority: r.params.priority,
                    arrival,
                })
                .collect()
        } else {
            Vec::new()
        };
        let inflight = self.prefilling.views();
        let active_slots = self.batcher.active_slots();
        let view = SchedView {
            queued: &queued,
            free_slots: &free_slots,
            inflight: &inflight,
            active_slots: &active_slots,
        };
        self.scheduler.plan(&view)
    }

    fn execute_plan(&mut self, plan: StepPlan) -> Result<StepOutcome> {
        let outcome = StepOutcome {
            admitted: plan.admissions.len(),
            prefill_chunks: plan.prefill_chunks.len(),
            decoded_slots: plan
                .decode
                .as_ref()
                .map(|d| d.slots.len())
                .unwrap_or(0),
        };
        self.model.plan_begin(&plan);
        for adm in &plan.admissions {
            self.admit(adm)?;
        }
        self.stats.max_concurrent_prefills = self
            .stats
            .max_concurrent_prefills
            .max(self.prefilling.len());
        for chunk in &plan.prefill_chunks {
            self.run_prefill_chunk(chunk)?;
        }
        if let Some(batch) = &plan.decode {
            self.do_decode_step(batch)?;
        }
        self.model.plan_end(&outcome);
        Ok(outcome)
    }

    /// Move a queued request into the KV slot the plan assigned it.
    fn admit(&mut self, adm: &Admission) -> Result<()> {
        let mut req = self.queue.take(adm.request).ok_or_else(|| {
            anyhow!("scheduler bug: admission of unqueued request {}",
                    adm.request)
        })?;
        ensure!(self.slots.claim(adm.slot),
                "scheduler bug: admission into unavailable slot {}", adm.slot);
        req.state = RequestState::Prefilling { slot: adm.slot, next: 0 };
        req.admitted_at = Some(Instant::now());
        self.rngs.insert(req.id, Rng::new(req.params.seed ^ req.id));
        self.stats.admitted += 1;
        self.prefilling
            .insert(PrefillJob { req, slot: adm.slot, next: 0 });
        Ok(())
    }

    /// Run one prompt chunk for the prefill job in `spec.slot`; on the
    /// final chunk, sample the first token and hand the request to the
    /// decode batcher.
    fn run_prefill_chunk(&mut self, spec: &ChunkSpec) -> Result<()> {
        let mut job = self.prefilling.remove(spec.slot).ok_or_else(|| {
            anyhow!("scheduler bug: prefill chunk for idle slot {}", spec.slot)
        })?;
        ensure!(job.req.id == spec.request,
                "scheduler bug: slot {} runs request {} not {}",
                spec.slot, job.req.id, spec.request);
        let prompt = &job.req.prompt;
        let remaining = prompt.len() - job.next;
        let bucket = self.model.bucket_for(remaining);
        let take = remaining.min(bucket);
        let mut chunk = prompt[job.next..job.next + take].to_vec();
        chunk.resize(bucket, 0); // pad; executable overwrites before reads
        let logits =
            self.model.prefill(bucket, &chunk, take, job.slot, job.next)?;
        self.stats.prefill_chunks += 1;
        job.next += take;
        if job.next < job.req.prompt.len() {
            job.req.state =
                RequestState::Prefilling { slot: job.slot, next: job.next };
            self.prefilling.insert(job);
            return Ok(());
        }
        // Prompt complete: sample the first generated token from the
        // prefill logits and move to decoding.
        let PrefillJob { mut req, slot, .. } = job;
        let rng = self.rngs.get_mut(&req.id).expect("rng");
        let tok = sample(&logits, &req.params, rng);
        req.record_token(tok);
        self.stats.tokens_generated += 1;
        if let Some(reason) = req.stop_reason(self.model.max_seq()) {
            self.finish(req, slot, reason, false);
            return Ok(());
        }
        req.state = RequestState::Decoding { slot };
        self.batcher.occupy(slot, req.id, req.prompt.len(), tok);
        self.active.insert(slot, req);
        Ok(())
    }

    fn do_decode_step(&mut self, batch: &DecodeBatch) -> Result<()> {
        let (tokens, pos) = self.batcher.decode_inputs();
        let t0 = Instant::now();
        let logits = self.model.decode(&tokens, &pos)?;
        self.decode_latency_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        self.stats.decode_steps += 1;
        self.stats.occupancy_sum += batch.slots.len() as u64;
        let vocab = self.model.vocab();
        // The plan's slot list is sorted: sampling order (and therefore
        // per-request RNG consumption) is deterministic, not HashMap
        // iteration order.
        for &slot in &batch.slots {
            let Some(req) = self.active.get_mut(&slot) else {
                return Err(anyhow!(
                    "scheduler bug: decode batch names idle slot {slot}"));
            };
            let row = &logits[slot * vocab..(slot + 1) * vocab];
            let rng = self.rngs.get_mut(&req.id).expect("rng");
            let tok = sample(row, &req.params, rng);
            req.record_token(tok);
            self.stats.tokens_generated += 1;
            self.batcher.advance(slot, tok);
            if let Some(reason) = req.stop_reason(self.model.max_seq()) {
                let req = self.active.remove(&slot).expect("req");
                self.finish(req, slot, reason, true);
            }
        }
        Ok(())
    }

    fn finish(&mut self, mut req: Request, slot: usize, reason: FinishReason,
              in_batcher: bool) {
        req.finish(reason);
        if in_batcher {
            self.batcher.vacate(slot);
        }
        self.slots.release(slot);
        self.rngs.remove(&req.id);
        self.stats.finished += 1;
        self.completions.push_back(Completion {
            id: req.id,
            prompt: req.prompt.clone(),
            tokens: req.generated.clone(),
            reason,
            queue_ms: req
                .admitted_at
                .map(|t| t.duration_since(req.enqueued_at).as_secs_f64() * 1e3)
                .unwrap_or(f64::NAN),
            first_token_ms: req
                .first_token_at
                .map(|t| t.duration_since(req.enqueued_at).as_secs_f64() * 1e3)
                .unwrap_or(f64::NAN),
            total_ms: req
                .finished_at
                .map(|t| t.duration_since(req.enqueued_at).as_secs_f64() * 1e3)
                .unwrap_or(f64::NAN),
        });
    }

    /// HF-like sequential baseline: run a single request start-to-finish
    /// with batch occupancy 1 (no continuous batching). Used by Fig 13 to
    /// compare runtimes.
    pub fn generate_sequential(&mut self, prompt: Vec<i32>,
                               params: SamplingParams) -> Result<Completion> {
        if !self.is_idle() {
            return Err(anyhow!("sequential generation requires an idle engine"));
        }
        let id = self.submit(prompt, params)?;
        let completions = self.run_to_completion()?;
        completions
            .into_iter()
            .find(|c| c.id == id)
            .ok_or_else(|| anyhow!("request {id} did not complete"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::model::MockModel;
    use crate::coordinator::scheduler::PolicyKind;

    fn engine(batch: usize) -> InferenceEngine<MockModel> {
        InferenceEngine::new(MockModel::new(batch, 64, 16, vec![4, 8]),
                             EngineConfig::default())
    }

    #[test]
    fn single_request_generates_expected_tokens() {
        let mut e = engine(2);
        // prompt [1,2,3]: last tok 3 at pos 2 -> first gen (3+2)%16 = 5
        // then 5 at pos 3 -> 8; 8 at pos 4 -> 12
        let id = e
            .submit(vec![1, 2, 3],
                    SamplingParams { max_tokens: 3, ..Default::default() })
            .unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].tokens, vec![5, 8, 12]);
        assert_eq!(done[0].reason, FinishReason::Length);
    }

    #[test]
    fn multi_chunk_prefill_matches_single_chunk() {
        // a 7-token prompt must split into 4+3 chunks with buckets [4,8]?
        // bucket_for(7)=8 so single chunk; force multi-chunk via buckets [4]
        let model = MockModel::new(1, 64, 16, vec![4]);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        let prompt = vec![1, 2, 3, 4, 5, 6, 7];
        let id = e
            .submit(prompt.clone(),
                    SamplingParams { max_tokens: 1, ..Default::default() })
            .unwrap();
        let done = e.run_to_completion().unwrap();
        // last tok 7 at pos 6 -> (7+6)%16 = 13
        assert_eq!(done[0].tokens, vec![13]);
        assert_eq!(done[0].id, id);
        assert!(e.stats.prefill_chunks >= 2);
    }

    #[test]
    fn concurrent_requests_share_decode_steps() {
        let mut e = engine(4);
        let n = 4;
        for i in 0..n {
            e.submit(vec![1 + i as i32, 2, 3],
                     SamplingParams { max_tokens: 8, ..Default::default() })
                .unwrap();
        }
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), n);
        // Continuous batching: far fewer decode steps than tokens.
        let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(tokens, 8 * n);
        assert!(
            (e.stats.decode_steps as usize) < tokens,
            "decode steps {} should be < total tokens {tokens}",
            e.stats.decode_steps
        );
        assert!(e.stats.mean_occupancy() > 1.5,
                "occupancy {}", e.stats.mean_occupancy());
    }

    #[test]
    fn more_requests_than_slots_queue_up() {
        let mut e = engine(2);
        for i in 0..6 {
            e.submit(vec![1 + i, 2],
                     SamplingParams { max_tokens: 4, ..Default::default() })
                .unwrap();
        }
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 6);
        assert!(e.is_idle());
    }

    #[test]
    fn backpressure_propagates() {
        let model = MockModel::new(1, 64, 16, vec![4]);
        let mut e = InferenceEngine::new(
            model,
            EngineConfig { queue_capacity: 2, ..Default::default() },
        );
        e.submit(vec![1], SamplingParams::default()).unwrap();
        e.submit(vec![2], SamplingParams::default()).unwrap();
        assert!(e.submit(vec![3], SamplingParams::default()).is_err());
    }

    #[test]
    fn rejects_overlong_prompt() {
        let mut e = engine(2);
        assert!(e.submit(vec![1; 64], SamplingParams::default()).is_err());
        assert!(e.submit(vec![1; 63], SamplingParams::default()).is_ok());
    }

    #[test]
    fn context_overflow_finishes_request() {
        let model = MockModel::new(1, 16, 8, vec![4]);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        e.submit(vec![1, 2, 3, 4],
                 SamplingParams { max_tokens: 1000, ..Default::default() })
            .unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done[0].reason, FinishReason::ContextOverflow);
        assert_eq!(done[0].tokens.len() + 4, 16);
    }

    #[test]
    fn sequential_equals_batched_output() {
        let mut e1 = engine(4);
        let c1 = e1
            .generate_sequential(vec![2, 4, 6],
                                 SamplingParams { max_tokens: 5, ..Default::default() })
            .unwrap();
        let mut e2 = engine(4);
        let id = e2
            .submit(vec![2, 4, 6],
                    SamplingParams { max_tokens: 5, ..Default::default() })
            .unwrap();
        // add noise requests around it
        e2.submit(vec![9, 9], SamplingParams { max_tokens: 5, ..Default::default() })
            .unwrap();
        let done = e2.run_to_completion().unwrap();
        let c2 = done.iter().find(|c| c.id == id).unwrap();
        assert_eq!(c1.tokens, c2.tokens, "batching must not change outputs");
    }

    #[test]
    fn queue_ms_measures_admission_not_first_token() {
        // One slow-prefill request hogs the engine while a second waits
        // in the queue: its queue_ms must be <= first_token_ms, and both
        // must be finite.
        let mut model = MockModel::new(1, 64, 16, vec![4]);
        model.spin_per_call = std::time::Duration::from_millis(2);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        e.submit(vec![1; 12],
                 SamplingParams { max_tokens: 2, ..Default::default() })
            .unwrap();
        e.submit(vec![2; 12],
                 SamplingParams { max_tokens: 2, ..Default::default() })
            .unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 2);
        for c in &done {
            assert!(c.queue_ms.is_finite(), "queue_ms {}", c.queue_ms);
            assert!(c.first_token_ms.is_finite());
            assert!(c.queue_ms <= c.first_token_ms + 1e-9,
                    "queue {} > first token {}", c.queue_ms, c.first_token_ms);
        }
        // The second request waited for the first's 3-chunk prefill and
        // 2 decode steps (batch=1 serializes): its prefill alone takes
        // ~3 spins, so queue time must be clearly below first-token time.
        let second = done.iter().find(|c| c.prompt[0] == 2).unwrap();
        assert!(second.first_token_ms > second.queue_ms,
                "first token {} should exceed queue {}",
                second.first_token_ms, second.queue_ms);
    }

    #[test]
    fn snapshot_reports_live_state() {
        let mut e = engine(2);
        for i in 0..4 {
            e.submit(vec![1 + i, 2, 3],
                     SamplingParams { max_tokens: 4, ..Default::default() })
                .unwrap();
        }
        let s = e.snapshot();
        assert_eq!(s.queue_depth, 4);
        assert_eq!(s.policy, "fifo");
        assert_eq!(s.slots_total, 2);
        assert_eq!(s.active_slots, 0);
        e.run_to_completion().unwrap();
        let s = e.snapshot();
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.finished, 4);
        assert!(s.tokens_generated >= 16);
    }

    #[test]
    fn fallback_rate_flows_into_snapshot() {
        use crate::config::{FfnMode, NativeModelConfig, TardisFfnConfig};
        use crate::coordinator::model::NativeModel;
        // Mock backend: no partially-linear FFN, no rate.
        let mut e = engine(2);
        e.submit(vec![1, 2], SamplingParams { max_tokens: 2, ..Default::default() })
            .unwrap();
        e.run_to_completion().unwrap();
        assert!(e.snapshot().ffn_fallback_rate.is_none());
        // Native tardis backend: rate is reported after any routed row.
        let cfg = NativeModelConfig {
            vocab: 32,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 64,
            max_seq: 32,
            batch: 2,
            prefill_buckets: vec![4],
            seed: 5,
            threads: 0,
        };
        let model = NativeModel::new(
            cfg,
            &FfnMode::Tardis(TardisFfnConfig::with_ratio(0.8)),
        );
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        e.submit(vec![1, 2, 3], SamplingParams { max_tokens: 4, ..Default::default() })
            .unwrap();
        e.run_to_completion().unwrap();
        let s = e.snapshot();
        let rate = s.ffn_fallback_rate.expect("tardis backend reports a rate");
        assert!((0.0..=1.0).contains(&rate), "rate {rate}");
        assert!(s.ffn_last_step_fallback_rate.is_some());
        assert!(e.stats.ffn_folded_rows + e.stats.ffn_fallback_rows > 0);
    }

    #[test]
    fn non_fifo_policy_selected_via_config() {
        let mut cfg = EngineConfig::default();
        cfg.scheduler.policy = PolicyKind::ShortestPromptFirst;
        cfg.scheduler.max_concurrent_prefills = 1; // serialize admissions
        cfg.scheduler.chunk_budget = 1;
        let model = MockModel::new(1, 64, 16, vec![4]);
        let mut e = InferenceEngine::new(model, cfg);
        // Long prompt first, short prompt second: SPF admits the short
        // one first, so it finishes first despite arriving later.
        let long = e
            .submit(vec![1; 20],
                    SamplingParams { max_tokens: 1, ..Default::default() })
            .unwrap();
        let short = e
            .submit(vec![2, 3],
                    SamplingParams { max_tokens: 1, ..Default::default() })
            .unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done[0].id, short);
        assert_eq!(done[1].id, long);
    }
}
