//! The serving engine: queue → scheduler → step-model → sampler, one
//! iteration at a time (so callers — CLI, server, benches — control
//! pacing and can interleave with I/O).
//!
//! This is the "vLLM-like" runtime of Fig 13: continuous batching with
//! slot-level admission. The "HF-like" sequential baseline is
//! [`InferenceEngine::generate_sequential`], which runs one request at a
//! time with batch occupancy 1 — the difference between the two is the
//! serving-system contribution the paper piggybacks on.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::util::rng::Rng;
use crate::util::stats::Samples;

use super::batcher::Batcher;
use super::kv::SlotAllocator;
use super::model::StepModel;
use super::queue::{AdmissionQueue, QueueFull};
use super::request::{FinishReason, Request, RequestId, RequestState,
                     SamplingParams};
use super::sampler::sample;
use super::scheduler::{Action, Scheduler, SchedulerPolicy};

#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub queue_capacity: usize,
    pub scheduler: SchedulerPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { queue_capacity: 64, scheduler: SchedulerPolicy::default() }
    }
}

#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub iterations: u64,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    pub tokens_generated: u64,
    pub finished: u64,
    /// decode-batch occupancy per decode step (continuous-batching win)
    pub occupancy: Vec<usize>,
}

impl EngineStats {
    pub fn mean_occupancy(&self) -> f64 {
        if self.occupancy.is_empty() {
            return 0.0;
        }
        self.occupancy.iter().sum::<usize>() as f64 / self.occupancy.len() as f64
    }
}

/// A finished request handed back to the caller.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub tokens: Vec<i32>,
    pub reason: FinishReason,
    pub queue_ms: f64,
    pub first_token_ms: f64,
    pub total_ms: f64,
}

/// An in-flight prefill: the prompt is written to the cache chunk by
/// chunk; `next` counts tokens already written.
struct PrefillJob {
    req: Request,
    slot: usize,
    next: usize,
}

pub struct InferenceEngine<M: StepModel> {
    pub model: M,
    cfg: EngineConfig,
    queue: AdmissionQueue,
    slots: SlotAllocator,
    batcher: Batcher,
    scheduler: Scheduler,
    /// requests currently decoding, by slot
    active: HashMap<usize, Request>,
    /// at most one multi-chunk prefill in flight (matches the exported
    /// batch-1 prefill executables)
    prefilling: Option<PrefillJob>,
    completions: VecDeque<Completion>,
    next_id: RequestId,
    rngs: HashMap<RequestId, Rng>,
    pub stats: EngineStats,
    pub decode_latency_ms: Samples,
}

impl<M: StepModel> InferenceEngine<M> {
    pub fn new(model: M, cfg: EngineConfig) -> Self {
        let batch = model.batch();
        let max_seq = model.max_seq();
        InferenceEngine {
            queue: AdmissionQueue::new(cfg.queue_capacity),
            slots: SlotAllocator::new(batch),
            batcher: Batcher::new(batch, max_seq),
            scheduler: Scheduler::new(cfg.scheduler.clone()),
            active: HashMap::new(),
            prefilling: None,
            completions: VecDeque::new(),
            next_id: 1,
            rngs: HashMap::new(),
            stats: EngineStats::default(),
            decode_latency_ms: Samples::new(),
            model,
            cfg,
        }
    }

    pub fn queue_pressure(&self) -> f64 {
        self.queue.pressure()
    }

    /// Submit a request; fails with backpressure when the queue is full.
    pub fn submit(&mut self, prompt: Vec<i32>, params: SamplingParams)
                  -> Result<RequestId> {
        let max_prompt = self.model.max_seq().saturating_sub(1);
        if prompt.is_empty() || prompt.len() > max_prompt {
            return Err(anyhow!(
                "prompt length {} not in 1..={max_prompt}", prompt.len()));
        }
        let id = self.next_id;
        self.next_id += 1;
        let req = Request::new(id, prompt, params);
        self.queue
            .push(req)
            .map_err(|QueueFull(_)| anyhow!("queue full (backpressure)"))?;
        Ok(id)
    }

    /// Pop any completions produced so far.
    pub fn take_completions(&mut self) -> Vec<Completion> {
        self.completions.drain(..).collect()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty() && self.prefilling.is_none()
    }

    /// Run one scheduler iteration. Returns the action taken.
    pub fn step(&mut self) -> Result<Action> {
        self.stats.iterations += 1;
        let action = self.scheduler.decide(
            self.queue.len(),
            self.active.len(),
            self.slots.available(),
            self.prefilling.is_some(),
        );
        match action {
            Action::Idle => {}
            Action::Prefill => self.do_prefill_chunk()?,
            Action::Decode => self.do_decode_step()?,
        }
        Ok(action)
    }

    /// Drive until every submitted request has finished.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_completions())
    }

    // -- internals ----------------------------------------------------------

    fn do_prefill_chunk(&mut self) -> Result<()> {
        if self.prefilling.is_none() {
            // Admit the queue head into a fresh slot.
            let mut req = self
                .queue
                .pop()
                .ok_or_else(|| anyhow!("scheduler bug: prefill with empty queue"))?;
            let slot = self
                .slots
                .alloc()
                .ok_or_else(|| anyhow!("scheduler bug: prefill with no free slot"))?;
            req.state = RequestState::Prefilling { slot, next: 0 };
            self.rngs.insert(req.id, Rng::new(req.params.seed ^ req.id));
            self.prefilling = Some(PrefillJob { req, slot, next: 0 });
        }
        let mut job = self.prefilling.take().expect("prefill job");
        let prompt = &job.req.prompt;
        let remaining = prompt.len() - job.next;
        let bucket = self.model.bucket_for(remaining);
        let take = remaining.min(bucket);
        let mut chunk = prompt[job.next..job.next + take].to_vec();
        chunk.resize(bucket, 0); // pad; executable overwrites before reads
        let logits =
            self.model.prefill(bucket, &chunk, take, job.slot, job.next)?;
        self.stats.prefill_chunks += 1;
        job.next += take;
        if job.next < prompt.len() {
            job.req.state = RequestState::Prefilling { slot: job.slot, next: job.next };
            self.prefilling = Some(job);
            return Ok(());
        }
        // Prompt complete: sample the first generated token from the
        // prefill logits and move to decoding.
        let PrefillJob { mut req, slot, .. } = job;
        let rng = self.rngs.get_mut(&req.id).expect("rng");
        let tok = sample(&logits, &req.params, rng);
        req.record_token(tok);
        self.stats.tokens_generated += 1;
        if let Some(reason) = req.stop_reason(self.model.max_seq()) {
            self.finish(req, slot, reason, false);
            return Ok(());
        }
        req.state = RequestState::Decoding { slot };
        self.batcher.occupy(slot, req.id, req.prompt.len(), tok);
        self.active.insert(slot, req);
        Ok(())
    }

    fn do_decode_step(&mut self) -> Result<()> {
        let (tokens, pos) = self.batcher.decode_inputs();
        let t0 = Instant::now();
        let logits = self.model.decode(&tokens, &pos)?;
        self.decode_latency_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        self.stats.decode_steps += 1;
        self.stats.occupancy.push(self.active.len());
        let vocab = self.model.vocab();
        let slots: Vec<usize> = self.active.keys().copied().collect();
        for slot in slots {
            let req = self.active.get_mut(&slot).expect("active req");
            let row = &logits[slot * vocab..(slot + 1) * vocab];
            let rng = self.rngs.get_mut(&req.id).expect("rng");
            let tok = sample(row, &req.params, rng);
            req.record_token(tok);
            self.stats.tokens_generated += 1;
            self.batcher.advance(slot, tok);
            if let Some(reason) = req.stop_reason(self.model.max_seq()) {
                let req = self.active.remove(&slot).expect("req");
                self.finish(req, slot, reason, true);
            }
        }
        Ok(())
    }

    fn finish(&mut self, mut req: Request, slot: usize, reason: FinishReason,
              in_batcher: bool) {
        req.finish(reason);
        if in_batcher {
            self.batcher.vacate(slot);
        }
        self.slots.release(slot);
        self.rngs.remove(&req.id);
        self.stats.finished += 1;
        let now = Instant::now();
        self.completions.push_back(Completion {
            id: req.id,
            prompt: req.prompt.clone(),
            tokens: req.generated.clone(),
            reason,
            queue_ms: 0.0f64.max(
                req.first_token_at
                    .unwrap_or(now)
                    .duration_since(req.enqueued_at)
                    .as_secs_f64()
                    * 1e3,
            ),
            first_token_ms: req
                .first_token_at
                .map(|t| t.duration_since(req.enqueued_at).as_secs_f64() * 1e3)
                .unwrap_or(f64::NAN),
            total_ms: req
                .finished_at
                .map(|t| t.duration_since(req.enqueued_at).as_secs_f64() * 1e3)
                .unwrap_or(f64::NAN),
        });
    }

    /// HF-like sequential baseline: run a single request start-to-finish
    /// with batch occupancy 1 (no continuous batching). Used by Fig 13 to
    /// compare runtimes.
    pub fn generate_sequential(&mut self, prompt: Vec<i32>,
                               params: SamplingParams) -> Result<Completion> {
        if !self.is_idle() {
            return Err(anyhow!("sequential generation requires an idle engine"));
        }
        let id = self.submit(prompt, params)?;
        let completions = self.run_to_completion()?;
        completions
            .into_iter()
            .find(|c| c.id == id)
            .ok_or_else(|| anyhow!("request {id} did not complete"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::model::MockModel;

    fn engine(batch: usize) -> InferenceEngine<MockModel> {
        InferenceEngine::new(MockModel::new(batch, 64, 16, vec![4, 8]),
                             EngineConfig::default())
    }

    #[test]
    fn single_request_generates_expected_tokens() {
        let mut e = engine(2);
        // prompt [1,2,3]: last tok 3 at pos 2 -> first gen (3+2)%16 = 5
        // then 5 at pos 3 -> 8; 8 at pos 4 -> 12
        let id = e
            .submit(vec![1, 2, 3],
                    SamplingParams { max_tokens: 3, ..Default::default() })
            .unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].tokens, vec![5, 8, 12]);
        assert_eq!(done[0].reason, FinishReason::Length);
    }

    #[test]
    fn multi_chunk_prefill_matches_single_chunk() {
        // a 7-token prompt must split into 4+3 chunks with buckets [4,8]?
        // bucket_for(7)=8 so single chunk; force multi-chunk via buckets [4]
        let model = MockModel::new(1, 64, 16, vec![4]);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        let prompt = vec![1, 2, 3, 4, 5, 6, 7];
        let id = e
            .submit(prompt.clone(),
                    SamplingParams { max_tokens: 1, ..Default::default() })
            .unwrap();
        let done = e.run_to_completion().unwrap();
        // last tok 7 at pos 6 -> (7+6)%16 = 13
        assert_eq!(done[0].tokens, vec![13]);
        assert_eq!(done[0].id, id);
        assert!(e.stats.prefill_chunks >= 2);
    }

    #[test]
    fn concurrent_requests_share_decode_steps() {
        let mut e = engine(4);
        let n = 4;
        for i in 0..n {
            e.submit(vec![1 + i as i32, 2, 3],
                     SamplingParams { max_tokens: 8, ..Default::default() })
                .unwrap();
        }
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), n);
        // Continuous batching: far fewer decode steps than tokens.
        let tokens: usize = done.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(tokens, 8 * n);
        assert!(
            (e.stats.decode_steps as usize) < tokens,
            "decode steps {} should be < total tokens {tokens}",
            e.stats.decode_steps
        );
        assert!(e.stats.mean_occupancy() > 1.5,
                "occupancy {}", e.stats.mean_occupancy());
    }

    #[test]
    fn more_requests_than_slots_queue_up() {
        let mut e = engine(2);
        for i in 0..6 {
            e.submit(vec![1 + i, 2],
                     SamplingParams { max_tokens: 4, ..Default::default() })
                .unwrap();
        }
        let done = e.run_to_completion().unwrap();
        assert_eq!(done.len(), 6);
        assert!(e.is_idle());
    }

    #[test]
    fn backpressure_propagates() {
        let model = MockModel::new(1, 64, 16, vec![4]);
        let mut e = InferenceEngine::new(
            model,
            EngineConfig { queue_capacity: 2, ..Default::default() },
        );
        e.submit(vec![1], SamplingParams::default()).unwrap();
        e.submit(vec![2], SamplingParams::default()).unwrap();
        assert!(e.submit(vec![3], SamplingParams::default()).is_err());
    }

    #[test]
    fn rejects_overlong_prompt() {
        let mut e = engine(2);
        assert!(e.submit(vec![1; 64], SamplingParams::default()).is_err());
        assert!(e.submit(vec![1; 63], SamplingParams::default()).is_ok());
    }

    #[test]
    fn context_overflow_finishes_request() {
        let model = MockModel::new(1, 16, 8, vec![4]);
        let mut e = InferenceEngine::new(model, EngineConfig::default());
        e.submit(vec![1, 2, 3, 4],
                 SamplingParams { max_tokens: 1000, ..Default::default() })
            .unwrap();
        let done = e.run_to_completion().unwrap();
        assert_eq!(done[0].reason, FinishReason::ContextOverflow);
        assert_eq!(done[0].tokens.len() + 4, 16);
    }

    #[test]
    fn sequential_equals_batched_output() {
        let mut e1 = engine(4);
        let c1 = e1
            .generate_sequential(vec![2, 4, 6],
                                 SamplingParams { max_tokens: 5, ..Default::default() })
            .unwrap();
        let mut e2 = engine(4);
        let id = e2
            .submit(vec![2, 4, 6],
                    SamplingParams { max_tokens: 5, ..Default::default() })
            .unwrap();
        // add noise requests around it
        e2.submit(vec![9, 9], SamplingParams { max_tokens: 5, ..Default::default() })
            .unwrap();
        let done = e2.run_to_completion().unwrap();
        let c2 = done.iter().find(|c| c.id == id).unwrap();
        assert_eq!(c1.tokens, c2.tokens, "batching must not change outputs");
    }
}
