//! Replica health state machine + deterministic fault injection.
//!
//! [`HealthTracker`] is the per-replica half of the front door's failure
//! isolation: a replica whose worker panics or errors goes
//! Healthy→Degraded (and →Quarantined after repeated failures), is
//! routed around while down, and is probed for restart on an
//! exponential backoff. The first completion served by a restarted
//! replica proves it out and returns it to Healthy.
//!
//! [`FaultPlan`] makes chaos scenarios reproducible unit tests: a parsed
//! plan (`TARDIS_FAULT_PLAN` env or programmatic) injects one-shot
//! faults — kill replica i at engine step k, fail a step with an error,
//! drop a connection mid-stream, fail a journal append — at exact,
//! deterministic points in the pipeline.

use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::engine_loop::StepFault;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    Healthy,
    /// Failed recently (or restarted and not yet proven); routed to
    /// only when healthier replicas are busier.
    Degraded,
    /// Repeated failures; restart probes back off to the maximum pace.
    Quarantined,
}

impl HealthState {
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Quarantined => "quarantined",
        }
    }

    /// Routing preference rank (lower routes first at equal load).
    pub fn rank(self) -> u8 {
        match self {
            HealthState::Healthy => 0,
            HealthState::Degraded => 1,
            HealthState::Quarantined => 2,
        }
    }
}

/// Failures before Degraded escalates to Quarantined.
const QUARANTINE_AFTER: u32 = 3;

#[derive(Debug, Clone)]
pub struct HealthTracker {
    state: HealthState,
    alive: bool,
    consecutive_failures: u32,
    pub failures: u64,
    pub restarts: u64,
    next_probe: Option<Instant>,
    probe_base: Duration,
    probe_max: Duration,
}

impl HealthTracker {
    pub fn new(probe_base: Duration, probe_max: Duration) -> HealthTracker {
        HealthTracker {
            state: HealthState::Healthy,
            alive: true,
            consecutive_failures: 0,
            failures: 0,
            restarts: 0,
            next_probe: None,
            probe_base,
            probe_max,
        }
    }

    pub fn state(&self) -> HealthState {
        self.state
    }

    pub fn is_alive(&self) -> bool {
        self.alive
    }

    /// The worker died: mark down and schedule a restart probe at
    /// `probe_base * 2^(failures-1)`, capped at `probe_max`.
    pub fn on_failure(&mut self, now: Instant) {
        self.failures += 1;
        self.consecutive_failures += 1;
        self.alive = false;
        self.state = if self.consecutive_failures >= QUARANTINE_AFTER {
            HealthState::Quarantined
        } else {
            HealthState::Degraded
        };
        let shift = self.consecutive_failures.saturating_sub(1).min(16);
        let delay = self
            .probe_base
            .saturating_mul(1u32 << shift)
            .min(self.probe_max);
        self.next_probe = Some(now + delay);
    }

    pub fn probe_due(&self, now: Instant) -> bool {
        !self.alive && self.next_probe.is_some_and(|t| now >= t)
    }

    /// Backoff remaining before the next restart probe (None when alive
    /// or due now) — the basis for `retry_after_ms` when every candidate
    /// replica is down.
    pub fn backoff_remaining(&self, now: Instant) -> Option<Duration> {
        if self.alive {
            return None;
        }
        self.next_probe.map(|t| t.saturating_duration_since(now))
    }

    /// A fresh worker was spawned; stays Degraded/Quarantined until a
    /// completion proves it out.
    pub fn on_restart(&mut self) {
        self.alive = true;
        self.restarts += 1;
        self.next_probe = None;
    }

    /// A completion was served by this replica.
    pub fn on_success(&mut self) {
        if self.alive {
            self.consecutive_failures = 0;
            self.state = HealthState::Healthy;
        }
    }
}

/// One injected fault. All faults are one-shot: consumed when armed or
/// fired, so a restarted replica comes back clean.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Panic inside `engine.step()` when replica `replica` reaches
    /// engine iteration `step` (exercises `catch_unwind` + replay).
    Kill { replica: usize, step: u64 },
    /// `engine.step()` returns an error instead of panicking.
    FailStep { replica: usize, step: u64 },
    /// Drop the reply channel of the `admit`-th accepted request
    /// (0-based): the client vanishes mid-stream.
    DropConn { admit: u64 },
    /// Fail the `append`-th journal write (0-based).
    JournalError { append: u64 },
}

/// A deterministic chaos scenario: a list of one-shot faults, parseable
/// from `TARDIS_FAULT_PLAN`, e.g.
/// `kill:1@40,fail:0@10,dropconn@3,journal@2`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Parse `TARDIS_FAULT_PLAN` (empty plan when unset).
    pub fn from_env() -> Result<FaultPlan> {
        match std::env::var("TARDIS_FAULT_PLAN") {
            Ok(spec) if !spec.trim().is_empty() => FaultPlan::parse(&spec),
            _ => Ok(FaultPlan::default()),
        }
    }

    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut faults = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            faults.push(parse_fault(part)?);
        }
        Ok(FaultPlan { faults })
    }

    /// Remove and return the step faults aimed at `replica` — armed into
    /// the worker at spawn, so a restarted incarnation is clean.
    pub fn take_step_faults(&mut self, replica: usize) -> Vec<(u64, StepFault)> {
        let mut out = Vec::new();
        self.faults.retain(|f| match *f {
            Fault::Kill { replica: r, step } if r == replica => {
                out.push((step, StepFault::Panic));
                false
            }
            Fault::FailStep { replica: r, step } if r == replica => {
                out.push((step, StepFault::Error));
                false
            }
            _ => true,
        });
        out
    }

    /// Whether the reply of admission number `admit` should be dropped.
    pub fn take_drop_conn(&mut self, admit: u64) -> bool {
        let before = self.faults.len();
        self.faults
            .retain(|f| !matches!(*f, Fault::DropConn { admit: a } if a == admit));
        self.faults.len() != before
    }

    /// Remove and return every injected journal-append failure index.
    pub fn take_journal_errors(&mut self) -> Vec<u64> {
        let mut out = Vec::new();
        self.faults.retain(|f| match *f {
            Fault::JournalError { append } => {
                out.push(append);
                false
            }
            _ => true,
        });
        out
    }
}

fn parse_fault(part: &str) -> Result<Fault> {
    let bad = || anyhow!("bad fault {part:?} (expected kill:R@S, fail:R@S, dropconn@N, journal@N)");
    if let Some(rest) = part.strip_prefix("kill:").or_else(|| part.strip_prefix("fail:")) {
        let (r, s) = rest.split_once('@').ok_or_else(bad)?;
        let replica = r.parse::<usize>().map_err(|_| bad())?;
        let step = s.parse::<u64>().map_err(|_| bad())?;
        return Ok(if part.starts_with("kill:") {
            Fault::Kill { replica, step }
        } else {
            Fault::FailStep { replica, step }
        });
    }
    if let Some(n) = part.strip_prefix("dropconn@") {
        return Ok(Fault::DropConn { admit: n.parse().map_err(|_| bad())? });
    }
    if let Some(n) = part.strip_prefix("journal@") {
        return Ok(Fault::JournalError { append: n.parse().map_err(|_| bad())? });
    }
    Err(bad())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracker() -> HealthTracker {
        HealthTracker::new(Duration::from_millis(10), Duration::from_millis(80))
    }

    #[test]
    fn degrades_then_quarantines() {
        let mut h = tracker();
        let t0 = Instant::now();
        assert_eq!(h.state(), HealthState::Healthy);
        h.on_failure(t0);
        assert_eq!(h.state(), HealthState::Degraded);
        assert!(!h.is_alive());
        h.on_restart();
        h.on_failure(t0);
        h.on_restart();
        h.on_failure(t0);
        assert_eq!(h.state(), HealthState::Quarantined);
        assert_eq!(h.failures, 3);
        assert_eq!(h.restarts, 2);
    }

    #[test]
    fn success_after_restart_returns_healthy() {
        let mut h = tracker();
        h.on_failure(Instant::now());
        h.on_success(); // dead replicas cannot prove themselves
        assert_eq!(h.state(), HealthState::Degraded);
        h.on_restart();
        h.on_success();
        assert_eq!(h.state(), HealthState::Healthy);
        assert!(h.is_alive());
    }

    #[test]
    fn probe_backoff_doubles_and_caps() {
        let mut h = tracker();
        let t0 = Instant::now();
        h.on_failure(t0);
        assert!(!h.probe_due(t0));
        assert!(h.probe_due(t0 + Duration::from_millis(10)));
        h.on_restart();
        h.on_failure(t0);
        assert!(!h.probe_due(t0 + Duration::from_millis(10)));
        assert!(h.probe_due(t0 + Duration::from_millis(20)));
        for _ in 0..6 {
            h.on_restart();
            h.on_failure(t0);
        }
        // Capped at probe_max.
        assert!(h.probe_due(t0 + Duration::from_millis(80)));
    }

    #[test]
    fn parses_fault_plan() {
        let plan = FaultPlan::parse("kill:1@40, fail:0@10,dropconn@3,journal@2").unwrap();
        assert_eq!(plan.faults.len(), 4);
        assert_eq!(plan.faults[0], Fault::Kill { replica: 1, step: 40 });
        assert_eq!(plan.faults[1], Fault::FailStep { replica: 0, step: 10 });
        assert_eq!(plan.faults[2], Fault::DropConn { admit: 3 });
        assert_eq!(plan.faults[3], Fault::JournalError { append: 2 });
        assert!(FaultPlan::parse("explode@9").is_err());
        assert!(FaultPlan::parse("kill:x@2").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn take_consumes_faults_once() {
        let mut plan = FaultPlan::parse("kill:1@40,fail:1@50,journal@2,dropconn@0").unwrap();
        let armed = plan.take_step_faults(1);
        assert_eq!(armed, vec![(40, StepFault::Panic), (50, StepFault::Error)]);
        assert!(plan.take_step_faults(1).is_empty());
        assert!(plan.take_drop_conn(0));
        assert!(!plan.take_drop_conn(0));
        assert_eq!(plan.take_journal_errors(), vec![2]);
        assert!(plan.is_empty());
    }
}
