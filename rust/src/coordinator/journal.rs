//! Durable admission journal: an append-only JSONL write-ahead log.
//!
//! The front door records every admission (ticket, prompt tokens,
//! sampling params, variant pin) *before* dispatching it to a replica,
//! and every completion after it. Recovery replays the log and returns
//! the admitted-but-not-completed set, so a crashed process (or a killed
//! replica whose in-flight work the front door replays live) loses zero
//! admitted requests.
//!
//! Format — one object per line, two event kinds:
//!
//! ```text
//! {"e":"admit","ticket":7,"prompt":[104,105],"max_tokens":8,
//!  "temperature":0,"top_k":0,"seed":0,"priority":0,"variant":"mock"}
//! {"e":"done","ticket":7,"reason":"length"}
//! ```
//!
//! A truncated or unparsable *final* line is tolerated silently — that is
//! the normal artifact of dying mid-append. Unparsable lines anywhere
//! else mean the file was corrupted at rest and recovery refuses to
//! guess. Appends are flushed per line; an append failure degrades to a
//! counter (`errors`) rather than refusing service — availability wins
//! over durability for the tail of the log.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::request::SamplingParams;
use crate::util::json::Json;

/// An admission as recorded in (and recovered from) the journal.
#[derive(Debug, Clone, PartialEq)]
pub struct JournalEntry {
    pub ticket: u64,
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
    /// Replica-variant pin, when the client asked for one.
    pub variant: Option<String>,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JournalStats {
    pub appends: u64,
    pub bytes: u64,
    /// Failed appends (I/O errors and injected faults). The admission
    /// proceeds; only its durability is lost.
    pub errors: u64,
}

/// What recovery found, for logs and tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    pub admits: u64,
    pub dones: u64,
    /// The final line was truncated/unparsable (normal crash artifact).
    pub truncated_tail: bool,
}

pub struct Journal {
    path: PathBuf,
    file: File,
    pub stats: JournalStats,
    /// Injected fault: append indices (0-based) that fail without
    /// writing. See [`crate::coordinator::health::FaultPlan`].
    fail_appends: Vec<u64>,
}

impl Journal {
    /// Open for appending (creating the file if needed).
    pub fn open(path: &Path) -> Result<Journal> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open journal {}", path.display()))?;
        Ok(Journal {
            path: path.to_path_buf(),
            file,
            stats: JournalStats::default(),
            fail_appends: Vec::new(),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Install injected append failures (chaos harness), by 0-based
    /// append index counted over this handle's lifetime.
    pub fn inject_fail_appends(&mut self, idxs: Vec<u64>) {
        self.fail_appends = idxs;
    }

    /// Replay an existing journal: every admission without a matching
    /// completion, sorted by ticket, plus the next unused ticket.
    pub fn recover(path: &Path) -> Result<(Vec<JournalEntry>, u64, RecoveryReport)> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read journal {}", path.display()))?;
        let lines: Vec<&str> = text.lines().collect();
        let mut pending: Vec<JournalEntry> = Vec::new();
        let mut report = RecoveryReport::default();
        let mut max_ticket = 0u64;
        for (i, line) in lines.iter().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let last = i + 1 == lines.len();
            let parsed = Json::parse(line).ok().and_then(|j| parse_event(&j));
            let Some(event) = parsed else {
                if last {
                    // Normal crash artifact: died mid-append.
                    report.truncated_tail = true;
                    continue;
                }
                return Err(anyhow!(
                    "journal {} corrupt at line {}: {line:?}",
                    path.display(),
                    i + 1
                ));
            };
            match event {
                Event::Admit(e) => {
                    max_ticket = max_ticket.max(e.ticket);
                    report.admits += 1;
                    // Idempotent on duplicate admits (re-journaled replays).
                    pending.retain(|p| p.ticket != e.ticket);
                    pending.push(e);
                }
                Event::Done(ticket) => {
                    max_ticket = max_ticket.max(ticket);
                    report.dones += 1;
                    pending.retain(|p| p.ticket != ticket);
                }
            }
        }
        pending.sort_by_key(|e| e.ticket);
        Ok((pending, max_ticket + 1, report))
    }

    pub fn append_admit(&mut self, e: &JournalEntry) -> Result<()> {
        let mut fields = vec![
            ("e", Json::str("admit")),
            ("ticket", Json::num(e.ticket as f64)),
            (
                "prompt",
                Json::arr(e.prompt.iter().map(|&t| Json::num(t as f64))),
            ),
            ("temperature", Json::num(e.params.temperature as f64)),
            ("top_k", Json::num(e.params.top_k as f64)),
            ("max_tokens", Json::num(e.params.max_tokens as f64)),
            ("seed", Json::num(e.params.seed as f64)),
            ("priority", Json::num(e.params.priority as f64)),
        ];
        if let Some(stop) = e.params.stop_token {
            fields.push(("stop_token", Json::num(stop as f64)));
        }
        // SLO/degrade fields are emitted only when set, so journals
        // written without them stay byte-identical — and recovery
        // tolerates their absence (old logs replay with no deadline).
        if let Some(ms) = e.params.ttft_deadline_ms {
            fields.push(("ttft_deadline_ms", Json::num(ms as f64)));
        }
        if let Some(ms) = e.params.tpot_deadline_ms {
            fields.push(("tpot_deadline_ms", Json::num(ms as f64)));
        }
        if e.params.degrade {
            fields.push(("degrade", Json::Bool(true)));
        }
        if let Some(v) = &e.variant {
            fields.push(("variant", Json::str(v)));
        }
        self.append_line(Json::obj(fields).render())
    }

    pub fn append_done(&mut self, ticket: u64, reason: &str) -> Result<()> {
        self.append_line(
            Json::obj(vec![
                ("e", Json::str("done")),
                ("ticket", Json::num(ticket as f64)),
                ("reason", Json::str(reason)),
            ])
            .render(),
        )
    }

    fn append_line(&mut self, line: String) -> Result<()> {
        let idx = self.stats.appends;
        self.stats.appends += 1;
        if let Some(pos) = self.fail_appends.iter().position(|&i| i == idx) {
            self.fail_appends.swap_remove(pos);
            self.stats.errors += 1;
            return Err(anyhow!("injected journal write fault (append {idx})"));
        }
        let res = self
            .file
            .write_all(line.as_bytes())
            .and_then(|()| self.file.write_all(b"\n"))
            .and_then(|()| self.file.flush());
        match res {
            Ok(()) => {
                self.stats.bytes += line.len() as u64 + 1;
                Ok(())
            }
            Err(e) => {
                self.stats.errors += 1;
                Err(anyhow!("journal append failed: {e}"))
            }
        }
    }
}

enum Event {
    Admit(JournalEntry),
    Done(u64),
}

fn parse_event(j: &Json) -> Option<Event> {
    let ticket = j.get("ticket").and_then(Json::as_i64)? as u64;
    match j.get("e").and_then(Json::as_str)? {
        "done" => Some(Event::Done(ticket)),
        "admit" => {
            let prompt = j
                .get("prompt")
                .and_then(Json::as_arr)?
                .iter()
                .map(|v| v.as_i64().map(|x| x as i32))
                .collect::<Option<Vec<i32>>>()?;
            let params = SamplingParams {
                temperature: j.get("temperature").and_then(Json::as_f64)? as f32,
                top_k: j.get("top_k").and_then(Json::as_usize)?,
                max_tokens: j.get("max_tokens").and_then(Json::as_usize)?,
                stop_token: j.get("stop_token").and_then(Json::as_i64).map(|v| v as i32),
                seed: j.get("seed").and_then(Json::as_i64)? as u64,
                priority: j.get("priority").and_then(Json::as_i64)? as i32,
                // Optional (PR 9 onward): absent in old journals.
                ttft_deadline_ms: j
                    .get("ttft_deadline_ms")
                    .and_then(Json::as_i64)
                    .map(|v| v as u64),
                tpot_deadline_ms: j
                    .get("tpot_deadline_ms")
                    .and_then(Json::as_i64)
                    .map(|v| v as u64),
                degrade: j.get("degrade").and_then(Json::as_bool).unwrap_or(false),
            };
            let variant = j.get("variant").and_then(Json::as_str).map(str::to_string);
            Some(Event::Admit(JournalEntry { ticket, prompt, params, variant }))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("tardis-journal-{name}-{}", std::process::id()));
        p
    }

    fn entry(ticket: u64, prompt: Vec<i32>) -> JournalEntry {
        JournalEntry {
            ticket,
            prompt,
            params: SamplingParams { max_tokens: 8, seed: 3, ..Default::default() },
            variant: Some("mock".to_string()),
        }
    }

    #[test]
    fn roundtrip_pending_only() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        {
            let mut jr = Journal::open(&path).unwrap();
            jr.append_admit(&entry(1, vec![10, 11])).unwrap();
            jr.append_admit(&entry(2, vec![12])).unwrap();
            jr.append_admit(&entry(3, vec![13, 14, 15])).unwrap();
            jr.append_done(2, "length").unwrap();
            assert_eq!(jr.stats.appends, 4);
            assert!(jr.stats.bytes > 0);
        }
        let (pending, next, report) = Journal::recover(&path).unwrap();
        assert_eq!(pending.len(), 2);
        assert_eq!(pending[0], entry(1, vec![10, 11]));
        assert_eq!(pending[1], entry(3, vec![13, 14, 15]));
        assert_eq!(next, 4);
        assert_eq!(report.admits, 3);
        assert_eq!(report.dones, 1);
        assert!(!report.truncated_tail);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tolerates_truncated_tail() {
        let path = tmp("tail");
        let _ = std::fs::remove_file(&path);
        {
            let mut jr = Journal::open(&path).unwrap();
            jr.append_admit(&entry(5, vec![9])).unwrap();
        }
        // Crash mid-append: a half-written final line.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"e\":\"admit\",\"tick").unwrap();
        }
        let (pending, next, report) = Journal::recover(&path).unwrap();
        assert_eq!(pending.len(), 1);
        assert_eq!(pending[0].ticket, 5);
        assert_eq!(next, 6);
        assert!(report.truncated_tail);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn recovers_mixed_old_and_new_lines() {
        // A journal written partly by a pre-SLO binary (no deadline or
        // degrade fields) and partly by this one must recover fully:
        // old lines replay with no deadline at full quality.
        let path = tmp("mixed-slo");
        let _ = std::fs::remove_file(&path);
        std::fs::write(
            &path,
            concat!(
                "{\"e\":\"admit\",\"ticket\":1,\"prompt\":[10,11],",
                "\"temperature\":0,\"top_k\":0,\"max_tokens\":8,",
                "\"seed\":3,\"priority\":0,\"variant\":\"mock\"}\n"
            ),
        )
        .unwrap();
        {
            let mut jr = Journal::open(&path).unwrap();
            let new = JournalEntry {
                ticket: 2,
                prompt: vec![12],
                params: SamplingParams {
                    max_tokens: 8,
                    seed: 3,
                    ttft_deadline_ms: Some(50),
                    tpot_deadline_ms: Some(20),
                    degrade: true,
                    ..Default::default()
                },
                variant: None,
            };
            jr.append_admit(&new).unwrap();
        }
        let (pending, next, report) = Journal::recover(&path).unwrap();
        assert_eq!(next, 3);
        assert_eq!(report.admits, 2);
        assert_eq!(pending.len(), 2);
        // old line: no SLO, full quality
        assert_eq!(pending[0].ticket, 1);
        assert_eq!(pending[0].params.ttft_deadline_ms, None);
        assert_eq!(pending[0].params.tpot_deadline_ms, None);
        assert!(!pending[0].params.degrade);
        // new line: round-trips its SLO and degrade mark
        assert_eq!(pending[1].ticket, 2);
        assert_eq!(pending[1].params.ttft_deadline_ms, Some(50));
        assert_eq!(pending[1].params.tpot_deadline_ms, Some(20));
        assert!(pending[1].params.degrade);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_mid_file_corruption() {
        let path = tmp("corrupt");
        std::fs::write(&path, "not json\n{\"e\":\"done\",\"ticket\":1,\"reason\":\"x\"}\n")
            .unwrap();
        assert!(Journal::recover(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn injected_append_fault_counts_and_degrades() {
        let path = tmp("fault");
        let _ = std::fs::remove_file(&path);
        let mut jr = Journal::open(&path).unwrap();
        jr.inject_fail_appends(vec![1]);
        jr.append_admit(&entry(1, vec![1])).unwrap();
        assert!(jr.append_admit(&entry(2, vec![2])).is_err());
        jr.append_admit(&entry(3, vec![3])).unwrap();
        assert_eq!(jr.stats.errors, 1);
        assert_eq!(jr.stats.appends, 3);
        // Ticket 2 was never durably admitted; 1 and 3 recover.
        let (pending, _, _) = Journal::recover(&path).unwrap();
        assert_eq!(
            pending.iter().map(|e| e.ticket).collect::<Vec<_>>(),
            vec![1, 3]
        );
        let _ = std::fs::remove_file(&path);
    }
}
