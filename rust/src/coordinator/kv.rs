//! Paged KV cache accounting: fixed-size token blocks, per-request
//! block tables, and cross-request prefix sharing (the vLLM/SGLang
//! paged-attention + radix-cache generalization; the old "one sequence
//! = one block span" slot scheme is now just the degenerate
//! [`KvLayout::degenerate`] case with `block_size == max_seq`).
//!
//! * [`BlockAllocator`] — a refcounted free list over `n`
//!   interchangeable units. The engine runs two of them: one over the
//!   decode-batch rows ("slots", refcount always 0/1) and one over the
//!   KV blocks, where a block shared between live requests and the
//!   prefix cache carries one reference per holder. Allocation order is
//!   deterministic *and history-invariant*: the free list is kept
//!   sorted and [`BlockAllocator::alloc`] always hands out the
//!   lowest-numbered free unit, so the physical binding produced by a
//!   plan depends only on the *set* of free blocks — not on the order
//!   in which shared references were dropped. That is what keeps
//!   [`super::scheduler::StepPlan`] execution bitwise replayable under
//!   refcounted release.
//! * [`BlockTable`] — one request's logical-position → physical-block
//!   mapping. Appending a token never moves data ("copy-free append"):
//!   growth only pushes a fresh block id; the K/V rows already written
//!   stay where they are. [`BlockTable::replace_block`] swaps a single
//!   id in place — the copy-on-write hook for diverging from a shared
//!   block.
//! * [`RadixCache`] — the prefix index: a trie keyed on token IDs at
//!   block granularity. Matching walks full-block chunks and finishes
//!   with a longest-common-prefix probe into one more block (a partial
//!   hit that the engine must copy-on-write before appending). Only
//!   *full* prompt blocks are ever inserted, so cached cells are
//!   immutable by construction. Eviction is leaf-only LRU over blocks
//!   whose refcount is 1 (held by the cache alone): cold leaves go
//!   first, shared trunks stay pinned while any request references
//!   them, and interior nodes become evictable leaves once their
//!   children are gone.
//! * [`KvLayout`] — the backend's paged geometry (how many blocks of
//!   how many tokens), reported by
//!   [`super::model::StepModel::kv_layout`].
//!
//! Swap contents for preempted requests live in the model layer (see
//! [`super::model::KvSwap`]); this module only does the accounting.

use std::collections::BTreeMap;

/// Blocks needed to hold `tokens` cache entries at `block_size` tokens
/// per block. The single source of this arithmetic — the scheduler's
/// planning ledger and the engine's allocations must agree on it.
pub fn blocks_for(tokens: usize, block_size: usize) -> usize {
    tokens.div_ceil(block_size.max(1))
}

/// Blocks a resumed request needs: its `tokens` resident entries *plus
/// room for the next decode write*, so a resume can always make progress
/// before the next block-pressure event (no zero-progress preempt/resume
/// livelock). Planner and engine must use the same formula — hence one
/// function.
pub fn blocks_to_resume(tokens: usize, block_size: usize) -> usize {
    blocks_for(tokens + 1, block_size)
}

/// Paged-KV geometry of a step model: `num_blocks` physical blocks of
/// `block_size` tokens each, shared by every slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvLayout {
    pub num_blocks: usize,
    pub block_size: usize,
}

impl KvLayout {
    /// The fixed-slot degenerate case: one block per decode slot, each
    /// spanning the whole context. Backends without paged storage (mock,
    /// pjrt) report this and ignore block tables entirely.
    pub fn degenerate(batch: usize, max_seq: usize) -> KvLayout {
        KvLayout { num_blocks: batch, block_size: max_seq.max(1) }
    }

    /// Total token capacity of the pool.
    pub fn capacity_tokens(&self) -> usize {
        self.num_blocks * self.block_size
    }

    /// Blocks needed to hold `tokens` cache entries.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        blocks_for(tokens, self.block_size)
    }

    /// See [`blocks_to_resume`].
    pub fn blocks_to_resume(&self, tokens: usize) -> usize {
        blocks_to_resume(tokens, self.block_size)
    }
}

/// Refcounted free-list allocator over `n` interchangeable units (KV
/// blocks, or decode slots). `alloc`/`claim` hand out a unit with one
/// reference; [`Self::retain`] adds a sharer, [`Self::release`] drops
/// one, and the unit re-enters the free list only when the last
/// reference is gone. The free list is kept sorted and `alloc` pops the
/// lowest free unit, so allocation is a function of the free *set* —
/// stable across release orderings (bitwise thread- and
/// history-invariant plans).
#[derive(Debug)]
pub struct BlockAllocator {
    n: usize,
    /// Free units, sorted descending so `pop` yields the lowest id.
    free: Vec<usize>,
    refs: Vec<u32>,
}

impl BlockAllocator {
    pub fn new(n: usize) -> Self {
        BlockAllocator { n, free: (0..n).rev().collect(), refs: vec![0; n] }
    }

    pub fn capacity(&self) -> usize {
        self.n
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn used(&self) -> usize {
        self.n - self.free.len()
    }

    /// Hand out the lowest-numbered free unit with refcount 1.
    pub fn alloc(&mut self) -> Option<usize> {
        let unit = self.free.pop()?;
        debug_assert!(self.refs[unit] == 0, "allocator invariant violated");
        self.refs[unit] = 1;
        Some(unit)
    }

    /// Free units in ascending order — the scheduler plans against this
    /// deterministic snapshot.
    pub fn free_list(&self) -> Vec<usize> {
        let mut v = self.free.clone();
        v.reverse();
        v
    }

    /// Claim the specific unit a [`super::scheduler::StepPlan`] assigned.
    /// Returns false if it is out of range or already in use (a scheduler
    /// bug the engine turns into an error).
    pub fn claim(&mut self, unit: usize) -> bool {
        if unit >= self.n || self.refs[unit] > 0 {
            return false;
        }
        let idx = self
            .free
            .binary_search_by(|u| unit.cmp(u))
            .expect("free list inconsistent with refcounts");
        self.free.remove(idx);
        self.refs[unit] = 1;
        true
    }

    /// Add a reference to an already-live unit (prefix sharing).
    pub fn retain(&mut self, unit: usize) {
        assert!(unit < self.n, "unit {unit} out of range");
        assert!(self.refs[unit] > 0, "retain of free unit {unit}");
        self.refs[unit] += 1;
    }

    /// Drop one reference; the unit re-enters the free list (in sorted
    /// position — release order never changes future allocations) when
    /// the last holder lets go.
    pub fn release(&mut self, unit: usize) {
        assert!(unit < self.n, "unit {unit} out of range");
        assert!(self.refs[unit] > 0, "double free of unit {unit}");
        self.refs[unit] -= 1;
        if self.refs[unit] == 0 {
            let idx = self
                .free
                .binary_search_by(|u| unit.cmp(u))
                .expect_err("freed unit already in free list");
            self.free.insert(idx, unit);
        }
    }

    pub fn ref_count(&self, unit: usize) -> u32 {
        self.refs[unit]
    }

    pub fn is_in_use(&self, unit: usize) -> bool {
        self.refs[unit] > 0
    }
}

/// One request's block table: logical token positions `0..capacity()`
/// map to cells of the physical blocks in order. Growth appends block
/// ids; existing entries never move (except an explicit copy-on-write
/// [`Self::replace_block`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BlockTable {
    block_size: usize,
    blocks: Vec<usize>,
}

impl BlockTable {
    pub fn new(block_size: usize) -> BlockTable {
        assert!(block_size >= 1, "block_size must be >= 1");
        BlockTable { block_size, blocks: Vec::new() }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn blocks(&self) -> &[usize] {
        &self.blocks
    }

    /// Token capacity of the blocks held so far.
    pub fn capacity(&self) -> usize {
        self.blocks.len() * self.block_size
    }

    pub fn push_block(&mut self, block: usize) {
        self.blocks.push(block);
    }

    /// Copy-on-write hook: swap the block id at table index `idx` for a
    /// private copy. The caller moves the K/V cells and fixes refcounts.
    pub fn replace_block(&mut self, idx: usize, block: usize) -> usize {
        assert!(idx < self.blocks.len(), "replace beyond block table");
        std::mem::replace(&mut self.blocks[idx], block)
    }

    /// Drop every block id (the caller releases them to the allocator).
    pub fn clear(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.blocks)
    }

    /// Speculative-decode rollback: keep the first `keep` blocks and pop
    /// the rest, returning the dropped ids in logical order (the caller
    /// releases them). Cells inside the kept blocks are untouched — a
    /// rejected draft tail never moves data, it only shrinks the mapping.
    pub fn truncate(&mut self, keep: usize) -> Vec<usize> {
        if keep >= self.blocks.len() {
            return Vec::new();
        }
        self.blocks.split_off(keep)
    }

    /// Physical cell index of logical position `pos` (in token units;
    /// multiply by the per-token stride for a flat buffer offset).
    pub fn physical(&self, pos: usize) -> usize {
        let (b, o) = (pos / self.block_size, pos % self.block_size);
        assert!(b < self.blocks.len(), "position {pos} beyond block table");
        self.blocks[b] * self.block_size + o
    }

    /// Iterate `(logical_start, physical_start, len)` runs covering
    /// logical positions `0..len` — each run is contiguous in the backing
    /// store, so gathers walk block-sized spans instead of per-token
    /// indirection.
    pub fn runs(&self, len: usize) -> impl Iterator<Item = (usize, usize, usize)> + '_ {
        let bs = self.block_size;
        self.blocks
            .iter()
            .enumerate()
            .take_while(move |(i, _)| i * bs < len)
            .map(move |(i, &blk)| {
                let t0 = i * bs;
                (t0, blk * bs, bs.min(len - t0))
            })
    }
}

/// A prefix-cache match: the shared physical blocks to map (in logical
/// order), how many prompt tokens they cover, and whether the last
/// block is only partially covered — in which case the engine must
/// copy-on-write it before the first append.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PrefixMatch {
    pub blocks: Vec<usize>,
    pub hit_tokens: usize,
    pub cow: bool,
}

impl PrefixMatch {
    pub fn is_hit(&self) -> bool {
        self.hit_tokens > 0
    }
}

#[derive(Debug)]
struct RadixNode {
    /// The `block_size` token IDs whose K/V rows live in `block`.
    key: Vec<i32>,
    block: usize,
    /// Logical LRU stamp (cache clock, not wall time).
    last_use: u64,
    parent: usize,
    children: BTreeMap<Vec<i32>, usize>,
}

/// Radix/trie prefix index over cached KV blocks, keyed on token IDs at
/// block granularity. Each node owns one cache reference on its block
/// (so a cached block's refcount is `1 + live sharers`). Structure:
/// node 0 is the blockless root; edges are exact `block_size`-token
/// chunks kept in a `BTreeMap` so matching and eviction are
/// deterministic. Divergence inside a block is handled at *match* time
/// (longest-common-prefix probe → partial hit + COW) rather than by
/// splitting stored nodes — two sibling keys sharing a token prefix
/// hold bitwise-identical cells for the shared positions, because K/V
/// at a position depends only on the token prefix up to it.
#[derive(Debug)]
pub struct RadixCache {
    block_size: usize,
    nodes: Vec<Option<RadixNode>>,
    free_nodes: Vec<usize>,
    clock: u64,
    live: usize,
}

impl RadixCache {
    pub fn new(block_size: usize) -> RadixCache {
        let root = RadixNode {
            key: Vec::new(),
            block: usize::MAX,
            last_use: 0,
            parent: 0,
            children: BTreeMap::new(),
        };
        RadixCache {
            block_size: block_size.max(1),
            nodes: vec![Some(root)],
            free_nodes: Vec::new(),
            clock: 0,
            live: 0,
        }
    }

    /// Number of blocks currently indexed (cache references held).
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    fn node(&self, id: usize) -> &RadixNode {
        self.nodes[id].as_ref().expect("radix node id stale")
    }

    fn node_mut(&mut self, id: usize) -> &mut RadixNode {
        self.nodes[id].as_mut().expect("radix node id stale")
    }

    fn new_node(&mut self, n: RadixNode) -> usize {
        self.live += 1;
        match self.free_nodes.pop() {
            Some(id) => {
                self.nodes[id] = Some(n);
                id
            }
            None => {
                self.nodes.push(Some(n));
                self.nodes.len() - 1
            }
        }
    }

    /// Longest cached prefix of `prompt`, capped at `prompt.len() - 1`
    /// so at least one token always runs through prefill (the sampler
    /// needs its logits). Walks exact full-block matches, then probes
    /// the children of the last matched node for a longest-common-prefix
    /// partial hit (ties broken by key order). Every matched block gets
    /// one caller reference via [`BlockAllocator::retain`]; the caller
    /// owns releasing them (or handing them to a block table).
    pub fn match_and_pin(&mut self, alloc: &mut BlockAllocator, prompt: &[i32]) -> PrefixMatch {
        let bs = self.block_size;
        let limit = prompt.len().saturating_sub(1);
        self.clock += 1;
        let stamp = self.clock;
        let mut m = PrefixMatch::default();
        let mut at = 0usize;
        while m.hit_tokens + bs <= limit {
            let chunk = &prompt[m.hit_tokens..m.hit_tokens + bs];
            let Some(&child) = self.node(at).children.get(chunk) else {
                break;
            };
            at = child;
            let n = self.node_mut(at);
            n.last_use = stamp;
            let block = n.block;
            alloc.retain(block);
            m.blocks.push(block);
            m.hit_tokens += bs;
        }
        let cap = limit - m.hit_tokens;
        if cap > 0 {
            let rest = &prompt[m.hit_tokens..m.hit_tokens + cap];
            let mut best: Option<(usize, usize)> = None;
            for (key, &child) in &self.node(at).children {
                let l = lcp(key, rest);
                if l > 0 && best.is_none_or(|(bl, _)| l > bl) {
                    best = Some((l, child));
                }
            }
            if let Some((l, child)) = best {
                let n = self.node_mut(child);
                n.last_use = stamp;
                let block = n.block;
                alloc.retain(block);
                m.blocks.push(block);
                m.hit_tokens += l;
                m.cow = true;
            }
        }
        m
    }

    /// Index the *full* blocks of a (partially) prefilled prompt. For
    /// each full `block_size` chunk of `prompt` not yet present, a node
    /// is created referencing the request's own physical block from
    /// `table_blocks` (the cache retains it); chunks already present
    /// just refresh LRU. Partial tail blocks are never inserted — they
    /// may later hold decode tokens. Returns the number of blocks newly
    /// indexed. Idempotent per chunk.
    pub fn insert(
        &mut self,
        alloc: &mut BlockAllocator,
        prompt: &[i32],
        table_blocks: &[usize],
    ) -> usize {
        let bs = self.block_size;
        debug_assert!(table_blocks.len() >= prompt.len() / bs, "table shorter than prompt");
        self.clock += 1;
        let stamp = self.clock;
        let mut at = 0usize;
        let mut created = 0usize;
        for (i, chunk) in prompt.chunks_exact(bs).enumerate() {
            if let Some(&child) = self.node(at).children.get(chunk) {
                at = child;
                self.node_mut(at).last_use = stamp;
                continue;
            }
            let block = table_blocks[i];
            alloc.retain(block);
            let id = self.new_node(RadixNode {
                key: chunk.to_vec(),
                block,
                last_use: stamp,
                parent: at,
                children: BTreeMap::new(),
            });
            self.node_mut(at).children.insert(chunk.to_vec(), id);
            at = id;
            created += 1;
        }
        created
    }

    /// Blocks the engine could reclaim right now by cascading leaf
    /// eviction: nodes whose whole subtree is held by the cache alone
    /// (refcount 1 all the way down). The scheduler counts these as
    /// free when budgeting plans; [`Self::evict_one`] makes good on it.
    pub fn evictable_blocks(&self, alloc: &BlockAllocator) -> usize {
        fn walk(c: &RadixCache, alloc: &BlockAllocator, id: usize) -> (usize, bool) {
            let n = c.node(id);
            let mut count = 0;
            let mut all_free = true;
            for &child in n.children.values() {
                let (k, f) = walk(c, alloc, child);
                count += k;
                all_free &= f;
            }
            if id == 0 {
                return (count, all_free);
            }
            let freeable = all_free && alloc.ref_count(n.block) == 1;
            (count + freeable as usize, freeable)
        }
        walk(self, alloc, 0).0
    }

    /// Evict the coldest unreferenced leaf (LRU by cache clock, ties by
    /// block id) and release its cache reference — the block re-enters
    /// the allocator free list. Interior nodes become leaves as their
    /// children go, so repeated calls drain whole cold subtrees.
    /// Returns the freed block, or None if every leaf is pinned.
    pub fn evict_one(&mut self, alloc: &mut BlockAllocator) -> Option<usize> {
        self.drop_coldest_leaf(alloc, true)
    }

    /// Last-resort unpinning: drop the cache's reference on the coldest
    /// leaf *even when live tables still share its block*. A trie leaf
    /// can hold rc > 1 while its ancestors sit at rc == 1 — then the
    /// ancestors are dead weight [`Self::evict_one`] refuses (their
    /// subtree is not all-free) and the pool can wedge with work in
    /// flight. Pruning shared leaves makes the trunk childless, after
    /// which further prunes actually free blocks. The block is only
    /// freed if the cache was its last holder; either way the returned
    /// id names the dropped entry (None = cache empty).
    pub fn prune_one(&mut self, alloc: &mut BlockAllocator) -> Option<usize> {
        self.drop_coldest_leaf(alloc, false)
    }

    fn drop_coldest_leaf(
        &mut self,
        alloc: &mut BlockAllocator,
        only_unshared: bool,
    ) -> Option<usize> {
        let mut best: Option<(u64, usize, usize)> = None;
        for (id, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if id == 0 || !n.children.is_empty() {
                continue;
            }
            if only_unshared && alloc.ref_count(n.block) != 1 {
                continue;
            }
            let cand = (n.last_use, n.block, id);
            if best.is_none_or(|(lu, b, _)| (cand.0, cand.1) < (lu, b)) {
                best = Some(cand);
            }
        }
        let (_, block, id) = best?;
        let node = self.nodes[id].take().expect("candidate vanished");
        self.node_mut(node.parent).children.remove(&node.key);
        self.free_nodes.push(id);
        self.live -= 1;
        alloc.release(block);
        Some(block)
    }
}

fn lcp(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::property;
    use crate::util::rng::Rng;

    #[test]
    fn alloc_release_cycle() {
        let mut a = BlockAllocator::new(3);
        assert_eq!(a.available(), 3);
        let s0 = a.alloc().unwrap();
        let s1 = a.alloc().unwrap();
        let s2 = a.alloc().unwrap();
        assert_ne!(s0, s1);
        assert_ne!(s1, s2);
        assert_eq!(a.alloc(), None);
        a.release(s1);
        assert_eq!(a.alloc(), Some(s1));
    }

    #[test]
    fn alloc_is_lowest_first_and_history_invariant() {
        let mut a = BlockAllocator::new(4);
        assert_eq!(a.alloc(), Some(0));
        assert_eq!(a.alloc(), Some(1));
        assert_eq!(a.alloc(), Some(2));
        assert_eq!(a.alloc(), Some(3));
        // release in scrambled order: next alloc is still the lowest id
        a.release(2);
        a.release(0);
        a.release(3);
        assert_eq!(a.free_list(), vec![0, 2, 3]);
        assert_eq!(a.alloc(), Some(0));
        assert_eq!(a.alloc(), Some(2));
        assert_eq!(a.alloc(), Some(3));
    }

    #[test]
    fn claim_specific_units() {
        let mut a = BlockAllocator::new(4);
        assert_eq!(a.free_list(), vec![0, 1, 2, 3]);
        assert!(a.claim(2));
        assert!(!a.claim(2), "double claim must fail");
        assert!(!a.claim(9), "out of range must fail");
        assert_eq!(a.free_list(), vec![0, 1, 3]);
        assert!(a.is_in_use(2));
        // alloc never hands out a claimed unit
        let mut handed = Vec::new();
        while let Some(s) = a.alloc() {
            handed.push(s);
        }
        handed.sort_unstable();
        assert_eq!(handed, vec![0, 1, 3]);
        a.release(2);
        assert_eq!(a.free_list(), vec![2]);
    }

    #[test]
    fn retain_release_refcounts() {
        let mut a = BlockAllocator::new(2);
        let b = a.alloc().unwrap();
        assert_eq!(a.ref_count(b), 1);
        a.retain(b);
        a.retain(b);
        assert_eq!(a.ref_count(b), 3);
        a.release(b);
        a.release(b);
        // still referenced: not free yet
        assert!(a.is_in_use(b));
        assert!(!a.free_list().contains(&b));
        a.release(b);
        assert!(!a.is_in_use(b));
        assert!(a.free_list().contains(&b));
    }

    #[test]
    #[should_panic(expected = "retain of free unit")]
    fn retain_free_unit_panics() {
        let mut a = BlockAllocator::new(2);
        a.retain(0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(2);
        let s = a.alloc().unwrap();
        a.release(s);
        a.release(s);
    }

    #[test]
    fn layout_arithmetic() {
        let l = KvLayout { num_blocks: 8, block_size: 4 };
        assert_eq!(l.capacity_tokens(), 32);
        assert_eq!(l.blocks_for(0), 0);
        assert_eq!(l.blocks_for(1), 1);
        assert_eq!(l.blocks_for(4), 1);
        assert_eq!(l.blocks_for(5), 2);
        // resume always reserves headroom for the next write
        assert_eq!(l.blocks_to_resume(3), 1);
        assert_eq!(l.blocks_to_resume(4), 2);
        let d = KvLayout::degenerate(2, 64);
        assert_eq!(d.num_blocks, 2);
        assert_eq!(d.block_size, 64);
    }

    #[test]
    fn block_table_maps_positions() {
        let mut t = BlockTable::new(4);
        assert_eq!(t.capacity(), 0);
        t.push_block(7);
        t.push_block(2);
        assert_eq!(t.capacity(), 8);
        assert_eq!(t.physical(0), 28);
        assert_eq!(t.physical(3), 31);
        assert_eq!(t.physical(4), 8);
        assert_eq!(t.physical(6), 10);
        let runs: Vec<_> = t.runs(6).collect();
        assert_eq!(runs, vec![(0, 28, 4), (4, 8, 2)]);
        let runs: Vec<_> = t.runs(4).collect();
        assert_eq!(runs, vec![(0, 28, 4)]);
        let old = t.replace_block(0, 5);
        assert_eq!(old, 7);
        assert_eq!(t.physical(0), 20);
        assert_eq!(t.physical(4), 8, "COW swap leaves other blocks alone");
        let freed = t.clear();
        assert_eq!(freed, vec![5, 2]);
        assert_eq!(t.capacity(), 0);
    }

    #[test]
    fn block_table_truncate_pops_tail_only() {
        let mut t = BlockTable::new(4);
        for b in [9, 3, 6] {
            t.push_block(b);
        }
        assert_eq!(t.truncate(3), Vec::<usize>::new());
        assert_eq!(t.truncate(4), Vec::<usize>::new(), "over-long keep is a no-op");
        assert_eq!(t.truncate(1), vec![3, 6]);
        assert_eq!(t.blocks(), &[9]);
        assert_eq!(t.physical(2), 38, "kept cells keep their mapping");
        assert_eq!(t.truncate(0), vec![9]);
        assert_eq!(t.capacity(), 0);
    }

    #[test]
    #[should_panic(expected = "beyond block table")]
    fn physical_out_of_range_panics() {
        let mut t = BlockTable::new(4);
        t.push_block(0);
        let _ = t.physical(4);
    }

    /// Property: under random alloc/claim/release traffic the allocator
    /// never hands out a unit that is already in use, available+used is
    /// conserved, and the free snapshot stays sorted and consistent.
    #[test]
    fn prop_allocator_soundness() {
        property("block allocator soundness", 200, |rng: &mut Rng| {
            let n = 1 + rng.usize_below(8);
            let mut a = BlockAllocator::new(n);
            let mut held: Vec<usize> = Vec::new();
            for _ in 0..100 {
                match rng.below(3) {
                    0 => {
                        if let Some(s) = a.alloc() {
                            prop_assert!(
                                !held.contains(&s),
                                "unit {s} double-allocated (held: {held:?})"
                            );
                            held.push(s);
                        } else {
                            prop_assert!(
                                held.len() == n,
                                "alloc failed with {} held of {n}",
                                held.len()
                            );
                        }
                    }
                    1 => {
                        // claim a random unit; must succeed iff free
                        let u = rng.usize_below(n);
                        let was_free = !held.contains(&u);
                        prop_assert!(a.claim(u) == was_free);
                        if was_free {
                            held.push(u);
                        }
                    }
                    _ => {
                        if !held.is_empty() {
                            let i = rng.usize_below(held.len());
                            let s = held.swap_remove(i);
                            a.release(s);
                        }
                    }
                }
                prop_assert!(a.available() + a.used() == n);
                prop_assert!(a.used() == held.len());
                let free = a.free_list();
                prop_assert!(free.windows(2).all(|w| w[0] < w[1]), "not ascending: {free:?}");
                prop_assert!(free.iter().all(|u| !held.contains(u)));
            }
            Ok(())
        });
    }

    /// Property: with random retain/release interleavings, a unit frees
    /// exactly when its model refcount hits zero, the allocator mirrors
    /// the model count, and allocation order depends only on the free
    /// set (history invariance).
    #[test]
    fn prop_refcount_conservation() {
        property("refcount conservation", 200, |rng: &mut Rng| {
            let n = 1 + rng.usize_below(6);
            let mut a = BlockAllocator::new(n);
            let mut model: Vec<u32> = vec![0; n];
            for _ in 0..120 {
                match rng.below(4) {
                    0 => {
                        if let Some(u) = a.alloc() {
                            prop_assert!(model[u] == 0, "alloc of referenced unit {u}");
                            model[u] = 1;
                        } else {
                            prop_assert!(model.iter().all(|&r| r > 0));
                        }
                    }
                    1 => {
                        let u = rng.usize_below(n);
                        if model[u] > 0 {
                            a.retain(u);
                            model[u] += 1;
                        }
                    }
                    _ => {
                        let live: Vec<usize> =
                            (0..n).filter(|&u| model[u] > 0).collect();
                        if let Some(&u) = live.get(rng.usize_below(live.len().max(1))) {
                            a.release(u);
                            model[u] -= 1;
                        }
                    }
                }
                for u in 0..n {
                    prop_assert!(a.ref_count(u) == model[u], "refcount mismatch on {u}");
                    prop_assert!(a.is_in_use(u) == (model[u] > 0));
                }
                let free = a.free_list();
                let expect: Vec<usize> = (0..n).filter(|&u| model[u] == 0).collect();
                prop_assert!(free == expect, "free list {free:?} != {expect:?}");
            }
            Ok(())
        });
    }

    /// Property: a block table filled through random alloc/grow traffic
    /// maps every logical position into the cell range of exactly the
    /// block that holds it, with no two logical positions sharing a cell
    /// (fragmented physical order included).
    #[test]
    fn prop_table_mapping_injective() {
        property("block table mapping injective", 100, |rng: &mut Rng| {
            let bs = 1 + rng.usize_below(6);
            let n_blocks = 2 + rng.usize_below(10);
            let mut alloc = BlockAllocator::new(n_blocks);
            let mut t = BlockTable::new(bs);
            let len = rng.usize_below(n_blocks * bs);
            let needed = len.div_ceil(bs);
            // Fragment the physical order: hold some blocks aside while
            // the table grows, so its ids are neither contiguous nor
            // ascending.
            let mut held: Vec<usize> = Vec::new();
            while t.blocks().len() < needed {
                let left = needed - t.blocks().len();
                if rng.bool(0.4) && alloc.available() > left {
                    held.push(alloc.alloc().expect("headroom checked"));
                }
                t.push_block(alloc.alloc().expect("pool sized for len"));
                if rng.bool(0.5) {
                    if let Some(b) = held.pop() {
                        alloc.release(b);
                    }
                }
            }
            let mut seen = std::collections::HashSet::new();
            for pos in 0..len {
                let cell = t.physical(pos);
                let blk = t.blocks()[pos / bs];
                prop_assert!(cell >= blk * bs && cell < (blk + 1) * bs);
                prop_assert!(seen.insert(cell), "cell {cell} reused");
            }
            // runs cover 0..len exactly once, in logical order
            let mut covered = 0usize;
            for (t0, p0, rl) in t.runs(len) {
                prop_assert!(t0 == covered, "runs out of order");
                for k in 0..rl {
                    prop_assert!(t.physical(t0 + k) == p0 + k);
                }
                covered += rl;
            }
            prop_assert!(covered == len);
            Ok(())
        });
    }

    fn fill_blocks(alloc: &mut BlockAllocator, k: usize) -> Vec<usize> {
        (0..k).map(|_| alloc.alloc().expect("pool sized")).collect()
    }

    #[test]
    fn radix_insert_then_match_full_blocks() {
        let mut alloc = BlockAllocator::new(16);
        let mut cache = RadixCache::new(4);
        let prompt: Vec<i32> = (0..10).collect(); // 2 full blocks + tail
        let blocks = fill_blocks(&mut alloc, 3);
        let created = cache.insert(&mut alloc, &prompt, &blocks);
        assert_eq!(created, 2, "only full blocks are indexed");
        assert_eq!(cache.len(), 2);
        assert_eq!(alloc.ref_count(blocks[0]), 2);
        assert_eq!(alloc.ref_count(blocks[1]), 2);
        assert_eq!(alloc.ref_count(blocks[2]), 1, "partial tail not cached");
        // identical prompt: full-block hit clamped below prompt len
        let m = cache.match_and_pin(&mut alloc, &prompt);
        assert_eq!(m.hit_tokens, 8);
        assert_eq!(m.blocks, vec![blocks[0], blocks[1]]);
        assert!(!m.cow);
        assert_eq!(alloc.ref_count(blocks[0]), 3, "match retains for caller");
        // reinsertion is idempotent
        assert_eq!(cache.insert(&mut alloc, &prompt, &blocks), 0);
        assert_eq!(alloc.ref_count(blocks[0]), 3);
    }

    #[test]
    fn radix_partial_hit_sets_cow() {
        let mut alloc = BlockAllocator::new(16);
        let mut cache = RadixCache::new(4);
        let cached: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let blocks = fill_blocks(&mut alloc, 2);
        cache.insert(&mut alloc, &cached, &blocks);
        // diverges inside the second block: LCP = 2 tokens into it
        let query: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 9, 9, 9];
        let m = cache.match_and_pin(&mut alloc, &query);
        assert_eq!(m.hit_tokens, 6);
        assert_eq!(m.blocks, vec![blocks[0], blocks[1]]);
        assert!(m.cow, "partial block hit must flag copy-on-write");
        // clamp: a fully-cached prompt still leaves one token to prefill
        let m2 = cache.match_and_pin(&mut alloc, &cached);
        assert_eq!(m2.hit_tokens, 7);
        assert!(m2.cow);
    }

    #[test]
    fn radix_eviction_is_leaf_lru_and_pins_shared_trunks() {
        let mut alloc = BlockAllocator::new(16);
        let mut cache = RadixCache::new(2);
        // two chains sharing a trunk: [0,1]->[2,3] and [0,1]->[8,9]
        let a: Vec<i32> = vec![0, 1, 2, 3, 7];
        let b: Vec<i32> = vec![0, 1, 8, 9, 7];
        let ba = fill_blocks(&mut alloc, 2);
        let bb = fill_blocks(&mut alloc, 2);
        cache.insert(&mut alloc, &a, &ba);
        cache.insert(&mut alloc, &b, &[bb[0], bb[1]]);
        // trunk deduped: b's first block was not indexed
        assert_eq!(cache.len(), 3);
        assert_eq!(alloc.ref_count(ba[0]), 2);
        assert_eq!(alloc.ref_count(bb[0]), 1);
        // drop the requests' own references: cache holds the rest
        for &blk in ba.iter().chain(&bb) {
            alloc.release(blk);
        }
        assert_eq!(cache.evictable_blocks(&alloc), 3);
        // pin branch a's leaf (a live request maps it): trunk + that leaf
        // are now both unevictable, branch b's leaf is not
        alloc.retain(ba[1]);
        assert_eq!(cache.evictable_blocks(&alloc), 1);
        assert_eq!(cache.evict_one(&mut alloc), Some(bb[1]));
        assert_eq!(cache.evict_one(&mut alloc), None, "trunk pinned by leaf");
        // unpin: leaf goes first (LRU), then the trunk cascades
        alloc.release(ba[1]);
        assert_eq!(cache.evictable_blocks(&alloc), 2);
        assert_eq!(cache.evict_one(&mut alloc), Some(ba[1]));
        assert_eq!(cache.evict_one(&mut alloc), Some(ba[0]));
        assert_eq!(cache.evict_one(&mut alloc), None);
        assert!(cache.is_empty());
        assert_eq!(alloc.used(), 0, "all cache references returned");
    }

    #[test]
    fn prune_unwedges_trunks_pinned_by_shared_leaves() {
        let mut alloc = BlockAllocator::new(8);
        let mut cache = RadixCache::new(2);
        // A live table holds the leaf [2,3] (rc 2); the rc-1 trunk [0,1]
        // above it is dead weight `evict_one` refuses (its subtree is
        // not all-free) — the wedge shape the engine's last-resort prune
        // breaker exists for.
        let blks = fill_blocks(&mut alloc, 2);
        cache.insert(&mut alloc, &[0, 1, 2, 3], &blks);
        alloc.release(blks[0]); // the table keeps only the leaf block
        assert_eq!(alloc.ref_count(blks[0]), 1); // cache alone
        assert_eq!(alloc.ref_count(blks[1]), 2); // cache + table
        assert_eq!(cache.evictable_blocks(&alloc), 0);
        assert_eq!(cache.evict_one(&mut alloc), None, "wedged");
        let avail = alloc.available();
        // Prune drops the shared leaf's cache ref — the block stays
        // live with the table, nothing is freed yet...
        assert_eq!(cache.prune_one(&mut alloc), Some(blks[1]));
        assert_eq!(alloc.ref_count(blks[1]), 1);
        assert_eq!(alloc.available(), avail);
        // ...but the trunk is now a childless rc-1 leaf: the next prune
        // actually frees its block.
        assert_eq!(cache.prune_one(&mut alloc), Some(blks[0]));
        assert_eq!(alloc.available(), avail + 1);
        assert!(cache.is_empty());
        assert_eq!(cache.prune_one(&mut alloc), None);
        alloc.release(blks[1]);
        assert_eq!(alloc.used(), 0, "all references returned");
    }

    /// Property: random insert/match/evict traffic conserves references
    /// — every cached block holds exactly one cache reference, matches
    /// retain exactly their block list, and draining the cache returns
    /// the allocator to a zero-reference state (no leaks, no double
    /// free).
    #[test]
    fn prop_radix_refcount_conservation() {
        property("radix refcount conservation", 120, |rng: &mut Rng| {
            let bs = 1 + rng.usize_below(3);
            let pool = 24;
            let mut alloc = BlockAllocator::new(pool);
            let mut cache = RadixCache::new(bs);
            // owned[i] = blocks a fake request still references
            let mut owned: Vec<Vec<usize>> = Vec::new();
            let mut pinned: Vec<Vec<usize>> = Vec::new();
            for _ in 0..60 {
                match rng.below(4) {
                    0 => {
                        // "prefill": alloc blocks for a short prompt, insert
                        let len = 1 + rng.usize_below(3 * bs + 1);
                        let need = len.div_ceil(bs);
                        if alloc.available() >= need {
                            let prompt: Vec<i32> =
                                (0..len).map(|_| rng.below(3) as i32).collect();
                            let blocks: Vec<usize> =
                                (0..need).map(|_| alloc.alloc().unwrap()).collect();
                            cache.insert(&mut alloc, &prompt, &blocks);
                            owned.push(blocks);
                        }
                    }
                    1 => {
                        // "match": pin a random prompt's cached prefix
                        let len = 1 + rng.usize_below(3 * bs + 1);
                        let prompt: Vec<i32> =
                            (0..len).map(|_| rng.below(3) as i32).collect();
                        let m = cache.match_and_pin(&mut alloc, &prompt);
                        prop_assert!(m.hit_tokens < prompt.len(), "hit must be clamped");
                        if !m.blocks.is_empty() {
                            pinned.push(m.blocks);
                        }
                    }
                    2 => {
                        // "finish": release a request's or a match's blocks
                        let from_owned = rng.bool(0.5);
                        let v = if from_owned { &mut owned } else { &mut pinned };
                        if !v.is_empty() {
                            let i = rng.usize_below(v.len());
                            for b in v.swap_remove(i) {
                                alloc.release(b);
                            }
                        }
                    }
                    _ => {
                        let before = alloc.available();
                        if let Some(blk) = cache.evict_one(&mut alloc) {
                            prop_assert!(alloc.available() == before + 1);
                            prop_assert!(!alloc.is_in_use(blk));
                        }
                    }
                }
                prop_assert!(alloc.available() + alloc.used() == pool);
            }
            // drain everything: refcounts must come back to zero exactly
            for v in owned.into_iter().chain(pinned) {
                for b in v {
                    alloc.release(b);
                }
            }
            while cache.evict_one(&mut alloc).is_some() {}
            prop_assert!(cache.is_empty(), "unevictable residue in cache");
            prop_assert!(alloc.used() == 0, "leaked references");
            Ok(())
        });
    }

    /// Property: a match result is always a true prefix of the query —
    /// the concatenated keys along the matched path equal the first
    /// `hit_tokens` tokens, and a full-block hit never exceeds the
    /// clamp.
    #[test]
    fn prop_radix_match_is_true_prefix() {
        property("radix match is true prefix", 120, |rng: &mut Rng| {
            let bs = 1 + rng.usize_below(4);
            let mut alloc = BlockAllocator::new(64);
            let mut cache = RadixCache::new(bs);
            // shared vocabulary of 2 symbols → heavy prefix collisions
            let mut inserted: Vec<(Vec<i32>, Vec<usize>)> = Vec::new();
            for _ in 0..8 {
                let len = 1 + rng.usize_below(4 * bs);
                let prompt: Vec<i32> = (0..len).map(|_| rng.below(2) as i32).collect();
                let need = len.div_ceil(bs);
                if alloc.available() < need {
                    continue;
                }
                let blocks: Vec<usize> = (0..need).map(|_| alloc.alloc().unwrap()).collect();
                cache.insert(&mut alloc, &prompt, &blocks);
                inserted.push((prompt, blocks));
            }
            for _ in 0..8 {
                let len = 1 + rng.usize_below(4 * bs);
                let query: Vec<i32> = (0..len).map(|_| rng.below(2) as i32).collect();
                let m = cache.match_and_pin(&mut alloc, &query);
                prop_assert!(m.hit_tokens <= len.saturating_sub(1));
                prop_assert!(m.blocks.len() == m.hit_tokens.div_ceil(bs));
                prop_assert!(m.cow == (m.hit_tokens % bs != 0));
                // the hit must be justified by some inserted prompt
                if m.hit_tokens > 0 {
                    let covered = &query[..m.hit_tokens];
                    prop_assert!(
                        inserted.iter().any(|(p, _)| {
                            p.len() >= covered.len() && p[..covered.len()] == *covered
                        }),
                        "hit {covered:?} matches no inserted prompt"
                    );
                }
                for b in m.blocks {
                    alloc.release(b);
                }
            }
            Ok(())
        });
    }
}
