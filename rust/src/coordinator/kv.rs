//! Paged KV cache accounting: fixed-size token blocks and per-request
//! block tables (the vLLM paged-attention generalization; the old
//! "one sequence = one block span" slot scheme is now just the
//! degenerate [`KvLayout::degenerate`] case with `block_size == max_seq`).
//!
//! * [`BlockAllocator`] — a free list over `n` interchangeable units.
//!   The engine runs two of them: one over the decode-batch rows
//!   ("slots") and one over the KV blocks. Its free-list order is
//!   deterministic (LIFO pop, ascending [`BlockAllocator::free_list`]
//!   snapshot), which is what makes [`super::scheduler::StepPlan`]
//!   execution replayable: the same plan sequence always binds the same
//!   physical blocks.
//! * [`BlockTable`] — one request's logical-position → physical-block
//!   mapping. Appending a token never moves data ("copy-free append"):
//!   growth only pushes a fresh block id; the K/V rows already written
//!   stay where they are.
//! * [`KvLayout`] — the backend's paged geometry (how many blocks of
//!   how many tokens), reported by
//!   [`super::model::StepModel::kv_layout`].
//!
//! Swap contents for preempted requests live in the model layer (see
//! [`super::model::KvSwap`]); this module only does the arithmetic.

/// Blocks needed to hold `tokens` cache entries at `block_size` tokens
/// per block. The single source of this arithmetic — the scheduler's
/// planning ledger and the engine's allocations must agree on it.
pub fn blocks_for(tokens: usize, block_size: usize) -> usize {
    tokens.div_ceil(block_size.max(1))
}

/// Blocks a resumed request needs: its `tokens` resident entries *plus
/// room for the next decode write*, so a resume can always make progress
/// before the next block-pressure event (no zero-progress preempt/resume
/// livelock). Planner and engine must use the same formula — hence one
/// function.
pub fn blocks_to_resume(tokens: usize, block_size: usize) -> usize {
    blocks_for(tokens + 1, block_size)
}

/// Paged-KV geometry of a step model: `num_blocks` physical blocks of
/// `block_size` tokens each, shared by every slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvLayout {
    pub num_blocks: usize,
    pub block_size: usize,
}

impl KvLayout {
    /// The fixed-slot degenerate case: one block per decode slot, each
    /// spanning the whole context. Backends without paged storage (mock,
    /// pjrt) report this and ignore block tables entirely.
    pub fn degenerate(batch: usize, max_seq: usize) -> KvLayout {
        KvLayout { num_blocks: batch, block_size: max_seq.max(1) }
    }

    /// Total token capacity of the pool.
    pub fn capacity_tokens(&self) -> usize {
        self.num_blocks * self.block_size
    }

    /// Blocks needed to hold `tokens` cache entries.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        blocks_for(tokens, self.block_size)
    }

    /// See [`blocks_to_resume`].
    pub fn blocks_to_resume(&self, tokens: usize) -> usize {
        blocks_to_resume(tokens, self.block_size)
    }
}

/// Free-list allocator over `n` interchangeable units (KV blocks, or
/// decode slots). Deterministic: `alloc` pops LIFO, [`Self::free_list`]
/// snapshots ascending, and [`Self::claim`] lets a plan bind a specific
/// unit it saw in that snapshot.
#[derive(Debug)]
pub struct BlockAllocator {
    n: usize,
    free: Vec<usize>,
    in_use: Vec<bool>,
}

impl BlockAllocator {
    pub fn new(n: usize) -> Self {
        BlockAllocator {
            n,
            free: (0..n).rev().collect(),
            in_use: vec![false; n],
        }
    }

    pub fn capacity(&self) -> usize {
        self.n
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn used(&self) -> usize {
        self.n - self.free.len()
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let unit = self.free.pop()?;
        debug_assert!(!self.in_use[unit], "allocator invariant violated");
        self.in_use[unit] = true;
        Some(unit)
    }

    /// Free units in ascending order — the scheduler plans against this
    /// deterministic snapshot.
    pub fn free_list(&self) -> Vec<usize> {
        let mut v = self.free.clone();
        v.sort_unstable();
        v
    }

    /// Claim the specific unit a [`super::scheduler::StepPlan`] assigned.
    /// Returns false if it is out of range or already in use (a scheduler
    /// bug the engine turns into an error).
    pub fn claim(&mut self, unit: usize) -> bool {
        if unit >= self.n || self.in_use[unit] {
            return false;
        }
        let idx = self
            .free
            .iter()
            .position(|&u| u == unit)
            .expect("free list inconsistent with in_use");
        self.free.swap_remove(idx);
        self.in_use[unit] = true;
        true
    }

    pub fn release(&mut self, unit: usize) {
        assert!(unit < self.n, "unit {unit} out of range");
        assert!(self.in_use[unit], "double free of unit {unit}");
        self.in_use[unit] = false;
        self.free.push(unit);
    }

    pub fn is_in_use(&self, unit: usize) -> bool {
        self.in_use[unit]
    }
}

/// One request's block table: logical token positions `0..capacity()`
/// map to cells of the physical blocks in order. Growth appends block
/// ids; existing entries never move.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BlockTable {
    block_size: usize,
    blocks: Vec<usize>,
}

impl BlockTable {
    pub fn new(block_size: usize) -> BlockTable {
        assert!(block_size >= 1, "block_size must be >= 1");
        BlockTable { block_size, blocks: Vec::new() }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn blocks(&self) -> &[usize] {
        &self.blocks
    }

    /// Token capacity of the blocks held so far.
    pub fn capacity(&self) -> usize {
        self.blocks.len() * self.block_size
    }

    pub fn push_block(&mut self, block: usize) {
        self.blocks.push(block);
    }

    /// Drop every block id (the caller releases them to the allocator).
    pub fn clear(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.blocks)
    }

    /// Physical cell index of logical position `pos` (in token units;
    /// multiply by the per-token stride for a flat buffer offset).
    pub fn physical(&self, pos: usize) -> usize {
        let (b, o) = (pos / self.block_size, pos % self.block_size);
        assert!(b < self.blocks.len(), "position {pos} beyond block table");
        self.blocks[b] * self.block_size + o
    }

    /// Iterate `(logical_start, physical_start, len)` runs covering
    /// logical positions `0..len` — each run is contiguous in the backing
    /// store, so gathers walk block-sized spans instead of per-token
    /// indirection.
    pub fn runs(&self, len: usize) -> impl Iterator<Item = (usize, usize, usize)> + '_ {
        let bs = self.block_size;
        self.blocks
            .iter()
            .enumerate()
            .take_while(move |(i, _)| i * bs < len)
            .map(move |(i, &blk)| {
                let t0 = i * bs;
                (t0, blk * bs, bs.min(len - t0))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::property;
    use crate::util::rng::Rng;

    #[test]
    fn alloc_release_cycle() {
        let mut a = BlockAllocator::new(3);
        assert_eq!(a.available(), 3);
        let s0 = a.alloc().unwrap();
        let s1 = a.alloc().unwrap();
        let s2 = a.alloc().unwrap();
        assert_ne!(s0, s1);
        assert_ne!(s1, s2);
        assert_eq!(a.alloc(), None);
        a.release(s1);
        assert_eq!(a.alloc(), Some(s1));
    }

    #[test]
    fn claim_specific_units() {
        let mut a = BlockAllocator::new(4);
        assert_eq!(a.free_list(), vec![0, 1, 2, 3]);
        assert!(a.claim(2));
        assert!(!a.claim(2), "double claim must fail");
        assert!(!a.claim(9), "out of range must fail");
        assert_eq!(a.free_list(), vec![0, 1, 3]);
        assert!(a.is_in_use(2));
        // alloc never hands out a claimed unit
        let mut handed = Vec::new();
        while let Some(s) = a.alloc() {
            handed.push(s);
        }
        handed.sort_unstable();
        assert_eq!(handed, vec![0, 1, 3]);
        a.release(2);
        assert_eq!(a.free_list(), vec![2]);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(2);
        let s = a.alloc().unwrap();
        a.release(s);
        a.release(s);
    }

    #[test]
    fn layout_arithmetic() {
        let l = KvLayout { num_blocks: 8, block_size: 4 };
        assert_eq!(l.capacity_tokens(), 32);
        assert_eq!(l.blocks_for(0), 0);
        assert_eq!(l.blocks_for(1), 1);
        assert_eq!(l.blocks_for(4), 1);
        assert_eq!(l.blocks_for(5), 2);
        // resume always reserves headroom for the next write
        assert_eq!(l.blocks_to_resume(3), 1);
        assert_eq!(l.blocks_to_resume(4), 2);
        let d = KvLayout::degenerate(2, 64);
        assert_eq!(d.num_blocks, 2);
        assert_eq!(d.block_size, 64);
    }

    #[test]
    fn block_table_maps_positions() {
        let mut t = BlockTable::new(4);
        assert_eq!(t.capacity(), 0);
        t.push_block(7);
        t.push_block(2);
        assert_eq!(t.capacity(), 8);
        assert_eq!(t.physical(0), 28);
        assert_eq!(t.physical(3), 31);
        assert_eq!(t.physical(4), 8);
        assert_eq!(t.physical(6), 10);
        let runs: Vec<_> = t.runs(6).collect();
        assert_eq!(runs, vec![(0, 28, 4), (4, 8, 2)]);
        let runs: Vec<_> = t.runs(4).collect();
        assert_eq!(runs, vec![(0, 28, 4)]);
        let freed = t.clear();
        assert_eq!(freed, vec![7, 2]);
        assert_eq!(t.capacity(), 0);
    }

    #[test]
    #[should_panic(expected = "beyond block table")]
    fn physical_out_of_range_panics() {
        let mut t = BlockTable::new(4);
        t.push_block(0);
        let _ = t.physical(4);
    }

    /// Property: under random alloc/claim/release traffic the allocator
    /// never hands out a unit that is already in use, available+used is
    /// conserved, and the free snapshot stays sorted and consistent.
    #[test]
    fn prop_allocator_soundness() {
        property("block allocator soundness", 200, |rng: &mut Rng| {
            let n = 1 + rng.usize_below(8);
            let mut a = BlockAllocator::new(n);
            let mut held: Vec<usize> = Vec::new();
            for _ in 0..100 {
                match rng.below(3) {
                    0 => {
                        if let Some(s) = a.alloc() {
                            prop_assert!(
                                !held.contains(&s),
                                "unit {s} double-allocated (held: {held:?})"
                            );
                            held.push(s);
                        } else {
                            prop_assert!(
                                held.len() == n,
                                "alloc failed with {} held of {n}",
                                held.len()
                            );
                        }
                    }
                    1 => {
                        // claim a random unit; must succeed iff free
                        let u = rng.usize_below(n);
                        let was_free = !held.contains(&u);
                        prop_assert!(a.claim(u) == was_free);
                        if was_free {
                            held.push(u);
                        }
                    }
                    _ => {
                        if !held.is_empty() {
                            let i = rng.usize_below(held.len());
                            let s = held.swap_remove(i);
                            a.release(s);
                        }
                    }
                }
                prop_assert!(a.available() + a.used() == n);
                prop_assert!(a.used() == held.len());
                let free = a.free_list();
                prop_assert!(free.windows(2).all(|w| w[0] < w[1]), "not ascending: {free:?}");
                prop_assert!(free.iter().all(|u| !held.contains(u)));
            }
            Ok(())
        });
    }

    /// Property: a block table filled through random alloc/grow traffic
    /// maps every logical position into the cell range of exactly the
    /// block that holds it, with no two logical positions sharing a cell
    /// (fragmented physical order included).
    #[test]
    fn prop_table_mapping_injective() {
        property("block table mapping injective", 100, |rng: &mut Rng| {
            let bs = 1 + rng.usize_below(6);
            let n_blocks = 2 + rng.usize_below(10);
            let mut alloc = BlockAllocator::new(n_blocks);
            let mut t = BlockTable::new(bs);
            let len = rng.usize_below(n_blocks * bs);
            let needed = len.div_ceil(bs);
            // Fragment the physical order: hold some blocks aside while
            // the table grows, so its ids are neither contiguous nor
            // ascending (LIFO would otherwise hand them out in order).
            let mut held: Vec<usize> = Vec::new();
            while t.blocks().len() < needed {
                let left = needed - t.blocks().len();
                if rng.bool(0.4) && alloc.available() > left {
                    held.push(alloc.alloc().expect("headroom checked"));
                }
                t.push_block(alloc.alloc().expect("pool sized for len"));
                if rng.bool(0.5) {
                    if let Some(b) = held.pop() {
                        alloc.release(b);
                    }
                }
            }
            let mut seen = std::collections::HashSet::new();
            for pos in 0..len {
                let cell = t.physical(pos);
                let blk = t.blocks()[pos / bs];
                prop_assert!(cell >= blk * bs && cell < (blk + 1) * bs);
                prop_assert!(seen.insert(cell), "cell {cell} reused");
            }
            // runs cover 0..len exactly once, in logical order
            let mut covered = 0usize;
            for (t0, p0, rl) in t.runs(len) {
                prop_assert!(t0 == covered, "runs out of order");
                for k in 0..rl {
                    prop_assert!(t.physical(t0 + k) == p0 + k);
                }
                covered += rl;
            }
            prop_assert!(covered == len);
            Ok(())
        });
    }
}
