//! KV slot allocator.
//!
//! The decode executable runs at a fixed batch `B`; the KV cache is one
//! device buffer `[L, 2, B, H, S, Dh]`. Each in-flight request owns one
//! batch slot from prefill start to finish. (The paged-attention
//! generalization would subdivide S; with a fixed S per slot this is the
//! vLLM "one sequence = one block span" degenerate case, which is what
//! our exported executables support.)

#[derive(Debug)]
pub struct SlotAllocator {
    n: usize,
    free: Vec<usize>,
    in_use: Vec<bool>,
}

impl SlotAllocator {
    pub fn new(n: usize) -> Self {
        SlotAllocator {
            n,
            free: (0..n).rev().collect(),
            in_use: vec![false; n],
        }
    }

    pub fn capacity(&self) -> usize {
        self.n
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn used(&self) -> usize {
        self.n - self.free.len()
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(!self.in_use[slot], "allocator invariant violated");
        self.in_use[slot] = true;
        Some(slot)
    }

    /// Free slots in ascending order — the scheduler plans admissions
    /// against this deterministic snapshot.
    pub fn free_slots(&self) -> Vec<usize> {
        let mut v = self.free.clone();
        v.sort_unstable();
        v
    }

    /// Claim the specific slot a [`crate::coordinator::scheduler::StepPlan`]
    /// assigned. Returns false if the slot is out of range or already in
    /// use (a scheduler bug the engine turns into an error).
    pub fn claim(&mut self, slot: usize) -> bool {
        if slot >= self.n || self.in_use[slot] {
            return false;
        }
        let idx = self
            .free
            .iter()
            .position(|&s| s == slot)
            .expect("free list inconsistent with in_use");
        self.free.swap_remove(idx);
        self.in_use[slot] = true;
        true
    }

    pub fn release(&mut self, slot: usize) {
        assert!(slot < self.n, "slot {slot} out of range");
        assert!(self.in_use[slot], "double free of slot {slot}");
        self.in_use[slot] = false;
        self.free.push(slot);
    }

    pub fn is_in_use(&self, slot: usize) -> bool {
        self.in_use[slot]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::property;
    use crate::util::rng::Rng;

    #[test]
    fn alloc_release_cycle() {
        let mut a = SlotAllocator::new(3);
        assert_eq!(a.available(), 3);
        let s0 = a.alloc().unwrap();
        let s1 = a.alloc().unwrap();
        let s2 = a.alloc().unwrap();
        assert_ne!(s0, s1);
        assert_ne!(s1, s2);
        assert_eq!(a.alloc(), None);
        a.release(s1);
        assert_eq!(a.alloc(), Some(s1));
    }

    #[test]
    fn claim_specific_slots() {
        let mut a = SlotAllocator::new(4);
        assert_eq!(a.free_slots(), vec![0, 1, 2, 3]);
        assert!(a.claim(2));
        assert!(!a.claim(2), "double claim must fail");
        assert!(!a.claim(9), "out of range must fail");
        assert_eq!(a.free_slots(), vec![0, 1, 3]);
        assert!(a.is_in_use(2));
        // alloc never hands out a claimed slot
        let mut handed = Vec::new();
        while let Some(s) = a.alloc() {
            handed.push(s);
        }
        handed.sort_unstable();
        assert_eq!(handed, vec![0, 1, 3]);
        a.release(2);
        assert_eq!(a.free_slots(), vec![2]);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = SlotAllocator::new(2);
        let s = a.alloc().unwrap();
        a.release(s);
        a.release(s);
    }

    /// Property: under random alloc/release traffic the allocator never
    /// hands out a slot that is already in use, and available+used == n.
    #[test]
    fn prop_no_double_allocation() {
        property("slot allocator soundness", 200, |rng: &mut Rng| {
            let n = 1 + rng.usize_below(8);
            let mut a = SlotAllocator::new(n);
            let mut held: Vec<usize> = Vec::new();
            for _ in 0..100 {
                if rng.bool(0.5) {
                    if let Some(s) = a.alloc() {
                        prop_assert!(
                            !held.contains(&s),
                            "slot {s} double-allocated (held: {held:?})"
                        );
                        held.push(s);
                    } else {
                        prop_assert!(held.len() == n,
                                     "alloc failed with {} held of {n}", held.len());
                    }
                } else if !held.is_empty() {
                    let i = rng.usize_below(held.len());
                    let s = held.swap_remove(i);
                    a.release(s);
                }
                prop_assert!(a.available() + a.used() == n);
                prop_assert!(a.used() == held.len());
            }
            Ok(())
        });
    }
}
