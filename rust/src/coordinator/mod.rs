//! L3: the serving coordinator (the paper integrates TARDIS into vLLM and
//! HuggingFace; this is our from-scratch equivalent).
//!
//! Components:
//! * [`model`]       — the step-model abstraction (native / PJRT / mock),
//!   including the paged-KV hooks (`kv_layout`/`kv_map`/`kv_save`/
//!   `kv_restore`)
//! * [`request`]     — request lifecycle + sampling params
//! * [`queue`]       — bounded admission queue with backpressure
//! * [`kv`]          — paged KV accounting: [`kv::BlockAllocator`],
//!   per-request [`kv::BlockTable`]s, [`kv::KvLayout`]
//! * [`batcher`]     — continuous batching of decode steps
//! * [`scheduler`]   — per-iteration [`scheduler::StepPlan`] assembly:
//!   a pluggable [`scheduler::SchedulerPolicy`] ranks admissions; the
//!   policy-independent driver co-schedules prefill chunks with the
//!   decode batch under a token budget, and preempts/resumes decodes
//!   under KV block pressure
//! * [`sampler`]     — greedy / temperature / top-k token sampling
//! * [`engine_loop`] — executes the plans: multi-prefill [`engine_loop::PrefillSet`],
//!   block-table growth, swap pool, decode batching, accounting
//! * [`router`]      — routes requests across variants/replicas

pub mod batcher;
pub mod engine_loop;
pub mod kv;
pub mod model;
pub mod queue;
pub mod request;
pub mod router;
pub mod sampler;
pub mod scheduler;

pub use engine_loop::{EngineConfig, EngineSnapshot, EngineStats, InferenceEngine};
pub use kv::{BlockAllocator, BlockTable, KvLayout, PrefixMatch, RadixCache};
pub use model::{KvSwap, MockModel, StepModel};
#[cfg(feature = "pjrt")]
pub use model::PjrtModel;
pub use request::{FinishReason, Request, RequestId, SamplingParams};
pub use scheduler::{PolicyKind, SchedulerConfig, SchedulerPolicy, StepOutcome, StepPlan};
