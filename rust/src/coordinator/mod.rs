//! L3: the serving coordinator (the paper integrates TARDIS into vLLM and
//! HuggingFace; this is our from-scratch equivalent).
//!
//! Components:
//! * [`model`]       — the step-model abstraction (PJRT-backed or mock)
//! * [`request`]     — request lifecycle + sampling params
//! * [`queue`]       — bounded admission queue with backpressure
//! * [`kv`]          — KV slot allocator over the fixed decode batch
//! * [`batcher`]     — continuous batching of decode steps
//! * [`scheduler`]   — iteration-level prefill/decode interleaving
//! * [`sampler`]     — greedy / temperature / top-k token sampling
//! * [`engine_loop`] — ties the above into a serving engine
//! * [`router`]      — routes requests across variants/replicas

pub mod batcher;
pub mod engine_loop;
pub mod kv;
pub mod model;
pub mod queue;
pub mod request;
pub mod router;
pub mod sampler;
pub mod scheduler;

pub use engine_loop::{EngineConfig, EngineStats, InferenceEngine};
pub use model::{MockModel, PjrtModel, StepModel};
pub use request::{FinishReason, Request, RequestId, SamplingParams};
