//! L3: the serving coordinator (the paper integrates TARDIS into vLLM and
//! HuggingFace; this is our from-scratch equivalent).
//!
//! Components:
//! * [`model`]       — the step-model abstraction (native / PJRT / mock),
//!   including the paged-KV hooks (`kv_layout`/`kv_map`/`kv_save`/
//!   `kv_restore`)
//! * [`request`]     — request lifecycle + sampling params
//! * [`queue`]       — bounded admission queue with backpressure
//! * [`kv`]          — paged KV accounting: [`kv::BlockAllocator`],
//!   per-request [`kv::BlockTable`]s, [`kv::KvLayout`]
//! * [`batcher`]     — continuous batching of decode steps
//! * [`scheduler`]   — per-iteration [`scheduler::StepPlan`] assembly:
//!   a pluggable [`scheduler::SchedulerPolicy`] ranks admissions; the
//!   policy-independent driver co-schedules prefill chunks with the
//!   decode batch under a token budget, and preempts/resumes decodes
//!   under KV block pressure
//! * [`sampler`]     — greedy / temperature / top-k token sampling
//! * [`engine_loop`] — executes the plans: multi-prefill [`engine_loop::PrefillSet`],
//!   block-table growth, swap pool, decode batching, accounting
//! * [`router`]      — routes requests across variants/replicas: the
//!   synchronous [`router::Router`] (single-thread, for non-Send
//!   backends) and the fault-tolerant [`router::FrontDoor`] (one worker
//!   thread per replica, `catch_unwind` failure isolation, journal
//!   replay, backpressure shedding)
//! * [`journal`]     — append-only JSONL admission journal + recovery
//! * [`health`]      — replica health state machine (Healthy→Degraded→
//!   Quarantined, backoff-paced restart probes) and the deterministic
//!   [`health::FaultPlan`] chaos harness

pub mod batcher;
pub mod engine_loop;
pub mod health;
pub mod journal;
pub mod kv;
pub mod model;
pub mod queue;
pub mod request;
pub mod router;
pub mod sampler;
pub mod scheduler;

pub use engine_loop::{
    EngineConfig, EngineSnapshot, EngineStats, InferenceEngine, StepFault, SubmitError,
};
pub use health::{Fault, FaultPlan, HealthState, HealthTracker};
pub use journal::{Journal, JournalEntry};
pub use kv::{BlockAllocator, BlockTable, KvLayout, PrefixMatch, RadixCache};
pub use model::{KvSwap, MockModel, StepModel};
#[cfg(feature = "pjrt")]
pub use model::PjrtModel;
pub use request::{FinishReason, Request, RequestId, SamplingParams};
pub use router::{
    FrontDoor, FrontDoorConfig, FrontDoorStats, FrontEnd, FrontReply, FrontSnapshot,
    ReplicaFactory, ReplicaView, Router, SubmitOutcome,
};
pub use scheduler::{PolicyKind, SchedulerConfig, SchedulerPolicy, StepOutcome, StepPlan};
