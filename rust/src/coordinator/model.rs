//! The step-model abstraction the coordinator schedules against, and the
//! backend matrix behind it.
//!
//! * `MockModel`   — deterministic pure-rust stand-in so every
//!   coordinator test and bench runs without artifacts.
//! * `NativeModel` — a real tiny GELU transformer (the costmodel's
//!   `TINY_GELU` shape) executed std-only on the CPU, with either a
//!   dense FFN or the TARDIS partially-linear fold from [`crate::ffn`];
//!   the whole scheduler/policy machinery runs unchanged on top of it.
//!   Its host KV cache is **paged**: K/V rows live in fixed-size blocks
//!   and every cache access goes through the slot's [`BlockTable`], so
//!   the engine can hand out fragmented blocks, swap a preempted
//!   request's cache to the host pool, and restore it bitwise into
//!   *different* physical blocks.
//! * `PjrtModel`   — (behind the `pjrt` feature) wraps a loaded
//!   [`crate::runtime::Variant`] and owns the device-resident KV cache.
//!   Its exported executables address KV by slot, i.e. the degenerate
//!   one-block-per-slot [`KvLayout`]; it ignores block tables and does
//!   not support preemption.

use anyhow::Result;

use super::kv::{BlockTable, KvLayout};
use super::scheduler::{StepOutcome, StepPlan};

use crate::config::{FfnMode, NativeModelConfig, TardisFfnConfig};
use crate::ffn::kernels::{dot, layernorm_into, matmul, Epilogue, Scratch};
use crate::ffn::{
    folded_units_for, DenseFfn, FfnBackend, FfnTelemetry, FoldedFfn, Linearization,
    RangeTable,
};
use crate::runtime::weights::NativeWeights;
use crate::util::threadpool::ThreadPool;

#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, Variant};

/// Host-side copy of one preempted request's KV cache, produced by
/// [`StepModel::kv_save`] and consumed bitwise by
/// [`StepModel::kv_restore`] — possibly into different physical blocks.
/// Opaque to the engine beyond the token count; the payload is
/// backend-private.
#[derive(Debug, Clone)]
pub struct KvSwap {
    /// Cache entries (logical token positions) saved.
    pub tokens: usize,
    payload: SwapPayload,
}

/// What a backend actually stashed; a restore into a different backend
/// kind is a hard error, not a silent no-op.
#[derive(Debug, Clone)]
enum SwapPayload {
    /// Native backend: per layer, the K rows then the V rows, each
    /// `[tokens * d_model]` in logical-position order.
    Layers(Vec<Vec<f32>>),
    /// Mock backend: the slot's (last token, position) state.
    MockState(Option<(i32, usize)>),
}

pub trait StepModel {
    /// Fixed decode batch (number of KV slots).
    fn batch(&self) -> usize;
    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Ascending prefill chunk sizes the model was exported with.
    fn prefill_buckets(&self) -> &[usize];

    /// Paged-KV geometry of this backend. The default is the degenerate
    /// one-block-per-slot layout (block tables carry no information and
    /// may be ignored); paged backends override it.
    fn kv_layout(&self) -> KvLayout {
        KvLayout::degenerate(self.batch(), self.max_seq())
    }

    /// Install `slot`'s block table (called by the engine whenever the
    /// table grows, clears, or is rebound on resume, before the next
    /// prefill/decode touching the slot). Backends with slot-addressed
    /// caches ignore it.
    fn kv_map(&mut self, _slot: usize, _table: &BlockTable) {}

    /// Whether [`Self::kv_save`]/[`Self::kv_restore`] work — i.e. the
    /// scheduler may preempt this backend's decodes under block pressure.
    fn supports_preemption(&self) -> bool {
        false
    }

    /// Copy `slot`'s first `tokens` cache entries (through its current
    /// block table) into a host swap buffer.
    fn kv_save(&mut self, _slot: usize, _tokens: usize) -> Result<KvSwap> {
        Err(anyhow::anyhow!("backend does not support KV save/restore"))
    }

    /// Write a saved cache back through `slot`'s *current* block table
    /// (installed via [`Self::kv_map`] first; the physical blocks may
    /// differ from the ones saved). Must be bitwise: a resumed request
    /// continues exactly the token stream it would have produced
    /// uninterrupted.
    fn kv_restore(&mut self, _slot: usize, _swap: &KvSwap) -> Result<()> {
        Err(anyhow::anyhow!("backend does not support KV save/restore"))
    }

    /// Whether one physical KV block may appear in several slots' block
    /// tables at once (prefix sharing) and [`Self::kv_copy_block`]
    /// works. Sharing requires truly paged storage: backends that
    /// address cache state by slot and ignore block tables may also
    /// return true (their state carries no per-block data to alias).
    fn supports_block_sharing(&self) -> bool {
        false
    }

    /// Copy the first `cells` token cells of physical block `src` into
    /// block `dst` — the copy-on-write divergence step the engine runs
    /// before appending into a partially-shared block. Backends with
    /// slot-addressed caches no-op.
    fn kv_copy_block(&mut self, _src: usize, _dst: usize, _cells: usize) -> Result<()> {
        Err(anyhow::anyhow!("backend does not support shared KV blocks"))
    }

    /// Mark `slot` for degraded service: every FFN row the slot
    /// contributes is forced through the folded path (predictor
    /// bypassed, no per-neuron fixes — effectively `--fix-k 0`). The
    /// engine sets it from [`SamplingParams::degrade`] at
    /// admission/resume and clears it at finish/preempt/abort, so a
    /// degraded request batched with full-quality neighbors degrades
    /// only its own rows. Backends without a partially-linear FFN no-op.
    ///
    /// [`SamplingParams::degrade`]: super::request::SamplingParams
    fn set_slot_degrade(&mut self, _slot: usize, _degraded: bool) {}

    /// Plan-level hook: called once per engine iteration with the
    /// [`StepPlan`] about to execute, before any prefill/decode dispatch.
    /// Backends can stage uploads for the whole iteration or record
    /// scheduling telemetry. Default: no-op.
    fn plan_begin(&mut self, _plan: &StepPlan) {}

    /// Plan-level hook: called after the plan's work has executed.
    fn plan_end(&mut self, _outcome: &StepOutcome) {}

    /// Prefill `tokens` (padded to `bucket`; the first `real_len` are
    /// real) into `slot` starting at absolute position `pos0`. Returns
    /// the logits of the last *real* token, `[vocab]`.
    fn prefill(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        real_len: usize,
        slot: usize,
        pos0: usize,
    ) -> Result<Vec<f32>>;

    /// One decode step over all slots. `tokens[b]`/`pos[b]` for inactive
    /// slots carry (0, max_seq) sentinels. Returns logits `[batch*vocab]`.
    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>>;

    /// Whether [`Self::decode_draft`] runs a genuinely cheaper path and
    /// [`Self::decode_multi`] works — the pair the engine's
    /// self-speculative decode loop needs. Default: no.
    fn supports_speculation(&self) -> bool {
        false
    }

    /// One *draft* decode step: identical contract to [`Self::decode`],
    /// but every FFN row is forced through the all-folded no-fallback
    /// path regardless of per-slot degrade marks — the zero-extra-weight
    /// draft model. KV rows it writes are approximations; the verify
    /// forward overwrites them with exact values. Default: the plain
    /// decode path (drafts then always agree, speculation degenerates to
    /// extra work but stays correct).
    fn decode_draft(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        self.decode(tokens, pos)
    }

    /// Multi-token verify: run `tokens[i]` in slot `slots[i]` at absolute
    /// position `pos[i]` — all rows in ONE batched forward — and return
    /// logits for every row, `[tokens.len()*vocab]` in input order. Rows
    /// of one slot must be listed at consecutive ascending positions;
    /// attention for row `i` sees the cache plus the same-forward rows
    /// before it, and every row's K/V cells are (re)written with exact
    /// values, overwriting whatever the draft pass left there. Backends
    /// without speculation support return Err.
    fn decode_multi(
        &mut self,
        _tokens: &[i32],
        _slots: &[usize],
        _pos: &[i32],
    ) -> Result<Vec<f32>> {
        Err(anyhow::anyhow!("backend does not support multi-token verify"))
    }

    /// Cumulative partially-linear FFN routing telemetry (how many batch
    /// rows ran the folded path vs the dense outlier fallback), if this
    /// backend runs a TARDIS fold. Default: none.
    fn ffn_telemetry(&self) -> Option<FfnTelemetry> {
        None
    }

    /// Smallest bucket that fits `n` tokens (or the largest bucket).
    fn bucket_for(&self, n: usize) -> usize {
        let buckets = self.prefill_buckets();
        for &b in buckets {
            if n <= b {
                return b;
            }
        }
        *buckets.last().expect("no prefill buckets")
    }
}

// ---------------------------------------------------------------------------
// PJRT-backed model.
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub struct PjrtModel<'e> {
    engine: &'e Engine,
    variant: Variant,
    kv: xla::PjRtBuffer,
    batch: usize,
    max_seq: usize,
    vocab: usize,
    buckets: Vec<usize>,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    /// Plan-hook telemetry: iterations seen, and how many planned >1
    /// concurrent prefill chunk (multi-prefill actually exercised).
    pub plans_seen: u64,
    pub multi_prefill_plans: u64,
}

#[cfg(feature = "pjrt")]
impl<'e> PjrtModel<'e> {
    pub fn new(
        engine: &'e Engine,
        variant: Variant,
        batch: usize,
        max_seq: usize,
        vocab: usize,
        buckets: Vec<usize>,
    ) -> Result<Self> {
        let kv = variant.fresh_kv(engine)?;
        Ok(PjrtModel {
            engine,
            variant,
            kv,
            batch,
            max_seq,
            vocab,
            buckets,
            decode_steps: 0,
            prefill_chunks: 0,
            plans_seen: 0,
            multi_prefill_plans: 0,
        })
    }

    pub fn variant_name(&self) -> &str {
        &self.variant.spec.name
    }

    pub fn compression_ratio(&self) -> f64 {
        self.variant.spec.compression_ratio
    }

    /// Reset the KV cache (between benchmark phases).
    pub fn reset_kv(&mut self) -> Result<()> {
        self.kv = self.variant.fresh_kv(self.engine)?;
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
impl<'e> StepModel for PjrtModel<'e> {
    fn batch(&self) -> usize {
        self.batch
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn plan_begin(&mut self, plan: &StepPlan) {
        self.plans_seen += 1;
        if plan.prefill_chunks.len() > 1 {
            self.multi_prefill_plans += 1;
        }
    }

    fn prefill(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        real_len: usize,
        slot: usize,
        pos0: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(
            real_len >= 1 && real_len <= bucket,
            "real_len {real_len} not in 1..={bucket}"
        );
        let (logits, kv) = self.variant.prefill(
            self.engine,
            bucket,
            tokens,
            &self.kv,
            slot as i32,
            pos0 as i32,
        )?;
        self.kv = kv;
        self.prefill_chunks += 1;
        // The executable returns logits for every chunk row; pad-query
        // rows are garbage — keep only the last real token's row.
        let row = real_len - 1;
        Ok(logits[row * self.vocab..(row + 1) * self.vocab].to_vec())
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let (logits, kv) = self.variant.decode(self.engine, tokens, pos, &self.kv)?;
        self.kv = kv;
        self.decode_steps += 1;
        Ok(logits)
    }
}

// ---------------------------------------------------------------------------
// Native std-only model: a tiny GELU transformer over crate::ffn.
// ---------------------------------------------------------------------------

/// One token's place in a forward batch.
#[derive(Debug, Clone, Copy)]
struct RowCtx {
    token: i32,
    slot: usize,
    pos: usize,
}

/// Host-resident paged K/V store of one layer:
/// `[num_blocks, block_size, d_model]` each. A logical position of a
/// slot resolves to a cell through the slot's [`BlockTable`].
struct LayerKv {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// A real (tiny) transformer executed in pure Rust: embedding → N
/// pre-LN blocks (bias-free MHA + FFN) → final LN → tied unembedding.
/// The FFN of every block is a [`FfnBackend`]: dense GELU for the
/// baseline variant, the TARDIS constant fold with online outlier
/// fallback for `tardis*` variants. Weights are synthesized
/// deterministically from the config seed, so no artifacts are needed.
pub struct NativeModel {
    cfg: NativeModelConfig,
    mode_name: &'static str,
    weights: NativeWeights,
    ffns: Vec<FfnBackend>,
    layout: KvLayout,
    /// Per-slot block tables (installed via [`StepModel::kv_map`]; a
    /// standalone model starts with the identity mapping when the pool
    /// is large enough to give every slot a full span).
    tables: Vec<BlockTable>,
    kv: Vec<LayerKv>,
    pool: Option<ThreadPool>,
    /// Reusable forward-pass buffers: once warm, the forward pass's
    /// intermediates allocate nothing (see [`Scratch`]; the returned
    /// logits and decode's small bookkeeping `Vec`s still allocate).
    scratch: Scratch,
    /// Per-slot degraded-service marks (see
    /// [`StepModel::set_slot_degrade`]): a marked slot's rows are forced
    /// through the folded FFN path.
    degraded: Vec<bool>,
    /// While true, [`NativeModel::forward`] forces EVERY row through the
    /// all-folded no-fallback FFN path regardless of per-slot degrade
    /// marks — the self-speculative draft pass. Set only inside
    /// [`StepModel::decode_draft`].
    draft_pass: bool,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
}

impl NativeModel {
    /// Build with deterministically synthesized weights.
    pub fn new(cfg: NativeModelConfig, mode: &FfnMode) -> NativeModel {
        let weights = NativeWeights::synthesize(&cfg);
        NativeModel::with_weights(cfg, weights, mode)
    }

    pub fn with_weights(
        cfg: NativeModelConfig,
        weights: NativeWeights,
        mode: &FfnMode,
    ) -> NativeModel {
        let _ = cfg.head_dim(); // validate the shape up front
        let ffns = weights
            .layers
            .iter()
            .map(|lw| {
                let dense = DenseFfn::new(
                    lw.w1.clone(),
                    lw.b1.clone(),
                    lw.w2.clone(),
                    lw.b2.clone(),
                    cfg.d_model,
                    cfg.d_ff,
                );
                match mode {
                    FfnMode::Dense => FfnBackend::Dense(dense),
                    // Per-neuron calibrated ranges (manifest-shipped)
                    // take precedence over the uniform configured range.
                    FfnMode::Tardis(t) => match &lw.calib {
                        Some(c) => {
                            // the exported scales fix the group size
                            let t = TardisFfnConfig {
                                predictor_group: c.group,
                                ..*t
                            };
                            FfnBackend::Folded(Box::new(
                                FoldedFfn::with_calibration(
                                    dense,
                                    &t,
                                    &c.lo,
                                    &c.hi,
                                    &c.lin_a,
                                    &c.lin_b,
                                    Some((&c.pred_codes, &c.pred_scales)),
                                ),
                            ))
                        }
                        None => {
                            FfnBackend::Folded(Box::new(FoldedFfn::new(dense, t)))
                        }
                    },
                    FfnMode::TardisReference(t) => {
                        let units = folded_units_for(t.fold_ratio, cfg.d_ff);
                        match &lw.calib {
                            Some(c) => {
                                FfnBackend::Dense(dense.with_ranges(
                                    RangeTable::from_calibration(
                                        &c.lo[..units],
                                        &c.hi[..units],
                                        &c.lin_a[..units],
                                        &c.lin_b[..units],
                                    ),
                                ))
                            }
                            None => {
                                let lin = Linearization::fit_gelu(t.linear_lo, t.linear_hi);
                                FfnBackend::Dense(dense.with_linearization(lin, units))
                            }
                        }
                    }
                }
            })
            .collect();
        let layout = cfg.resolved_kv_layout();
        let layout = KvLayout { num_blocks: layout.0, block_size: layout.1 };
        let kv = (0..cfg.n_layers)
            .map(|_| LayerKv {
                k: vec![0f32; layout.capacity_tokens() * cfg.d_model],
                v: vec![0f32; layout.capacity_tokens() * cfg.d_model],
            })
            .collect();
        // Standalone (engine-less) use gets the identity mapping when the
        // pool spans every slot; an undersized pool starts unmapped and
        // relies on the engine's kv_map calls.
        let bps = cfg.max_seq.div_ceil(layout.block_size);
        let tables = (0..cfg.batch)
            .map(|s| {
                let mut t = BlockTable::new(layout.block_size);
                if layout.num_blocks >= cfg.batch * bps {
                    for b in 0..bps {
                        t.push_block(s * bps + b);
                    }
                }
                t
            })
            .collect();
        let pool = if cfg.threads > 0 {
            Some(ThreadPool::new(cfg.threads))
        } else {
            None
        };
        NativeModel {
            mode_name: mode.name(),
            weights,
            ffns,
            layout,
            tables,
            kv,
            pool,
            scratch: Scratch::new(),
            degraded: vec![false; cfg.batch],
            draft_pass: false,
            decode_steps: 0,
            prefill_chunks: 0,
            cfg,
        }
    }

    /// Scratch-arena allocation misses so far (constant once warm).
    pub fn scratch_misses(&self) -> u64 {
        self.scratch.misses
    }

    pub fn config(&self) -> &NativeModelConfig {
        &self.cfg
    }

    pub fn ffn_mode_name(&self) -> &'static str {
        self.mode_name
    }

    /// Mean FFN parameter compression across layers (None for dense).
    pub fn fold_compression_ratio(&self) -> Option<f64> {
        let ratios: Vec<f64> = self
            .ffns
            .iter()
            .filter_map(|f| f.compression_ratio())
            .collect();
        if ratios.is_empty() {
            None
        } else {
            Some(ratios.iter().sum::<f64>() / ratios.len() as f64)
        }
    }

    /// Run the transformer over `rows`, returning the logits of the rows
    /// listed in `logit_rows` (concatenated, `[logit_rows.len()*vocab]`).
    ///
    /// Every intermediate comes from the model's [`Scratch`] arena and
    /// is recycled before returning — the returned logits buffer (which
    /// the engine consumes) is the forward pass's only per-call heap
    /// allocation. All projections (attention, FFN, unembedding) run the
    /// blocked kernels over weights packed at load time. K/V reads and
    /// writes go through the per-slot block tables, walking whole-block
    /// runs so the gather stays span-contiguous.
    fn forward(&mut self, rows: &[RowCtx], logit_rows: &[usize]) -> Vec<f32> {
        let n = rows.len();
        let d = self.cfg.d_model;
        let max_seq = self.cfg.max_seq;
        let n_heads = self.cfg.n_heads;
        let hd = d / n_heads;
        let scale = 1.0 / (hd as f32).sqrt();

        // Embedding lookup.
        let mut x = self.scratch.take(n * d);
        for (xi, r) in x.chunks_exact_mut(d).zip(rows) {
            let t = r.token.rem_euclid(self.cfg.vocab as i32) as usize;
            xi.copy_from_slice(&self.weights.embed[t * d..(t + 1) * d]);
        }

        // Degraded-service row mask: rows of marked slots take the
        // forced-fold FFN path in every layer (None when nothing is
        // degraded, so the common case allocates no mask). A draft pass
        // forces every row, whatever the per-slot marks say.
        let forced: Option<Vec<bool>> = if self.draft_pass {
            Some(vec![true; n])
        } else if self.degraded.iter().any(|&on| on) {
            Some(rows.iter().map(|r| self.degraded[r.slot]).collect())
        } else {
            None
        };

        let mut a = self.scratch.take(n * d);
        let mut q = self.scratch.take(n * d);
        let mut kb = self.scratch.take(n * d);
        let mut vb = self.scratch.take(n * d);
        let mut ctx = self.scratch.take(n * d);
        let mut o = self.scratch.take(n * d);
        let mut f = self.scratch.take(n * d);
        let mut scores = self.scratch.take(max_seq);

        for li in 0..self.cfg.n_layers {
            // -- attention ----------------------------------------------
            let lw = &self.weights.layers[li];
            let pool = self.pool.as_ref();
            layernorm_into(&x, n, d, &lw.ln1_gain, &lw.ln1_bias, &mut a);
            matmul(pool, &a, n, &lw.attn.wq_packed, Epilogue::Store, &mut q);
            matmul(pool, &a, n, &lw.attn.wk_packed, Epilogue::Store, &mut kb);
            matmul(pool, &a, n, &lw.attn.wv_packed, Epilogue::Store, &mut vb);
            let tables = &self.tables;
            let kv = &mut self.kv[li];
            for (i, r) in rows.iter().enumerate() {
                let off = tables[r.slot].physical(r.pos) * d;
                kv.k[off..off + d].copy_from_slice(&kb[i * d..(i + 1) * d]);
                kv.v[off..off + d].copy_from_slice(&vb[i * d..(i + 1) * d]);
            }
            // Causal attention per row over its slot's cache 0..=pos,
            // gathered block-run by block-run through the slot's table.
            // Rows never share a (slot, pos) cell and each attends only
            // up to its own position, so batch order cannot leak.
            ctx.fill(0.0);
            for (i, r) in rows.iter().enumerate() {
                let table = &tables[r.slot];
                for head in 0..n_heads {
                    let qh = &q[i * d + head * hd..i * d + (head + 1) * hd];
                    let mut max_s = f32::NEG_INFINITY;
                    for (t0, p0, rl) in table.runs(r.pos + 1) {
                        for (j, s) in scores[t0..t0 + rl].iter_mut().enumerate() {
                            let koff = (p0 + j) * d + head * hd;
                            let sv = dot(qh, &kv.k[koff..koff + hd]) * scale;
                            max_s = max_s.max(sv);
                            *s = sv;
                        }
                    }
                    let mut denom = 0f32;
                    for s in scores[..=r.pos].iter_mut() {
                        *s = (*s - max_s).exp();
                        denom += *s;
                    }
                    let out = &mut ctx[i * d + head * hd..i * d + (head + 1) * hd];
                    for (t0, p0, rl) in table.runs(r.pos + 1) {
                        for (j, &w) in scores[t0..t0 + rl].iter().enumerate() {
                            let voff = (p0 + j) * d + head * hd;
                            let p = w / denom;
                            for (ov, &vv) in out.iter_mut().zip(&kv.v[voff..voff + hd]) {
                                *ov += p * vv;
                            }
                        }
                    }
                }
            }
            matmul(pool, &ctx, n, &lw.attn.wo_packed, Epilogue::Store, &mut o);
            for (xv, &ov) in x.iter_mut().zip(o.iter()) {
                *xv += ov;
            }
            // -- FFN ----------------------------------------------------
            layernorm_into(&x, n, d, &lw.ln2_gain, &lw.ln2_bias, &mut f);
            let y = match &forced {
                Some(m) => self.ffns[li].forward_forced(
                    self.pool.as_ref(),
                    &mut self.scratch,
                    &f,
                    n,
                    m,
                ),
                None => {
                    self.ffns[li].forward(self.pool.as_ref(), &mut self.scratch, &f, n)
                }
            };
            for (xv, &yv) in x.iter_mut().zip(y.iter()) {
                *xv += yv;
            }
            self.scratch.give(y);
        }

        // Final LN + tied unembedding (packed GEMM) for the requested
        // rows only.
        let vocab = self.cfg.vocab;
        let mut xf = self.scratch.take(n * d);
        layernorm_into(&x, n, d, &self.weights.lnf_gain, &self.weights.lnf_bias, &mut xf);
        let nl = logit_rows.len();
        let mut xg = self.scratch.take(nl * d);
        for (dst, &ri) in xg.chunks_exact_mut(d).zip(logit_rows) {
            dst.copy_from_slice(&xf[ri * d..(ri + 1) * d]);
        }
        let mut logits = vec![0f32; nl * vocab];
        matmul(
            self.pool.as_ref(),
            &xg,
            nl,
            &self.weights.unembed_packed,
            Epilogue::Store,
            &mut logits,
        );
        self.scratch.give(xg);
        self.scratch.give(xf);
        self.scratch.give(scores);
        self.scratch.give(f);
        self.scratch.give(o);
        self.scratch.give(ctx);
        self.scratch.give(vb);
        self.scratch.give(kb);
        self.scratch.give(q);
        self.scratch.give(a);
        self.scratch.give(x);
        logits
    }
}

impl StepModel for NativeModel {
    fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn max_seq(&self) -> usize {
        self.cfg.max_seq
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.cfg.prefill_buckets
    }

    fn kv_layout(&self) -> KvLayout {
        self.layout
    }

    fn kv_map(&mut self, slot: usize, table: &BlockTable) {
        assert!(slot < self.cfg.batch, "slot {slot} out of range");
        assert_eq!(table.block_size(), self.layout.block_size);
        assert!(
            table.blocks().iter().all(|&b| b < self.layout.num_blocks),
            "block table references blocks outside the pool"
        );
        self.tables[slot] = table.clone();
    }

    fn supports_preemption(&self) -> bool {
        true
    }

    fn set_slot_degrade(&mut self, slot: usize, degraded: bool) {
        assert!(slot < self.cfg.batch, "slot {slot} out of range");
        self.degraded[slot] = degraded;
    }

    fn kv_save(&mut self, slot: usize, tokens: usize) -> Result<KvSwap> {
        anyhow::ensure!(slot < self.cfg.batch, "slot {slot} out of range");
        let table = self.tables[slot].clone();
        anyhow::ensure!(
            table.capacity() >= tokens,
            "kv_save of {tokens} tokens beyond table capacity {}",
            table.capacity()
        );
        let d = self.cfg.d_model;
        let mut layers = Vec::with_capacity(self.kv.len() * 2);
        for layer in &self.kv {
            for buf in [&layer.k, &layer.v] {
                let mut out = Vec::with_capacity(tokens * d);
                for (_t0, p0, rl) in table.runs(tokens) {
                    out.extend_from_slice(&buf[p0 * d..(p0 + rl) * d]);
                }
                layers.push(out);
            }
        }
        Ok(KvSwap { tokens, payload: SwapPayload::Layers(layers) })
    }

    fn supports_block_sharing(&self) -> bool {
        true
    }

    fn kv_copy_block(&mut self, src: usize, dst: usize, cells: usize) -> Result<()> {
        let bs = self.layout.block_size;
        anyhow::ensure!(
            src < self.layout.num_blocks && dst < self.layout.num_blocks,
            "kv_copy_block {src}->{dst} outside pool of {}",
            self.layout.num_blocks
        );
        anyhow::ensure!(cells <= bs, "kv_copy_block of {cells} cells > block size {bs}");
        let d = self.cfg.d_model;
        let (s0, d0, n) = (src * bs * d, dst * bs * d, cells * d);
        for layer in &mut self.kv {
            layer.k.copy_within(s0..s0 + n, d0);
            layer.v.copy_within(s0..s0 + n, d0);
        }
        Ok(())
    }

    fn kv_restore(&mut self, slot: usize, swap: &KvSwap) -> Result<()> {
        anyhow::ensure!(slot < self.cfg.batch, "slot {slot} out of range");
        let table = self.tables[slot].clone();
        anyhow::ensure!(
            table.capacity() >= swap.tokens,
            "kv_restore of {} tokens beyond table capacity {} (missing kv_map?)",
            swap.tokens,
            table.capacity()
        );
        let SwapPayload::Layers(layers) = &swap.payload else {
            anyhow::bail!("kv swap payload is not native layer data");
        };
        anyhow::ensure!(layers.len() == self.kv.len() * 2, "kv swap layer count mismatch");
        let d = self.cfg.d_model;
        for (li, layer) in self.kv.iter_mut().enumerate() {
            let ksrc = &layers[2 * li];
            let vsrc = &layers[2 * li + 1];
            for (t0, p0, rl) in table.runs(swap.tokens) {
                layer.k[p0 * d..(p0 + rl) * d]
                    .copy_from_slice(&ksrc[t0 * d..(t0 + rl) * d]);
                layer.v[p0 * d..(p0 + rl) * d]
                    .copy_from_slice(&vsrc[t0 * d..(t0 + rl) * d]);
            }
        }
        Ok(())
    }

    fn ffn_telemetry(&self) -> Option<FfnTelemetry> {
        let mut total = FfnTelemetry::default();
        let mut any = false;
        for f in &self.ffns {
            if let FfnBackend::Folded(_) = f {
                any = true;
            }
            total.accumulate(f.telemetry());
        }
        if any {
            Some(total)
        } else {
            None
        }
    }

    fn prefill(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        real_len: usize,
        slot: usize,
        pos0: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == bucket, "tokens not padded to bucket");
        anyhow::ensure!(slot < self.cfg.batch, "slot {slot} out of range");
        anyhow::ensure!(real_len >= 1 && real_len <= bucket);
        anyhow::ensure!(pos0 + real_len <= self.cfg.max_seq, "prefill past max_seq");
        anyhow::ensure!(
            self.tables[slot].capacity() >= pos0 + real_len,
            "slot {slot} block table holds {} tokens, prefill needs {} \
             (missing kv_map?)",
            self.tables[slot].capacity(),
            pos0 + real_len
        );
        let rows: Vec<RowCtx> = tokens[..real_len]
            .iter()
            .enumerate()
            .map(|(i, &token)| RowCtx { token, slot, pos: pos0 + i })
            .collect();
        let logits = self.forward(&rows, &[real_len - 1]);
        self.prefill_chunks += 1;
        Ok(logits)
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let batch = self.cfg.batch;
        anyhow::ensure!(tokens.len() == batch && pos.len() == batch);
        let mut rows = Vec::new();
        let mut row_slots = Vec::new();
        for b in 0..batch {
            let p = pos[b];
            if p >= 0 && (p as usize) < self.cfg.max_seq {
                anyhow::ensure!(
                    self.tables[b].capacity() > p as usize,
                    "slot {b} block table holds {} tokens, decode writes at \
                     {p} (missing kv_map?)",
                    self.tables[b].capacity()
                );
                rows.push(RowCtx { token: tokens[b], slot: b, pos: p as usize });
                row_slots.push(b);
            }
        }
        let vocab = self.cfg.vocab;
        let mut out = vec![0f32; batch * vocab];
        if !rows.is_empty() {
            let logit_rows: Vec<usize> = (0..rows.len()).collect();
            let logits = self.forward(&rows, &logit_rows);
            for (i, &b) in row_slots.iter().enumerate() {
                out[b * vocab..(b + 1) * vocab]
                    .copy_from_slice(&logits[i * vocab..(i + 1) * vocab]);
            }
        }
        self.decode_steps += 1;
        Ok(out)
    }

    fn supports_speculation(&self) -> bool {
        true
    }

    fn decode_draft(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        self.draft_pass = true;
        let out = self.decode(tokens, pos);
        self.draft_pass = false;
        out
    }

    fn decode_multi(&mut self, tokens: &[i32], slots: &[usize], pos: &[i32]) -> Result<Vec<f32>> {
        let n = tokens.len();
        anyhow::ensure!(slots.len() == n && pos.len() == n, "decode_multi: ragged row arrays");
        let mut rows = Vec::with_capacity(n);
        let mut last: Option<(usize, usize)> = None;
        for i in 0..n {
            let (b, p) = (slots[i], pos[i]);
            anyhow::ensure!(b < self.cfg.batch, "decode_multi: slot {b} out of range");
            anyhow::ensure!(
                p >= 0 && (p as usize) < self.cfg.max_seq,
                "decode_multi: position {p} out of range"
            );
            let p = p as usize;
            anyhow::ensure!(
                self.tables[b].capacity() > p,
                "slot {b} block table holds {} tokens, verify writes at {p} (missing kv_map?)",
                self.tables[b].capacity()
            );
            if let Some((lb, lp)) = last {
                anyhow::ensure!(
                    b > lb || (b == lb && p == lp + 1),
                    "decode_multi: rows must be slot-ascending and position-consecutive"
                );
            }
            last = Some((b, p));
            rows.push(RowCtx { token: tokens[i], slot: b, pos: p });
        }
        let logit_rows: Vec<usize> = (0..n).collect();
        let logits = self.forward(&rows, &logit_rows);
        self.decode_steps += 1;
        Ok(logits)
    }
}

// ---------------------------------------------------------------------------
// Deterministic mock model (tests + coordinator benches).
// ---------------------------------------------------------------------------

/// Produces logits that deterministically depend on (slot, last token,
/// position): `argmax = (token + position) % vocab`. This makes generated
/// sequences predictable so scheduler tests can assert exact outputs, and
/// lets tests detect cross-slot contamination (a wrong slot's state would
/// change the argmax). Its per-slot state swaps in and out through
/// [`StepModel::kv_save`]/[`StepModel::kv_restore`], and an overridden
/// [`KvLayout`] lets scheduler tests exercise block pressure and
/// preemption without the native backend's compute cost.
pub struct MockModel {
    batch: usize,
    max_seq: usize,
    vocab: usize,
    buckets: Vec<usize>,
    /// Paged-geometry override ([`MockModel::with_kv_layout`]); the
    /// default is the degenerate one-block-per-slot layout.
    layout: Option<KvLayout>,
    /// last (token, pos) per slot — emulates per-slot KV state.
    state: Vec<Option<(i32, usize)>>,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    /// Every prefill call as (slot, pos0): scheduler tests assert that
    /// chunks of concurrent prompts genuinely interleave.
    pub prefill_log: Vec<(usize, usize)>,
    /// Plan-hook telemetry (see [`StepModel::plan_begin`]).
    pub plans_seen: u64,
    pub max_planned_prefills: usize,
    pub plan_ends_seen: u64,
    /// Every [`StepModel::set_slot_degrade`] call as (slot, on): engine
    /// tests assert the degrade mark is armed at admission and cleared
    /// when the slot frees.
    pub degrade_log: Vec<(usize, bool)>,
    /// artificial per-call cost knob for scheduler benches
    pub spin_per_call: std::time::Duration,
    /// Deterministic draft-divergence knob: every `period`-th position
    /// (1-based `pos + 1`) the draft argmax is shifted off the dense one,
    /// so speculative tests exercise the rejection/rollback path. 0 =
    /// drafts always agree (the default).
    draft_miss_period: usize,
}

impl MockModel {
    pub fn new(batch: usize, max_seq: usize, vocab: usize, buckets: Vec<usize>) -> Self {
        MockModel {
            batch,
            max_seq,
            vocab,
            buckets,
            layout: None,
            state: vec![None; batch],
            decode_steps: 0,
            prefill_chunks: 0,
            prefill_log: Vec::new(),
            plans_seen: 0,
            max_planned_prefills: 0,
            plan_ends_seen: 0,
            degrade_log: Vec::new(),
            spin_per_call: std::time::Duration::ZERO,
            draft_miss_period: 0,
        }
    }

    /// Report a paged [`KvLayout`] so engine tests can put the block
    /// allocator under pressure (the mock itself addresses state by slot
    /// and ignores block tables).
    pub fn with_kv_layout(mut self, num_blocks: usize, block_size: usize) -> Self {
        self.layout = Some(KvLayout { num_blocks, block_size });
        self
    }

    /// Make the mock's draft path disagree with the dense path at every
    /// `period`-th position (0 = drafts always agree), so speculative
    /// tests can hit the reject/rollback path deterministically.
    pub fn with_draft_misses(mut self, period: usize) -> Self {
        self.draft_miss_period = period;
        self
    }

    fn logits_for(&self, token: i32, pos: usize) -> Vec<f32> {
        let mut l = vec![0f32; self.vocab];
        let target = ((token as usize) + pos) % self.vocab;
        l[target] = 10.0;
        l
    }

    /// Draft-path logits: identical to the dense path except at the
    /// configured miss positions, where the argmax shifts by one.
    fn draft_logits_for(&self, token: i32, pos: usize) -> Vec<f32> {
        let mut l = vec![0f32; self.vocab];
        let miss = self.draft_miss_period > 0 && (pos + 1) % self.draft_miss_period == 0;
        let shift = if miss { 1 } else { 0 };
        let target = ((token as usize) + pos + shift) % self.vocab;
        l[target] = 10.0;
        l
    }

    /// The token the mock will deterministically emit for (token, pos).
    pub fn expected_next(&self, token: i32, pos: usize) -> i32 {
        (((token as usize) + pos) % self.vocab) as i32
    }
}

impl StepModel for MockModel {
    fn batch(&self) -> usize {
        self.batch
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn kv_layout(&self) -> KvLayout {
        self.layout
            .unwrap_or_else(|| KvLayout::degenerate(self.batch, self.max_seq))
    }

    fn supports_preemption(&self) -> bool {
        true
    }

    fn kv_save(&mut self, slot: usize, tokens: usize) -> Result<KvSwap> {
        Ok(KvSwap { tokens, payload: SwapPayload::MockState(self.state[slot]) })
    }

    fn kv_restore(&mut self, slot: usize, swap: &KvSwap) -> Result<()> {
        let SwapPayload::MockState(state) = &swap.payload else {
            anyhow::bail!("kv swap payload is not mock state");
        };
        self.state[slot] = *state;
        Ok(())
    }

    fn supports_block_sharing(&self) -> bool {
        true
    }

    fn set_slot_degrade(&mut self, slot: usize, degraded: bool) {
        // No FFN to degrade; the mock just records the call so tests can
        // assert the engine arms and clears the mark at the right times.
        self.degrade_log.push((slot, degraded));
    }

    fn kv_copy_block(&mut self, _src: usize, _dst: usize, _cells: usize) -> Result<()> {
        // State is slot-addressed: a prefix hit leaves the slot's
        // (token, pos) exactly where suffix prefill will put it anyway.
        Ok(())
    }

    fn plan_begin(&mut self, plan: &StepPlan) {
        self.plans_seen += 1;
        let distinct = {
            let mut slots: Vec<usize> = plan.prefill_chunks.iter().map(|c| c.slot).collect();
            slots.sort_unstable();
            slots.dedup();
            slots.len()
        };
        self.max_planned_prefills = self.max_planned_prefills.max(distinct);
    }

    fn plan_end(&mut self, _outcome: &StepOutcome) {
        self.plan_ends_seen += 1;
    }

    fn prefill(
        &mut self,
        bucket: usize,
        tokens: &[i32],
        real_len: usize,
        slot: usize,
        pos0: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == bucket, "tokens not padded to bucket");
        anyhow::ensure!(slot < self.batch, "slot out of range");
        anyhow::ensure!(real_len >= 1 && real_len <= bucket);
        if !self.spin_per_call.is_zero() {
            std::thread::sleep(self.spin_per_call);
        }
        let last_tok = tokens[real_len - 1];
        let last_pos = pos0 + real_len - 1;
        self.state[slot] = Some((last_tok, last_pos));
        self.prefill_chunks += 1;
        self.prefill_log.push((slot, pos0));
        Ok(self.logits_for(last_tok, last_pos))
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == self.batch && pos.len() == self.batch);
        if !self.spin_per_call.is_zero() {
            std::thread::sleep(self.spin_per_call);
        }
        let mut out = Vec::with_capacity(self.batch * self.vocab);
        for b in 0..self.batch {
            if (pos[b] as usize) < self.max_seq {
                self.state[b] = Some((tokens[b], pos[b] as usize));
                out.extend(self.logits_for(tokens[b], pos[b] as usize));
            } else {
                out.extend(std::iter::repeat(0f32).take(self.vocab));
            }
        }
        self.decode_steps += 1;
        Ok(out)
    }

    fn supports_speculation(&self) -> bool {
        true
    }

    fn decode_draft(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == self.batch && pos.len() == self.batch);
        let mut out = Vec::with_capacity(self.batch * self.vocab);
        for b in 0..self.batch {
            if (pos[b] as usize) < self.max_seq {
                self.state[b] = Some((tokens[b], pos[b] as usize));
                out.extend(self.draft_logits_for(tokens[b], pos[b] as usize));
            } else {
                out.extend(std::iter::repeat(0f32).take(self.vocab));
            }
        }
        self.decode_steps += 1;
        Ok(out)
    }

    fn decode_multi(&mut self, tokens: &[i32], slots: &[usize], pos: &[i32]) -> Result<Vec<f32>> {
        let n = tokens.len();
        anyhow::ensure!(slots.len() == n && pos.len() == n, "decode_multi: ragged row arrays");
        let mut out = Vec::with_capacity(n * self.vocab);
        for i in 0..n {
            anyhow::ensure!(slots[i] < self.batch, "decode_multi: slot out of range");
            anyhow::ensure!(
                pos[i] >= 0 && (pos[i] as usize) < self.max_seq,
                "decode_multi: position out of range"
            );
            self.state[slots[i]] = Some((tokens[i], pos[i] as usize));
            out.extend(self.logits_for(tokens[i], pos[i] as usize));
        }
        self.decode_steps += 1;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let mut m = MockModel::new(2, 32, 16, vec![4, 8]);
        let l1 = m.prefill(4, &[1, 2, 3, 0], 3, 0, 0).unwrap();
        let l2 = m.prefill(4, &[1, 2, 3, 0], 3, 1, 0).unwrap();
        assert_eq!(l1, l2);
        // last real token 3 at pos 2 -> argmax (3+2)%16 = 5
        let am = crate::coordinator::sampler::argmax(&l1);
        assert_eq!(am, 5);
        assert_eq!(m.expected_next(3, 2), 5);
        assert_eq!(m.prefill_log, vec![(0, 0), (1, 0)]);
    }

    #[test]
    fn mock_state_swaps_in_and_out() {
        let mut m = MockModel::new(2, 32, 16, vec![4]).with_kv_layout(4, 8);
        assert_eq!(m.kv_layout(), KvLayout { num_blocks: 4, block_size: 8 });
        assert!(m.supports_preemption());
        let _ = m.prefill(4, &[1, 2, 3, 0], 3, 0, 0).unwrap();
        let swap = m.kv_save(0, 3).unwrap();
        // clobber the slot, then restore: decode continues identically
        m.state[0] = Some((9, 9));
        m.kv_restore(0, &swap).unwrap();
        assert_eq!(m.state[0], Some((3, 2)));
    }

    #[test]
    fn bucket_for_picks_smallest_fit() {
        let m = MockModel::new(2, 32, 16, vec![4, 8, 16]);
        assert_eq!(m.bucket_for(1), 4);
        assert_eq!(m.bucket_for(4), 4);
        assert_eq!(m.bucket_for(5), 8);
        assert_eq!(m.bucket_for(100), 16); // clamped to largest
    }

    #[test]
    fn decode_masks_inactive_slots() {
        let mut m = MockModel::new(2, 8, 4, vec![4]);
        let logits = m.decode(&[1, 0], &[2, 8]).unwrap(); // slot 1 inactive
        assert_eq!(logits.len(), 8);
        assert!(logits[4..].iter().all(|&v| v == 0.0));
        assert!(logits[..4].iter().any(|&v| v > 0.0));
    }

    fn native_cfg() -> NativeModelConfig {
        NativeModelConfig {
            vocab: 32,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 64,
            max_seq: 32,
            batch: 2,
            prefill_buckets: vec![4, 8],
            seed: 1234,
            threads: 0,
            kv_block_size: 8,
            kv_blocks: 0,
        }
    }

    #[test]
    fn native_reports_paged_layout() {
        let m = NativeModel::new(native_cfg(), &FfnMode::Dense);
        // auto pool: batch 2 * ceil(32/8) = 8 blocks of 8 tokens
        assert_eq!(m.kv_layout(), KvLayout { num_blocks: 8, block_size: 8 });
        assert!(m.supports_preemption());
    }

    #[test]
    fn native_decode_masks_inactive_slots() {
        let mut m = NativeModel::new(native_cfg(), &FfnMode::Dense);
        let logits = m.decode(&[1, 0], &[0, 32]).unwrap(); // slot 1 inactive
        assert_eq!(logits.len(), 2 * 32);
        assert!(logits[32..].iter().all(|&v| v == 0.0));
        assert!(logits[..32].iter().any(|&v| v != 0.0));
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn native_chunked_prefill_matches_single_chunk() {
        let cfg = native_cfg();
        let prompt = [3i32, 7, 11, 2];
        let mut single = NativeModel::new(cfg.clone(), &FfnMode::Dense);
        let l_single = single
            .prefill(4, &prompt, 4, 0, 0)
            .unwrap();
        let mut chunked = NativeModel::new(cfg, &FfnMode::Dense);
        let _ = chunked.prefill(4, &[3, 7, 0, 0], 2, 0, 0).unwrap();
        let l_chunked = chunked.prefill(4, &[11, 2, 0, 0], 2, 0, 2).unwrap();
        for (a, b) in l_single.iter().zip(&l_chunked) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn native_slots_are_isolated() {
        let cfg = native_cfg();
        // Slot 1 alone vs slot 1 with a busy neighbor: same logits.
        let mut solo = NativeModel::new(cfg.clone(), &FfnMode::Dense);
        let mut both = NativeModel::new(cfg, &FfnMode::Dense);
        let l_solo = solo.prefill(4, &[5, 9, 0, 0], 2, 1, 0).unwrap();
        let _ = both.prefill(4, &[8, 1, 4, 0], 3, 0, 0).unwrap();
        let l_both = both.prefill(4, &[5, 9, 0, 0], 2, 1, 0).unwrap();
        for (a, b) in l_solo.iter().zip(&l_both) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        // decode with a busy neighbor still matches the solo stream
        let d_solo = solo.decode(&[6, 6], &[32, 2]).unwrap();
        let d_both = both.decode(&[6, 6], &[3, 2]).unwrap();
        for (a, b) in d_solo[32..].iter().zip(&d_both[32..]) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn native_fragmented_table_matches_identity_mapping() {
        // The same token stream through an arbitrarily scrambled block
        // table must produce bitwise the logits of the identity mapping:
        // attention gathers by logical position, never physical order.
        let cfg = native_cfg();
        let mut ident = NativeModel::new(cfg.clone(), &FfnMode::Dense);
        let mut paged = NativeModel::new(cfg, &FfnMode::Dense);
        let mut t = BlockTable::new(8);
        for b in [5, 1, 6, 3] {
            t.push_block(b);
        }
        paged.kv_map(0, &t);
        let lp_i = ident.prefill(8, &[3, 7, 11, 2, 5, 0, 0, 0], 5, 0, 0).unwrap();
        let lp_p = paged.prefill(8, &[3, 7, 11, 2, 5, 0, 0, 0], 5, 0, 0).unwrap();
        assert_eq!(
            lp_i.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            lp_p.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        for s in 5..12 {
            let di = ident.decode(&[s, 0], &[s, 32]).unwrap();
            let dp = paged.decode(&[s, 0], &[s, 32]).unwrap();
            assert_eq!(
                di.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                dp.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "step {s}"
            );
        }
    }

    #[test]
    fn native_save_restore_is_bitwise_into_different_blocks() {
        let cfg = native_cfg();
        let mut base = NativeModel::new(cfg.clone(), &FfnMode::Dense);
        let mut moved = NativeModel::new(cfg, &FfnMode::Dense);
        let _ = base.prefill(8, &[3, 7, 11, 2, 5, 0, 0, 0], 5, 0, 0).unwrap();
        let _ = moved.prefill(8, &[3, 7, 11, 2, 5, 0, 0, 0], 5, 0, 0).unwrap();
        // Save 7 cached tokens (5 prompt + 2 decodes), rebind the slot to
        // different physical blocks, restore, and continue decoding.
        for s in 5..7 {
            let _ = base.decode(&[s, 0], &[s, 32]).unwrap();
            let _ = moved.decode(&[s, 0], &[s, 32]).unwrap();
        }
        let swap = moved.kv_save(0, 7).unwrap();
        assert_eq!(swap.tokens, 7);
        let mut t = BlockTable::new(8);
        for b in [7, 4, 2, 6] {
            t.push_block(b);
        }
        moved.kv_map(0, &t);
        moved.kv_restore(0, &swap).unwrap();
        for s in 7..12 {
            let db = base.decode(&[s, 0], &[s, 32]).unwrap();
            let dm = moved.decode(&[s, 0], &[s, 32]).unwrap();
            assert_eq!(
                db.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                dm.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "step {s}"
            );
        }
    }

    #[test]
    fn native_decode_multi_matches_sequential_decode_bitwise() {
        // Draft forwards write approximate KV at the drafted positions;
        // the one batched verify forward must overwrite them with exact
        // values and return, row for row, bitwise the logits of plain
        // sequential decode — the invariant the speculative loop's
        // bitwise-identity guarantee rests on.
        let tardis = crate::config::TardisFfnConfig {
            fold_ratio: 0.8,
            linear_lo: -8.0,
            linear_hi: 8.0,
            predictor_threshold: 1.05,
        };
        for mode in [FfnMode::Dense, FfnMode::Tardis(tardis)] {
            let cfg = native_cfg();
            let mut seq = NativeModel::new(cfg.clone(), &mode);
            let mut spec = NativeModel::new(cfg, &mode);
            let _ = seq.prefill(8, &[3, 7, 11, 2, 5, 0, 0, 0], 5, 0, 0).unwrap();
            let _ = spec.prefill(8, &[3, 7, 11, 2, 5, 0, 0, 0], 5, 0, 0).unwrap();
            let mut want = Vec::new();
            for s in 5..8 {
                let d = seq.decode(&[s, 0], &[s, 32]).unwrap();
                want.extend_from_slice(&d[..32]);
            }
            // Approximate draft writes at positions 5 and 6...
            let _ = spec.decode_draft(&[5, 0], &[5, 32]).unwrap();
            let _ = spec.decode_draft(&[6, 0], &[6, 32]).unwrap();
            // ...then one multi-row verify over positions 5..=7.
            let got = spec.decode_multi(&[5, 6, 7], &[0, 0, 0], &[5, 6, 7]).unwrap();
            assert_eq!(
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{} verify rows",
                mode.name()
            );
            // The verify left exact KV behind: the next plain decode
            // matches the sequential stream bitwise too.
            let ds = seq.decode(&[8, 0], &[8, 32]).unwrap();
            let dm = spec.decode(&[8, 0], &[8, 32]).unwrap();
            assert_eq!(
                ds.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                dm.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{} post-verify decode",
                mode.name()
            );
        }
    }

    #[test]
    fn native_draft_pass_is_forced_fold_and_resets() {
        // decode_draft must equal a degraded (forced-fold) decode bitwise
        // and must not leave the forcing armed for later plain decodes.
        let tardis = crate::config::TardisFfnConfig {
            fold_ratio: 0.8,
            linear_lo: -2.0,
            linear_hi: 2.0,
            predictor_threshold: 1.05,
        };
        let cfg = native_cfg();
        let mode = FfnMode::Tardis(tardis);
        let mut drafted = NativeModel::new(cfg.clone(), &mode);
        let mut degraded = NativeModel::new(cfg.clone(), &mode);
        let mut plain = NativeModel::new(cfg, &mode);
        for m in [&mut drafted, &mut degraded, &mut plain] {
            let _ = m.prefill(4, &[3, 7, 11, 0], 3, 0, 0).unwrap();
        }
        degraded.set_slot_degrade(0, true);
        let a = drafted.decode_draft(&[4, 0], &[3, 32]).unwrap();
        let b = degraded.decode(&[4, 0], &[3, 32]).unwrap();
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // Forcing is gone afterwards: the drafted model's next decode is
        // the plain (predictor-routed) path again.
        let c = drafted.decode(&[4, 0], &[4, 32]).unwrap();
        let _ = plain.decode_draft(&[4, 0], &[3, 32]).unwrap();
        let e = plain.decode(&[4, 0], &[4, 32]).unwrap();
        assert_eq!(
            c.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            e.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn native_tardis_tracks_reference_and_reports_telemetry() {
        let cfg = native_cfg();
        // Wide linear range: pre-activations are ~N(0,1) post-LN, so
        // every row is (provably or observably) in-range and the only
        // tardis-vs-reference difference is the fold's reassociation.
        let t = crate::config::TardisFfnConfig {
            fold_ratio: 0.8,
            linear_lo: -8.0,
            linear_hi: 8.0,
            predictor_threshold: 1.05,
            ..Default::default()
        };
        let mut tardis = NativeModel::new(cfg.clone(), &FfnMode::Tardis(t));
        let mut reference = NativeModel::new(cfg, &FfnMode::TardisReference(t));
        assert_eq!(tardis.ffn_mode_name(), "tardis");
        assert!(tardis.fold_compression_ratio().unwrap() > 0.3);
        assert!(reference.fold_compression_ratio().is_none());
        let lp_t = tardis.prefill(4, &[2, 4, 6, 8], 4, 0, 0).unwrap();
        let lp_r = reference.prefill(4, &[2, 4, 6, 8], 4, 0, 0).unwrap();
        for (a, b) in lp_t.iter().zip(&lp_r) {
            assert!((a - b).abs() < 2e-2 * b.abs().max(1.0), "{a} vs {b}");
        }
        for s in 4..12 {
            let dt = tardis.decode(&[s, s], &[s, s]).unwrap();
            let dr = reference.decode(&[s, s], &[s, s]).unwrap();
            for (a, b) in dt.iter().zip(&dr) {
                assert!((a - b).abs() < 2e-2 * b.abs().max(1.0), "{a} vs {b}");
            }
        }
        let tele = tardis.ffn_telemetry().expect("tardis has telemetry");
        assert!(tele.total_rows() > 0);
        assert!(reference.ffn_telemetry().is_none(), "reference path reports no fold telemetry");
    }

    #[test]
    fn plan_hooks_record_concurrency() {
        use crate::coordinator::scheduler::ChunkSpec;
        let mut m = MockModel::new(2, 8, 4, vec![4]);
        let plan = StepPlan {
            prefill_chunks: vec![
                ChunkSpec { request: 1, slot: 0 },
                ChunkSpec { request: 2, slot: 1 },
            ],
            ..Default::default()
        };
        m.plan_begin(&plan);
        m.plan_end(&StepOutcome::default());
        assert_eq!(m.plans_seen, 1);
        assert_eq!(m.plan_ends_seen, 1);
        assert_eq!(m.max_planned_prefills, 2);
    }
}
