//! The step-model abstraction the coordinator schedules against.
//!
//! `PjrtModel` wraps a loaded [`crate::runtime::Variant`] and owns the
//! device-resident KV cache, threading it through prefill/decode calls.
//! `MockModel` is a deterministic pure-rust stand-in so every coordinator
//! test and bench runs without artifacts.

use anyhow::Result;

use crate::runtime::{Engine, Variant};

pub trait StepModel {
    /// Fixed decode batch (number of KV slots).
    fn batch(&self) -> usize;
    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Ascending prefill chunk sizes the model was exported with.
    fn prefill_buckets(&self) -> &[usize];

    /// Prefill `tokens` (padded to `bucket`; the first `real_len` are
    /// real) into `slot` starting at absolute position `pos0`. Returns
    /// the logits of the last *real* token, `[vocab]`.
    fn prefill(&mut self, bucket: usize, tokens: &[i32], real_len: usize,
               slot: usize, pos0: usize) -> Result<Vec<f32>>;

    /// One decode step over all slots. `tokens[b]`/`pos[b]` for inactive
    /// slots carry (0, max_seq) sentinels. Returns logits `[batch*vocab]`.
    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>>;

    /// Smallest bucket that fits `n` tokens (or the largest bucket).
    fn bucket_for(&self, n: usize) -> usize {
        let buckets = self.prefill_buckets();
        for &b in buckets {
            if n <= b {
                return b;
            }
        }
        *buckets.last().expect("no prefill buckets")
    }
}

// ---------------------------------------------------------------------------
// PJRT-backed model.
// ---------------------------------------------------------------------------

pub struct PjrtModel<'e> {
    engine: &'e Engine,
    variant: Variant,
    kv: xla::PjRtBuffer,
    batch: usize,
    max_seq: usize,
    vocab: usize,
    buckets: Vec<usize>,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
}

impl<'e> PjrtModel<'e> {
    pub fn new(engine: &'e Engine, variant: Variant, batch: usize,
               max_seq: usize, vocab: usize, buckets: Vec<usize>)
               -> Result<Self> {
        let kv = variant.fresh_kv(engine)?;
        Ok(PjrtModel {
            engine,
            variant,
            kv,
            batch,
            max_seq,
            vocab,
            buckets,
            decode_steps: 0,
            prefill_chunks: 0,
        })
    }

    pub fn variant_name(&self) -> &str {
        &self.variant.spec.name
    }

    pub fn compression_ratio(&self) -> f64 {
        self.variant.spec.compression_ratio
    }

    /// Reset the KV cache (between benchmark phases).
    pub fn reset_kv(&mut self) -> Result<()> {
        self.kv = self.variant.fresh_kv(self.engine)?;
        Ok(())
    }
}

impl<'e> StepModel for PjrtModel<'e> {
    fn batch(&self) -> usize {
        self.batch
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&mut self, bucket: usize, tokens: &[i32], real_len: usize,
               slot: usize, pos0: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(real_len >= 1 && real_len <= bucket,
                        "real_len {real_len} not in 1..={bucket}");
        let (logits, kv) = self.variant.prefill(
            self.engine, bucket, tokens, &self.kv, slot as i32, pos0 as i32)?;
        self.kv = kv;
        self.prefill_chunks += 1;
        // The executable returns logits for every chunk row; pad-query
        // rows are garbage — keep only the last real token's row.
        let row = real_len - 1;
        Ok(logits[row * self.vocab..(row + 1) * self.vocab].to_vec())
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let (logits, kv) =
            self.variant.decode(self.engine, tokens, pos, &self.kv)?;
        self.kv = kv;
        self.decode_steps += 1;
        Ok(logits)
    }
}

// ---------------------------------------------------------------------------
// Deterministic mock model (tests + coordinator benches).
// ---------------------------------------------------------------------------

/// Produces logits that deterministically depend on (slot, last token,
/// position): `argmax = (token + position) % vocab`. This makes generated
/// sequences predictable so scheduler tests can assert exact outputs, and
/// lets tests detect cross-slot contamination (a wrong slot's state would
/// change the argmax).
pub struct MockModel {
    batch: usize,
    max_seq: usize,
    vocab: usize,
    buckets: Vec<usize>,
    /// last (token, pos) per slot — emulates per-slot KV state.
    state: Vec<Option<(i32, usize)>>,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    /// artificial per-call cost knob for scheduler benches
    pub spin_per_call: std::time::Duration,
}

impl MockModel {
    pub fn new(batch: usize, max_seq: usize, vocab: usize,
               buckets: Vec<usize>) -> Self {
        MockModel {
            batch,
            max_seq,
            vocab,
            buckets,
            state: vec![None; batch],
            decode_steps: 0,
            prefill_chunks: 0,
            spin_per_call: std::time::Duration::ZERO,
        }
    }

    fn logits_for(&self, token: i32, pos: usize) -> Vec<f32> {
        let mut l = vec![0f32; self.vocab];
        let target = ((token as usize) + pos) % self.vocab;
        l[target] = 10.0;
        l
    }

    /// The token the mock will deterministically emit for (token, pos).
    pub fn expected_next(&self, token: i32, pos: usize) -> i32 {
        (((token as usize) + pos) % self.vocab) as i32
    }
}

impl StepModel for MockModel {
    fn batch(&self) -> usize {
        self.batch
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn prefill(&mut self, bucket: usize, tokens: &[i32], real_len: usize,
               slot: usize, pos0: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == bucket, "tokens not padded to bucket");
        anyhow::ensure!(slot < self.batch, "slot out of range");
        anyhow::ensure!(real_len >= 1 && real_len <= bucket);
        if !self.spin_per_call.is_zero() {
            std::thread::sleep(self.spin_per_call);
        }
        let last_tok = tokens[real_len - 1];
        let last_pos = pos0 + real_len - 1;
        self.state[slot] = Some((last_tok, last_pos));
        self.prefill_chunks += 1;
        Ok(self.logits_for(last_tok, last_pos))
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == self.batch && pos.len() == self.batch);
        if !self.spin_per_call.is_zero() {
            std::thread::sleep(self.spin_per_call);
        }
        let mut out = Vec::with_capacity(self.batch * self.vocab);
        for b in 0..self.batch {
            if (pos[b] as usize) < self.max_seq {
                self.state[b] = Some((tokens[b], pos[b] as usize));
                out.extend(self.logits_for(tokens[b], pos[b] as usize));
            } else {
                out.extend(std::iter::repeat(0f32).take(self.vocab));
            }
        }
        self.decode_steps += 1;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let mut m = MockModel::new(2, 32, 16, vec![4, 8]);
        let l1 = m.prefill(4, &[1, 2, 3, 0], 3, 0, 0).unwrap();
        let l2 = m.prefill(4, &[1, 2, 3, 0], 3, 1, 0).unwrap();
        assert_eq!(l1, l2);
        // last real token 3 at pos 2 -> argmax (3+2)%16 = 5
        let am = crate::coordinator::sampler::argmax(&l1);
        assert_eq!(am, 5);
        assert_eq!(m.expected_next(3, 2), 5);
    }

    #[test]
    fn bucket_for_picks_smallest_fit() {
        let m = MockModel::new(2, 32, 16, vec![4, 8, 16]);
        assert_eq!(m.bucket_for(1), 4);
        assert_eq!(m.bucket_for(4), 4);
        assert_eq!(m.bucket_for(5), 8);
        assert_eq!(m.bucket_for(100), 16); // clamped to largest
    }

    #[test]
    fn decode_masks_inactive_slots() {
        let mut m = MockModel::new(2, 8, 4, vec![4]);
        let logits = m.decode(&[1, 0], &[2, 8]).unwrap(); // slot 1 inactive
        assert_eq!(logits.len(), 8);
        assert!(logits[4..].iter().all(|&v| v == 0.0));
        assert!(logits[..4].iter().any(|&v| v > 0.0));
    }
}
