//! The step-model abstraction the coordinator schedules against.
//!
//! `PjrtModel` (behind the `pjrt` feature) wraps a loaded
//! [`crate::runtime::Variant`] and owns the device-resident KV cache,
//! threading it through prefill/decode calls. `MockModel` is a
//! deterministic pure-rust stand-in so every coordinator test and bench
//! runs without artifacts.

use anyhow::Result;

use super::scheduler::{StepOutcome, StepPlan};

#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, Variant};

pub trait StepModel {
    /// Fixed decode batch (number of KV slots).
    fn batch(&self) -> usize;
    fn max_seq(&self) -> usize;
    fn vocab(&self) -> usize;
    /// Ascending prefill chunk sizes the model was exported with.
    fn prefill_buckets(&self) -> &[usize];

    /// Plan-level hook: called once per engine iteration with the
    /// [`StepPlan`] about to execute, before any prefill/decode dispatch.
    /// Backends can stage uploads for the whole iteration or record
    /// scheduling telemetry. Default: no-op.
    fn plan_begin(&mut self, _plan: &StepPlan) {}

    /// Plan-level hook: called after the plan's work has executed.
    fn plan_end(&mut self, _outcome: &StepOutcome) {}

    /// Prefill `tokens` (padded to `bucket`; the first `real_len` are
    /// real) into `slot` starting at absolute position `pos0`. Returns
    /// the logits of the last *real* token, `[vocab]`.
    fn prefill(&mut self, bucket: usize, tokens: &[i32], real_len: usize,
               slot: usize, pos0: usize) -> Result<Vec<f32>>;

    /// One decode step over all slots. `tokens[b]`/`pos[b]` for inactive
    /// slots carry (0, max_seq) sentinels. Returns logits `[batch*vocab]`.
    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>>;

    /// Smallest bucket that fits `n` tokens (or the largest bucket).
    fn bucket_for(&self, n: usize) -> usize {
        let buckets = self.prefill_buckets();
        for &b in buckets {
            if n <= b {
                return b;
            }
        }
        *buckets.last().expect("no prefill buckets")
    }
}

// ---------------------------------------------------------------------------
// PJRT-backed model.
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub struct PjrtModel<'e> {
    engine: &'e Engine,
    variant: Variant,
    kv: xla::PjRtBuffer,
    batch: usize,
    max_seq: usize,
    vocab: usize,
    buckets: Vec<usize>,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    /// Plan-hook telemetry: iterations seen, and how many planned >1
    /// concurrent prefill chunk (multi-prefill actually exercised).
    pub plans_seen: u64,
    pub multi_prefill_plans: u64,
}

#[cfg(feature = "pjrt")]
impl<'e> PjrtModel<'e> {
    pub fn new(engine: &'e Engine, variant: Variant, batch: usize,
               max_seq: usize, vocab: usize, buckets: Vec<usize>)
               -> Result<Self> {
        let kv = variant.fresh_kv(engine)?;
        Ok(PjrtModel {
            engine,
            variant,
            kv,
            batch,
            max_seq,
            vocab,
            buckets,
            decode_steps: 0,
            prefill_chunks: 0,
            plans_seen: 0,
            multi_prefill_plans: 0,
        })
    }

    pub fn variant_name(&self) -> &str {
        &self.variant.spec.name
    }

    pub fn compression_ratio(&self) -> f64 {
        self.variant.spec.compression_ratio
    }

    /// Reset the KV cache (between benchmark phases).
    pub fn reset_kv(&mut self) -> Result<()> {
        self.kv = self.variant.fresh_kv(self.engine)?;
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
impl<'e> StepModel for PjrtModel<'e> {
    fn batch(&self) -> usize {
        self.batch
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn plan_begin(&mut self, plan: &StepPlan) {
        self.plans_seen += 1;
        if plan.prefill_chunks.len() > 1 {
            self.multi_prefill_plans += 1;
        }
    }

    fn prefill(&mut self, bucket: usize, tokens: &[i32], real_len: usize,
               slot: usize, pos0: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(real_len >= 1 && real_len <= bucket,
                        "real_len {real_len} not in 1..={bucket}");
        let (logits, kv) = self.variant.prefill(
            self.engine, bucket, tokens, &self.kv, slot as i32, pos0 as i32)?;
        self.kv = kv;
        self.prefill_chunks += 1;
        // The executable returns logits for every chunk row; pad-query
        // rows are garbage — keep only the last real token's row.
        let row = real_len - 1;
        Ok(logits[row * self.vocab..(row + 1) * self.vocab].to_vec())
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let (logits, kv) =
            self.variant.decode(self.engine, tokens, pos, &self.kv)?;
        self.kv = kv;
        self.decode_steps += 1;
        Ok(logits)
    }
}

// ---------------------------------------------------------------------------
// Deterministic mock model (tests + coordinator benches).
// ---------------------------------------------------------------------------

/// Produces logits that deterministically depend on (slot, last token,
/// position): `argmax = (token + position) % vocab`. This makes generated
/// sequences predictable so scheduler tests can assert exact outputs, and
/// lets tests detect cross-slot contamination (a wrong slot's state would
/// change the argmax).
pub struct MockModel {
    batch: usize,
    max_seq: usize,
    vocab: usize,
    buckets: Vec<usize>,
    /// last (token, pos) per slot — emulates per-slot KV state.
    state: Vec<Option<(i32, usize)>>,
    pub decode_steps: u64,
    pub prefill_chunks: u64,
    /// Every prefill call as (slot, pos0): scheduler tests assert that
    /// chunks of concurrent prompts genuinely interleave.
    pub prefill_log: Vec<(usize, usize)>,
    /// Plan-hook telemetry (see [`StepModel::plan_begin`]).
    pub plans_seen: u64,
    pub max_planned_prefills: usize,
    pub plan_ends_seen: u64,
    /// artificial per-call cost knob for scheduler benches
    pub spin_per_call: std::time::Duration,
}

impl MockModel {
    pub fn new(batch: usize, max_seq: usize, vocab: usize,
               buckets: Vec<usize>) -> Self {
        MockModel {
            batch,
            max_seq,
            vocab,
            buckets,
            state: vec![None; batch],
            decode_steps: 0,
            prefill_chunks: 0,
            prefill_log: Vec::new(),
            plans_seen: 0,
            max_planned_prefills: 0,
            plan_ends_seen: 0,
            spin_per_call: std::time::Duration::ZERO,
        }
    }

    fn logits_for(&self, token: i32, pos: usize) -> Vec<f32> {
        let mut l = vec![0f32; self.vocab];
        let target = ((token as usize) + pos) % self.vocab;
        l[target] = 10.0;
        l
    }

    /// The token the mock will deterministically emit for (token, pos).
    pub fn expected_next(&self, token: i32, pos: usize) -> i32 {
        (((token as usize) + pos) % self.vocab) as i32
    }
}

impl StepModel for MockModel {
    fn batch(&self) -> usize {
        self.batch
    }

    fn max_seq(&self) -> usize {
        self.max_seq
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn prefill_buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn plan_begin(&mut self, plan: &StepPlan) {
        self.plans_seen += 1;
        let distinct = {
            let mut slots: Vec<usize> =
                plan.prefill_chunks.iter().map(|c| c.slot).collect();
            slots.sort_unstable();
            slots.dedup();
            slots.len()
        };
        self.max_planned_prefills = self.max_planned_prefills.max(distinct);
    }

    fn plan_end(&mut self, _outcome: &StepOutcome) {
        self.plan_ends_seen += 1;
    }

    fn prefill(&mut self, bucket: usize, tokens: &[i32], real_len: usize,
               slot: usize, pos0: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == bucket, "tokens not padded to bucket");
        anyhow::ensure!(slot < self.batch, "slot out of range");
        anyhow::ensure!(real_len >= 1 && real_len <= bucket);
        if !self.spin_per_call.is_zero() {
            std::thread::sleep(self.spin_per_call);
        }
        let last_tok = tokens[real_len - 1];
        let last_pos = pos0 + real_len - 1;
        self.state[slot] = Some((last_tok, last_pos));
        self.prefill_chunks += 1;
        self.prefill_log.push((slot, pos0));
        Ok(self.logits_for(last_tok, last_pos))
    }

    fn decode(&mut self, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == self.batch && pos.len() == self.batch);
        if !self.spin_per_call.is_zero() {
            std::thread::sleep(self.spin_per_call);
        }
        let mut out = Vec::with_capacity(self.batch * self.vocab);
        for b in 0..self.batch {
            if (pos[b] as usize) < self.max_seq {
                self.state[b] = Some((tokens[b], pos[b] as usize));
                out.extend(self.logits_for(tokens[b], pos[b] as usize));
            } else {
                out.extend(std::iter::repeat(0f32).take(self.vocab));
            }
        }
        self.decode_steps += 1;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let mut m = MockModel::new(2, 32, 16, vec![4, 8]);
        let l1 = m.prefill(4, &[1, 2, 3, 0], 3, 0, 0).unwrap();
        let l2 = m.prefill(4, &[1, 2, 3, 0], 3, 1, 0).unwrap();
        assert_eq!(l1, l2);
        // last real token 3 at pos 2 -> argmax (3+2)%16 = 5
        let am = crate::coordinator::sampler::argmax(&l1);
        assert_eq!(am, 5);
        assert_eq!(m.expected_next(3, 2), 5);
        assert_eq!(m.prefill_log, vec![(0, 0), (1, 0)]);
    }

    #[test]
    fn bucket_for_picks_smallest_fit() {
        let m = MockModel::new(2, 32, 16, vec![4, 8, 16]);
        assert_eq!(m.bucket_for(1), 4);
        assert_eq!(m.bucket_for(4), 4);
        assert_eq!(m.bucket_for(5), 8);
        assert_eq!(m.bucket_for(100), 16); // clamped to largest
    }

    #[test]
    fn decode_masks_inactive_slots() {
        let mut m = MockModel::new(2, 8, 4, vec![4]);
        let logits = m.decode(&[1, 0], &[2, 8]).unwrap(); // slot 1 inactive
        assert_eq!(logits.len(), 8);
        assert!(logits[4..].iter().all(|&v| v == 0.0));
        assert!(logits[..4].iter().any(|&v| v > 0.0));
    }

    #[test]
    fn plan_hooks_record_concurrency() {
        use crate::coordinator::scheduler::ChunkSpec;
        let mut m = MockModel::new(2, 8, 4, vec![4]);
        let plan = StepPlan {
            admissions: vec![],
            prefill_chunks: vec![
                ChunkSpec { request: 1, slot: 0 },
                ChunkSpec { request: 2, slot: 1 },
            ],
            decode: None,
        };
        m.plan_begin(&plan);
        m.plan_end(&StepOutcome::default());
        assert_eq!(m.plans_seen, 1);
        assert_eq!(m.plan_ends_seen, 1);
        assert_eq!(m.max_planned_prefills, 2);
    }
}
