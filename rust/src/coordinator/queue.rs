//! Bounded admission queue with backpressure.
//!
//! Requests wait here until the scheduler can claim a KV slot for them.
//! `push` refuses above capacity — the server maps that to an explicit
//! "try later" response instead of unbounded memory growth.

use std::collections::VecDeque;

use super::request::Request;

#[derive(Debug)]
pub struct AdmissionQueue {
    cap: usize,
    q: VecDeque<Request>,
    rejected: u64,
    admitted: u64,
}

#[derive(Debug)]
pub struct QueueFull(pub Request);

impl AdmissionQueue {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        AdmissionQueue { cap, q: VecDeque::new(), rejected: 0, admitted: 0 }
    }

    pub fn push(&mut self, r: Request) -> Result<(), QueueFull> {
        if self.q.len() >= self.cap {
            self.rejected += 1;
            return Err(QueueFull(r));
        }
        self.admitted += 1;
        self.q.push_back(r);
        Ok(())
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.q.pop_front()
    }

    /// Put an already-admitted request back at the *front* of the queue
    /// (a last-resort prefill abort under KV block pressure; it restarts
    /// from its prompt on re-admission). Bypasses the capacity check —
    /// the request's slot in the system was already granted once, and
    /// dropping it here would lose it.
    pub fn requeue_front(&mut self, r: Request) {
        self.q.push_front(r);
    }

    pub fn peek(&self) -> Option<&Request> {
        self.q.front()
    }

    /// Iterate queued requests oldest-first (the scheduler's snapshot
    /// source; the iteration index is the FIFO arrival key).
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.q.iter()
    }

    /// Remove a queued request by id (plan admission may pick any queued
    /// request, not just the head). Returns it if present.
    pub fn take(&mut self, id: u64) -> Option<Request> {
        let idx = self.q.iter().position(|r| r.id == id)?;
        self.q.remove(idx)
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Backpressure signal in [0, 1]: how full the queue is.
    pub fn pressure(&self) -> f64 {
        self.q.len() as f64 / self.cap as f64
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Cancel a queued request by id; returns it if found.
    pub fn cancel(&mut self, id: u64) -> Option<Request> {
        self.take(id)
    }
}

/// What the overload admission controller decided for one submission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverloadAction {
    /// Serve at full quality.
    Admit,
    /// Serve, but force the request's FFN rows through the folded path
    /// (`SamplingParams::degrade`) — cheaper tokens, same stream shape.
    Degrade,
    /// Refuse; the caller maps this to an overloaded/retry-later reply.
    Shed,
}

/// Tiered overload admission control: as queue pressure climbs, the
/// lowest priority tiers are *degraded* first (forced-fold FFN) and
/// *shed* only past a higher watermark, so high-tier deadlines survive
/// an overload instead of every deadline collapsing together. The
/// decision is made once, at the submission boundary (front door or
/// trace harness) **before** the admission is journaled, so a crash
/// replay re-runs the same degraded request bitwise.
///
/// Disabled by default: thresholds above 1.0 can never trigger on a
/// pressure signal that saturates at 1.0.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverloadPolicy {
    /// Queue pressure in [0, 1] at which eligible tiers degrade.
    pub degrade_at: f64,
    /// Queue pressure at which eligible tiers shed (>= `degrade_at` to
    /// keep the ladder ordered: degrade before you drop).
    pub shed_at: f64,
    /// Only requests with `priority <= tier_max` are degraded or shed;
    /// higher tiers always admit at full quality.
    pub tier_max: i32,
}

impl Default for OverloadPolicy {
    fn default() -> Self {
        OverloadPolicy { degrade_at: 2.0, shed_at: 2.0, tier_max: 0 }
    }
}

impl OverloadPolicy {
    pub fn enabled(&self) -> bool {
        self.degrade_at <= 1.0 || self.shed_at <= 1.0
    }

    /// Decide for one submission given the current queue pressure.
    pub fn action(&self, pressure: f64, priority: i32) -> OverloadAction {
        if priority > self.tier_max {
            return OverloadAction::Admit;
        }
        if pressure >= self.shed_at {
            return OverloadAction::Shed;
        }
        if pressure >= self.degrade_at {
            return OverloadAction::Degrade;
        }
        OverloadAction::Admit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], SamplingParams::default())
    }

    #[test]
    fn fifo_order() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn backpressure_at_capacity() {
        let mut q = AdmissionQueue::new(2);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        assert_eq!(q.pressure(), 1.0);
        let err = q.push(req(3)).unwrap_err();
        assert_eq!(err.0.id, 3);
        assert_eq!(q.rejected(), 1);
        assert_eq!(q.admitted(), 2);
        q.pop().unwrap();
        q.push(req(3)).unwrap(); // space again
    }

    #[test]
    fn take_removes_mid_queue() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        q.push(req(3)).unwrap();
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        assert_eq!(q.take(2).unwrap().id, 2);
        assert!(q.take(2).is_none());
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 3);
    }

    #[test]
    fn cancel_removes_by_id() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        q.push(req(3)).unwrap();
        assert_eq!(q.cancel(2).unwrap().id, 2);
        assert!(q.cancel(2).is_none());
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 3);
    }

    #[test]
    fn overload_policy_disabled_by_default() {
        let p = OverloadPolicy::default();
        assert!(!p.enabled());
        // a saturated queue still admits everyone at full quality
        assert_eq!(p.action(1.0, 0), OverloadAction::Admit);
        assert_eq!(p.action(1.0, -5), OverloadAction::Admit);
    }

    #[test]
    fn overload_ladder_degrades_before_shedding() {
        let p = OverloadPolicy { degrade_at: 0.5, shed_at: 0.9, tier_max: 0 };
        assert!(p.enabled());
        assert_eq!(p.action(0.49, 0), OverloadAction::Admit);
        assert_eq!(p.action(0.5, 0), OverloadAction::Degrade);
        assert_eq!(p.action(0.89, 0), OverloadAction::Degrade);
        assert_eq!(p.action(0.9, 0), OverloadAction::Shed);
        assert_eq!(p.action(1.0, 0), OverloadAction::Shed);
    }

    #[test]
    fn overload_spares_higher_tiers() {
        let p = OverloadPolicy { degrade_at: 0.5, shed_at: 0.9, tier_max: 0 };
        // tier 1 rides above tier_max: full quality even at saturation
        assert_eq!(p.action(1.0, 1), OverloadAction::Admit);
        // tier 0 and below take the ladder
        assert_eq!(p.action(1.0, 0), OverloadAction::Shed);
        assert_eq!(p.action(0.7, -3), OverloadAction::Degrade);
    }
}
