//! Bounded admission queue with backpressure.
//!
//! Requests wait here until the scheduler can claim a KV slot for them.
//! `push` refuses above capacity — the server maps that to an explicit
//! "try later" response instead of unbounded memory growth.

use std::collections::VecDeque;

use super::request::Request;

#[derive(Debug)]
pub struct AdmissionQueue {
    cap: usize,
    q: VecDeque<Request>,
    rejected: u64,
    admitted: u64,
}

#[derive(Debug)]
pub struct QueueFull(pub Request);

impl AdmissionQueue {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        AdmissionQueue { cap, q: VecDeque::new(), rejected: 0, admitted: 0 }
    }

    pub fn push(&mut self, r: Request) -> Result<(), QueueFull> {
        if self.q.len() >= self.cap {
            self.rejected += 1;
            return Err(QueueFull(r));
        }
        self.admitted += 1;
        self.q.push_back(r);
        Ok(())
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.q.pop_front()
    }

    /// Put an already-admitted request back at the *front* of the queue
    /// (a last-resort prefill abort under KV block pressure; it restarts
    /// from its prompt on re-admission). Bypasses the capacity check —
    /// the request's slot in the system was already granted once, and
    /// dropping it here would lose it.
    pub fn requeue_front(&mut self, r: Request) {
        self.q.push_front(r);
    }

    pub fn peek(&self) -> Option<&Request> {
        self.q.front()
    }

    /// Iterate queued requests oldest-first (the scheduler's snapshot
    /// source; the iteration index is the FIFO arrival key).
    pub fn iter(&self) -> impl Iterator<Item = &Request> {
        self.q.iter()
    }

    /// Remove a queued request by id (plan admission may pick any queued
    /// request, not just the head). Returns it if present.
    pub fn take(&mut self, id: u64) -> Option<Request> {
        let idx = self.q.iter().position(|r| r.id == id)?;
        self.q.remove(idx)
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Backpressure signal in [0, 1]: how full the queue is.
    pub fn pressure(&self) -> f64 {
        self.q.len() as f64 / self.cap as f64
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Cancel a queued request by id; returns it if found.
    pub fn cancel(&mut self, id: u64) -> Option<Request> {
        self.take(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], SamplingParams::default())
    }

    #[test]
    fn fifo_order() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn backpressure_at_capacity() {
        let mut q = AdmissionQueue::new(2);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        assert_eq!(q.pressure(), 1.0);
        let err = q.push(req(3)).unwrap_err();
        assert_eq!(err.0.id, 3);
        assert_eq!(q.rejected(), 1);
        assert_eq!(q.admitted(), 2);
        q.pop().unwrap();
        q.push(req(3)).unwrap(); // space again
    }

    #[test]
    fn take_removes_mid_queue() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        q.push(req(3)).unwrap();
        let ids: Vec<u64> = q.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        assert_eq!(q.take(2).unwrap().id, 2);
        assert!(q.take(2).is_none());
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 3);
    }

    #[test]
    fn cancel_removes_by_id() {
        let mut q = AdmissionQueue::new(4);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        q.push(req(3)).unwrap();
        assert_eq!(q.cancel(2).unwrap().id, 2);
        assert!(q.cancel(2).is_none());
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 3);
    }
}
