//! Request lifecycle: a request enters the admission queue, is prefilled
//! chunk by chunk into KV blocks, decodes one token per engine iteration
//! (possibly swapping out and back in under block pressure), and finishes
//! on length / stop-token / cancellation.

use std::time::Instant;

pub type RequestId = u64;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// 0.0 = greedy argmax.
    pub temperature: f32,
    /// 0 = no top-k restriction.
    pub top_k: usize,
    pub max_tokens: usize,
    pub stop_token: Option<i32>,
    pub seed: u64,
    /// Admission urgency: larger = sooner under the priority scheduling
    /// policy; ignored by FIFO. Never affects sampling, only ordering.
    pub priority: i32,
    /// TTFT SLO: the first token must arrive within this many ms of
    /// enqueue. `None` = no deadline (sorts last under the `edf` policy).
    pub ttft_deadline_ms: Option<u64>,
    /// TPOT SLO: mean inter-token time after the first token must stay
    /// under this many ms. Scheduling ignores it (decode order is fixed);
    /// it only feeds goodput accounting.
    pub tpot_deadline_ms: Option<u64>,
    /// Degraded service under overload: every FFN row of this request is
    /// forced through the folded path (predictor bypassed, no per-neuron
    /// fixes — effectively `--fix-k 0`). Never affects scheduling order,
    /// only the numeric path, so degraded streams stay deterministic.
    pub degrade: bool,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            max_tokens: 64,
            stop_token: None,
            seed: 0,
            priority: 0,
            ttft_deadline_ms: None,
            tpot_deadline_ms: None,
            degrade: false,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// hit max_tokens
    Length,
    /// produced the stop token
    Stop,
    /// ran out of KV positions
    ContextOverflow,
    Cancelled,
}

impl FinishReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::ContextOverflow => "context_overflow",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum RequestState {
    Queued,
    /// `next` = how many prompt tokens are already in the KV cache.
    Prefilling { slot: usize, next: usize },
    Decoding { slot: usize },
    /// Evicted under KV block pressure: the cache sits in the host swap
    /// pool until a [`crate::coordinator::scheduler::Resume`] restores it
    /// bitwise into fresh blocks.
    Preempted,
    Finished(FinishReason),
}

#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
    pub state: RequestState,
    pub generated: Vec<i32>,
    pub enqueued_at: Instant,
    /// When the scheduler moved this request from the queue into a KV
    /// slot; `None` while still queued. Basis for `Completion::queue_ms`.
    pub admitted_at: Option<Instant>,
    pub first_token_at: Option<Instant>,
    pub finished_at: Option<Instant>,
    /// Engine-clock stamps in µs (wall epoch or virtual replay clock,
    /// see [`crate::coordinator::engine_loop::InferenceEngine`]): set at
    /// submit / first sampled token / finish. Basis for deterministic
    /// TTFT/TPOT and for the `edf` policy's absolute deadline.
    pub enqueued_us: u64,
    pub first_token_us: Option<u64>,
    pub finished_us: Option<u64>,
    /// Prompt tokens served from the prefix cache at admission (their
    /// prefill was skipped); 0 when sharing is off or nothing matched.
    pub prefix_hit: usize,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, params: SamplingParams) -> Self {
        assert!(!prompt.is_empty(), "empty prompt");
        Request {
            id,
            prompt,
            params,
            state: RequestState::Queued,
            generated: Vec::new(),
            enqueued_at: Instant::now(),
            admitted_at: None,
            first_token_at: None,
            finished_at: None,
            enqueued_us: 0,
            first_token_us: None,
            finished_us: None,
            prefix_hit: 0,
        }
    }

    /// Absolute TTFT deadline on the engine clock, for EDF ordering.
    /// `u64::MAX` when the request carries no TTFT SLO (sorts last).
    pub fn deadline_us(&self) -> u64 {
        match self.params.ttft_deadline_ms {
            Some(ms) => self.enqueued_us.saturating_add(ms.saturating_mul(1000)),
            None => u64::MAX,
        }
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.state, RequestState::Finished(_))
    }

    /// Total sequence length so far (prompt + generated).
    pub fn seq_len(&self) -> usize {
        self.prompt.len() + self.generated.len()
    }

    pub fn record_token(&mut self, tok: i32) {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        self.generated.push(tok);
    }

    pub fn finish(&mut self, reason: FinishReason) {
        self.state = RequestState::Finished(reason);
        self.finished_at = Some(Instant::now());
    }

    /// Why (if at all) this request must stop after the latest token.
    pub fn stop_reason(&self, max_seq: usize) -> Option<FinishReason> {
        if let Some(stop) = self.params.stop_token {
            if self.generated.last() == Some(&stop) {
                return Some(FinishReason::Stop);
            }
        }
        if self.generated.len() >= self.params.max_tokens {
            return Some(FinishReason::Length);
        }
        if self.seq_len() >= max_seq {
            return Some(FinishReason::ContextOverflow);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(prompt_len: usize, params: SamplingParams) -> Request {
        Request::new(1, vec![7; prompt_len], params)
    }

    #[test]
    fn lifecycle() {
        let mut r = req(4, SamplingParams { max_tokens: 2, ..Default::default() });
        assert_eq!(r.state, RequestState::Queued);
        assert!(!r.is_finished());
        r.record_token(5);
        assert!(r.first_token_at.is_some());
        assert_eq!(r.stop_reason(100), None);
        r.record_token(6);
        assert_eq!(r.stop_reason(100), Some(FinishReason::Length));
        r.finish(FinishReason::Length);
        assert!(r.is_finished());
        assert!(r.finished_at.is_some());
    }

    #[test]
    fn stop_token_wins() {
        let mut r = req(2, SamplingParams {
            max_tokens: 10,
            stop_token: Some(0),
            ..Default::default()
        });
        r.record_token(3);
        assert_eq!(r.stop_reason(100), None);
        r.record_token(0);
        assert_eq!(r.stop_reason(100), Some(FinishReason::Stop));
    }

    #[test]
    fn context_overflow() {
        let mut r = req(6, SamplingParams { max_tokens: 100, ..Default::default() });
        r.record_token(1);
        r.record_token(2);
        assert_eq!(r.stop_reason(8), Some(FinishReason::ContextOverflow));
        assert_eq!(r.stop_reason(9), None);
    }

    #[test]
    fn deadline_from_ttft_slo() {
        let mut r = req(2, SamplingParams { ttft_deadline_ms: Some(50), ..Default::default() });
        r.enqueued_us = 1_000;
        assert_eq!(r.deadline_us(), 51_000);
        let no_slo = req(2, SamplingParams::default());
        assert_eq!(no_slo.deadline_us(), u64::MAX);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn rejects_empty_prompt() {
        let _ = Request::new(1, vec![], SamplingParams::default());
    }
}
