//! Request routing across model replicas/variants, at two service tiers:
//!
//! * [`Router`] — the synchronous single-thread tier: every replica's
//!   engine steps on the caller's thread. This is the only option for
//!   backends whose buffers are not `Send` (PJRT), and the cheapest for
//!   tests.
//! * [`FrontDoor`] — the fault-tolerant tier: each replica's engine
//!   steps on its own worker thread behind a command channel, with a
//!   durable admission journal (replay on crash), `catch_unwind`
//!   failure isolation + health-tracked restart probes, per-replica
//!   backpressure with explicit shed signaling, and a deterministic
//!   fault-injection harness.
//!
//! Both implement [`FrontEnd`], the contract the TCP server loop drives:
//! submit → pump → take replies. Routing policy in both: an explicit
//! variant tag on the request wins; otherwise healthiest-then-least-
//! loaded (ties broken by replica index / round-robin).

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::engine_loop::{Completion, EngineSnapshot, InferenceEngine, SubmitError};
use super::health::{FaultPlan, HealthState, HealthTracker};
use super::journal::{Journal, JournalEntry};
use super::model::StepModel;
use super::queue::{OverloadAction, OverloadPolicy};
use super::request::{RequestId, SamplingParams};

// ---------------------------------------------------------------------------
// The front-end contract (what the TCP serve loop drives)
// ---------------------------------------------------------------------------

/// Outcome of a front-end admission attempt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitOutcome {
    Admitted {
        ticket: u64,
        /// Injected `dropconn` fault: the serve loop must drop the reply
        /// channel, simulating a client that vanished mid-stream.
        drop_reply: bool,
    },
    /// Overloaded — the wire protocol's
    /// `{"ok":false,"err":"overloaded","retry_after_ms":N}`.
    Shed { retry_after_ms: u64 },
    /// Permanently invalid (bad variant, bad prompt); never retryable.
    Rejected(String),
}

/// A finished (or terminally failed) admission handed back to the serve
/// loop, keyed by the front-end ticket it was admitted under.
#[derive(Debug, Clone)]
pub struct FrontReply {
    pub ticket: u64,
    /// Replica instance that served it.
    pub replica: String,
    pub result: Result<Completion, String>,
    /// Replayed from the journal at startup: no live client is waiting.
    pub recovered: bool,
}

/// Front-door robustness counters (zeros for the synchronous tier where
/// the failure modes cannot occur).
#[derive(Debug, Clone, Default)]
pub struct FrontDoorStats {
    pub submitted: u64,
    pub completed: u64,
    /// Admissions refused with `overloaded` + `retry_after_ms`.
    pub shed: u64,
    /// Admitted requests that carried a client retry marker.
    pub retries_honored: u64,
    /// In-flight requests re-dispatched after their replica died.
    pub replays: u64,
    pub replica_failures: u64,
    pub replica_restarts: u64,
    /// Journaled admissions replayed at startup.
    pub recovered: u64,
    /// Completions whose client had disconnected.
    pub replies_dropped: u64,
    pub journal_appends: u64,
    pub journal_bytes: u64,
    pub journal_errors: u64,
}

/// Per-replica live view for the `stats` op.
#[derive(Debug, Clone)]
pub struct ReplicaView {
    pub name: String,
    /// Health-state machine name: healthy|degraded|quarantined.
    pub health: &'static str,
    pub alive: bool,
    /// Front-door-tracked in-flight admissions on this replica.
    pub inflight: usize,
    pub snapshot: EngineSnapshot,
}

#[derive(Debug, Clone)]
pub struct FrontSnapshot {
    pub front: FrontDoorStats,
    pub replicas: Vec<ReplicaView>,
}

/// What the serve loop needs from a front-end: admission with explicit
/// shed/reject outcomes, a pump that advances work (blocking at most
/// `max_wait` when idle), and completed replies.
pub trait FrontEnd {
    fn submit_front(
        &mut self,
        variant: Option<&str>,
        prompt: Vec<i32>,
        params: SamplingParams,
        retry: bool,
    ) -> SubmitOutcome;

    /// Advance work. Returns whether anything progressed; may block up
    /// to `max_wait` when there is nothing to do.
    fn pump(&mut self, max_wait: Duration) -> Result<bool>;

    fn take_replies(&mut self) -> Vec<FrontReply>;

    fn front_snapshot(&mut self) -> FrontSnapshot;

    /// A reply could not be delivered (client gone): account it.
    fn note_reply_dropped(&mut self) {}
}

// ---------------------------------------------------------------------------
// Synchronous tier
// ---------------------------------------------------------------------------

pub struct Replica<M: StepModel> {
    pub name: String,
    pub engine: InferenceEngine<M>,
}

pub struct Router<M: StepModel> {
    replicas: Vec<Replica<M>>,
    rr: usize,
    pub routed: u64,
    next_ticket: u64,
    /// (replica, engine request id) -> front-end ticket.
    tickets: HashMap<(usize, RequestId), u64>,
    replies: VecDeque<FrontReply>,
    fstats: FrontDoorStats,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteTicket {
    pub replica: usize,
    pub request: RequestId,
}

impl<M: StepModel> Router<M> {
    pub fn new(replicas: Vec<(String, InferenceEngine<M>)>) -> Self {
        assert!(!replicas.is_empty());
        Router {
            replicas: replicas
                .into_iter()
                .map(|(name, engine)| Replica { name, engine })
                .collect(),
            rr: 0,
            routed: 0,
            next_ticket: 1,
            tickets: HashMap::new(),
            replies: VecDeque::new(),
            fstats: FrontDoorStats::default(),
        }
    }

    pub fn replica_names(&self) -> Vec<&str> {
        self.replicas.iter().map(|r| r.name.as_str()).collect()
    }

    pub fn replica(&mut self, idx: usize) -> &mut Replica<M> {
        &mut self.replicas[idx]
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    fn pick(&mut self, variant: Option<&str>) -> Result<usize> {
        if let Some(v) = variant {
            return self
                .replicas
                .iter()
                .position(|r| r.name == v)
                .ok_or_else(|| anyhow!("no replica for variant {v:?}"));
        }
        // least pressure, round-robin tie-break
        let n = self.replicas.len();
        let mut best = None;
        for off in 0..n {
            let i = (self.rr + off) % n;
            let p = self.replicas[i].engine.queue_pressure();
            match best {
                None => best = Some((i, p)),
                Some((_, bp)) if p < bp - 1e-12 => best = Some((i, p)),
                _ => {}
            }
        }
        let (idx, _) = best.expect("non-empty replicas");
        self.rr = (idx + 1) % n;
        Ok(idx)
    }

    pub fn submit(
        &mut self,
        variant: Option<&str>,
        prompt: Vec<i32>,
        params: SamplingParams,
    ) -> Result<RouteTicket> {
        let idx = self.pick(variant)?;
        let id = self.replicas[idx].engine.submit(prompt, params)?;
        self.routed += 1;
        Ok(RouteTicket { replica: idx, request: id })
    }

    /// One scheduler iteration on every replica. Returns true if any
    /// replica did work.
    pub fn step_all(&mut self) -> Result<bool> {
        let mut busy = false;
        for r in &mut self.replicas {
            if !r.engine.is_idle() {
                busy |= r.engine.step()?.did_work();
            }
        }
        Ok(busy)
    }

    /// Per-replica live stats (the server's `stats` op).
    pub fn stats_snapshot(&self) -> Vec<(String, EngineSnapshot)> {
        self.replicas
            .iter()
            .map(|r| (r.name.clone(), r.engine.snapshot()))
            .collect()
    }

    pub fn run_to_completion(&mut self) -> Result<Vec<(String, Completion)>> {
        let mut out = Vec::new();
        loop {
            let busy = self.step_all()?;
            for r in &mut self.replicas {
                for c in r.engine.take_completions() {
                    out.push((r.name.clone(), c));
                }
            }
            if !busy && self.replicas.iter().all(|r| r.engine.is_idle()) {
                break;
            }
        }
        Ok(out)
    }
}

impl<M: StepModel> FrontEnd for Router<M> {
    fn submit_front(
        &mut self,
        variant: Option<&str>,
        prompt: Vec<i32>,
        params: SamplingParams,
        retry: bool,
    ) -> SubmitOutcome {
        let idx = match self.pick(variant) {
            Ok(i) => i,
            Err(e) => return SubmitOutcome::Rejected(e.to_string()),
        };
        match self.replicas[idx].engine.try_submit(prompt, params) {
            Ok(id) => {
                self.routed += 1;
                let ticket = self.next_ticket;
                self.next_ticket += 1;
                self.tickets.insert((idx, id), ticket);
                self.fstats.submitted += 1;
                if retry {
                    self.fstats.retries_honored += 1;
                }
                SubmitOutcome::Admitted { ticket, drop_reply: false }
            }
            Err(SubmitError::Backpressure { queue_depth, .. }) => {
                self.fstats.shed += 1;
                SubmitOutcome::Shed {
                    retry_after_ms: (10 + 2 * queue_depth as u64).min(500),
                }
            }
            Err(SubmitError::Invalid(msg)) => SubmitOutcome::Rejected(msg),
        }
    }

    fn pump(&mut self, max_wait: Duration) -> Result<bool> {
        let busy = self.step_all()?;
        let mut any = false;
        for i in 0..self.replicas.len() {
            let name = self.replicas[i].name.clone();
            for c in self.replicas[i].engine.take_completions() {
                let ticket = self.tickets.remove(&(i, c.id)).unwrap_or(0);
                self.fstats.completed += 1;
                self.replies.push_back(FrontReply {
                    ticket,
                    replica: name.clone(),
                    result: Ok(c),
                    recovered: false,
                });
                any = true;
            }
        }
        if !busy && !any && !max_wait.is_zero() {
            std::thread::sleep(max_wait.min(Duration::from_millis(1)));
        }
        Ok(busy || any)
    }

    fn take_replies(&mut self) -> Vec<FrontReply> {
        self.replies.drain(..).collect()
    }

    fn front_snapshot(&mut self) -> FrontSnapshot {
        FrontSnapshot {
            front: self.fstats.clone(),
            replicas: self
                .replicas
                .iter()
                .map(|r| {
                    let snapshot = r.engine.snapshot();
                    ReplicaView {
                        name: r.name.clone(),
                        health: HealthState::Healthy.name(),
                        alive: true,
                        inflight: snapshot.queue_depth
                            + snapshot.active_slots
                            + snapshot.inflight_prefills,
                        snapshot,
                    }
                })
                .collect(),
        }
    }

    fn note_reply_dropped(&mut self) {
        self.fstats.replies_dropped += 1;
    }
}

// ---------------------------------------------------------------------------
// Fault-tolerant tier
// ---------------------------------------------------------------------------

/// Builds a fresh engine for a replica — called at spawn and on every
/// restart probe, so a factory failure is a restartable fault, not a
/// crash.
pub type ReplicaFactory<M> = Box<dyn FnMut() -> Result<InferenceEngine<M>> + Send>;

#[derive(Debug, Clone)]
pub struct FrontDoorConfig {
    /// Per-replica in-flight admission bound; beyond it, submissions are
    /// shed with `retry_after_ms` (keep it at or below the engines' own
    /// `queue_capacity` so the front door sheds before the engines do).
    pub queue_cap: usize,
    /// Admission journal path (None = durability off).
    pub journal: Option<PathBuf>,
    pub fault_plan: FaultPlan,
    /// Restart-probe backoff: `probe_base * 2^(failures-1)`, capped at
    /// `probe_max`.
    pub probe_base: Duration,
    pub probe_max: Duration,
    /// Overload admission ladder: degrade then shed the lowest tiers as
    /// the chosen replica's queue pressure rises. Disabled by default.
    /// Applied *before* the admission is journaled, so a crash replay
    /// re-runs the same degraded request bitwise.
    pub overload: OverloadPolicy,
}

impl Default for FrontDoorConfig {
    fn default() -> Self {
        FrontDoorConfig {
            queue_cap: 64,
            journal: None,
            fault_plan: FaultPlan::default(),
            probe_base: Duration::from_millis(25),
            probe_max: Duration::from_secs(2),
            overload: OverloadPolicy::default(),
        }
    }
}

enum ReplicaCmd {
    Submit { ticket: u64, prompt: Vec<i32>, params: SamplingParams },
}

enum ReplicaEvent {
    Done { replica: usize, generation: u64, ticket: u64, completion: Completion },
    Rejected {
        replica: usize,
        generation: u64,
        ticket: u64,
        backpressure: bool,
        error: String,
    },
    Died { replica: usize, generation: u64, reason: String },
}

struct ReplicaSlot<M: StepModel> {
    name: String,
    /// Base variant (instance names get `-k` suffixes when replicated).
    variant: String,
    factory: ReplicaFactory<M>,
    cmd: Option<Sender<ReplicaCmd>>,
    handle: Option<JoinHandle<()>>,
    health: HealthTracker,
    /// Incarnation counter: events from dead generations are ignored.
    generation: u64,
    /// Front-door-tracked in-flight admissions (dispatched, not done).
    inflight: usize,
    /// Published by the worker after every step.
    snapshot: Arc<Mutex<EngineSnapshot>>,
}

struct Inflight {
    prompt: Vec<i32>,
    params: SamplingParams,
    variant: Option<String>,
    /// (replica, generation) currently executing it; None while parked.
    assigned: Option<(usize, u64)>,
    recovered: bool,
}

/// The fault-tolerant front door: owns N replicas on worker threads.
///
/// Every admission is journaled (when configured) and tracked in an
/// in-flight table until its completion arrives. A worker that panics or
/// errors mid-step dies as a *replica*, not a process: its in-flight
/// admissions replay onto survivors, its health degrades, and backoff-
/// paced probes restart it from the factory. Admissions beyond
/// `queue_cap` per replica shed with an explicit `retry_after_ms`.
pub struct FrontDoor<M: StepModel> {
    slots: Vec<ReplicaSlot<M>>,
    events_tx: Sender<ReplicaEvent>,
    events_rx: Receiver<ReplicaEvent>,
    inflight: HashMap<u64, Inflight>,
    /// Admitted tickets awaiting a replica with capacity, FIFO.
    parked: VecDeque<u64>,
    replies: VecDeque<FrontReply>,
    next_ticket: u64,
    queue_cap: usize,
    overload: OverloadPolicy,
    journal: Option<Journal>,
    faults: FaultPlan,
    /// Admissions accepted so far (the `dropconn@N` fault index).
    admits_seen: u64,
    probe_base: Duration,
    probe_max: Duration,
    pub stats: FrontDoorStats,
}

impl<M: StepModel + Send + 'static> FrontDoor<M> {
    /// Build and start the replicas. `replicas` pairs a *variant* name
    /// with an engine factory; repeated variants become distinct
    /// instances (`name-0`, `name-1`, ...) sharing the variant for
    /// pinned routing. An existing journal at `cfg.journal` is recovered
    /// first: its un-completed admissions re-enter the dispatch queue.
    pub fn new(replicas: Vec<(String, ReplicaFactory<M>)>, cfg: FrontDoorConfig) -> Result<Self> {
        assert!(!replicas.is_empty());
        let mut counts: HashMap<String, usize> = HashMap::new();
        for (v, _) in &replicas {
            *counts.entry(v.clone()).or_insert(0) += 1;
        }
        let mut seen: HashMap<String, usize> = HashMap::new();
        let (events_tx, events_rx) = channel();
        let slots = replicas
            .into_iter()
            .map(|(variant, factory)| {
                let k = seen.entry(variant.clone()).or_insert(0);
                let name = if counts[&variant] == 1 {
                    variant.clone()
                } else {
                    let n = format!("{variant}-{k}");
                    *k += 1;
                    n
                };
                ReplicaSlot {
                    name,
                    variant,
                    factory,
                    cmd: None,
                    handle: None,
                    health: HealthTracker::new(cfg.probe_base, cfg.probe_max),
                    generation: 0,
                    inflight: 0,
                    snapshot: Arc::new(Mutex::new(empty_snapshot())),
                }
            })
            .collect();
        let mut front = FrontDoor {
            slots,
            events_tx,
            events_rx,
            inflight: HashMap::new(),
            parked: VecDeque::new(),
            replies: VecDeque::new(),
            next_ticket: 1,
            queue_cap: cfg.queue_cap.max(1),
            overload: cfg.overload,
            journal: None,
            faults: cfg.fault_plan,
            admits_seen: 0,
            probe_base: cfg.probe_base,
            probe_max: cfg.probe_max,
            stats: FrontDoorStats::default(),
        };
        if let Some(path) = &cfg.journal {
            let mut pending = Vec::new();
            if path.exists() {
                let (p, next_ticket, report) = Journal::recover(path)?;
                front.next_ticket = next_ticket.max(1);
                if report.admits > 0 {
                    eprintln!(
                        "[front] journal {}: {} admits / {} dones, replaying {}{}",
                        path.display(),
                        report.admits,
                        report.dones,
                        p.len(),
                        if report.truncated_tail { " (truncated tail)" } else { "" },
                    );
                }
                pending = p;
            }
            let mut journal = Journal::open(path)?;
            journal.inject_fail_appends(front.faults.take_journal_errors());
            front.journal = Some(journal);
            for e in pending {
                front.stats.recovered += 1;
                front.inflight.insert(
                    e.ticket,
                    Inflight {
                        prompt: e.prompt,
                        params: e.params,
                        variant: e.variant,
                        assigned: None,
                        recovered: true,
                    },
                );
                front.parked.push_back(e.ticket);
            }
        }
        for idx in 0..front.slots.len() {
            front.spawn_replica(idx)?;
        }
        front.pump_parked();
        Ok(front)
    }

    /// Admitted-but-not-finished requests (in flight + parked).
    pub fn pending(&self) -> usize {
        self.inflight.len()
    }

    pub fn replica_names(&self) -> Vec<&str> {
        self.slots.iter().map(|s| s.name.as_str()).collect()
    }

    pub fn replica_health(&self, idx: usize) -> (HealthState, bool) {
        let h = &self.slots[idx].health;
        (h.state(), h.is_alive())
    }

    /// Pump until every admitted request has a reply, or fail after
    /// `deadline` (tests and benches; replica restarts happen inside).
    pub fn drain(&mut self, deadline: Duration) -> Result<Vec<FrontReply>> {
        let t0 = Instant::now();
        let mut out = Vec::new();
        loop {
            out.extend(self.take_replies());
            if self.inflight.is_empty() {
                return Ok(out);
            }
            if t0.elapsed() > deadline {
                return Err(anyhow!(
                    "drain deadline exceeded with {} requests still pending",
                    self.inflight.len()
                ));
            }
            self.pump(Duration::from_millis(1))?;
        }
    }

    fn spawn_replica(&mut self, idx: usize) -> Result<()> {
        let step_faults = self.faults.take_step_faults(idx);
        let slot = &mut self.slots[idx];
        let mut engine = (slot.factory)()?;
        for (step, fault) in step_faults {
            engine.inject_step_fault(step, fault);
        }
        *slot.snapshot.lock().unwrap() = engine.snapshot();
        let (cmd_tx, cmd_rx) = channel();
        let events = self.events_tx.clone();
        let snapshot = Arc::clone(&slot.snapshot);
        let generation = slot.generation;
        let handle = std::thread::Builder::new()
            .name(format!("tardis-replica-{}", slot.name))
            .spawn(move || worker_loop(engine, cmd_rx, events, snapshot, idx, generation))?;
        slot.cmd = Some(cmd_tx);
        slot.handle = Some(handle);
        Ok(())
    }

    /// Healthiest-then-least-loaded alive replica with capacity, matching
    /// the variant pin when present.
    fn best_slot(&self, variant: Option<&str>) -> Option<usize> {
        let mut best: Option<(u8, usize, usize)> = None;
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(v) = variant {
                if s.variant != v {
                    continue;
                }
            }
            if !s.health.is_alive() || s.cmd.is_none() || s.inflight >= self.queue_cap {
                continue;
            }
            let key = (s.health.state().rank(), s.inflight, i);
            if best.is_none_or(|b| key < b) {
                best = Some(key);
            }
        }
        best.map(|(_, _, i)| i)
    }

    fn retry_after_ms(&self, variant: Option<&str>) -> u64 {
        let now = Instant::now();
        let mut any_alive = false;
        let mut min_inflight = usize::MAX;
        let mut min_backoff: Option<Duration> = None;
        for s in &self.slots {
            if let Some(v) = variant {
                if s.variant != v {
                    continue;
                }
            }
            if s.health.is_alive() {
                any_alive = true;
                min_inflight = min_inflight.min(s.inflight);
            } else if let Some(b) = s.health.backoff_remaining(now) {
                min_backoff = Some(min_backoff.map_or(b, |m| m.min(b)));
            }
        }
        if any_alive {
            (10 + 2 * min_inflight as u64).min(500)
        } else {
            min_backoff.map_or(50, |d| d.as_millis() as u64 + 10).min(1000)
        }
    }

    fn dispatch(&mut self, ticket: u64, idx: usize) -> bool {
        let (prompt, params) = match self.inflight.get(&ticket) {
            Some(inf) => (inf.prompt.clone(), inf.params),
            None => return true, // already resolved; nothing to send
        };
        let generation = self.slots[idx].generation;
        let sent = self.slots[idx]
            .cmd
            .as_ref()
            .is_some_and(|tx| tx.send(ReplicaCmd::Submit { ticket, prompt, params }).is_ok());
        if sent {
            self.slots[idx].inflight += 1;
            if let Some(inf) = self.inflight.get_mut(&ticket) {
                inf.assigned = Some((idx, generation));
            }
        }
        sent
    }

    fn pump_parked(&mut self) -> bool {
        let mut progressed = false;
        let mut requeue = VecDeque::new();
        while let Some(ticket) = self.parked.pop_front() {
            let Some(inf) = self.inflight.get(&ticket) else { continue };
            let variant = inf.variant.clone();
            if let Some(v) = &variant {
                if !self.slots.iter().any(|s| &s.variant == v) {
                    // A recovered admission pinned to a variant this run
                    // does not serve: fail it rather than wedge drain.
                    let inf = self.inflight.remove(&ticket).unwrap();
                    self.journal_done(ticket, "rejected");
                    self.replies.push_back(FrontReply {
                        ticket,
                        replica: v.clone(),
                        result: Err(format!("no replica for variant {v:?}")),
                        recovered: inf.recovered,
                    });
                    progressed = true;
                    continue;
                }
            }
            match self.best_slot(variant.as_deref()) {
                Some(idx) if self.dispatch(ticket, idx) => progressed = true,
                _ => requeue.push_back(ticket),
            }
        }
        self.parked = requeue;
        progressed
    }

    fn journal_done(&mut self, ticket: u64, reason: &str) {
        if let Some(j) = &mut self.journal {
            let _ = j.append_done(ticket, reason);
        }
    }

    fn on_event(&mut self, ev: ReplicaEvent) {
        match ev {
            ReplicaEvent::Done { replica, generation, ticket, completion } => {
                let Some(inf) = self.inflight.remove(&ticket) else { return };
                if inf.assigned == Some((replica, generation)) {
                    let s = &mut self.slots[replica];
                    s.inflight = s.inflight.saturating_sub(1);
                }
                self.slots[replica].health.on_success();
                self.stats.completed += 1;
                self.journal_done(ticket, completion.reason.as_str());
                self.replies.push_back(FrontReply {
                    ticket,
                    replica: self.slots[replica].name.clone(),
                    result: Ok(completion),
                    recovered: inf.recovered,
                });
            }
            ReplicaEvent::Rejected { replica, generation, ticket, backpressure, error } => {
                let assigned = self.inflight.get(&ticket).map(|i| i.assigned);
                let Some(assigned) = assigned else { return };
                if assigned == Some((replica, generation)) {
                    let s = &mut self.slots[replica];
                    s.inflight = s.inflight.saturating_sub(1);
                    if let Some(inf) = self.inflight.get_mut(&ticket) {
                        inf.assigned = None;
                    }
                }
                if backpressure {
                    // The engine's own queue is tighter than our cap:
                    // park and retry on the next capacity change.
                    self.parked.push_back(ticket);
                } else {
                    let inf = self.inflight.remove(&ticket).unwrap();
                    self.journal_done(ticket, "rejected");
                    self.replies.push_back(FrontReply {
                        ticket,
                        replica: self.slots[replica].name.clone(),
                        result: Err(error),
                        recovered: inf.recovered,
                    });
                }
            }
            ReplicaEvent::Died { replica, generation, reason } => {
                if self.slots[replica].generation != generation {
                    return;
                }
                eprintln!("[front] replica {} died: {reason}", self.slots[replica].name);
                self.stats.replica_failures += 1;
                let slot = &mut self.slots[replica];
                slot.cmd = None;
                if let Some(h) = slot.handle.take() {
                    let _ = h.join();
                }
                slot.health.on_failure(Instant::now());
                slot.inflight = 0;
                // Replay: everything the dead incarnation held goes back
                // to the dispatch queue, in ticket order.
                let mut orphans: Vec<u64> = self
                    .inflight
                    .iter()
                    .filter(|(_, inf)| inf.assigned == Some((replica, generation)))
                    .map(|(&t, _)| t)
                    .collect();
                orphans.sort_unstable();
                for t in orphans {
                    if let Some(inf) = self.inflight.get_mut(&t) {
                        inf.assigned = None;
                    }
                    self.stats.replays += 1;
                    self.parked.push_back(t);
                }
            }
        }
    }

    fn run_probes(&mut self) -> bool {
        let now = Instant::now();
        let mut progressed = false;
        for idx in 0..self.slots.len() {
            if !self.slots[idx].health.probe_due(now) {
                continue;
            }
            self.slots[idx].generation += 1;
            self.slots[idx].health.on_restart();
            self.stats.replica_restarts += 1;
            match self.spawn_replica(idx) {
                Ok(()) => progressed = true,
                Err(e) => {
                    eprintln!(
                        "[front] replica {} restart failed: {e}",
                        self.slots[idx].name
                    );
                    self.slots[idx].cmd = None;
                    self.slots[idx].health.on_failure(Instant::now());
                }
            }
        }
        progressed
    }
}

impl<M: StepModel + Send + 'static> FrontEnd for FrontDoor<M> {
    fn submit_front(
        &mut self,
        variant: Option<&str>,
        prompt: Vec<i32>,
        mut params: SamplingParams,
        retry: bool,
    ) -> SubmitOutcome {
        if let Some(v) = variant {
            if !self.slots.iter().any(|s| s.variant == v) {
                return SubmitOutcome::Rejected(format!("no replica for variant {v:?}"));
            }
        }
        let Some(idx) = self.best_slot(variant) else {
            self.stats.shed += 1;
            return SubmitOutcome::Shed { retry_after_ms: self.retry_after_ms(variant) };
        };
        // Overload ladder: decided before the journal append so a crash
        // replay re-admits the identical (possibly degraded) request.
        if self.overload.enabled() {
            let pressure = self.slots[idx].inflight as f64 / self.queue_cap as f64;
            match self.overload.action(pressure, params.priority) {
                OverloadAction::Admit => {}
                OverloadAction::Degrade => params.degrade = true,
                OverloadAction::Shed => {
                    self.stats.shed += 1;
                    return SubmitOutcome::Shed { retry_after_ms: self.retry_after_ms(variant) };
                }
            }
        }
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        if self.journal.is_some() {
            let entry = JournalEntry {
                ticket,
                prompt: prompt.clone(),
                params,
                variant: variant.map(str::to_string),
            };
            if let Some(j) = &mut self.journal {
                let _ = j.append_admit(&entry);
            }
        }
        self.stats.submitted += 1;
        if retry {
            self.stats.retries_honored += 1;
        }
        let drop_reply = self.faults.take_drop_conn(self.admits_seen);
        self.admits_seen += 1;
        self.inflight.insert(
            ticket,
            Inflight {
                prompt,
                params,
                variant: variant.map(str::to_string),
                assigned: None,
                recovered: false,
            },
        );
        if !self.dispatch(ticket, idx) {
            self.parked.push_back(ticket);
        }
        SubmitOutcome::Admitted { ticket, drop_reply }
    }

    fn pump(&mut self, max_wait: Duration) -> Result<bool> {
        let mut progressed = false;
        let first = if max_wait.is_zero() {
            self.events_rx.try_recv().ok()
        } else {
            match self.events_rx.recv_timeout(max_wait) {
                Ok(ev) => Some(ev),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => None,
            }
        };
        if let Some(ev) = first {
            self.on_event(ev);
            progressed = true;
        }
        while let Ok(ev) = self.events_rx.try_recv() {
            self.on_event(ev);
            progressed = true;
        }
        progressed |= self.run_probes();
        progressed |= self.pump_parked();
        Ok(progressed)
    }

    fn take_replies(&mut self) -> Vec<FrontReply> {
        self.replies.drain(..).collect()
    }

    fn front_snapshot(&mut self) -> FrontSnapshot {
        let mut front = self.stats.clone();
        if let Some(j) = &self.journal {
            front.journal_appends = j.stats.appends;
            front.journal_bytes = j.stats.bytes;
            front.journal_errors = j.stats.errors;
        }
        FrontSnapshot {
            front,
            replicas: self
                .slots
                .iter()
                .map(|s| ReplicaView {
                    name: s.name.clone(),
                    health: s.health.state().name(),
                    alive: s.health.is_alive(),
                    inflight: s.inflight,
                    snapshot: s.snapshot.lock().unwrap().clone(),
                })
                .collect(),
        }
    }

    fn note_reply_dropped(&mut self) {
        self.stats.replies_dropped += 1;
    }
}

impl<M: StepModel> Drop for FrontDoor<M> {
    fn drop(&mut self) {
        for s in &mut self.slots {
            s.cmd = None; // disconnect: workers drain and exit
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

/// The per-replica worker: drains the command channel into the engine,
/// steps it under `catch_unwind`, and streams completions back. Any
/// panic or step error kills this incarnation only — the front door
/// replays its in-flight work and probes for restart.
fn worker_loop<M: StepModel>(
    mut engine: InferenceEngine<M>,
    cmd_rx: Receiver<ReplicaCmd>,
    events: Sender<ReplicaEvent>,
    snapshot: Arc<Mutex<EngineSnapshot>>,
    replica: usize,
    generation: u64,
) {
    // engine request id -> front-door ticket, for this incarnation.
    let mut tickets: HashMap<RequestId, u64> = HashMap::new();
    let mut handle_cmd = |engine: &mut InferenceEngine<M>,
                          tickets: &mut HashMap<RequestId, u64>,
                          cmd: ReplicaCmd| {
        let ReplicaCmd::Submit { ticket, prompt, params } = cmd;
        match engine.try_submit(prompt, params) {
            Ok(id) => {
                tickets.insert(id, ticket);
            }
            Err(e) => {
                let backpressure = matches!(e, SubmitError::Backpressure { .. });
                let _ = events.send(ReplicaEvent::Rejected {
                    replica,
                    generation,
                    ticket,
                    backpressure,
                    error: e.to_string(),
                });
            }
        }
    };
    loop {
        let mut disconnected = false;
        if engine.is_idle() {
            match cmd_rx.recv_timeout(Duration::from_millis(5)) {
                Ok(cmd) => handle_cmd(&mut engine, &mut tickets, cmd),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
        }
        loop {
            match cmd_rx.try_recv() {
                Ok(cmd) => handle_cmd(&mut engine, &mut tickets, cmd),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if engine.is_idle() {
            if disconnected {
                return;
            }
            continue;
        }
        match catch_unwind(AssertUnwindSafe(|| engine.step())) {
            Ok(Ok(_)) => {}
            Ok(Err(e)) => {
                let _ = events.send(ReplicaEvent::Died {
                    replica,
                    generation,
                    reason: format!("step error: {e}"),
                });
                return;
            }
            Err(panic) => {
                let _ = events.send(ReplicaEvent::Died {
                    replica,
                    generation,
                    reason: format!("panic: {}", panic_message(&panic)),
                });
                return;
            }
        }
        for c in engine.take_completions() {
            if let Some(ticket) = tickets.remove(&c.id) {
                let _ = events.send(ReplicaEvent::Done {
                    replica,
                    generation,
                    ticket,
                    completion: c,
                });
            }
        }
        *snapshot.lock().unwrap() = engine.snapshot();
    }
}

fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Placeholder published before a replica's first step.
fn empty_snapshot() -> EngineSnapshot {
    EngineSnapshot {
        policy: "unstarted",
        queue_depth: 0,
        queue_pressure: 0.0,
        active_slots: 0,
        inflight_prefills: 0,
        slots_total: 0,
        kv_blocks_total: 0,
        kv_blocks_used: 0,
        block_utilization: 0.0,
        swapped: 0,
        preemptions: 0,
        mixed_step_ratio: None,
        mean_occupancy: 0.0,
        tokens_generated: 0,
        admitted: 0,
        finished: 0,
        iterations: 0,
        ffn_fallback_rate: None,
        ffn_last_step_fallback_rate: None,
        prefix_cached_blocks: 0,
        prefix_evictable_blocks: 0,
        prefix_hit_tokens: 0,
        prefix_shared_blocks: 0,
        cow_copies: 0,
        prefix_evictions: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine_loop::EngineConfig;
    use crate::coordinator::model::MockModel;

    fn router(n: usize) -> Router<MockModel> {
        Router::new(
            (0..n)
                .map(|i| {
                    (
                        format!("v{i}"),
                        InferenceEngine::new(
                            MockModel::new(2, 64, 16, vec![4, 8]),
                            EngineConfig::default(),
                        ),
                    )
                })
                .collect(),
        )
    }

    #[test]
    fn explicit_variant_routing() {
        let mut r = router(3);
        let t = r
            .submit(Some("v1"), vec![1, 2], SamplingParams::default())
            .unwrap();
        assert_eq!(t.replica, 1);
        assert!(r.submit(Some("nope"), vec![1], SamplingParams::default()).is_err());
    }

    #[test]
    fn least_loaded_spreads() {
        let mut r = router(2);
        let mut counts = [0usize; 2];
        for i in 0..8 {
            let params = SamplingParams { max_tokens: 2, ..Default::default() };
            let t = r.submit(None, vec![1 + i], params).unwrap();
            counts[t.replica] += 1;
        }
        assert!(counts[0] >= 3 && counts[1] >= 3, "unbalanced {counts:?}");
    }

    #[test]
    fn stats_snapshot_covers_every_replica() {
        let mut r = router(2);
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        r.submit(Some("v1"), vec![1, 2], params).unwrap();
        let stats = r.stats_snapshot();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].0, "v0");
        assert_eq!(stats[1].0, "v1");
        assert_eq!(stats[1].1.queue_depth, 1);
        assert_eq!(stats[0].1.queue_depth, 0);
        r.run_to_completion().unwrap();
        let stats = r.stats_snapshot();
        assert_eq!(stats[1].1.finished, 1);
        assert_eq!(stats[1].1.queue_depth, 0);
    }

    #[test]
    fn run_to_completion_drains_all() {
        let mut r = router(2);
        for i in 0..6 {
            let params = SamplingParams { max_tokens: 3, ..Default::default() };
            r.submit(None, vec![1 + i, 2], params).unwrap();
        }
        let done = r.run_to_completion().unwrap();
        assert_eq!(done.len(), 6);
        assert!(done.iter().all(|(_, c)| c.tokens.len() == 3));
    }

    #[test]
    fn router_front_end_sheds_on_backpressure() {
        let mut r = Router::new(vec![(
            "v0".to_string(),
            InferenceEngine::new(
                MockModel::new(2, 64, 16, vec![4, 8]),
                EngineConfig { queue_capacity: 2, ..Default::default() },
            ),
        )]);
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        for _ in 0..2 {
            let out = r.submit_front(None, vec![1, 2], params, false);
            assert!(matches!(out, SubmitOutcome::Admitted { .. }), "{out:?}");
        }
        match r.submit_front(None, vec![1, 2], params, false) {
            SubmitOutcome::Shed { retry_after_ms } => assert!(retry_after_ms > 0),
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(r.front_snapshot().front.shed, 1);
    }

    fn mock_factory(slow_us: u64) -> ReplicaFactory<MockModel> {
        Box::new(move || {
            let mut model = MockModel::new(4, 128, 256, vec![4, 16]);
            model.spin_per_call = Duration::from_micros(slow_us);
            Ok(InferenceEngine::new(model, EngineConfig::default()))
        })
    }

    #[test]
    fn front_door_serves_and_completes() {
        let mut front = FrontDoor::new(
            vec![
                ("mock".to_string(), mock_factory(0)),
                ("mock".to_string(), mock_factory(0)),
            ],
            FrontDoorConfig::default(),
        )
        .unwrap();
        assert_eq!(front.replica_names(), vec!["mock-0", "mock-1"]);
        let params = SamplingParams { max_tokens: 4, ..Default::default() };
        for i in 0..6 {
            let out = front.submit_front(None, vec![1 + i], params, false);
            assert!(matches!(out, SubmitOutcome::Admitted { .. }), "{out:?}");
        }
        let replies = front.drain(Duration::from_secs(10)).unwrap();
        assert_eq!(replies.len(), 6);
        assert!(replies.iter().all(|r| r.result.is_ok()));
        let snap = front.front_snapshot();
        assert_eq!(snap.front.submitted, 6);
        assert_eq!(snap.front.completed, 6);
        assert_eq!(snap.front.shed, 0);
        assert_eq!(snap.replicas.len(), 2);
    }

    #[test]
    fn front_door_sheds_past_queue_cap() {
        let mut front = FrontDoor::new(
            vec![("mock".to_string(), mock_factory(1000))],
            FrontDoorConfig { queue_cap: 2, ..Default::default() },
        )
        .unwrap();
        let params = SamplingParams { max_tokens: 8, ..Default::default() };
        for _ in 0..2 {
            let out = front.submit_front(None, vec![1, 2, 3], params, false);
            assert!(matches!(out, SubmitOutcome::Admitted { .. }), "{out:?}");
        }
        // No pump between submits: both slots are still in flight, so
        // the third submission sheds deterministically.
        match front.submit_front(None, vec![1, 2, 3], params, true) {
            SubmitOutcome::Shed { retry_after_ms } => assert!(retry_after_ms >= 10),
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(front.stats.shed, 1);
        let replies = front.drain(Duration::from_secs(10)).unwrap();
        assert_eq!(replies.len(), 2);
    }

    #[test]
    fn front_door_overload_ladder_degrades_then_sheds() {
        let mut front = FrontDoor::new(
            vec![("mock".to_string(), mock_factory(0))],
            FrontDoorConfig {
                queue_cap: 4,
                overload: OverloadPolicy { degrade_at: 0.25, shed_at: 0.75, tier_max: 0 },
                ..Default::default()
            },
        )
        .unwrap();
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        // No pump between submits: inflight only decays in `pump`, so
        // the pressure seen by each admission is deterministic.
        // pressure 0/4: full quality
        let a = front.submit_front(None, vec![1, 2], params, false);
        let SubmitOutcome::Admitted { ticket: t_full, .. } = a else {
            panic!("expected admit, got {a:?}")
        };
        // pressure 1/4 >= degrade_at: lowest tier degrades
        let b = front.submit_front(None, vec![1, 2], params, false);
        let SubmitOutcome::Admitted { ticket: t_degraded, .. } = b else {
            panic!("expected admit, got {b:?}")
        };
        // higher tier rides above tier_max: full quality at any pressure
        let hi = SamplingParams { priority: 1, ..params };
        let c = front.submit_front(None, vec![1, 2], hi, false);
        let SubmitOutcome::Admitted { ticket: t_hi, .. } = c else {
            panic!("expected admit, got {c:?}")
        };
        // pressure 3/4 >= shed_at: lowest tier is refused outright
        match front.submit_front(None, vec![1, 2], params, false) {
            SubmitOutcome::Shed { retry_after_ms } => assert!(retry_after_ms > 0),
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(front.stats.shed, 1);
        let replies = front.drain(Duration::from_secs(10)).unwrap();
        assert_eq!(replies.len(), 3);
        let degraded_of = |t: u64| {
            replies
                .iter()
                .find(|r| r.ticket == t)
                .unwrap()
                .result
                .as_ref()
                .unwrap()
                .degraded
        };
        assert!(!degraded_of(t_full), "first admit must be full quality");
        assert!(degraded_of(t_degraded), "second admit must be degraded");
        assert!(!degraded_of(t_hi), "high tier must never degrade");
    }

    #[test]
    fn front_door_pins_variants_and_rejects_unknown() {
        let mut front = FrontDoor::new(
            vec![
                ("a".to_string(), mock_factory(0)),
                ("b".to_string(), mock_factory(0)),
            ],
            FrontDoorConfig::default(),
        )
        .unwrap();
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        match front.submit_front(Some("nope"), vec![1], params, false) {
            SubmitOutcome::Rejected(msg) => assert!(msg.contains("nope"), "{msg}"),
            other => panic!("expected reject, got {other:?}"),
        }
        let out = front.submit_front(Some("b"), vec![1, 2], params, false);
        assert!(matches!(out, SubmitOutcome::Admitted { .. }));
        let replies = front.drain(Duration::from_secs(10)).unwrap();
        assert_eq!(replies.len(), 1);
        assert_eq!(replies[0].replica, "b");
    }

    #[test]
    fn front_door_rejects_invalid_prompt_via_worker() {
        let mut front = FrontDoor::new(
            vec![("mock".to_string(), mock_factory(0))],
            FrontDoorConfig::default(),
        )
        .unwrap();
        // 4000-token prompt > mock max_seq 128: the engine rejects it as
        // invalid and the reply is a terminal error, not a shed.
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        let out = front.submit_front(None, vec![7; 4000], params, false);
        assert!(matches!(out, SubmitOutcome::Admitted { .. }));
        let replies = front.drain(Duration::from_secs(10)).unwrap();
        assert_eq!(replies.len(), 1);
        let err = replies[0].result.as_ref().unwrap_err();
        assert!(err.contains("prompt length"), "{err}");
    }
}
