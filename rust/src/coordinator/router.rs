//! Request router: spreads requests across model replicas/variants.
//!
//! Each replica is its own [`InferenceEngine`] (own KV cache, own queue).
//! Routing policy: an explicit variant tag on the request wins; otherwise
//! least-queue-pressure, tie-broken round-robin. This is the multi-variant
//! deployment story for TARDIS: e.g. a `dense` replica for quality-pinned
//! traffic and a `tardis80` replica for latency-pinned traffic.

use anyhow::{anyhow, Result};

use super::engine_loop::{Completion, EngineSnapshot, InferenceEngine};
use super::model::StepModel;
use super::request::{RequestId, SamplingParams};

pub struct Replica<M: StepModel> {
    pub name: String,
    pub engine: InferenceEngine<M>,
}

pub struct Router<M: StepModel> {
    replicas: Vec<Replica<M>>,
    rr: usize,
    pub routed: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouteTicket {
    pub replica: usize,
    pub request: RequestId,
}

impl<M: StepModel> Router<M> {
    pub fn new(replicas: Vec<(String, InferenceEngine<M>)>) -> Self {
        assert!(!replicas.is_empty());
        Router {
            replicas: replicas
                .into_iter()
                .map(|(name, engine)| Replica { name, engine })
                .collect(),
            rr: 0,
            routed: 0,
        }
    }

    pub fn replica_names(&self) -> Vec<&str> {
        self.replicas.iter().map(|r| r.name.as_str()).collect()
    }

    pub fn replica(&mut self, idx: usize) -> &mut Replica<M> {
        &mut self.replicas[idx]
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    fn pick(&mut self, variant: Option<&str>) -> Result<usize> {
        if let Some(v) = variant {
            return self
                .replicas
                .iter()
                .position(|r| r.name == v)
                .ok_or_else(|| anyhow!("no replica for variant {v:?}"));
        }
        // least pressure, round-robin tie-break
        let n = self.replicas.len();
        let mut best = None;
        for off in 0..n {
            let i = (self.rr + off) % n;
            let p = self.replicas[i].engine.queue_pressure();
            match best {
                None => best = Some((i, p)),
                Some((_, bp)) if p < bp - 1e-12 => best = Some((i, p)),
                _ => {}
            }
        }
        let (idx, _) = best.expect("non-empty replicas");
        self.rr = (idx + 1) % n;
        Ok(idx)
    }

    pub fn submit(
        &mut self,
        variant: Option<&str>,
        prompt: Vec<i32>,
        params: SamplingParams,
    ) -> Result<RouteTicket> {
        let idx = self.pick(variant)?;
        let id = self.replicas[idx].engine.submit(prompt, params)?;
        self.routed += 1;
        Ok(RouteTicket { replica: idx, request: id })
    }

    /// One scheduler iteration on every replica. Returns true if any
    /// replica did work.
    pub fn step_all(&mut self) -> Result<bool> {
        let mut busy = false;
        for r in &mut self.replicas {
            if !r.engine.is_idle() {
                busy |= r.engine.step()?.did_work();
            }
        }
        Ok(busy)
    }

    /// Per-replica live stats (the server's `stats` op).
    pub fn stats_snapshot(&self) -> Vec<(String, EngineSnapshot)> {
        self.replicas
            .iter()
            .map(|r| (r.name.clone(), r.engine.snapshot()))
            .collect()
    }

    pub fn run_to_completion(&mut self) -> Result<Vec<(String, Completion)>> {
        let mut out = Vec::new();
        loop {
            let busy = self.step_all()?;
            for r in &mut self.replicas {
                for c in r.engine.take_completions() {
                    out.push((r.name.clone(), c));
                }
            }
            if !busy && self.replicas.iter().all(|r| r.engine.is_idle()) {
                break;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine_loop::EngineConfig;
    use crate::coordinator::model::MockModel;

    fn router(n: usize) -> Router<MockModel> {
        Router::new(
            (0..n)
                .map(|i| {
                    (
                        format!("v{i}"),
                        InferenceEngine::new(
                            MockModel::new(2, 64, 16, vec![4, 8]),
                            EngineConfig::default(),
                        ),
                    )
                })
                .collect(),
        )
    }

    #[test]
    fn explicit_variant_routing() {
        let mut r = router(3);
        let t = r
            .submit(Some("v1"), vec![1, 2], SamplingParams::default())
            .unwrap();
        assert_eq!(t.replica, 1);
        assert!(r.submit(Some("nope"), vec![1], SamplingParams::default()).is_err());
    }

    #[test]
    fn least_loaded_spreads() {
        let mut r = router(2);
        let mut counts = [0usize; 2];
        for i in 0..8 {
            let params = SamplingParams { max_tokens: 2, ..Default::default() };
            let t = r.submit(None, vec![1 + i], params).unwrap();
            counts[t.replica] += 1;
        }
        assert!(counts[0] >= 3 && counts[1] >= 3, "unbalanced {counts:?}");
    }

    #[test]
    fn stats_snapshot_covers_every_replica() {
        let mut r = router(2);
        let params = SamplingParams { max_tokens: 2, ..Default::default() };
        r.submit(Some("v1"), vec![1, 2], params).unwrap();
        let stats = r.stats_snapshot();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].0, "v0");
        assert_eq!(stats[1].0, "v1");
        assert_eq!(stats[1].1.queue_depth, 1);
        assert_eq!(stats[0].1.queue_depth, 0);
        r.run_to_completion().unwrap();
        let stats = r.stats_snapshot();
        assert_eq!(stats[1].1.finished, 1);
        assert_eq!(stats[1].1.queue_depth, 0);
    }

    #[test]
    fn run_to_completion_drains_all() {
        let mut r = router(2);
        for i in 0..6 {
            let params = SamplingParams { max_tokens: 3, ..Default::default() };
            r.submit(None, vec![1 + i, 2], params).unwrap();
        }
        let done = r.run_to_completion().unwrap();
        assert_eq!(done.len(), 6);
        assert!(done.iter().all(|(_, c)| c.tokens.len() == 3));
    }
}
