//! Token sampling over the decode step's logits: greedy argmax,
//! temperature softmax, and top-k restriction, all deterministic given
//! the request's seed.

use crate::util::rng::Rng;

use super::request::SamplingParams;

/// Sample one token from a `[vocab]` logits slice.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> i32 {
    assert!(!logits.is_empty());
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // Collect the candidate set (top-k or everything).
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if params.top_k > 0 && params.top_k < logits.len() {
        idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        idx.truncate(params.top_k);
    }
    // Softmax with temperature over candidates (max-subtracted).
    let t = params.temperature;
    let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx.iter().map(|&i| (((logits[i] - m) / t) as f64).exp()).collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    for (k, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return idx[k] as i32;
        }
    }
    *idx.last().unwrap() as i32
}

pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Log-softmax probability of `token` (used by tests and the evaluation
/// endpoints of the server).
pub fn log_prob(logits: &[f32], token: usize) -> f64 {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = logits.iter().map(|&v| ((v as f64) - m).exp()).sum();
    (logits[token] as f64) - m - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::property;

    #[test]
    fn greedy_is_argmax() {
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        let p = SamplingParams { temperature: 0.0, ..Default::default() };
        let mut rng = Rng::new(0);
        assert_eq!(sample(&logits, &p, &mut rng), 1);
    }

    #[test]
    fn top1_equals_greedy_at_any_temperature() {
        let logits = vec![0.5, 3.0, -2.0];
        let p = SamplingParams { temperature: 1.0, top_k: 1, ..Default::default() };
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            assert_eq!(sample(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let logits = vec![1.0, 1.0, 1.0, 1.0];
        let p = SamplingParams { temperature: 1.0, ..Default::default() };
        let mut rng = Rng::new(4);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample(&logits, &p, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn log_prob_normalizes() {
        let logits = vec![0.3, -1.2, 2.0, 0.0];
        let total: f64 = (0..4).map(|i| log_prob(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9, "total {total}");
    }

    #[test]
    fn prop_sample_in_candidate_set() {
        property("sampled token is a valid top-k candidate", 200, |rng| {
            let v = 2 + rng.usize_below(30);
            let logits: Vec<f32> = (0..v).map(|_| rng.normal() as f32).collect();
            let k = 1 + rng.usize_below(v);
            let p = SamplingParams {
                temperature: 0.1 + rng.f32(),
                top_k: k,
                ..Default::default()
            };
            let tok = sample(&logits, &p, rng) as usize;
            prop_assert!(tok < v, "token {tok} out of vocab {v}");
            // token must be among the k largest logits
            let mut sorted: Vec<f32> = logits.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let cutoff = sorted[k - 1];
            prop_assert!(
                logits[tok] >= cutoff,
                "token {tok} (logit {}) below top-{k} cutoff {cutoff}",
                logits[tok]
            );
            Ok(())
        });
    }
}
