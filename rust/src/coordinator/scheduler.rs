//! Iteration-level planning (Orca/vLLM-style continuous batching with
//! chunked-prefill co-scheduling).
//!
//! Every engine iteration the scheduler inspects a [`SchedView`] — the
//! admission queue, free slots and KV blocks, in-flight prefill jobs,
//! active decodes, and swapped-out (preempted) requests — and emits one
//! composite [`StepPlan`]:
//!  * `preemptions`     — decodes to evict under KV block pressure (their
//!    cache is saved to the host swap pool and restored bitwise later);
//!  * `resumes`         — swapped requests to re-admit into free slots;
//!  * `admissions`      — queued requests to move into free slots now;
//!  * `prefill_chunks`  — one prompt chunk per selected in-flight prefill
//!    job (several jobs ride in flight concurrently);
//!  * `decode`          — one batched decode step over the active slots,
//!    listed in sorted order so sampling is deterministic.
//!
//! In the default **mixed** mode a single plan carries admissions,
//! prefill chunks *and* the decode batch at once, bounded by the
//! `max_step_tokens` budget — the vLLM chunked-prefill regime where new
//! prompts stream into the batch without stalling in-flight decodes.
//! `mixed = false` reproduces the earlier segregated planner (prefill-only
//! or decode-only iterations alternating under the starvation guard),
//! kept as the measured baseline.
//!
//! Which queued requests are admitted first is the pluggable part: a
//! [`SchedulerPolicy`] ranks the queue snapshot ([`Fifo`],
//! [`ShortestPromptFirst`], [`PriorityFirst`], [`Edf`]). Everything else — the
//! co-scheduling, block accounting, preemption-victim choice (lowest
//! priority, youngest first) and resume order (FIFO) — is
//! policy-independent, which is what keeps batching invariance (same
//! tokens for a request regardless of policy, batch-mates, or
//! preemptions) easy to preserve: policies reorder *work*, never
//! *sampling*, and a preempted request's cache restores bitwise.

use super::kv;
use super::request::RequestId;

// ---------------------------------------------------------------------------
// What the scheduler sees.
// ---------------------------------------------------------------------------

/// Snapshot of one queued (not yet admitted) request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueuedRequest {
    pub id: RequestId,
    pub prompt_len: usize,
    /// Larger = more urgent. Carried on [`super::request::SamplingParams`].
    pub priority: i32,
    /// Position in the admission queue (0 = oldest): the FIFO key.
    pub arrival: usize,
    /// Tokens the engine would run in this request's first prefill chunk
    /// — the *unshared suffix* clamped to the model's chunk bucket
    /// (prefix-cache hits are skipped, not prefilled).
    pub first_chunk: usize,
    /// Prompt tokens covered by a pinned prefix-cache hit. Admission
    /// costs only the suffix: the hit blocks are already resident.
    pub hit_tokens: usize,
    /// Shared blocks the hit maps (pinned — they cost this request
    /// nothing to admit).
    pub hit_blocks: usize,
    /// True when the hit ends inside a shared block: the first append
    /// must copy-on-write it, which costs one extra block.
    pub cow: bool,
    /// Absolute TTFT deadline on the engine clock in µs
    /// ([`super::request::Request::deadline_us`]); `u64::MAX` when the
    /// request carries no TTFT SLO, so deadline-free traffic sorts last
    /// under [`Edf`] and the field is inert under every other policy.
    pub deadline_us: u64,
}

impl QueuedRequest {
    /// Fresh blocks admitting this request and running its first chunk
    /// would allocate: the post-chunk table size minus the shared blocks
    /// the hit already maps, plus the copy-on-write block for a partial
    /// hit. This is the prefix-aware admission cost — a 95%-shared
    /// prompt charges only its suffix.
    pub fn admission_blocks(&self, block_size: usize) -> usize {
        kv::blocks_for(self.hit_tokens + self.first_chunk, block_size)
            .saturating_sub(self.hit_blocks)
            + self.cow as usize
    }
}

/// Snapshot of one in-flight prefill job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillView {
    pub request: RequestId,
    pub slot: usize,
    /// Prompt tokens not yet written to the KV cache.
    pub remaining: usize,
    /// Prompt tokens already written to the KV cache.
    pub written: usize,
    /// KV blocks this job's table currently holds (owned *and* shared —
    /// the growth arithmetic cares about capacity, not ownership).
    pub blocks_held: usize,
    /// Tokens the next chunk would run (remaining clamped to a bucket).
    pub next_chunk: usize,
    /// True while the job's next append lands in a shared block it has
    /// not yet copied: the next chunk costs one extra block (the COW
    /// copy) on top of any growth.
    pub cow_pending: bool,
}

/// Snapshot of one actively decoding slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeSlotView {
    pub slot: usize,
    pub request: RequestId,
    pub priority: i32,
    /// KV blocks preempting this request would actually reclaim — its
    /// solely-owned blocks. Blocks shared with the prefix cache or other
    /// requests survive the release and must not be counted as
    /// preemption gain (they only become cache-evictable).
    pub blocks_held: usize,
    /// Next KV write position (== tokens resident in the slot's cache).
    pub next_pos: usize,
    /// Blocks the slot's table currently maps — owned *and* shared —
    /// i.e. its write capacity in block units, which is what growth
    /// arithmetic must be measured against (not `blocks_held`).
    pub table_blocks: usize,
    /// Draft tokens the engine wants to speculate for this slot on top
    /// of the one guaranteed decode token (0 = plain decode: speculation
    /// off, non-greedy sampling, or no window left). The planner may
    /// grant any width `0..=spec_window`; every granted draft token is
    /// charged against the token budget and the block ledger, so
    /// speculation stays visible to preemption and SLO accounting.
    pub spec_window: usize,
}

impl DecodeSlotView {
    /// Fresh blocks this slot must allocate to retire `1 + draft` tokens
    /// this step (decode writes at `next_pos..=next_pos + draft`).
    fn blocks_needed(&self, draft: usize, block_size: usize) -> usize {
        kv::blocks_for(self.next_pos + 1 + draft, block_size).saturating_sub(self.table_blocks)
    }
}

/// Snapshot of one preempted (swapped-out) request, FIFO by preemption
/// time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SwappedView {
    pub request: RequestId,
    pub priority: i32,
    /// KV entries resident when preempted (what a resume must restore).
    pub tokens: usize,
}

/// Everything a plan is built from. Borrowed snapshots: the scheduler
/// never touches engine state directly.
#[derive(Debug, Clone, Copy)]
pub struct SchedView<'a> {
    pub queued: &'a [QueuedRequest],
    /// Free decode slots, ascending.
    pub free_slots: &'a [usize],
    /// In-flight prefill jobs, slot-ascending (the engine's `PrefillSet`
    /// is keyed by slot); the plan's chunk order follows this order.
    pub inflight: &'a [PrefillView],
    /// Slots currently decoding, slot-ascending.
    pub decoding: &'a [DecodeSlotView],
    /// Preempted requests awaiting re-admission, oldest first.
    pub swapped: &'a [SwappedView],
    /// KV blocks the engine can hand out this iteration: the allocator's
    /// free list *plus* cold prefix-cache leaves it would reclaim on
    /// demand (leaf-LRU eviction). Pinned blocks — shared trunks still
    /// referenced by live requests or queue pins — are excluded, which
    /// is exactly the "evict cold leaves, never hot trunks" policy seen
    /// from the planner's side.
    pub free_blocks: usize,
    /// Tokens per KV block (see [`super::kv::KvLayout`]).
    pub block_size: usize,
    /// Whether the backend supports KV save/restore (preemption).
    pub can_preempt: bool,
}

impl SchedView<'_> {
    /// Planner-side block arithmetic, delegating to [`kv::blocks_for`] /
    /// [`kv::blocks_to_resume`] so the ledger can never diverge from the
    /// engine's allocations.
    fn blocks_for(&self, tokens: usize) -> usize {
        kv::blocks_for(tokens, self.block_size)
    }

    fn blocks_to_resume(&self, tokens: usize) -> usize {
        kv::blocks_to_resume(tokens, self.block_size)
    }
}

// ---------------------------------------------------------------------------
// What the scheduler emits.
// ---------------------------------------------------------------------------

/// Admit `request` from the queue into decode slot `slot`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admission {
    pub request: RequestId,
    pub slot: usize,
}

/// Run one prompt chunk for the prefill job occupying `slot`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkSpec {
    pub request: RequestId,
    pub slot: usize,
}

/// Evict the decode in `slot`: save its KV blocks to the host swap pool
/// and release them (restored bitwise on a later [`Resume`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Preemption {
    pub request: RequestId,
    pub slot: usize,
}

/// Re-admit the swapped `request` into free slot `slot`, restoring its
/// saved KV blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Resume {
    pub request: RequestId,
    pub slot: usize,
}

/// Abort the in-flight prefill in `slot` back to the *front* of the
/// admission queue, releasing its blocks (recompute-style eviction: no
/// token has been sampled yet, so re-prefilling from scratch cannot
/// change the stream). Last-resort only — issued when every runnable
/// piece of work is block-starved and freeing this job's blocks is the
/// only way forward.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Abort {
    pub request: RequestId,
    pub slot: usize,
}

/// One batched decode step; `slots` is sorted ascending and sampling
/// follows that order (deterministic, not HashMap iteration order).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct DecodeBatch {
    pub slots: Vec<usize>,
    /// Speculative draft tokens granted per slot, parallel to `slots`
    /// (all zeros when speculation is off): slot `slots[i]` retires
    /// `1..=1 + draft[i]` tokens this step — the extra writes are
    /// already charged in the plan's block ledger and token budget.
    pub draft: Vec<usize>,
}

impl DecodeBatch {
    /// A plain (non-speculative) batch: one token per slot.
    pub fn plain(slots: Vec<usize>) -> Self {
        let draft = vec![0; slots.len()];
        DecodeBatch { slots, draft }
    }

    /// Tokens this batch may retire at most (rows + draft tokens).
    pub fn planned_tokens(&self) -> usize {
        self.slots.len() + self.draft.iter().sum::<usize>()
    }
}

/// The composite plan for one engine iteration. Execution order:
/// preemptions and aborts (freeing blocks) → resumes → admissions →
/// prefill chunks → the decode step, so a chunk may target a request
/// admitted by the same plan and a resume may reuse blocks a preemption
/// just freed.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct StepPlan {
    pub preemptions: Vec<Preemption>,
    pub aborts: Vec<Abort>,
    pub resumes: Vec<Resume>,
    pub admissions: Vec<Admission>,
    pub prefill_chunks: Vec<ChunkSpec>,
    pub decode: Option<DecodeBatch>,
}

impl StepPlan {
    pub fn is_idle(&self) -> bool {
        self.preemptions.is_empty()
            && self.aborts.is_empty()
            && self.resumes.is_empty()
            && self.admissions.is_empty()
            && self.prefill_chunks.is_empty()
            && self.decode.is_none()
    }

    /// True when this plan carries both prefill work and a decode batch —
    /// the chunked-prefill co-scheduling case the mixed planner exists
    /// for.
    pub fn is_mixed(&self) -> bool {
        !self.prefill_chunks.is_empty() && self.decode.is_some()
    }
}

/// What one executed plan actually did (returned by the engine's `step`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StepOutcome {
    pub admitted: usize,
    pub prefill_chunks: usize,
    pub decoded_slots: usize,
    /// Tokens the decode step actually retired: equals `decoded_slots`
    /// for plain decode, up to `1 + draft` per slot when the step ran
    /// speculatively (accepted drafts + the verify's own token).
    pub decoded_tokens: usize,
    pub preempted: usize,
    pub resumed: usize,
    pub aborted: usize,
}

impl StepOutcome {
    pub fn did_work(&self) -> bool {
        self.admitted > 0
            || self.prefill_chunks > 0
            || self.decoded_slots > 0
            || self.preempted > 0
            || self.resumed > 0
            || self.aborted > 0
    }
}

// ---------------------------------------------------------------------------
// Policies: how the admission queue is ranked.
// ---------------------------------------------------------------------------

/// Ranks queued requests for admission. Policies only order work — the
/// plan assembly, chunking, block accounting and preemption live in
/// [`Scheduler`] — so a request's token stream cannot depend on the
/// policy in force.
pub trait SchedulerPolicy: Send {
    fn name(&self) -> &'static str;
    /// Request ids in admission order, most urgent first. Must be a
    /// permutation of `queued`.
    fn admission_order(&mut self, queued: &[QueuedRequest]) -> Vec<RequestId>;
}

/// Seed-compatible first-come-first-served admission.
#[derive(Debug, Default)]
pub struct Fifo;

impl SchedulerPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn admission_order(&mut self, queued: &[QueuedRequest]) -> Vec<RequestId> {
        // The engine's snapshot is already arrival-ordered (arrival is
        // the queue index), so FIFO is the identity permutation.
        queued.iter().map(|r| r.id).collect()
    }
}

/// Shortest prompt first (ties broken by arrival): minimizes mean
/// time-to-first-token under bursty mixed-length traffic, at the price
/// of long prompts waiting out bursts of short ones.
#[derive(Debug, Default)]
pub struct ShortestPromptFirst;

impl SchedulerPolicy for ShortestPromptFirst {
    fn name(&self) -> &'static str {
        "spf"
    }

    fn admission_order(&mut self, queued: &[QueuedRequest]) -> Vec<RequestId> {
        let mut q: Vec<&QueuedRequest> = queued.iter().collect();
        q.sort_by_key(|r| (r.prompt_len, r.arrival));
        q.into_iter().map(|r| r.id).collect()
    }
}

/// Highest `SamplingParams::priority` first (ties broken by arrival):
/// the quality-vs-latency variant-routing story — latency-pinned traffic
/// jumps the queue.
#[derive(Debug, Default)]
pub struct PriorityFirst;

impl SchedulerPolicy for PriorityFirst {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn admission_order(&mut self, queued: &[QueuedRequest]) -> Vec<RequestId> {
        let mut q: Vec<&QueuedRequest> = queued.iter().collect();
        q.sort_by_key(|r| (std::cmp::Reverse(r.priority), r.arrival));
        q.into_iter().map(|r| r.id).collect()
    }
}

/// Earliest deadline first (ties broken by arrival): admissions are
/// ranked by their absolute TTFT deadline, so under overload the work
/// most about to miss its SLO runs first instead of waiting out older
/// deadline-free traffic. Requests without a TTFT SLO carry
/// `deadline_us == u64::MAX` and sort last (among themselves: FIFO), so
/// an un-SLO'd workload behaves exactly like [`Fifo`] — the lowest tier
/// is not starved when the system is not overloaded.
#[derive(Debug, Default)]
pub struct Edf;

impl SchedulerPolicy for Edf {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn admission_order(&mut self, queued: &[QueuedRequest]) -> Vec<RequestId> {
        let mut q: Vec<&QueuedRequest> = queued.iter().collect();
        q.sort_by_key(|r| (r.deadline_us, r.arrival));
        q.into_iter().map(|r| r.id).collect()
    }
}

/// Config-friendly policy selector (the trait object itself is not
/// Clone, so [`super::engine_loop::EngineConfig`] carries this instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    #[default]
    Fifo,
    ShortestPromptFirst,
    Priority,
    Edf,
}

impl PolicyKind {
    pub fn build(self) -> Box<dyn SchedulerPolicy> {
        match self {
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::ShortestPromptFirst => Box::new(ShortestPromptFirst),
            PolicyKind::Priority => Box::new(PriorityFirst),
            PolicyKind::Edf => Box::new(Edf),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::ShortestPromptFirst => "spf",
            PolicyKind::Priority => "priority",
            PolicyKind::Edf => "edf",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "fifo" => Some(PolicyKind::Fifo),
            "spf" | "shortest-prompt-first" => Some(PolicyKind::ShortestPromptFirst),
            "priority" => Some(PolicyKind::Priority),
            "edf" | "deadline" => Some(PolicyKind::Edf),
            _ => None,
        }
    }

    /// Every shipped policy (batching-invariance tests sweep this).
    pub fn all() -> [PolicyKind; 4] {
        [PolicyKind::Fifo, PolicyKind::ShortestPromptFirst, PolicyKind::Priority, PolicyKind::Edf]
    }
}

// ---------------------------------------------------------------------------
// The policy-independent plan assembly.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: PolicyKind,
    /// Mixed mode (default): one plan may carry admissions, prefill
    /// chunks and the decode batch simultaneously, bounded by
    /// `max_step_tokens`. `false` reproduces the earlier segregated
    /// planner (prefill-only or decode-only iterations, alternating
    /// under the starvation guard) — the measured baseline.
    pub mixed: bool,
    /// Token budget of one mixed iteration: decode rows count 1 each,
    /// prefill chunks their chunk length. 0 = unbounded. The budget is
    /// soft — a decode batch always runs whole, and one prefill chunk
    /// always runs when prefill work exists (so neither side can starve);
    /// it caps the chunks *beyond* the first.
    pub max_step_tokens: usize,
    /// Segregated-mode starvation guard: max consecutive prefill
    /// *chunks* (model calls) while decodes are pending. Unused in mixed
    /// mode, where decodes ride along every iteration.
    pub max_consecutive_prefills: usize,
    /// How many prefill jobs may be in flight at once (the PrefillSet
    /// size cap). 1 reproduces the seed single-prefill behavior.
    pub max_concurrent_prefills: usize,
    /// How many prefill chunks (distinct jobs) run per iteration.
    pub chunk_budget: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: PolicyKind::Fifo,
            mixed: true,
            max_step_tokens: 0,
            max_consecutive_prefills: 4,
            max_concurrent_prefills: 2,
            chunk_budget: 2,
        }
    }
}

impl SchedulerConfig {
    /// The pre-paged planner: prefill-only or decode-only iterations
    /// alternating under the starvation guard. Benchmarks use this as
    /// the mixed planner's baseline.
    pub fn segregated() -> Self {
        SchedulerConfig { mixed: false, ..Default::default() }
    }

    /// The seed engine's behavior: segregated, FIFO, at most one prefill
    /// job in flight, one chunk per iteration.
    pub fn single_prefill() -> Self {
        SchedulerConfig {
            mixed: false,
            max_concurrent_prefills: 1,
            chunk_budget: 1,
            ..Default::default()
        }
    }

    pub fn with_policy(policy: PolicyKind) -> Self {
        SchedulerConfig { policy, ..Default::default() }
    }
}

pub struct Scheduler {
    cfg: SchedulerConfig,
    policy: Box<dyn SchedulerPolicy>,
    /// Segregated mode: prefill chunks issued since the last decode turn.
    consecutive_prefills: usize,
    /// Round-robin cursor so jobs beyond the chunk budget are not starved.
    chunk_rr: usize,
}

/// One prefill job the planner may chunk this iteration (in-flight or
/// freshly admitted).
#[derive(Debug, Clone, Copy)]
struct ChunkJob {
    request: RequestId,
    slot: usize,
    chunk: usize,
    new_blocks: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let policy = cfg.policy.build();
        Scheduler { cfg, policy, consecutive_prefills: 0, chunk_rr: 0 }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Build the next iteration's plan.
    pub fn plan(&mut self, view: &SchedView) -> StepPlan {
        if self.cfg.mixed {
            self.plan_mixed(view)
        } else {
            self.plan_segregated(view)
        }
    }

    // -- mixed: decode + prefill + admissions in one iteration --------------

    fn plan_mixed(&mut self, view: &SchedView) -> StepPlan {
        let mut plan = StepPlan::default();
        let mut avail_blocks = view.free_blocks;
        let budget = if self.cfg.max_step_tokens == 0 {
            usize::MAX
        } else {
            self.cfg.max_step_tokens
        };
        let decode_tokens = self.plan_decode(view, &mut plan, &mut avail_blocks, budget);
        let mut budget_left = budget.saturating_sub(decode_tokens);

        let mut free_slots = view.free_slots.iter().copied();
        let resume_blocked = self.plan_resumes(view, &mut plan, &mut free_slots, &mut avail_blocks);

        // Prefill jobs: in-flight first, then policy-ranked admissions
        // into the slots the resumes left over (none while a swapped
        // request is waiting on blocks — resumes outrank fresh prompts).
        let mut jobs = self.inflight_jobs(view);
        if !resume_blocked {
            self.plan_admissions(
                view,
                &mut plan,
                &mut jobs,
                &mut free_slots,
                &mut avail_blocks,
                budget_left,
            );
        }
        let cap = self.cfg.chunk_budget;
        self.plan_chunks(&jobs, &mut plan, &mut avail_blocks, &mut budget_left, cap);
        plan_last_resort(view, &mut plan);
        plan
    }

    /// Decode batch with block-pressure preemption. The guaranteed one
    /// token per slot is planned first (preempting/stalling on block
    /// pressure exactly as before); speculative draft widths are granted
    /// per retained slot afterwards, strictly from leftover blocks and
    /// leftover token budget, so drafts can never cause a preemption or
    /// starve prefill of its budget share. Returns the number of decode
    /// TOKENS planned (rows + granted draft tokens) for the caller's
    /// token-budget ledger.
    fn plan_decode(
        &mut self,
        view: &SchedView,
        plan: &mut StepPlan,
        avail_blocks: &mut usize,
        token_budget: usize,
    ) -> usize {
        let bs = view.block_size;
        let mut decoding: Vec<&DecodeSlotView> = view.decoding.iter().collect();
        let mut need: usize = decoding.iter().map(|d| d.blocks_needed(0, bs)).sum();
        if view.can_preempt {
            while need > *avail_blocks {
                // Victim: lowest priority, tie-broken youngest (largest
                // id). Policy-independent; never the last decoder (its
                // own eviction would free blocks no one else can use).
                let Some(vi) = pick_victim(&decoding) else { break };
                let v = decoding.remove(vi);
                *avail_blocks += v.blocks_held;
                need -= v.blocks_needed(0, bs);
                plan.preemptions.push(Preemption { request: v.request, slot: v.slot });
            }
        }
        if need > *avail_blocks {
            // No (further) preemption possible: stall the overflowing
            // slots this iteration, lowest slot first keeps going.
            let mut grant = *avail_blocks;
            need = 0;
            decoding.retain(|d| {
                let n = d.blocks_needed(0, bs);
                if n <= grant {
                    grant -= n;
                    need += n;
                    true
                } else {
                    false
                }
            });
        }
        *avail_blocks -= need;
        if decoding.is_empty() {
            return 0;
        }
        // Draft grants, slot-ascending: each retained slot may draft up
        // to its requested window, paying the *marginal* blocks past its
        // base reservation and one budget token per draft token.
        let mut tokens = decoding.len();
        let mut spec_budget = token_budget.saturating_sub(tokens);
        let draft: Vec<usize> = decoding
            .iter()
            .map(|d| {
                let base = d.blocks_needed(0, bs);
                let mut w = d.spec_window.min(spec_budget);
                while w > 0 && d.blocks_needed(w, bs) - base > *avail_blocks {
                    w -= 1;
                }
                *avail_blocks -= d.blocks_needed(w, bs) - base;
                spec_budget -= w;
                tokens += w;
                w
            })
            .collect();
        let slots: Vec<usize> = decoding.iter().map(|d| d.slot).collect();
        debug_assert!(slots.windows(2).all(|w| w[0] < w[1]));
        plan.decode = Some(DecodeBatch { slots, draft });
        tokens
    }

    /// Resumes: preempted work takes free slots before fresh prompts,
    /// FIFO with head-of-line blocking (no size-biased queue jumping).
    /// Returns true when the head of the swap queue could not be placed
    /// for lack of blocks — the caller must then hold off *fresh*
    /// admissions, or a sustained arrival stream would consume every
    /// freed block first and starve the preempted request indefinitely.
    /// (In-flight prefills and decodes are not held back: draining them
    /// is what frees the blocks the resume is waiting for.)
    fn plan_resumes(
        &self,
        view: &SchedView,
        plan: &mut StepPlan,
        free_slots: &mut impl Iterator<Item = usize>,
        avail_blocks: &mut usize,
    ) -> bool {
        for s in view.swapped {
            let blocks = view.blocks_to_resume(s.tokens);
            if blocks > *avail_blocks {
                return true;
            }
            let Some(slot) = free_slots.next() else { break };
            plan.resumes.push(Resume { request: s.request, slot });
            *avail_blocks -= blocks;
        }
        false
    }

    fn inflight_jobs(&self, view: &SchedView) -> Vec<ChunkJob> {
        view.inflight
            .iter()
            .map(|j| ChunkJob {
                request: j.request,
                slot: j.slot,
                chunk: j.next_chunk,
                new_blocks: view
                    .blocks_for(j.written + j.next_chunk)
                    .saturating_sub(j.blocks_held)
                    + j.cow_pending as usize,
            })
            .collect()
    }

    fn plan_admissions(
        &mut self,
        view: &SchedView,
        plan: &mut StepPlan,
        jobs: &mut Vec<ChunkJob>,
        free_slots: &mut impl Iterator<Item = usize>,
        avail_blocks: &mut usize,
        budget_left: usize,
    ) {
        let concurrency = self.cfg.max_concurrent_prefills.max(1);
        if jobs.len() >= concurrency || view.queued.is_empty() {
            return;
        }
        // Blocks and tokens the already-selected jobs may claim when they
        // chunk this iteration (conservative: assumes every one of them
        // does). The ledger itself is charged in plan_chunks — this only
        // keeps the admission gate honest, so a request is not admitted
        // against blocks or budget already promised to earlier jobs and
        // then left sitting in a slot it cannot use.
        let mut promised: usize = jobs.iter().map(|j| j.new_blocks).sum();
        let mut budget =
            budget_left.saturating_sub(jobs.iter().map(|j| j.chunk).sum::<usize>());
        for id in self.policy.admission_order(view.queued) {
            if jobs.len() >= concurrency {
                break;
            }
            let q = view
                .queued
                .iter()
                .find(|q| q.id == id)
                .expect("policy must permute the queue snapshot");
            let new_blocks = q.admission_blocks(view.block_size);
            // Admit only when the first chunk could run now; stop at the
            // first misfit rather than skipping past the policy's choice.
            if (q.first_chunk > budget && !jobs.is_empty())
                || promised + new_blocks > *avail_blocks
            {
                break;
            }
            let Some(slot) = free_slots.next() else { break };
            plan.admissions.push(Admission { request: id, slot });
            jobs.push(ChunkJob { request: id, slot, chunk: q.first_chunk, new_blocks });
            promised += new_blocks;
            budget = budget.saturating_sub(q.first_chunk);
        }
    }

    /// One chunk per selected job, up to `take_cap` chunks (the
    /// chunk budget, clamped by the segregated starvation guard),
    /// rotating the starting job across iterations so a wide PrefillSet
    /// shares the budget fairly. The first block-feasible chunk always
    /// runs, even over the token budget and even alongside a planned
    /// decode batch (the budget caps the chunks *beyond* the first), so
    /// a wide chunk can never starve behind a continuous decode stream —
    /// meaning one mixed iteration may exceed `max_step_tokens` by up to
    /// one chunk length.
    fn plan_chunks(
        &mut self,
        jobs: &[ChunkJob],
        plan: &mut StepPlan,
        avail_blocks: &mut usize,
        budget_left: &mut usize,
        take_cap: usize,
    ) {
        if jobs.is_empty() {
            return;
        }
        let n = jobs.len();
        let take_max = take_cap.max(1).min(n);
        let start = self.chunk_rr % n;
        let mut taken = 0usize;
        let mut advance = 0usize;
        for k in 0..n {
            if taken >= take_max {
                break;
            }
            let j = &jobs[(start + k) % n];
            if j.new_blocks > *avail_blocks {
                continue;
            }
            // The first block-feasible chunk always runs, even over
            // budget — otherwise a chunk wider than `max_step_tokens`
            // could starve forever behind a continuous decode stream.
            // The budget caps the chunks *beyond* the first.
            let force = plan.prefill_chunks.is_empty();
            if j.chunk > *budget_left && !force {
                continue;
            }
            plan.prefill_chunks.push(ChunkSpec { request: j.request, slot: j.slot });
            *avail_blocks -= j.new_blocks;
            *budget_left = budget_left.saturating_sub(j.chunk);
            taken += 1;
            advance = k + 1;
        }
        if taken > 0 {
            self.chunk_rr = (start + advance) % n;
        }
    }

    // -- segregated: the pre-paged alternating planner ----------------------

    /// Mirrors the seed decision tree: prefill-bearing iterations are
    /// prioritized (slots fill fastest, maximizing decode occupancy)
    /// until the starvation guard trips, then the pending decodes get a
    /// turn. Block accounting still gates chunks and the decode batch —
    /// and block-pressure preemption/resume works the same as in mixed
    /// mode (a pressured segregated engine must not strand its swapped
    /// requests) — but plans stay prefill-only or decode-only.
    fn plan_segregated(&mut self, view: &SchedView) -> StepPlan {
        let concurrency = self.cfg.max_concurrent_prefills.max(1);
        let can_admit = !view.queued.is_empty()
            && !view.free_slots.is_empty()
            && view.inflight.len() < concurrency;
        let want_prefill = !view.inflight.is_empty() || can_admit;
        let active = view.decoding.len();
        let starving = active > 0
            && self.consecutive_prefills >= self.cfg.max_consecutive_prefills;

        let mut plan = StepPlan::default();
        let mut avail_blocks = view.free_blocks;
        let mut free_slots = view.free_slots.iter().copied();
        let resume_blocked = self.plan_resumes(view, &mut plan, &mut free_slots, &mut avail_blocks);
        if want_prefill && !starving {
            // While decodes are pending, never issue more chunks than the
            // guard has left (so the stall bound is exactly the guard, not
            // guard + chunk_budget - 1); with nothing to decode the guard
            // is moot and the budget alone caps the plan.
            let allowance = if active > 0 {
                self.cfg
                    .max_consecutive_prefills
                    .saturating_sub(self.consecutive_prefills)
            } else {
                usize::MAX
            };
            let mut jobs = self.inflight_jobs(view);
            if !resume_blocked && jobs.len() < concurrency && !view.queued.is_empty() {
                self.plan_admissions(
                    view,
                    &mut plan,
                    &mut jobs,
                    &mut free_slots,
                    &mut avail_blocks,
                    usize::MAX,
                );
            }
            let cap = self.cfg.chunk_budget.max(1).min(allowance.max(1));
            let mut budget_left = usize::MAX;
            self.plan_chunks(&jobs, &mut plan, &mut avail_blocks, &mut budget_left, cap);
        }
        if plan.prefill_chunks.is_empty() && active > 0 {
            // Segregated steps carry no token budget — draft grants are
            // bounded by the per-slot windows and the block ledger only.
            self.plan_decode(view, &mut plan, &mut avail_blocks, usize::MAX);
        }
        plan_last_resort(view, &mut plan);

        if !plan.prefill_chunks.is_empty() {
            self.consecutive_prefills += plan.prefill_chunks.len();
        } else {
            self.consecutive_prefills = 0;
        }
        plan
    }
}

/// Preemption victim among the decoding slots: lowest priority, then
/// youngest (largest request id). `None` when at most one decode remains
/// — evicting the sole decoder frees blocks nothing else can use, it
/// would only thrash the swap pool (see [`plan_last_resort`] for the
/// one exception).
fn pick_victim(decoding: &[&DecodeSlotView]) -> Option<usize> {
    if decoding.len() <= 1 {
        return None;
    }
    decoding
        .iter()
        .enumerate()
        .min_by_key(|(_, d)| (d.priority, std::cmp::Reverse(d.request)))
        .map(|(i, _)| i)
}

/// Deadlock breaker, run after plan assembly in both modes. An idle plan
/// while work is in flight means every runnable piece is block-starved
/// (e.g. a half-prefilled prompt holds blocks a stalled decode needs, or
/// two concurrent prefills each hold half the pool) — without
/// intervention the engine would spin forever. Freeing someone's blocks
/// is the only way forward:
///  * preferably swap out the lowest-priority decode — even the sole one
///    — as long as another consumer (prefill job or swapped request) can
///    use its blocks; its resume headroom guarantees it decodes again
///    before the next pressure event, so this cannot livelock;
///  * otherwise (no decodes) abort the *youngest* of ≥ 2 starved prefill
///    jobs back to the queue front: nothing has been sampled yet, so the
///    recompute changes no token stream. A lone starved prefill cannot
///    happen (its whole prompt fits the pool by the admission clamp).
fn plan_last_resort(view: &SchedView, plan: &mut StepPlan) {
    if !plan.is_idle() {
        return;
    }
    if view.can_preempt
        && !view.decoding.is_empty()
        && !(view.inflight.is_empty() && view.swapped.is_empty())
    {
        let d = view
            .decoding
            .iter()
            .min_by_key(|d| (d.priority, std::cmp::Reverse(d.request)))
            .expect("non-empty decoding");
        plan.preemptions.push(Preemption { request: d.request, slot: d.slot });
    } else if view.inflight.len() > 1 {
        let j = view
            .inflight
            .iter()
            .max_by_key(|j| j.request)
            .expect("non-empty inflight");
        plan.aborts.push(Abort { request: j.request, slot: j.slot });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::property;

    fn queued(specs: &[(RequestId, usize, i32)]) -> Vec<QueuedRequest> {
        specs
            .iter()
            .enumerate()
            .map(|(arrival, &(id, prompt_len, priority))| QueuedRequest {
                id,
                prompt_len,
                priority,
                arrival,
                first_chunk: prompt_len.min(64),
                hit_tokens: 0,
                hit_blocks: 0,
                cow: false,
                deadline_us: u64::MAX,
            })
            .collect()
    }

    /// Legacy-shaped helper: `needs_block` is encoded as a `next_pos` /
    /// `table_blocks` pair (block size 16, matching [`view`]) so the
    /// pre-speculation tests keep reading naturally. `spec_window` = 0.
    fn decoding(specs: &[(usize, RequestId, i32, usize, bool)]) -> Vec<DecodeSlotView> {
        specs
            .iter()
            .map(|&(slot, request, priority, blocks_held, needs_block)| {
                let table_blocks = blocks_held;
                let next_pos = if needs_block {
                    table_blocks * 16
                } else {
                    (table_blocks * 16).saturating_sub(1)
                };
                DecodeSlotView {
                    slot,
                    request,
                    priority,
                    blocks_held,
                    next_pos,
                    table_blocks,
                    spec_window: 0,
                }
            })
            .collect()
    }

    fn inflight(specs: &[(RequestId, usize, usize)]) -> Vec<PrefillView> {
        specs
            .iter()
            .map(|&(request, slot, remaining)| PrefillView {
                request,
                slot,
                remaining,
                written: 0,
                blocks_held: 0,
                next_chunk: remaining.min(64),
                cow_pending: false,
            })
            .collect()
    }

    /// A view with ample blocks so tests exercising slot/queue logic are
    /// not perturbed by block accounting.
    fn view<'a>(
        queued: &'a [QueuedRequest],
        free_slots: &'a [usize],
        inflight: &'a [PrefillView],
        decoding: &'a [DecodeSlotView],
    ) -> SchedView<'a> {
        SchedView {
            queued,
            free_slots,
            inflight,
            decoding,
            swapped: &[],
            free_blocks: 1 << 20,
            block_size: 16,
            can_preempt: true,
        }
    }

    #[test]
    fn idle_when_nothing_to_do() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let plan = s.plan(&view(&[], &[0, 1], &[], &[]));
        assert!(plan.is_idle());
    }

    #[test]
    fn admits_multiple_requests_up_to_concurrency() {
        let mut s = Scheduler::new(SchedulerConfig::default()); // concurrency 2
        let q = queued(&[(1, 8, 0), (2, 8, 0), (3, 8, 0)]);
        let plan = s.plan(&view(&q, &[0, 1, 2, 3], &[], &[]));
        assert_eq!(
            plan.admissions,
            vec![Admission { request: 1, slot: 0 }, Admission { request: 2, slot: 1 }]
        );
        assert_eq!(plan.prefill_chunks.len(), 2);
        assert!(plan.decode.is_none());
    }

    #[test]
    fn continues_inflight_even_with_no_free_slots() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let inf = inflight(&[(7, 3, 4)]);
        let dec = decoding(&[(0, 10, 0, 1, false), (1, 11, 0, 1, false)]);
        let plan = s.plan(&view(&[], &[], &inf, &dec));
        assert_eq!(plan.prefill_chunks, vec![ChunkSpec { request: 7, slot: 3 }]);
        assert!(plan.admissions.is_empty());
    }

    #[test]
    fn mixed_plan_carries_admissions_chunks_and_decode_together() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let q = queued(&[(5, 8, 0)]);
        let inf = inflight(&[(7, 3, 32)]);
        let dec = decoding(&[(0, 10, 0, 2, false), (1, 11, 0, 2, false)]);
        let plan = s.plan(&view(&q, &[4, 5], &inf, &dec));
        assert_eq!(plan.admissions, vec![Admission { request: 5, slot: 4 }]);
        assert_eq!(plan.prefill_chunks.len(), 2, "{plan:?}");
        assert_eq!(plan.decode, Some(DecodeBatch::plain(vec![0, 1])));
        assert!(plan.is_mixed());
        assert!(plan.preemptions.is_empty());
    }

    #[test]
    fn token_budget_caps_prefill_chunks_but_not_decode() {
        // Budget 20: decode (2 rows) leaves 18 — only one 16-token chunk
        // fits; the second job waits.
        let mut s = Scheduler::new(SchedulerConfig {
            max_step_tokens: 20,
            max_concurrent_prefills: 2,
            ..Default::default()
        });
        let mut inf = inflight(&[(7, 2, 40), (8, 3, 40)]);
        for j in inf.iter_mut() {
            j.next_chunk = 16;
        }
        let dec = decoding(&[(0, 10, 0, 1, false), (1, 11, 0, 1, false)]);
        let plan = s.plan(&view(&[], &[], &inf, &dec));
        assert_eq!(plan.decode.as_ref().unwrap().slots.len(), 2);
        assert_eq!(plan.prefill_chunks.len(), 1, "{plan:?}");
        // Next iteration the round-robin cursor reaches the other job.
        let plan2 = s.plan(&view(&[], &[], &inf, &dec));
        assert_ne!(
            plan2.prefill_chunks[0].request, plan.prefill_chunks[0].request,
            "budget-capped chunks rotate across jobs"
        );
    }

    #[test]
    fn budget_never_plans_idle_iterations() {
        // Budget smaller than any chunk: the chunk is forced through
        // anyway when the plan carries no other model work.
        let mut s = Scheduler::new(SchedulerConfig {
            max_step_tokens: 2,
            ..Default::default()
        });
        let inf = inflight(&[(7, 0, 64)]);
        let plan = s.plan(&view(&[], &[], &inf, &[]));
        assert_eq!(plan.prefill_chunks.len(), 1);
    }

    #[test]
    fn block_pressure_preempts_lowest_priority_youngest() {
        // Three decodes, two need a block, none free: the planner evicts
        // the lowest-priority victim (ties: youngest id) until feasible.
        let mut s = Scheduler::new(SchedulerConfig::default());
        let dec = decoding(&[
            (0, 10, 1, 4, true),
            (1, 20, 0, 3, false), // lowest priority => first victim
            (2, 30, 1, 4, true),
        ]);
        let mut v = view(&[], &[], &[], &dec);
        v.free_blocks = 0;
        let plan = s.plan(&v);
        assert_eq!(plan.preemptions, vec![Preemption { request: 20, slot: 1 }]);
        assert_eq!(plan.decode, Some(DecodeBatch::plain(vec![0, 2])));
    }

    #[test]
    fn preemption_tie_breaks_youngest_first() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let dec = decoding(&[
            (0, 10, 0, 2, true),
            (1, 99, 0, 1, false), // same priority, youngest id
            (2, 11, 0, 1, false),
        ]);
        let mut v = view(&[], &[], &[], &dec);
        v.free_blocks = 0;
        let plan = s.plan(&v);
        assert_eq!(plan.preemptions, vec![Preemption { request: 99, slot: 1 }]);
    }

    #[test]
    fn sole_decoder_is_never_preempted() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let dec = decoding(&[(0, 10, 0, 4, true)]);
        let mut v = view(&[], &[], &[], &dec);
        v.free_blocks = 0;
        let plan = s.plan(&v);
        assert!(plan.preemptions.is_empty());
        // The blocked slot stalls instead of thrashing the swap pool.
        assert!(plan.decode.is_none());
    }

    #[test]
    fn last_resort_swaps_sole_decoder_for_starved_prefill() {
        // A half-prefilled job holds blocks the sole (stalled) decoder
        // cannot take, and vice versa: the plan would be idle forever, so
        // the deadlock breaker swaps the decoder out.
        let mut s = Scheduler::new(SchedulerConfig::default());
        let inf = inflight(&[(7, 1, 2)]);
        let dec = decoding(&[(0, 10, 0, 2, true)]);
        let mut v = view(&[], &[], &inf, &dec);
        v.free_blocks = 0;
        let plan = s.plan(&v);
        assert_eq!(plan.preemptions, vec![Preemption { request: 10, slot: 0 }]);
        assert!(plan.aborts.is_empty());
        assert!(plan.decode.is_none() && plan.prefill_chunks.is_empty());
        // Same shape in segregated mode.
        let mut s = Scheduler::new(SchedulerConfig::segregated());
        let plan = s.plan(&v);
        assert_eq!(plan.preemptions, vec![Preemption { request: 10, slot: 0 }]);
    }

    #[test]
    fn last_resort_aborts_youngest_of_competing_prefills() {
        // Two concurrent prefills each hold half the pool and both need
        // one more block: no decoders to swap, so the youngest job aborts
        // back to the queue (recompute) to free its blocks.
        let mut s = Scheduler::new(SchedulerConfig::default());
        let mut inf = inflight(&[(7, 0, 2), (9, 1, 2)]);
        for j in inf.iter_mut() {
            j.written = 8;
            j.blocks_held = 2;
        }
        let mut v = view(&[], &[], &inf, &[]);
        v.block_size = 4; // tail chunk needs ceil(10/4)=3 blocks, 2 held
        v.free_blocks = 0;
        let plan = s.plan(&v);
        assert_eq!(plan.aborts, vec![Abort { request: 9, slot: 1 }]);
        assert!(plan.preemptions.is_empty());
        assert!(!plan.is_idle());
    }

    #[test]
    fn stalls_without_preemption_support() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let dec = decoding(&[(0, 10, 0, 2, true), (1, 11, 5, 2, false)]);
        let mut v = view(&[], &[], &[], &dec);
        v.free_blocks = 0;
        v.can_preempt = false;
        let plan = s.plan(&v);
        assert!(plan.preemptions.is_empty());
        assert_eq!(
            plan.decode,
            Some(DecodeBatch::plain(vec![1])),
            "block-starved slot is excluded, the rest decode"
        );
    }

    #[test]
    fn speculative_drafts_granted_from_leftover_blocks() {
        // Slot 0 sits mid-block (its 4 drafts cost no new blocks); slot 1
        // sits one write before a block boundary with nothing free, so
        // its drafts — which would need a fresh block — are denied while
        // its guaranteed token still runs. Drafts never trigger
        // preemption: they only spend what the base plan left over.
        let mut s = Scheduler::new(SchedulerConfig::default());
        let dec = vec![
            DecodeSlotView {
                slot: 0,
                request: 1,
                priority: 0,
                blocks_held: 1,
                next_pos: 10,
                table_blocks: 1,
                spec_window: 4,
            },
            DecodeSlotView {
                slot: 1,
                request: 2,
                priority: 0,
                blocks_held: 1,
                next_pos: 15,
                table_blocks: 1,
                spec_window: 4,
            },
        ];
        let mut v = view(&[], &[], &[], &dec);
        v.free_blocks = 0;
        let plan = s.plan(&v);
        assert_eq!(plan.decode, Some(DecodeBatch { slots: vec![0, 1], draft: vec![4, 0] }));
        assert!(plan.preemptions.is_empty(), "drafts must never preempt: {plan:?}");
    }

    #[test]
    fn speculative_drafts_capped_by_token_budget() {
        // 4-token mixed budget, two guaranteed decode rows: 2 budget
        // tokens remain for drafts, granted slot-ascending.
        let mut s =
            Scheduler::new(SchedulerConfig { max_step_tokens: 4, ..SchedulerConfig::default() });
        let dec = vec![
            DecodeSlotView {
                slot: 0,
                request: 1,
                priority: 0,
                blocks_held: 1,
                next_pos: 4,
                table_blocks: 1,
                spec_window: 8,
            },
            DecodeSlotView {
                slot: 1,
                request: 2,
                priority: 0,
                blocks_held: 1,
                next_pos: 4,
                table_blocks: 1,
                spec_window: 8,
            },
        ];
        let v = view(&[], &[], &[], &dec);
        let plan = s.plan(&v);
        assert_eq!(plan.decode, Some(DecodeBatch { slots: vec![0, 1], draft: vec![2, 0] }));
    }

    #[test]
    fn resumes_take_free_slots_before_admissions() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let q = queued(&[(1, 8, 0)]);
        let swapped = [SwappedView { request: 42, priority: 0, tokens: 20 }];
        let mut v = view(&q, &[3], &[], &[]);
        v.swapped = &swapped;
        let plan = s.plan(&v);
        assert_eq!(plan.resumes, vec![Resume { request: 42, slot: 3 }]);
        assert!(plan.admissions.is_empty(), "the only free slot went to the resume: {plan:?}");
    }

    #[test]
    fn resume_waits_for_enough_blocks() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        // 20 resident tokens at block_size 16 need 2 blocks plus the
        // next-write headroom => blocks_to_resume = 2.
        let swapped = [SwappedView { request: 42, priority: 0, tokens: 20 }];
        let mut v = view(&[], &[3], &[], &[]);
        v.swapped = &swapped;
        v.free_blocks = 1;
        let plan = s.plan(&v);
        assert!(plan.resumes.is_empty());
        v.free_blocks = 2;
        let plan = s.plan(&v);
        assert_eq!(plan.resumes.len(), 1);
    }

    #[test]
    fn blocked_resume_holds_off_fresh_admissions() {
        // A swapped request waiting on blocks reserves the pipeline:
        // fresh prompts are not admitted against the blocks it needs, or
        // a sustained arrival stream would starve it indefinitely.
        let q = queued(&[(1, 8, 0)]);
        let swapped = [SwappedView { request: 42, priority: 0, tokens: 20 }];
        let mut v = view(&q, &[2, 3], &[], &[]);
        v.swapped = &swapped;
        v.free_blocks = 1; // resume needs 2
        let plan = Scheduler::new(SchedulerConfig::default()).plan(&v);
        assert!(plan.resumes.is_empty());
        assert!(plan.admissions.is_empty(), "admission starves the resume: {plan:?}");
        // With blocks for both, the resume takes the first slot and the
        // admission the next.
        v.free_blocks = 3;
        let plan = Scheduler::new(SchedulerConfig::default()).plan(&v);
        assert_eq!(plan.resumes, vec![Resume { request: 42, slot: 2 }]);
        assert_eq!(plan.admissions, vec![Admission { request: 1, slot: 3 }]);
    }

    #[test]
    fn admission_waits_for_blocks() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let q = queued(&[(1, 40, 0)]); // first_chunk 40 => 3 blocks of 16
        let mut v = view(&q, &[0], &[], &[]);
        v.free_blocks = 2;
        let plan = s.plan(&v);
        assert!(plan.admissions.is_empty(), "{plan:?}");
        v.free_blocks = 3;
        let plan = s.plan(&v);
        assert_eq!(plan.admissions.len(), 1);
    }

    #[test]
    fn prefix_hit_discounts_admission_cost() {
        // 40-token prompt, 32 tokens covered by a pinned full-block hit:
        // the suffix chunk is 8 tokens, so admission needs only
        // ceil(40/16) - 2 = 1 fresh block where a cold prompt needs 3.
        let mut s = Scheduler::new(SchedulerConfig::default());
        let mut q = queued(&[(1, 40, 0)]);
        q[0].first_chunk = 8;
        q[0].hit_tokens = 32;
        q[0].hit_blocks = 2;
        let mut v = view(&q, &[0], &[], &[]);
        v.free_blocks = 1;
        let plan = s.plan(&v);
        assert_eq!(plan.admissions.len(), 1, "hit-covered blocks are free: {plan:?}");
        // A partial hit costs one extra block for the copy-on-write.
        q[0].hit_tokens = 30;
        q[0].first_chunk = 10;
        q[0].cow = true;
        assert_eq!(q[0].admission_blocks(16), 2);
        let mut v = view(&q, &[0], &[], &[]);
        v.free_blocks = 1;
        let plan = Scheduler::new(SchedulerConfig::default()).plan(&v);
        assert!(plan.admissions.is_empty(), "COW block not budgeted: {plan:?}");
        v.free_blocks = 2;
        let plan = Scheduler::new(SchedulerConfig::default()).plan(&v);
        assert_eq!(plan.admissions.len(), 1);
    }

    #[test]
    fn cow_pending_charges_inflight_chunk() {
        // An in-flight job whose next append still has to copy a shared
        // block needs its COW block on top of growth: with 0 free the
        // chunk cannot run, with 1 it can (no table growth here: written
        // 8 + chunk 8 stays within the 1 block held at block_size 16).
        let mut s = Scheduler::new(SchedulerConfig::default());
        let mut inf = inflight(&[(7, 0, 8)]);
        inf[0].written = 8;
        inf[0].blocks_held = 1;
        inf[0].next_chunk = 8;
        inf[0].cow_pending = true;
        let mut v = view(&[], &[], &inf, &[]);
        v.free_blocks = 0;
        let plan = s.plan(&v);
        assert!(plan.prefill_chunks.is_empty(), "{plan:?}");
        v.free_blocks = 1;
        let plan = s.plan(&v);
        assert_eq!(plan.prefill_chunks.len(), 1);
    }

    #[test]
    fn admissions_do_not_overpromise_blocks() {
        // Two queued prompts whose first chunks need 3 blocks each, 4
        // free: admitting both would grant the second against blocks
        // already promised to the first, parking it in a slot it cannot
        // use. Only the first is admitted.
        let mut s = Scheduler::new(SchedulerConfig::default());
        let q = queued(&[(1, 40, 0), (2, 40, 0)]);
        let mut v = view(&q, &[0, 1], &[], &[]);
        v.free_blocks = 4;
        let plan = s.plan(&v);
        assert_eq!(plan.admissions, vec![Admission { request: 1, slot: 0 }]);
        assert_eq!(plan.prefill_chunks.len(), 1);
        // With room for both, both are admitted.
        v.free_blocks = 6;
        let plan = Scheduler::new(SchedulerConfig::default()).plan(&v);
        assert_eq!(plan.admissions.len(), 2);
    }

    #[test]
    fn segregated_decode_when_no_prefill_possible() {
        let mut s = Scheduler::new(SchedulerConfig::segregated());
        let q = queued(&[(9, 4, 0)]);
        let dec = decoding(&[(2, 1, 0, 1, false), (5, 2, 0, 1, false)]);
        let plan = s.plan(&view(&q, &[], &[], &dec)); // queue deep, no slot
        assert_eq!(plan.decode, Some(DecodeBatch::plain(vec![2, 5])));
        assert!(plan.admissions.is_empty());
    }

    #[test]
    fn starvation_guard_gives_decodes_a_turn() {
        // Segregated mode, guard of 4 *chunks* with 2-chunk plans: two
        // prefill plans, then the pending decodes get a turn.
        let mut s = Scheduler::new(SchedulerConfig {
            max_consecutive_prefills: 4,
            ..SchedulerConfig::segregated()
        });
        let q = queued(&[(1, 64, 0), (2, 64, 0), (3, 64, 0), (4, 64, 0)]);
        let dec = decoding(&[(0, 90, 0, 1, false), (1, 91, 0, 1, false), (2, 92, 0, 1, false)]);
        let v = view(&q, &[4, 5, 6, 7], &[], &dec);
        assert_eq!(s.plan(&v).prefill_chunks.len(), 2);
        assert_eq!(s.plan(&v).prefill_chunks.len(), 2);
        // Guard trips: decode-only plan, sorted slots.
        let p3 = s.plan(&v);
        assert!(p3.prefill_chunks.is_empty());
        assert_eq!(p3.decode, Some(DecodeBatch::plain(vec![0, 1, 2])));
        // Counter reset: prefill again.
        assert!(!s.plan(&v).prefill_chunks.is_empty());
    }

    #[test]
    fn starvation_guard_counts_chunks_not_iterations() {
        // One 2-chunk plan already reaches a guard of 2: the seed's
        // decode-stall bound (in model calls) survives chunk_budget > 1.
        let mut s = Scheduler::new(SchedulerConfig {
            max_consecutive_prefills: 2,
            ..SchedulerConfig::segregated()
        });
        let q = queued(&[(1, 64, 0), (2, 64, 0)]);
        let dec = decoding(&[(0, 90, 0, 1, false)]);
        let v = view(&q, &[4, 5], &[], &dec);
        assert_eq!(s.plan(&v).prefill_chunks.len(), 2);
        assert!(s.plan(&v).decode.is_some(), "2 chunks hit the guard of 2");
    }

    #[test]
    fn prefill_allowed_when_no_decodes_regardless_of_guard() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_consecutive_prefills: 1,
            ..SchedulerConfig::segregated()
        });
        let q = queued(&[(1, 4, 0), (2, 4, 0), (3, 4, 0)]);
        for _ in 0..5 {
            let inf = inflight(&[(1, 0, 64)]);
            let plan = s.plan(&view(&q, &[1, 2], &inf, &[]));
            assert!(!plan.prefill_chunks.is_empty());
        }
    }

    #[test]
    fn policies_rank_admissions() {
        // id 1: long prompt, low priority, first in.
        // id 2: short prompt, mid priority.
        // id 3: mid prompt, high priority, last in.
        let mut q = queued(&[(1, 32, 0), (2, 4, 1), (3, 16, 9)]);
        assert_eq!(Fifo.admission_order(&q), vec![1, 2, 3]);
        assert_eq!(ShortestPromptFirst.admission_order(&q), vec![2, 3, 1]);
        assert_eq!(PriorityFirst.admission_order(&q), vec![3, 2, 1]);
        // EDF ranks by absolute deadline, not priority or length.
        q[0].deadline_us = 9_000;
        q[1].deadline_us = u64::MAX; // no SLO: last
        q[2].deadline_us = 4_000;
        assert_eq!(Edf.admission_order(&q), vec![3, 1, 2]);
    }

    #[test]
    fn edf_tie_breaks_by_arrival() {
        // Equal deadlines (and the no-deadline bucket) resolve FIFO, so
        // EDF is a deterministic total order over any snapshot.
        let mut q = queued(&[(1, 8, 0), (2, 8, 0), (3, 8, 0), (4, 8, 0)]);
        q[0].deadline_us = 5_000;
        q[2].deadline_us = 5_000;
        assert_eq!(Edf.admission_order(&q), vec![1, 3, 2, 4]);
        assert_eq!(Edf.admission_order(&q), vec![1, 3, 2, 4], "stable across calls");
    }

    #[test]
    fn edf_without_deadlines_is_fifo() {
        // Deadline-free traffic (the lowest tier's usual shape) keeps its
        // arrival order: EDF cannot starve it when nothing is urgent.
        let q = queued(&[(4, 64, 0), (5, 2, 3), (6, 16, -1)]);
        assert_eq!(Edf.admission_order(&q), Fifo.admission_order(&q));
    }

    #[test]
    fn edf_admits_lowest_tier_when_not_overloaded() {
        // One loose-deadline low-tier request behind a tight-deadline
        // high-tier one: with slots and blocks for both, both are
        // admitted in the same plan — EDF reorders, it does not shed.
        let mut q = queued(&[(1, 8, 0), (2, 8, 9)]);
        q[0].deadline_us = 800_000; // loose
        q[1].deadline_us = 1_000; // tight
        let mut s = Scheduler::new(SchedulerConfig::with_policy(PolicyKind::Edf));
        let plan = s.plan(&view(&q, &[0, 1, 2], &[], &[]));
        assert_eq!(
            plan.admissions,
            vec![Admission { request: 2, slot: 0 }, Admission { request: 1, slot: 1 }]
        );
    }

    #[test]
    fn policy_kind_parses() {
        assert_eq!(PolicyKind::parse("fifo"), Some(PolicyKind::Fifo));
        assert_eq!(PolicyKind::parse("spf"), Some(PolicyKind::ShortestPromptFirst));
        assert_eq!(
            PolicyKind::parse("shortest-prompt-first"),
            Some(PolicyKind::ShortestPromptFirst)
        );
        assert_eq!(PolicyKind::parse("priority"), Some(PolicyKind::Priority));
        assert_eq!(PolicyKind::parse("edf"), Some(PolicyKind::Edf));
        assert_eq!(PolicyKind::parse("deadline"), Some(PolicyKind::Edf));
        assert_eq!(PolicyKind::parse("nope"), None);
        for kind in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(kind.name()), Some(kind));
            // The trait impl's name must agree with the enum's, or the
            // stats op would report a policy that --policy rejects.
            assert_eq!(kind.build().name(), kind.name());
        }
    }

    #[test]
    fn chunk_budget_rotates_across_jobs() {
        // 3 in-flight jobs, budget 2: over two iterations every job gets
        // at least one chunk.
        let mut s = Scheduler::new(SchedulerConfig {
            max_concurrent_prefills: 3,
            chunk_budget: 2,
            ..Default::default()
        });
        let inf = inflight(&[(1, 0, 64), (2, 1, 64), (3, 2, 64)]);
        let v = view(&[], &[], &inf, &[]);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..2 {
            for c in s.plan(&v).prefill_chunks {
                seen.insert(c.request);
            }
        }
        assert_eq!(seen.len(), 3, "every job chunked within two iterations");
    }

    #[test]
    fn prop_no_starvation_segregated() {
        // Under any adversarial view stream with decodes always pending,
        // at most `guard` consecutive prefill-bearing plans occur between
        // decode plans, and the scheduler never goes idle.
        property("decode starvation bounded", 100, |rng| {
            let guard = 1 + rng.usize_below(6);
            let mut s = Scheduler::new(SchedulerConfig {
                max_consecutive_prefills: guard,
                max_concurrent_prefills: 1 + rng.usize_below(4),
                chunk_budget: 1 + rng.usize_below(4),
                ..SchedulerConfig::segregated()
            });
            let mut run = 0usize;
            for iter in 0..200u64 {
                let q: Vec<QueuedRequest> = (0..rng.usize_below(10))
                    .map(|i| QueuedRequest {
                        id: iter * 100 + i as u64,
                        prompt_len: 1 + rng.usize_below(64),
                        priority: rng.below(5) as i32,
                        arrival: i,
                        first_chunk: 1 + rng.usize_below(16),
                        hit_tokens: 0,
                        hit_blocks: 0,
                        cow: false,
                        deadline_us: u64::MAX,
                    })
                    .collect();
                let free: Vec<usize> = (8..8 + rng.usize_below(4)).collect();
                let inf: Vec<PrefillView> = (0..rng.usize_below(3))
                    .map(|i| PrefillView {
                        request: iter * 100 + 50 + i as u64,
                        slot: 20 + i,
                        remaining: 1 + rng.usize_below(32),
                        written: rng.usize_below(32),
                        blocks_held: 2,
                        next_chunk: 1 + rng.usize_below(16),
                        cow_pending: false,
                    })
                    .collect();
                let n_active = 1 + rng.usize_below(8); // always pending
                let dec: Vec<DecodeSlotView> = (0..n_active)
                    .map(|slot| DecodeSlotView {
                        slot,
                        request: 9000 + slot as u64,
                        priority: 0,
                        blocks_held: 1,
                        next_pos: 15,
                        table_blocks: 1,
                        spec_window: 0,
                    })
                    .collect();
                let plan = s.plan(&view(&q, &free, &inf, &dec));
                prop_assert!(!plan.is_idle(), "idle while decodes active");
                if !plan.prefill_chunks.is_empty() {
                    // A prefill plan is only issued while the chunk count
                    // since the last decode is under the guard, and its
                    // chunks never push the total past the guard.
                    prop_assert!(run < guard, "prefill planned at {run} chunks >= guard {guard}");
                    run += plan.prefill_chunks.len();
                    prop_assert!(run <= guard, "{run} chunks since last decode > guard {guard}");
                } else {
                    prop_assert!(plan.decode.is_some(), "plan neither prefills nor decodes");
                    run = 0;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mixed_plans_respect_block_budget() {
        // Under random pressure the mixed planner never plans more new
        // blocks than are free (counting blocks freed by its own
        // preemptions), never preempts the sole decoder, and always
        // includes every feasible decode slot.
        property("mixed block accounting sound", 150, |rng| {
            let bs = 1 + rng.usize_below(8);
            let mut s = Scheduler::new(SchedulerConfig {
                max_step_tokens: rng.usize_below(64),
                max_concurrent_prefills: 1 + rng.usize_below(3),
                chunk_budget: 1 + rng.usize_below(3),
                ..Default::default()
            });
            for iter in 0..100u64 {
                let n_dec = rng.usize_below(5);
                let dec: Vec<DecodeSlotView> = (0..n_dec)
                    .map(|slot| {
                        let table_blocks = 1 + rng.usize_below(4);
                        // half the slots need a fresh block for the base
                        // token; spec windows ask for 0..=3 draft tokens
                        let next_pos = if rng.bool(0.5) {
                            table_blocks * bs
                        } else {
                            table_blocks * bs - 1
                        };
                        DecodeSlotView {
                            slot,
                            request: iter * 100 + slot as u64,
                            priority: rng.below(3) as i32,
                            blocks_held: table_blocks,
                            next_pos,
                            table_blocks,
                            spec_window: rng.usize_below(4),
                        }
                    })
                    .collect();
                let inf: Vec<PrefillView> = (0..rng.usize_below(3))
                    .map(|i| {
                        let written = rng.usize_below(20);
                        PrefillView {
                            request: iter * 100 + 50 + i as u64,
                            slot: 10 + i,
                            remaining: 1 + rng.usize_below(32),
                            written,
                            blocks_held: written.div_ceil(bs),
                            next_chunk: 1 + rng.usize_below(16),
                            cow_pending: rng.bool(0.3),
                        }
                    })
                    .collect();
                let q: Vec<QueuedRequest> = (0..rng.usize_below(4))
                    .map(|i| {
                        let prompt_len = 1 + rng.usize_below(64);
                        // a pinned prefix hit covers up to prompt_len - 1
                        // tokens; hit_blocks/cow are derived the way the
                        // engine derives them from a RadixCache match
                        let hit_tokens = rng.usize_below(prompt_len);
                        let suffix = prompt_len - hit_tokens;
                        QueuedRequest {
                            id: iter * 100 + 80 + i as u64,
                            prompt_len,
                            priority: rng.below(3) as i32,
                            arrival: i,
                            first_chunk: 1 + rng.usize_below(suffix.max(1)),
                            hit_tokens,
                            hit_blocks: hit_tokens.div_ceil(bs),
                            cow: hit_tokens % bs != 0,
                            deadline_us: u64::MAX,
                        }
                    })
                    .collect();
                let swapped: Vec<SwappedView> = (0..rng.usize_below(3))
                    .map(|i| SwappedView {
                        request: iter * 100 + 90 + i as u64,
                        priority: 0,
                        tokens: 1 + rng.usize_below(40),
                    })
                    .collect();
                let free_slots: Vec<usize> = (20..20 + rng.usize_below(3)).collect();
                let free_blocks = rng.usize_below(6);
                let v = SchedView {
                    queued: &q,
                    free_slots: &free_slots,
                    inflight: &inf,
                    decoding: &dec,
                    swapped: &swapped,
                    free_blocks,
                    block_size: bs,
                    can_preempt: true,
                };
                let plan = s.plan(&v);
                // Replay the block ledger the way the engine will.
                let mut avail = free_blocks;
                for p in &plan.preemptions {
                    let d = dec.iter().find(|d| d.request == p.request).unwrap();
                    prop_assert!(d.slot == p.slot);
                    avail += d.blocks_held;
                }
                // The sole decoder may only go via the last-resort
                // deadlock breaker: a plan that does nothing else, with
                // another block consumer waiting.
                let bare = plan.decode.is_none()
                    && plan.prefill_chunks.is_empty()
                    && plan.resumes.is_empty()
                    && plan.admissions.is_empty()
                    && plan.aborts.is_empty();
                prop_assert!(
                    plan.preemptions.len() < dec.len().max(1)
                        || (bare
                            && plan.preemptions.len() == 1
                            && !(inf.is_empty() && swapped.is_empty())),
                    "sole decoder preempted outside last resort: {plan:?}"
                );
                // Aborts are last-resort only: a lone abort in an
                // otherwise-empty plan, naming one of >= 2 real jobs.
                if !plan.aborts.is_empty() {
                    prop_assert!(plan.aborts.len() == 1);
                    prop_assert!(
                        plan.preemptions.is_empty()
                            && plan.decode.is_none()
                            && plan.prefill_chunks.is_empty()
                            && plan.resumes.is_empty()
                            && plan.admissions.is_empty()
                    );
                    let a = plan.aborts[0];
                    prop_assert!(inf.iter().any(|j| j.request == a.request && j.slot == a.slot));
                    prop_assert!(inf.len() > 1, "lone prefill job aborted");
                }
                let mut spend = 0usize;
                if let Some(b) = &plan.decode {
                    prop_assert!(b.slots.windows(2).all(|w| w[0] < w[1]));
                    prop_assert!(b.draft.len() == b.slots.len(), "ragged draft widths");
                    for (i, &slot) in b.slots.iter().enumerate() {
                        let d = dec.iter().find(|d| d.slot == slot).unwrap();
                        prop_assert!(
                            !plan.preemptions.iter().any(|p| p.slot == slot),
                            "decoding a preempted slot"
                        );
                        let w = b.draft[i];
                        prop_assert!(
                            w <= d.spec_window,
                            "granted draft {w} beyond requested window {}",
                            d.spec_window
                        );
                        // Charge what the engine will allocate: growth to
                        // cover the base write plus the granted drafts.
                        spend += kv::blocks_for(d.next_pos + 1 + w, bs)
                            .saturating_sub(d.table_blocks);
                    }
                }
                for r in &plan.resumes {
                    let sv = swapped.iter().find(|s| s.request == r.request).unwrap();
                    spend += (sv.tokens + 1).div_ceil(bs);
                }
                for a in &plan.admissions {
                    let qv = q.iter().find(|q| q.id == a.request).unwrap();
                    if plan.prefill_chunks.iter().any(|c| c.request == a.request) {
                        // prefix-aware: the hit blocks are already
                        // resident, only suffix growth + COW is new
                        spend += qv.admission_blocks(bs);
                    }
                }
                for c in &plan.prefill_chunks {
                    if let Some(j) = inf.iter().find(|j| j.request == c.request) {
                        spend += (j.written + j.next_chunk)
                            .div_ceil(bs)
                            .saturating_sub(j.blocks_held)
                            + j.cow_pending as usize;
                    }
                }
                prop_assert!(
                    spend <= avail,
                    "plan spends {spend} blocks with {avail} available: {plan:?}"
                );
                // Slot uniqueness across every plan component.
                let mut slots: Vec<usize> = plan
                    .resumes
                    .iter()
                    .map(|r| r.slot)
                    .chain(plan.admissions.iter().map(|a| a.slot))
                    .collect();
                slots.sort_unstable();
                slots.dedup();
                prop_assert!(slots.len() == plan.resumes.len() + plan.admissions.len());
            }
            Ok(())
        });
    }
}
