//! Iteration-level planning (Orca/vLLM-style continuous batching).
//!
//! Every engine iteration the scheduler inspects a [`SchedView`] — the
//! admission queue, free KV slots, in-flight prefill jobs, and active
//! decodes — and emits one composite [`StepPlan`]:
//!  * `admissions`      — queued requests to move into free slots now;
//!  * `prefill_chunks`  — one prompt chunk per in-flight prefill job to
//!    run this iteration (several jobs may be in flight concurrently, so
//!    a short prompt is not serialized behind a long one);
//!  * `decode`          — one batched decode step over the active slots,
//!    listed in sorted order so sampling is deterministic.
//!
//! Which queued requests are admitted first is the pluggable part: a
//! [`SchedulerPolicy`] ranks the queue snapshot ([`Fifo`],
//! [`ShortestPromptFirst`], [`PriorityFirst`]). Everything else — the
//! prefill/decode interleaving and the starvation guard that caps
//! consecutive prefill-only iterations so a flood of new prompts cannot
//! stall in-flight decodes (the regime the paper's Fig 13 measures) — is
//! policy-independent, which is what keeps batching invariance (same
//! tokens for a request regardless of policy or batch-mates) easy to
//! preserve: policies reorder *work*, never *sampling*.

use super::request::RequestId;

// ---------------------------------------------------------------------------
// What the scheduler sees.
// ---------------------------------------------------------------------------

/// Snapshot of one queued (not yet admitted) request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueuedRequest {
    pub id: RequestId,
    pub prompt_len: usize,
    /// Larger = more urgent. Carried on [`super::request::SamplingParams`].
    pub priority: i32,
    /// Position in the admission queue (0 = oldest): the FIFO key.
    pub arrival: usize,
}

/// Snapshot of one in-flight prefill job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillView {
    pub request: RequestId,
    pub slot: usize,
    /// Prompt tokens not yet written to the KV cache.
    pub remaining: usize,
}

/// Everything a plan is built from. Borrowed snapshots: the scheduler
/// never touches engine state directly.
#[derive(Debug, Clone, Copy)]
pub struct SchedView<'a> {
    pub queued: &'a [QueuedRequest],
    /// Free KV slots, ascending.
    pub free_slots: &'a [usize],
    /// In-flight prefill jobs, slot-ascending (the engine's `PrefillSet`
    /// is keyed by slot); the plan's chunk order follows this order.
    pub inflight: &'a [PrefillView],
    /// Slots currently decoding, ascending.
    pub active_slots: &'a [usize],
}

// ---------------------------------------------------------------------------
// What the scheduler emits.
// ---------------------------------------------------------------------------

/// Admit `request` from the queue into KV slot `slot`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admission {
    pub request: RequestId,
    pub slot: usize,
}

/// Run one prompt chunk for the prefill job occupying `slot`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkSpec {
    pub request: RequestId,
    pub slot: usize,
}

/// One batched decode step; `slots` is sorted ascending and sampling
/// follows that order (deterministic, not HashMap iteration order).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct DecodeBatch {
    pub slots: Vec<usize>,
}

/// The composite plan for one engine iteration. Admissions execute
/// first (so a chunk may target a request admitted by the same plan),
/// then prefill chunks, then the decode step.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct StepPlan {
    pub admissions: Vec<Admission>,
    pub prefill_chunks: Vec<ChunkSpec>,
    pub decode: Option<DecodeBatch>,
}

impl StepPlan {
    pub fn is_idle(&self) -> bool {
        self.admissions.is_empty()
            && self.prefill_chunks.is_empty()
            && self.decode.is_none()
    }
}

/// What one executed plan actually did (returned by the engine's `step`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StepOutcome {
    pub admitted: usize,
    pub prefill_chunks: usize,
    pub decoded_slots: usize,
}

impl StepOutcome {
    pub fn did_work(&self) -> bool {
        self.admitted > 0 || self.prefill_chunks > 0 || self.decoded_slots > 0
    }
}

// ---------------------------------------------------------------------------
// Policies: how the admission queue is ranked.
// ---------------------------------------------------------------------------

/// Ranks queued requests for admission. Policies only order work — the
/// plan assembly, chunking, and starvation guard live in [`Scheduler`] —
/// so a request's token stream cannot depend on the policy in force.
pub trait SchedulerPolicy: Send {
    fn name(&self) -> &'static str;
    /// Request ids in admission order, most urgent first. Must be a
    /// permutation of `queued`.
    fn admission_order(&mut self, queued: &[QueuedRequest]) -> Vec<RequestId>;
}

/// Seed-compatible first-come-first-served admission.
#[derive(Debug, Default)]
pub struct Fifo;

impl SchedulerPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn admission_order(&mut self, queued: &[QueuedRequest]) -> Vec<RequestId> {
        // The engine's snapshot is already arrival-ordered (arrival is
        // the queue index), so FIFO is the identity permutation.
        queued.iter().map(|r| r.id).collect()
    }
}

/// Shortest prompt first (ties broken by arrival): minimizes mean
/// time-to-first-token under bursty mixed-length traffic, at the price
/// of long prompts waiting out bursts of short ones.
#[derive(Debug, Default)]
pub struct ShortestPromptFirst;

impl SchedulerPolicy for ShortestPromptFirst {
    fn name(&self) -> &'static str {
        "spf"
    }

    fn admission_order(&mut self, queued: &[QueuedRequest]) -> Vec<RequestId> {
        let mut q: Vec<&QueuedRequest> = queued.iter().collect();
        q.sort_by_key(|r| (r.prompt_len, r.arrival));
        q.into_iter().map(|r| r.id).collect()
    }
}

/// Highest `SamplingParams::priority` first (ties broken by arrival):
/// the quality-vs-latency variant-routing story — latency-pinned traffic
/// jumps the queue.
#[derive(Debug, Default)]
pub struct PriorityFirst;

impl SchedulerPolicy for PriorityFirst {
    fn name(&self) -> &'static str {
        "priority"
    }

    fn admission_order(&mut self, queued: &[QueuedRequest]) -> Vec<RequestId> {
        let mut q: Vec<&QueuedRequest> = queued.iter().collect();
        q.sort_by_key(|r| (std::cmp::Reverse(r.priority), r.arrival));
        q.into_iter().map(|r| r.id).collect()
    }
}

/// Config-friendly policy selector (the trait object itself is not
/// Clone, so [`super::engine_loop::EngineConfig`] carries this instead).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    #[default]
    Fifo,
    ShortestPromptFirst,
    Priority,
}

impl PolicyKind {
    pub fn build(self) -> Box<dyn SchedulerPolicy> {
        match self {
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::ShortestPromptFirst => Box::new(ShortestPromptFirst),
            PolicyKind::Priority => Box::new(PriorityFirst),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::ShortestPromptFirst => "spf",
            PolicyKind::Priority => "priority",
        }
    }

    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "fifo" => Some(PolicyKind::Fifo),
            "spf" | "shortest-prompt-first" => Some(PolicyKind::ShortestPromptFirst),
            "priority" => Some(PolicyKind::Priority),
            _ => None,
        }
    }

    /// Every shipped policy (batching-invariance tests sweep this).
    pub fn all() -> [PolicyKind; 3] {
        [PolicyKind::Fifo, PolicyKind::ShortestPromptFirst, PolicyKind::Priority]
    }
}

// ---------------------------------------------------------------------------
// The policy-independent plan assembly.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    pub policy: PolicyKind,
    /// Starvation guard: max consecutive prefill *chunks* (model calls)
    /// while decodes are pending — the same unit as the seed's
    /// single-chunk iterations, so the decode-stall bound does not grow
    /// with `chunk_budget`.
    pub max_consecutive_prefills: usize,
    /// How many prefill jobs may be in flight at once (the PrefillSet
    /// size cap). 1 reproduces the seed single-prefill behavior.
    pub max_concurrent_prefills: usize,
    /// How many prefill chunks (distinct jobs) run per iteration.
    pub chunk_budget: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            policy: PolicyKind::Fifo,
            max_consecutive_prefills: 4,
            max_concurrent_prefills: 2,
            chunk_budget: 2,
        }
    }
}

impl SchedulerConfig {
    /// The seed engine's behavior: FIFO, at most one prefill job in
    /// flight, one chunk per iteration. Benchmarks use this baseline.
    pub fn single_prefill() -> Self {
        SchedulerConfig {
            max_concurrent_prefills: 1,
            chunk_budget: 1,
            ..Default::default()
        }
    }

    pub fn with_policy(policy: PolicyKind) -> Self {
        SchedulerConfig { policy, ..Default::default() }
    }
}

pub struct Scheduler {
    cfg: SchedulerConfig,
    policy: Box<dyn SchedulerPolicy>,
    /// Prefill chunks issued since the last decode turn (guard counter).
    consecutive_prefills: usize,
    /// Round-robin cursor so jobs beyond the chunk budget are not starved.
    chunk_rr: usize,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        let policy = cfg.policy.build();
        Scheduler { cfg, policy, consecutive_prefills: 0, chunk_rr: 0 }
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Build the next iteration's plan. Mirrors the seed decision tree:
    /// prefill-bearing iterations are prioritized (slots fill fastest,
    /// maximizing decode occupancy) until the starvation guard trips,
    /// then the pending decodes get a turn.
    pub fn plan(&mut self, view: &SchedView) -> StepPlan {
        let concurrency = self.cfg.max_concurrent_prefills.max(1);
        let can_admit = !view.queued.is_empty()
            && !view.free_slots.is_empty()
            && view.inflight.len() < concurrency;
        let want_prefill = !view.inflight.is_empty() || can_admit;
        let active = view.active_slots.len();
        let starving = active > 0
            && self.consecutive_prefills >= self.cfg.max_consecutive_prefills;

        let mut plan = StepPlan::default();
        if want_prefill && !starving {
            // While decodes are pending, never issue more chunks than the
            // guard has left (so the stall bound is exactly the guard, not
            // guard + chunk_budget - 1); with nothing to decode the guard
            // is moot and the budget alone caps the plan.
            let allowance = if active > 0 {
                self.cfg
                    .max_consecutive_prefills
                    .saturating_sub(self.consecutive_prefills)
            } else {
                usize::MAX
            };
            self.fill_prefill(view, &mut plan, allowance);
        } else if active > 0 {
            plan.decode = Some(DecodeBatch { slots: view.active_slots.to_vec() });
        }

        if !plan.prefill_chunks.is_empty() {
            self.consecutive_prefills += plan.prefill_chunks.len();
        } else {
            self.consecutive_prefills = 0;
        }
        plan
    }

    fn fill_prefill(&mut self, view: &SchedView, plan: &mut StepPlan,
                    allowance: usize) {
        let concurrency = self.cfg.max_concurrent_prefills.max(1);
        let budget = self.cfg.chunk_budget.max(1).min(allowance.max(1));

        // Jobs to advance this iteration: in-flight first (the view's
        // slot order — ascending per the SchedView contract — keeps this
        // deterministic), then fresh admissions chosen by the policy.
        let mut jobs: Vec<(RequestId, usize)> = view
            .inflight
            .iter()
            .map(|j| (j.request, j.slot))
            .collect();

        let mut free = view.free_slots.iter().copied();
        if jobs.len() < concurrency && !view.queued.is_empty() {
            for id in self.policy.admission_order(view.queued) {
                if jobs.len() >= concurrency {
                    break;
                }
                let Some(slot) = free.next() else { break };
                plan.admissions.push(Admission { request: id, slot });
                jobs.push((id, slot));
            }
        }

        // One chunk per job, up to the budget, rotating the starting job
        // across iterations so a wide PrefillSet shares the budget fairly.
        if jobs.is_empty() {
            return;
        }
        let n = jobs.len();
        let take = n.min(budget);
        let start = self.chunk_rr % n;
        for k in 0..take {
            let (request, slot) = jobs[(start + k) % n];
            plan.prefill_chunks.push(ChunkSpec { request, slot });
        }
        self.chunk_rr = (start + take) % n.max(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::property;

    fn queued(specs: &[(RequestId, usize, i32)]) -> Vec<QueuedRequest> {
        specs
            .iter()
            .enumerate()
            .map(|(arrival, &(id, prompt_len, priority))| QueuedRequest {
                id,
                prompt_len,
                priority,
                arrival,
            })
            .collect()
    }

    #[test]
    fn idle_when_nothing_to_do() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let plan = s.plan(&SchedView {
            queued: &[],
            free_slots: &[0, 1],
            inflight: &[],
            active_slots: &[],
        });
        assert!(plan.is_idle());
    }

    #[test]
    fn admits_multiple_requests_up_to_concurrency() {
        let mut s = Scheduler::new(SchedulerConfig::default()); // concurrency 2
        let q = queued(&[(1, 8, 0), (2, 8, 0), (3, 8, 0)]);
        let plan = s.plan(&SchedView {
            queued: &q,
            free_slots: &[0, 1, 2, 3],
            inflight: &[],
            active_slots: &[],
        });
        assert_eq!(
            plan.admissions,
            vec![
                Admission { request: 1, slot: 0 },
                Admission { request: 2, slot: 1 }
            ]
        );
        assert_eq!(plan.prefill_chunks.len(), 2);
        assert!(plan.decode.is_none());
    }

    #[test]
    fn continues_inflight_even_with_no_free_slots() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let inflight = [PrefillView { request: 7, slot: 3, remaining: 4 }];
        let plan = s.plan(&SchedView {
            queued: &[],
            free_slots: &[],
            inflight: &inflight,
            active_slots: &[0, 1],
        });
        assert_eq!(plan.prefill_chunks,
                   vec![ChunkSpec { request: 7, slot: 3 }]);
        assert!(plan.admissions.is_empty());
    }

    #[test]
    fn starvation_guard_gives_decodes_a_turn() {
        // Guard of 4 *chunks* with 2-chunk plans: two prefill plans, then
        // the pending decodes get a turn.
        let mut s = Scheduler::new(SchedulerConfig {
            max_consecutive_prefills: 4,
            ..Default::default()
        });
        let q = queued(&[(1, 64, 0), (2, 64, 0), (3, 64, 0), (4, 64, 0)]);
        let view = SchedView {
            queued: &q,
            free_slots: &[4, 5, 6, 7],
            inflight: &[],
            active_slots: &[0, 1, 2],
        };
        assert_eq!(s.plan(&view).prefill_chunks.len(), 2);
        assert_eq!(s.plan(&view).prefill_chunks.len(), 2);
        // Guard trips: decode-only plan, sorted slots.
        let p3 = s.plan(&view);
        assert!(p3.prefill_chunks.is_empty());
        assert_eq!(p3.decode, Some(DecodeBatch { slots: vec![0, 1, 2] }));
        // Counter reset: prefill again.
        assert!(!s.plan(&view).prefill_chunks.is_empty());
    }

    #[test]
    fn starvation_guard_counts_chunks_not_iterations() {
        // One 2-chunk plan already reaches a guard of 2: the seed's
        // decode-stall bound (in model calls) survives chunk_budget > 1.
        let mut s = Scheduler::new(SchedulerConfig {
            max_consecutive_prefills: 2,
            ..Default::default()
        });
        let q = queued(&[(1, 64, 0), (2, 64, 0)]);
        let view = SchedView {
            queued: &q,
            free_slots: &[4, 5],
            inflight: &[],
            active_slots: &[0],
        };
        assert_eq!(s.plan(&view).prefill_chunks.len(), 2);
        assert!(s.plan(&view).decode.is_some(),
                "2 chunks hit the guard of 2");
    }

    #[test]
    fn decode_when_no_prefill_possible() {
        let mut s = Scheduler::new(SchedulerConfig::default());
        let q = queued(&[(9, 4, 0)]);
        let plan = s.plan(&SchedView {
            queued: &q,
            free_slots: &[], // queue deep but no slot: decode
            inflight: &[],
            active_slots: &[2, 5],
        });
        assert_eq!(plan.decode, Some(DecodeBatch { slots: vec![2, 5] }));
        assert!(plan.admissions.is_empty());
    }

    #[test]
    fn prefill_allowed_when_no_decodes_regardless_of_guard() {
        let mut s = Scheduler::new(SchedulerConfig {
            max_consecutive_prefills: 1,
            ..Default::default()
        });
        let q = queued(&[(1, 4, 0), (2, 4, 0), (3, 4, 0)]);
        for _ in 0..5 {
            let inflight = [PrefillView { request: 1, slot: 0, remaining: 64 }];
            let plan = s.plan(&SchedView {
                queued: &q,
                free_slots: &[1, 2],
                inflight: &inflight,
                active_slots: &[],
            });
            assert!(!plan.prefill_chunks.is_empty());
        }
    }

    #[test]
    fn policies_rank_admissions() {
        // id 1: long prompt, low priority, first in.
        // id 2: short prompt, mid priority.
        // id 3: mid prompt, high priority, last in.
        let q = queued(&[(1, 32, 0), (2, 4, 1), (3, 16, 9)]);
        assert_eq!(Fifo.admission_order(&q), vec![1, 2, 3]);
        assert_eq!(ShortestPromptFirst.admission_order(&q), vec![2, 3, 1]);
        assert_eq!(PriorityFirst.admission_order(&q), vec![3, 2, 1]);
    }

    #[test]
    fn policy_kind_parses() {
        assert_eq!(PolicyKind::parse("fifo"), Some(PolicyKind::Fifo));
        assert_eq!(PolicyKind::parse("spf"),
                   Some(PolicyKind::ShortestPromptFirst));
        assert_eq!(PolicyKind::parse("shortest-prompt-first"),
                   Some(PolicyKind::ShortestPromptFirst));
        assert_eq!(PolicyKind::parse("priority"), Some(PolicyKind::Priority));
        assert_eq!(PolicyKind::parse("nope"), None);
        for kind in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(kind.name()), Some(kind));
            // The trait impl's name must agree with the enum's, or the
            // stats op would report a policy that --policy rejects.
            assert_eq!(kind.build().name(), kind.name());
        }
    }

    #[test]
    fn chunk_budget_rotates_across_jobs() {
        // 3 in-flight jobs, budget 2: over two iterations every job gets
        // at least one chunk.
        let mut s = Scheduler::new(SchedulerConfig {
            max_concurrent_prefills: 3,
            chunk_budget: 2,
            ..Default::default()
        });
        let inflight = [
            PrefillView { request: 1, slot: 0, remaining: 64 },
            PrefillView { request: 2, slot: 1, remaining: 64 },
            PrefillView { request: 3, slot: 2, remaining: 64 },
        ];
        let view = SchedView {
            queued: &[],
            free_slots: &[],
            inflight: &inflight,
            active_slots: &[],
        };
        let mut seen = std::collections::HashSet::new();
        for _ in 0..2 {
            for c in s.plan(&view).prefill_chunks {
                seen.insert(c.request);
            }
        }
        assert_eq!(seen.len(), 3, "every job chunked within two iterations");
    }

    #[test]
    fn prop_no_starvation() {
        // Under any adversarial view stream with decodes always pending,
        // at most `guard` consecutive prefill-bearing plans occur between
        // decode plans, and the scheduler never goes idle.
        property("decode starvation bounded", 100, |rng| {
            let guard = 1 + rng.usize_below(6);
            let mut s = Scheduler::new(SchedulerConfig {
                max_consecutive_prefills: guard,
                max_concurrent_prefills: 1 + rng.usize_below(4),
                chunk_budget: 1 + rng.usize_below(4),
                ..Default::default()
            });
            let mut run = 0usize;
            for iter in 0..200u64 {
                let q: Vec<QueuedRequest> = (0..rng.usize_below(10))
                    .map(|i| QueuedRequest {
                        id: iter * 100 + i as u64,
                        prompt_len: 1 + rng.usize_below(64),
                        priority: rng.below(5) as i32,
                        arrival: i,
                    })
                    .collect();
                let free: Vec<usize> =
                    (8..8 + rng.usize_below(4)).collect();
                let inflight: Vec<PrefillView> = (0..rng.usize_below(3))
                    .map(|i| PrefillView {
                        request: iter * 100 + 50 + i as u64,
                        slot: 20 + i,
                        remaining: 1 + rng.usize_below(32),
                    })
                    .collect();
                let n_active = 1 + rng.usize_below(8); // always pending
                let active: Vec<usize> = (0..n_active).collect();
                let plan = s.plan(&SchedView {
                    queued: &q,
                    free_slots: &free,
                    inflight: &inflight,
                    active_slots: &active,
                });
                prop_assert!(!plan.is_idle(), "idle while decodes active");
                if !plan.prefill_chunks.is_empty() {
                    // A prefill plan is only issued while the chunk count
                    // since the last decode is under the guard, and its
                    // chunks never push the total past the guard.
                    prop_assert!(run < guard,
                                 "prefill planned at {run} chunks >= guard {guard}");
                    run += plan.prefill_chunks.len();
                    prop_assert!(run <= guard,
                                 "{run} chunks since last decode > guard {guard}");
                } else {
                    prop_assert!(plan.decode.is_some(),
                                 "plan neither prefills nor decodes");
                    run = 0;
                }
            }
            Ok(())
        });
    }
}
