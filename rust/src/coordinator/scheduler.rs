//! Iteration-level scheduling policy (Orca/vLLM-style).
//!
//! Every engine iteration the scheduler picks ONE action:
//!  * `Prefill` — admit the queue head into a free KV slot and run one
//!    prompt chunk (prefill-prioritized keeps slots full, which maximizes
//!    decode-batch occupancy — the whole point of continuous batching);
//!  * `Decode`  — one batched decode step for all active slots;
//!  * `Idle`    — nothing to do.
//!
//! A starvation guard caps consecutive prefill actions so a flood of new
//! prompts cannot stall in-flight decodes indefinitely (the paper's Fig 13
//! measures exactly this interleaved decode regime).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Run a prefill chunk for the queue head (slot to use, whether this
    /// is a fresh admission needing a slot).
    Prefill,
    /// Run one batched decode step.
    Decode,
    Idle,
}

#[derive(Debug, Clone)]
pub struct SchedulerPolicy {
    /// Max prefill actions in a row while decodes are pending.
    pub max_consecutive_prefills: usize,
}

impl Default for SchedulerPolicy {
    fn default() -> Self {
        SchedulerPolicy { max_consecutive_prefills: 4 }
    }
}

#[derive(Debug)]
pub struct Scheduler {
    policy: SchedulerPolicy,
    consecutive_prefills: usize,
    pub prefill_actions: u64,
    pub decode_actions: u64,
}

impl Scheduler {
    pub fn new(policy: SchedulerPolicy) -> Self {
        Scheduler {
            policy,
            consecutive_prefills: 0,
            prefill_actions: 0,
            decode_actions: 0,
        }
    }

    /// Decide the next action given the observable state.
    pub fn decide(&mut self, queued: usize, active_decodes: usize,
                  free_slots: usize, pending_prefill: bool) -> Action {
        // An in-flight multi-chunk prefill always continues first: its
        // slot is claimed and useless until the prompt is in the cache.
        let want_prefill = pending_prefill || (queued > 0 && free_slots > 0);
        let starving = active_decodes > 0
            && self.consecutive_prefills >= self.policy.max_consecutive_prefills;
        let action = if want_prefill && !starving {
            Action::Prefill
        } else if active_decodes > 0 {
            Action::Decode
        } else if want_prefill {
            // nothing to decode; starvation guard is moot
            Action::Prefill
        } else {
            Action::Idle
        };
        match action {
            Action::Prefill => {
                self.consecutive_prefills += 1;
                self.prefill_actions += 1;
            }
            Action::Decode => {
                self.consecutive_prefills = 0;
                self.decode_actions += 1;
            }
            Action::Idle => {
                self.consecutive_prefills = 0;
            }
        }
        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::property;

    #[test]
    fn idle_when_nothing_to_do() {
        let mut s = Scheduler::new(SchedulerPolicy::default());
        assert_eq!(s.decide(0, 0, 8, false), Action::Idle);
    }

    #[test]
    fn prefill_prioritized_until_guard() {
        let mut s = Scheduler::new(SchedulerPolicy { max_consecutive_prefills: 2 });
        // active decodes exist, queue is deep, slots free
        assert_eq!(s.decide(10, 3, 5, false), Action::Prefill);
        assert_eq!(s.decide(10, 3, 5, false), Action::Prefill);
        // guard trips -> decode gets a turn
        assert_eq!(s.decide(10, 3, 5, false), Action::Decode);
        // counter reset -> prefill again
        assert_eq!(s.decide(10, 3, 5, false), Action::Prefill);
    }

    #[test]
    fn decode_when_no_free_slots() {
        let mut s = Scheduler::new(SchedulerPolicy::default());
        assert_eq!(s.decide(5, 8, 0, false), Action::Decode);
    }

    #[test]
    fn pending_prefill_continues_even_with_full_slots() {
        let mut s = Scheduler::new(SchedulerPolicy::default());
        assert_eq!(s.decide(0, 3, 0, true), Action::Prefill);
    }

    #[test]
    fn prefill_allowed_when_no_decodes_regardless_of_guard() {
        let mut s = Scheduler::new(SchedulerPolicy { max_consecutive_prefills: 1 });
        for _ in 0..5 {
            assert_eq!(s.decide(3, 0, 2, false), Action::Prefill);
        }
    }

    #[test]
    fn prop_no_starvation() {
        // Under any adversarial (queued, free) stream, between any two
        // decode opportunities with active decodes, at most
        // max_consecutive_prefills prefills happen.
        property("decode starvation bounded", 100, |rng| {
            let guard = 1 + rng.usize_below(6);
            let mut s = Scheduler::new(SchedulerPolicy {
                max_consecutive_prefills: guard,
            });
            let mut run = 0usize;
            for _ in 0..200 {
                let queued = rng.usize_below(10);
                let free = rng.usize_below(4);
                let active = 1 + rng.usize_below(8); // decodes always pending
                match s.decide(queued, active, free, rng.bool(0.2)) {
                    Action::Prefill => {
                        run += 1;
                        prop_assert!(run <= guard,
                                     "{run} consecutive prefills > guard {guard}");
                    }
                    Action::Decode => run = 0,
                    Action::Idle => {
                        prop_assert!(false, "idle while decodes active");
                    }
                }
            }
            Ok(())
        });
    }
}
