//! Analytic roofline cost model (reproduces Fig 1b and the theoretical
//! side of Fig 13).
//!
//! The paper's motivating breakdown runs Falcon-7B on an RTX 4090 and
//! shows parameter-loading I/O dominating auto-regressive decode, with
//! FFN I/O alone at 78.2% of inference time. We model each transformer
//! block as (bytes moved, flops executed) per token and take
//! `time = max(bytes / bandwidth, flops / peak_flops)` per component
//! (I/O and compute overlap on GPUs; the paper's Figure 1b reports the
//! two sides separately, which we also expose).

/// Hardware description. Defaults model the paper's RTX 4090:
/// ~1 TB/s VRAM bandwidth, ~82.6 TFLOP/s fp16 tensor throughput.
#[derive(Debug, Clone, Copy)]
pub struct HwSpec {
    pub name: &'static str,
    pub mem_bw_gbs: f64,
    pub peak_tflops: f64,
}

pub const RTX_4090: HwSpec =
    HwSpec { name: "rtx4090", mem_bw_gbs: 1008.0, peak_tflops: 82.6 };

/// This repo's actual testbed (single-core CPU PJRT). Rough numbers used
/// only for sanity overlays, never for paper claims.
pub const CPU_1CORE: HwSpec =
    HwSpec { name: "cpu-1core", mem_bw_gbs: 20.0, peak_tflops: 0.05 };

/// Transformer shape. `dtype_bytes` = 2 for the fp16 deployments the
/// paper measures.
#[derive(Debug, Clone, Copy)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub dtype_bytes: usize,
    /// attention projection parameter count per layer as a multiple of
    /// d^2 (4 for full MHA; ~2.06 for Falcon's multi-query attention:
    /// query d^2 + fused dense d^2 + a single 64-wide K/V head).
    pub attn_param_factor: f64,
    /// per-token K (or V) cache width: d_model for MHA, one head (64)
    /// for Falcon-style multi-query attention.
    pub kv_dim: usize,
}

pub const FALCON_7B: ModelSpec = ModelSpec {
    name: "falcon-7b",
    n_layers: 32,
    d_model: 4544,
    d_ff: 4 * 4544,
    vocab: 65024,
    dtype_bytes: 2,
    attn_param_factor: 2.06,
    kv_dim: 64,
};

pub const TINY_GELU: ModelSpec = ModelSpec {
    name: "tiny-gelu",
    n_layers: 4,
    d_model: 128,
    d_ff: 512,
    vocab: 256,
    dtype_bytes: 4,
    attn_param_factor: 4.0,
    kv_dim: 128,
};

impl ModelSpec {
    pub fn attn_params_per_layer(&self) -> f64 {
        self.attn_param_factor * (self.d_model as f64) * (self.d_model as f64)
    }

    pub fn ffn_params_per_layer(&self) -> f64 {
        2.0 * self.d_model as f64 * self.d_ff as f64
    }

    pub fn total_params(&self) -> f64 {
        let per_layer = self.attn_params_per_layer() + self.ffn_params_per_layer();
        // tied input/output embedding counted once (Falcon/GPT-2 style)
        self.n_layers as f64 * per_layer
            + self.d_model as f64 * self.vocab as f64
    }

    pub fn ffn_param_fraction(&self) -> f64 {
        self.n_layers as f64 * self.ffn_params_per_layer() / self.total_params()
    }
}

/// Per-component cost of one generation step over a batch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BlockCost {
    pub io_s: f64,
    pub compute_s: f64,
}

impl BlockCost {
    pub fn bound(&self) -> f64 {
        self.io_s.max(self.compute_s)
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct StepBreakdown {
    pub attn: BlockCost,
    pub ffn: BlockCost,
}

impl StepBreakdown {
    pub fn total_s(&self) -> f64 {
        self.attn.bound() + self.ffn.bound()
    }
}

/// Cost of one auto-regressive decode step: every parameter is loaded
/// once; each parameter contributes 2 flops per token in the batch.
pub fn decode_step(
    model: &ModelSpec,
    hw: &HwSpec,
    batch: usize,
    ctx_len: usize,
) -> StepBreakdown {
    let b = batch as f64;
    let attn_p = model.n_layers as f64 * model.attn_params_per_layer();
    let ffn_p = model.n_layers as f64 * model.ffn_params_per_layer();
    // KV cache reads for attention over the context.
    let kv_bytes = model.n_layers as f64
        * 2.0
        * b
        * ctx_len as f64
        * model.kv_dim as f64
        * model.dtype_bytes as f64;
    let bw = hw.mem_bw_gbs * 1e9;
    let fl = hw.peak_tflops * 1e12;
    let attn = BlockCost {
        io_s: (attn_p * model.dtype_bytes as f64 + kv_bytes) / bw,
        compute_s: (2.0 * attn_p * b
            + 2.0 * model.n_layers as f64 * 2.0 * b * ctx_len as f64
                * model.d_model as f64)
            / fl,
    };
    let ffn = BlockCost {
        io_s: ffn_p * model.dtype_bytes as f64 / bw,
        compute_s: 2.0 * ffn_p * b / fl,
    };
    StepBreakdown { attn, ffn }
}

/// Cost of prefilling `prompt` tokens (parameters loaded once; compute
/// scales with prompt length).
pub fn prefill(
    model: &ModelSpec,
    hw: &HwSpec,
    batch: usize,
    prompt: usize,
) -> StepBreakdown {
    let tokens = (batch * prompt) as f64;
    let attn_p = model.n_layers as f64 * model.attn_params_per_layer();
    let ffn_p = model.n_layers as f64 * model.ffn_params_per_layer();
    let bw = hw.mem_bw_gbs * 1e9;
    let fl = hw.peak_tflops * 1e12;
    let attn = BlockCost {
        io_s: attn_p * model.dtype_bytes as f64 / bw,
        compute_s: (2.0 * attn_p * tokens
            + 2.0 * model.n_layers as f64 * (prompt as f64)
                * tokens * model.d_model as f64)
            / fl,
    };
    let ffn = BlockCost {
        io_s: ffn_p * model.dtype_bytes as f64 / bw,
        compute_s: 2.0 * ffn_p * tokens / fl,
    };
    StepBreakdown { attn, ffn }
}

/// Fig 1b: fraction of end-to-end time per (block, io/compute) cell for a
/// `prompt`-token prefill plus `gen` decode steps.
#[derive(Debug, Clone, Copy)]
pub struct InferenceBreakdown {
    pub attn_io: f64,
    pub attn_compute: f64,
    pub ffn_io: f64,
    pub ffn_compute: f64,
    pub total_s: f64,
}

pub fn inference_breakdown(
    model: &ModelSpec,
    hw: &HwSpec,
    batch: usize,
    prompt: usize,
    gen: usize,
) -> InferenceBreakdown {
    let pre = prefill(model, hw, batch, prompt);
    let mut attn = BlockCost { io_s: pre.attn.io_s, compute_s: pre.attn.compute_s };
    let mut ffn = BlockCost { io_s: pre.ffn.io_s, compute_s: pre.ffn.compute_s };
    for step in 0..gen {
        let d = decode_step(model, hw, batch, prompt + step);
        attn.io_s += d.attn.io_s;
        attn.compute_s += d.attn.compute_s;
        ffn.io_s += d.ffn.io_s;
        ffn.compute_s += d.ffn.compute_s;
    }
    let total = attn.io_s + attn.compute_s + ffn.io_s + ffn.compute_s;
    InferenceBreakdown {
        attn_io: attn.io_s / total,
        attn_compute: attn.compute_s / total,
        ffn_io: ffn.io_s / total,
        ffn_compute: ffn.compute_s / total,
        total_s: total,
    }
}

/// Theoretical FFN + end-to-end speedup of a TARDIS fold at `ratio`
/// FFN-parameter compression (the model for Fig 13's upper envelope).
/// `fix_fraction` = expected share of original FFN weights touched by the
/// result-fixing path per step.
pub fn tardis_speedup(
    model: &ModelSpec,
    hw: &HwSpec,
    batch: usize,
    ctx: usize,
    ratio: f64,
    fix_fraction: f64,
) -> (f64, f64) {
    let base = decode_step(model, hw, batch, ctx);
    let ffn_scale = (1.0 - ratio) + fix_fraction;
    let folded_ffn = BlockCost {
        io_s: base.ffn.io_s * ffn_scale,
        compute_s: base.ffn.compute_s * ffn_scale,
    };
    let ffn_speedup = base.ffn.bound() / folded_ffn.bound();
    let e2e = (base.attn.bound() + base.ffn.bound())
        / (base.attn.bound() + folded_ffn.bound());
    (ffn_speedup, e2e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn falcon_ffn_fraction_matches_paper() {
        // Paper Table 2: ~80% of Falcon-7B parameters are FFN.
        let f = FALCON_7B.ffn_param_fraction();
        assert!(f > 0.70 && f < 0.85, "fraction {f}");
    }

    #[test]
    fn falcon_param_count_near_7b() {
        let p = FALCON_7B.total_params();
        assert!(p > 6.0e9 && p < 8.5e9, "params {p}");
    }

    #[test]
    fn decode_is_io_bound_on_4090() {
        let d = decode_step(&FALCON_7B, &RTX_4090, 1, 128);
        assert!(d.ffn.io_s > d.ffn.compute_s * 10.0);
        assert!(d.attn.io_s > d.attn.compute_s);
    }

    #[test]
    fn fig1b_ffn_io_dominates() {
        // Paper: FFN I/O alone is 78.2% of inference time (91 + 178 tok).
        let b = inference_breakdown(&FALCON_7B, &RTX_4090, 1, 91, 178);
        assert!(b.ffn_io > 0.65 && b.ffn_io < 0.90, "ffn_io {}", b.ffn_io);
        assert!(b.ffn_io > b.attn_io);
        assert!((b.attn_io + b.attn_compute + b.ffn_io + b.ffn_compute - 1.0)
            .abs() < 1e-9);
    }

    #[test]
    fn tardis_speedup_increases_with_ratio() {
        let (f50, e50) = tardis_speedup(&FALCON_7B, &RTX_4090, 1, 128, 0.5, 0.05);
        let (f80, e80) = tardis_speedup(&FALCON_7B, &RTX_4090, 1, 128, 0.8, 0.05);
        assert!(f80 > f50 && f50 > 1.0);
        assert!(e80 > e50 && e50 > 1.0);
        // Paper's headline region: ~1.86x FFN, ~1.6x e2e at 80%.
        assert!(f80 > 1.5 && f80 < 6.0, "ffn speedup {f80}");
        assert!(e80 > 1.2, "e2e speedup {e80}");
    }

    #[test]
    fn prefill_compute_grows_with_prompt() {
        let short = prefill(&FALCON_7B, &RTX_4090, 1, 16);
        let long = prefill(&FALCON_7B, &RTX_4090, 1, 512);
        assert!(long.ffn.compute_s > short.ffn.compute_s * 20.0);
        assert_eq!(long.ffn.io_s, short.ffn.io_s); // params loaded once
    }
}
