//! Dense FFN reference path: `y = σ(x·W_up + b_up)·W_down + b_down`.
//!
//! For TARDIS variants the first `linear_units` hidden units carry a
//! [`Linearization`]: inside the approximated range `[lo, hi)` the
//! activation is replaced by its least-squares linear fit `a·z + c`
//! (paper §5.1), outside it the true GELU applies. This partially-linear
//! dense path is both the semantic reference the fold must reproduce and
//! the fallback executed for predicted-outlier rows.
//!
//! Both projections are pre-packed ([`PackedMatrix`]) at construction;
//! the pure-GELU path fuses bias+activation into the up-projection's
//! tile store, and `forward` draws every intermediate from the caller's
//! [`Scratch`] arena.

use std::sync::Arc;

use crate::util::threadpool::ThreadPool;

use super::kernels::{gelu, matmul, Epilogue, PackedMatrix, Scratch};

/// Least-squares linear surrogate of the activation on `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Linearization {
    pub lo: f32,
    pub hi: f32,
    pub slope: f32,
    pub intercept: f32,
}

impl Linearization {
    /// Fit `a·z + c` to GELU over `[lo, hi]` by least squares on a dense
    /// uniform grid (f64 accumulation; deterministic).
    pub fn fit_gelu(lo: f32, hi: f32) -> Linearization {
        assert!(lo < hi, "empty linear range [{lo}, {hi})");
        const GRID: usize = 1024;
        let (lo64, hi64) = (lo as f64, hi as f64);
        let (mut sz, mut sy, mut szz, mut szy) = (0f64, 0f64, 0f64, 0f64);
        for i in 0..=GRID {
            let z = lo64 + (hi64 - lo64) * i as f64 / GRID as f64;
            let y = gelu(z as f32) as f64;
            sz += z;
            sy += y;
            szz += z * z;
            szy += z * y;
        }
        let n = (GRID + 1) as f64;
        let denom = n * szz - sz * sz;
        let a = (n * szy - sz * sy) / denom;
        let c = (sy - a * sz) / n;
        Linearization {
            lo,
            hi,
            slope: a as f32,
            intercept: c as f32,
        }
    }

    /// The deployed activation: linear inside the range, GELU outside.
    pub fn apply(&self, z: f32) -> f32 {
        if (self.lo..self.hi).contains(&z) {
            self.slope * z + self.intercept
        } else {
            gelu(z)
        }
    }
}

/// Dense (reference) FFN with optional partial linearization.
#[derive(Debug, Clone)]
pub struct DenseFfn {
    pub d_model: usize,
    pub d_ff: usize,
    /// `[d_model, d_ff]` row-major (kept for fold construction and
    /// introspection; the hot path runs on the packed form).
    pub w_up: Arc<Vec<f32>>,
    /// `[d_ff]`.
    pub b_up: Arc<Vec<f32>>,
    /// `[d_ff, d_model]` row-major.
    pub w_down: Arc<Vec<f32>>,
    /// `[d_model]`.
    pub b_down: Arc<Vec<f32>>,
    /// Packed `[d_model, d_ff]` up-projection.
    pub w_up_packed: PackedMatrix,
    /// Packed `[d_ff, d_model]` down-projection.
    pub w_down_packed: PackedMatrix,
    /// Linear surrogate for units `0..linear_units` (None = pure GELU).
    pub lin: Option<Linearization>,
    pub linear_units: usize,
}

impl DenseFfn {
    pub fn new(
        w_up: Arc<Vec<f32>>,
        b_up: Arc<Vec<f32>>,
        w_down: Arc<Vec<f32>>,
        b_down: Arc<Vec<f32>>,
        d_model: usize,
        d_ff: usize,
    ) -> DenseFfn {
        assert_eq!(w_up.len(), d_model * d_ff);
        assert_eq!(b_up.len(), d_ff);
        assert_eq!(w_down.len(), d_ff * d_model);
        assert_eq!(b_down.len(), d_model);
        let w_up_packed = PackedMatrix::pack(&w_up, d_model, d_ff);
        let w_down_packed = PackedMatrix::pack(&w_down, d_ff, d_model);
        DenseFfn {
            d_model,
            d_ff,
            w_up,
            b_up,
            w_down,
            b_down,
            w_up_packed,
            w_down_packed,
            lin: None,
            linear_units: 0,
        }
    }

    /// Linearize the activation of units `0..units` on `lin`'s range.
    pub fn with_linearization(mut self, lin: Linearization, units: usize) -> DenseFfn {
        assert!(units <= self.d_ff);
        self.lin = Some(lin);
        self.linear_units = units;
        self
    }

    /// `z = x·W_up + b_up` into `z` (`[rows, d_ff]`).
    pub fn preactivations_into(
        &self,
        pool: Option<&ThreadPool>,
        x: &[f32],
        rows: usize,
        z: &mut [f32],
    ) {
        matmul(pool, x, rows, &self.w_up_packed, Epilogue::Bias(&self.b_up), z);
    }

    /// In-place activation of one `[d_ff]` row: linear surrogate on
    /// linearized units inside their range, GELU everywhere else.
    pub fn activate_row(&self, row: &mut [f32]) {
        if let Some(lin) = self.lin {
            for v in row.iter_mut().take(self.linear_units) {
                *v = lin.apply(*v);
            }
            for v in row.iter_mut().skip(self.linear_units) {
                *v = gelu(*v);
            }
        } else {
            for v in row.iter_mut() {
                *v = gelu(*v);
            }
        }
    }

    /// In-place activation of `[rows, d_ff]`.
    pub fn activate(&self, z: &mut [f32]) {
        for row in z.chunks_mut(self.d_ff) {
            self.activate_row(row);
        }
    }

    /// `y = h·W_down + b_down` into `y` (`[rows, d_model]`).
    pub fn project_into(&self, pool: Option<&ThreadPool>, h: &[f32], rows: usize, y: &mut [f32]) {
        matmul(pool, h, rows, &self.w_down_packed, Epilogue::Bias(&self.b_down), y);
    }

    /// Full forward; the returned buffer comes from `scratch` (hand it
    /// back with [`Scratch::give`] for steady-state zero allocation).
    pub fn forward(
        &self,
        pool: Option<&ThreadPool>,
        scratch: &mut Scratch,
        x: &[f32],
        rows: usize,
    ) -> Vec<f32> {
        let mut z = scratch.take(rows * self.d_ff);
        if self.lin.is_none() {
            // pure GELU: bias + activation fused into the tile store
            matmul(pool, x, rows, &self.w_up_packed, Epilogue::BiasGelu(&self.b_up), &mut z);
        } else {
            self.preactivations_into(pool, x, rows, &mut z);
            self.activate(&mut z);
        }
        let mut y = scratch.take(rows * self.d_model);
        self.project_into(pool, &z, rows, &mut y);
        scratch.give(z);
        y
    }

    pub fn param_count(&self) -> usize {
        2 * self.d_model * self.d_ff + self.d_ff + self.d_model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> DenseFfn {
        // d=2, h=3; w_up = [[1,0,1],[0,1,1]], w_down = [[1,0],[0,1],[1,1]]
        DenseFfn::new(
            Arc::new(vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0]),
            Arc::new(vec![0.0, 0.0, 0.5]),
            Arc::new(vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]),
            Arc::new(vec![0.1, -0.1]),
            2,
            3,
        )
    }

    #[test]
    fn forward_matches_hand_computation() {
        let f = tiny();
        let x = vec![1.0, 2.0];
        // z = [1, 2, 3.5]; h = gelu(z); y = [h0+h2+0.1, h1+h2-0.1]
        let (h0, h1, h2) = (gelu(1.0), gelu(2.0), gelu(3.5));
        let mut scratch = Scratch::new();
        let y = f.forward(None, &mut scratch, &x, 1);
        assert!((y[0] - (h0 + h2 + 0.1)).abs() < 1e-6);
        assert!((y[1] - (h1 + h2 - 0.1)).abs() < 1e-6);
    }

    #[test]
    fn linearization_fits_gelu_inside_range() {
        let lin = Linearization::fit_gelu(2.0, 6.0);
        // gelu is nearly the identity on [2, 6]
        assert!((lin.slope - 1.0).abs() < 0.05, "slope {}", lin.slope);
        for z in [2.0f32, 3.0, 4.5, 5.9] {
            assert!((lin.apply(z) - gelu(z)).abs() < 0.05);
        }
        // outside the range the true GELU applies exactly
        assert_eq!(lin.apply(-3.0), gelu(-3.0));
        assert_eq!(lin.apply(7.0), gelu(7.0));
    }

    #[test]
    fn linearized_units_use_the_surrogate() {
        let lin = Linearization::fit_gelu(-6.0, 6.0);
        let f = tiny().with_linearization(lin, 2);
        let mut z = vec![1.0, 1.0, 1.0];
        f.activate(&mut z);
        assert!((z[0] - lin.apply(1.0)).abs() < 1e-7);
        assert!((z[1] - lin.apply(1.0)).abs() < 1e-7);
        assert!((z[2] - gelu(1.0)).abs() < 1e-7); // unit 2 not linearized
        assert!((z[0] - z[2]).abs() > 1e-4, "surrogate differs from gelu");
    }

    #[test]
    fn fused_gelu_path_matches_unfused() {
        // the same weights with a no-op linearization boundary at 0
        // units run the unfused path; results must agree bitwise.
        let fused = tiny();
        let unfused = tiny().with_linearization(Linearization::fit_gelu(-1.0, 1.0), 0);
        let x = vec![0.3, -0.7, 1.4, 0.2];
        let mut scratch = Scratch::new();
        let a = fused.forward(None, &mut scratch, &x, 2);
        let b = unfused.forward(None, &mut scratch, &x, 2);
        assert_eq!(a, b);
    }

    #[test]
    fn param_count_is_dense_size() {
        assert_eq!(tiny().param_count(), 2 * 2 * 3 + 3 + 2);
    }
}
