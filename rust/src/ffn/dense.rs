//! Dense FFN reference path: `y = σ(x·W_up + b_up)·W_down + b_down`.
//!
//! For TARDIS variants the first `linear_units` hidden units carry a
//! [`Linearization`]: inside the approximated range `[lo, hi)` the
//! activation is replaced by its least-squares linear fit `a·z + c`
//! (paper §5.1), outside it the true GELU applies. This partially-linear
//! dense path is both the semantic reference the fold must reproduce and
//! the fallback executed for predicted-outlier rows.

use std::sync::Arc;

use crate::util::threadpool::ThreadPool;

use super::linalg::{gelu, matmul};

/// Least-squares linear surrogate of the activation on `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Linearization {
    pub lo: f32,
    pub hi: f32,
    pub slope: f32,
    pub intercept: f32,
}

impl Linearization {
    /// Fit `a·z + c` to GELU over `[lo, hi]` by least squares on a dense
    /// uniform grid (f64 accumulation; deterministic).
    pub fn fit_gelu(lo: f32, hi: f32) -> Linearization {
        assert!(lo < hi, "empty linear range [{lo}, {hi})");
        const GRID: usize = 1024;
        let (lo64, hi64) = (lo as f64, hi as f64);
        let (mut sz, mut sy, mut szz, mut szy) = (0f64, 0f64, 0f64, 0f64);
        for i in 0..=GRID {
            let z = lo64 + (hi64 - lo64) * i as f64 / GRID as f64;
            let y = gelu(z as f32) as f64;
            sz += z;
            sy += y;
            szz += z * z;
            szy += z * y;
        }
        let n = (GRID + 1) as f64;
        let denom = n * szz - sz * sz;
        let a = (n * szy - sz * sy) / denom;
        let c = (sy - a * sz) / n;
        Linearization {
            lo,
            hi,
            slope: a as f32,
            intercept: c as f32,
        }
    }

    /// The deployed activation: linear inside the range, GELU outside.
    pub fn apply(&self, z: f32) -> f32 {
        if (self.lo..self.hi).contains(&z) {
            self.slope * z + self.intercept
        } else {
            gelu(z)
        }
    }
}

/// Dense (reference) FFN with optional partial linearization.
#[derive(Debug, Clone)]
pub struct DenseFfn {
    pub d_model: usize,
    pub d_ff: usize,
    /// `[d_model, d_ff]` row-major.
    pub w_up: Arc<Vec<f32>>,
    /// `[d_ff]`.
    pub b_up: Arc<Vec<f32>>,
    /// `[d_ff, d_model]` row-major.
    pub w_down: Arc<Vec<f32>>,
    /// `[d_model]`.
    pub b_down: Arc<Vec<f32>>,
    /// Linear surrogate for units `0..linear_units` (None = pure GELU).
    pub lin: Option<Linearization>,
    pub linear_units: usize,
}

impl DenseFfn {
    pub fn new(
        w_up: Arc<Vec<f32>>,
        b_up: Arc<Vec<f32>>,
        w_down: Arc<Vec<f32>>,
        b_down: Arc<Vec<f32>>,
        d_model: usize,
        d_ff: usize,
    ) -> DenseFfn {
        assert_eq!(w_up.len(), d_model * d_ff);
        assert_eq!(b_up.len(), d_ff);
        assert_eq!(w_down.len(), d_ff * d_model);
        assert_eq!(b_down.len(), d_model);
        DenseFfn {
            d_model,
            d_ff,
            w_up,
            b_up,
            w_down,
            b_down,
            lin: None,
            linear_units: 0,
        }
    }

    /// Linearize the activation of units `0..units` on `lin`'s range.
    pub fn with_linearization(mut self, lin: Linearization, units: usize) -> DenseFfn {
        assert!(units <= self.d_ff);
        self.lin = Some(lin);
        self.linear_units = units;
        self
    }

    /// `x·W_up + b_up`, `[rows, d_ff]`.
    pub fn preactivations(&self, pool: Option<&ThreadPool>, x: &[f32], rows: usize) -> Vec<f32> {
        matmul(
            pool,
            x,
            rows,
            self.d_model,
            &self.w_up,
            self.d_ff,
            Some(&self.b_up),
        )
    }

    /// In-place activation: linear surrogate on linearized units inside
    /// their range, GELU everywhere else.
    pub fn activate(&self, z: &mut [f32]) {
        for row in z.chunks_mut(self.d_ff) {
            if let Some(lin) = self.lin {
                for v in row.iter_mut().take(self.linear_units) {
                    *v = lin.apply(*v);
                }
                for v in row.iter_mut().skip(self.linear_units) {
                    *v = gelu(*v);
                }
            } else {
                for v in row.iter_mut() {
                    *v = gelu(*v);
                }
            }
        }
    }

    /// `h·W_down + b_down`, `[rows, d_model]`.
    pub fn project(&self, pool: Option<&ThreadPool>, h: &[f32], rows: usize) -> Vec<f32> {
        matmul(
            pool,
            h,
            rows,
            self.d_ff,
            &self.w_down,
            self.d_model,
            Some(&self.b_down),
        )
    }

    pub fn forward(&self, pool: Option<&ThreadPool>, x: &[f32], rows: usize) -> Vec<f32> {
        let mut z = self.preactivations(pool, x, rows);
        self.activate(&mut z);
        self.project(pool, &z, rows)
    }

    pub fn param_count(&self) -> usize {
        2 * self.d_model * self.d_ff + self.d_ff + self.d_model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> DenseFfn {
        // d=2, h=3; w_up = [[1,0,1],[0,1,1]], w_down = [[1,0],[0,1],[1,1]]
        DenseFfn::new(
            Arc::new(vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0]),
            Arc::new(vec![0.0, 0.0, 0.5]),
            Arc::new(vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]),
            Arc::new(vec![0.1, -0.1]),
            2,
            3,
        )
    }

    #[test]
    fn forward_matches_hand_computation() {
        let f = tiny();
        let x = vec![1.0, 2.0];
        // z = [1, 2, 3.5]; h = gelu(z); y = [h0+h2+0.1, h1+h2-0.1]
        let (h0, h1, h2) = (gelu(1.0), gelu(2.0), gelu(3.5));
        let y = f.forward(None, &x, 1);
        assert!((y[0] - (h0 + h2 + 0.1)).abs() < 1e-6);
        assert!((y[1] - (h1 + h2 - 0.1)).abs() < 1e-6);
    }

    #[test]
    fn linearization_fits_gelu_inside_range() {
        let lin = Linearization::fit_gelu(2.0, 6.0);
        // gelu is nearly the identity on [2, 6]
        assert!((lin.slope - 1.0).abs() < 0.05, "slope {}", lin.slope);
        for z in [2.0f32, 3.0, 4.5, 5.9] {
            assert!((lin.apply(z) - gelu(z)).abs() < 0.05);
        }
        // outside the range the true GELU applies exactly
        assert_eq!(lin.apply(-3.0), gelu(-3.0));
        assert_eq!(lin.apply(7.0), gelu(7.0));
    }

    #[test]
    fn linearized_units_use_the_surrogate() {
        let lin = Linearization::fit_gelu(-6.0, 6.0);
        let f = tiny().with_linearization(lin, 2);
        let mut z = vec![1.0, 1.0, 1.0];
        f.activate(&mut z);
        assert!((z[0] - lin.apply(1.0)).abs() < 1e-7);
        assert!((z[1] - lin.apply(1.0)).abs() < 1e-7);
        assert!((z[2] - gelu(1.0)).abs() < 1e-7); // unit 2 not linearized
        assert!((z[0] - z[2]).abs() > 1e-4, "surrogate differs from gelu");
    }

    #[test]
    fn param_count_is_dense_size() {
        assert_eq!(tiny().param_count(), 2 * 2 * 3 + 3 + 2);
    }
}
