//! Dense FFN reference path: `y = σ(x·W_up + b_up)·W_down + b_down`.
//!
//! For TARDIS variants the leading hidden units carry a [`RangeTable`]:
//! inside unit `j`'s approximated range `[lo_j, hi_j)` the activation is
//! replaced by its least-squares linear fit `a_j·z + c_j` (paper §5.1),
//! outside it the true GELU applies. The table is either *uniform* (one
//! configured `[lo, hi)` and one GELU fit shared by every linearized
//! unit — the no-artifacts default) or *calibrated* (per-neuron ranges
//! and fits from the python pipeline's Algorithm 1, loaded through the
//! manifest). This partially-linear dense path is both the semantic
//! reference the fold must reproduce and the fallback executed for
//! predicted-outlier rows.
//!
//! Both projections are pre-packed ([`PackedMatrix`]) at construction;
//! the pure-GELU path fuses bias+activation into the up-projection's
//! tile store, and `forward` draws every intermediate from the caller's
//! [`Scratch`] arena. The GEMMs run on whichever micro-kernel family
//! the process-wide [`KernelDispatch`](super::KernelDispatch) selected
//! (portable tiles or explicit AVX2/FMA) — this module never branches
//! on ISA itself.

use std::sync::Arc;

use crate::util::threadpool::ThreadPool;

use super::kernels::{gelu, matmul, Epilogue, PackedMatrix, Scratch};

/// Least-squares linear surrogate of the activation on `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Linearization {
    pub lo: f32,
    pub hi: f32,
    pub slope: f32,
    pub intercept: f32,
}

impl Linearization {
    /// Fit `a·z + c` to GELU over `[lo, hi]` by least squares on a dense
    /// uniform grid (f64 accumulation; deterministic).
    pub fn fit_gelu(lo: f32, hi: f32) -> Linearization {
        assert!(lo < hi, "empty linear range [{lo}, {hi})");
        const GRID: usize = 1024;
        let (lo64, hi64) = (lo as f64, hi as f64);
        let (mut sz, mut sy, mut szz, mut szy) = (0f64, 0f64, 0f64, 0f64);
        for i in 0..=GRID {
            let z = lo64 + (hi64 - lo64) * i as f64 / GRID as f64;
            let y = gelu(z as f32) as f64;
            sz += z;
            sy += y;
            szz += z * z;
            szy += z * y;
        }
        let n = (GRID + 1) as f64;
        let denom = n * szz - sz * sz;
        let a = (n * szy - sz * sy) / denom;
        let c = (sy - a * sz) / n;
        Linearization {
            lo,
            hi,
            slope: a as f32,
            intercept: c as f32,
        }
    }

    /// The deployed activation: linear inside the range, GELU outside.
    pub fn apply(&self, z: f32) -> f32 {
        if (self.lo..self.hi).contains(&z) {
            self.slope * z + self.intercept
        } else {
            gelu(z)
        }
    }
}

/// Per-unit linear surrogates for the first `units()` hidden units of a
/// layer: unit `j` is approximated by `slope[j]·z + intercept[j]` on
/// `[lo[j], hi[j])` and keeps the true GELU outside.
///
/// The uniform configuration broadcasts one [`Linearization`] across all
/// linearized units; the calibrated path carries the python pipeline's
/// per-neuron ranges and fits verbatim.
#[derive(Debug, Clone, PartialEq)]
pub struct RangeTable {
    pub lo: Vec<f32>,
    pub hi: Vec<f32>,
    pub slope: Vec<f32>,
    pub intercept: Vec<f32>,
}

impl RangeTable {
    /// Broadcast one fit across `units` linearized units.
    pub fn uniform(lin: Linearization, units: usize) -> RangeTable {
        RangeTable {
            lo: vec![lin.lo; units],
            hi: vec![lin.hi; units],
            slope: vec![lin.slope; units],
            intercept: vec![lin.intercept; units],
        }
    }

    /// Per-neuron calibrated table (all slices must have equal length
    /// and every range must be non-empty).
    pub fn from_calibration(
        lo: &[f32],
        hi: &[f32],
        slope: &[f32],
        intercept: &[f32],
    ) -> RangeTable {
        assert!(
            lo.len() == hi.len() && lo.len() == slope.len() && lo.len() == intercept.len(),
            "range table arrays disagree: {} {} {} {}",
            lo.len(),
            hi.len(),
            slope.len(),
            intercept.len()
        );
        for (j, (&l, &h)) in lo.iter().zip(hi).enumerate() {
            assert!(l < h, "unit {j}: empty linear range [{l}, {h})");
        }
        RangeTable {
            lo: lo.to_vec(),
            hi: hi.to_vec(),
            slope: slope.to_vec(),
            intercept: intercept.to_vec(),
        }
    }

    /// Number of linearized units.
    pub fn units(&self) -> usize {
        self.lo.len()
    }

    #[inline]
    pub fn in_range(&self, j: usize, z: f32) -> bool {
        self.lo[j] <= z && z < self.hi[j]
    }

    /// The deployed activation of unit `j`: its linear surrogate inside
    /// the unit's range, GELU outside.
    #[inline]
    pub fn apply(&self, j: usize, z: f32) -> f32 {
        if self.in_range(j, z) {
            self.slope[j] * z + self.intercept[j]
        } else {
            gelu(z)
        }
    }

    /// The surrogate `slope[j]·z + intercept[j]` regardless of range —
    /// what the folded map contributes for unit `j`.
    #[inline]
    pub fn surrogate(&self, j: usize, z: f32) -> f32 {
        self.slope[j] * z + self.intercept[j]
    }
}

/// Dense (reference) FFN with optional partial linearization.
#[derive(Debug, Clone)]
pub struct DenseFfn {
    pub d_model: usize,
    pub d_ff: usize,
    /// `[d_model, d_ff]` row-major (kept for fold construction and
    /// introspection; the hot path runs on the packed form).
    pub w_up: Arc<Vec<f32>>,
    /// `[d_ff]`.
    pub b_up: Arc<Vec<f32>>,
    /// `[d_ff, d_model]` row-major.
    pub w_down: Arc<Vec<f32>>,
    /// `[d_model]`.
    pub b_down: Arc<Vec<f32>>,
    /// Packed `[d_model, d_ff]` up-projection.
    pub w_up_packed: PackedMatrix,
    /// Packed `[d_ff, d_model]` down-projection.
    pub w_down_packed: PackedMatrix,
    /// Per-unit linear surrogates for units `0..ranges.units()`
    /// (None = pure GELU).
    pub ranges: Option<RangeTable>,
}

impl DenseFfn {
    pub fn new(
        w_up: Arc<Vec<f32>>,
        b_up: Arc<Vec<f32>>,
        w_down: Arc<Vec<f32>>,
        b_down: Arc<Vec<f32>>,
        d_model: usize,
        d_ff: usize,
    ) -> DenseFfn {
        assert_eq!(w_up.len(), d_model * d_ff);
        assert_eq!(b_up.len(), d_ff);
        assert_eq!(w_down.len(), d_ff * d_model);
        assert_eq!(b_down.len(), d_model);
        let w_up_packed = PackedMatrix::pack(&w_up, d_model, d_ff);
        let w_down_packed = PackedMatrix::pack(&w_down, d_ff, d_model);
        DenseFfn {
            d_model,
            d_ff,
            w_up,
            b_up,
            w_down,
            b_down,
            w_up_packed,
            w_down_packed,
            ranges: None,
        }
    }

    /// Linearize the activation of units `0..units` on `lin`'s range
    /// (uniform table).
    pub fn with_linearization(self, lin: Linearization, units: usize) -> DenseFfn {
        assert!(units <= self.d_ff);
        self.with_ranges(RangeTable::uniform(lin, units))
    }

    /// Linearize the leading units with per-unit calibrated ranges.
    pub fn with_ranges(mut self, ranges: RangeTable) -> DenseFfn {
        assert!(ranges.units() <= self.d_ff);
        self.ranges = Some(ranges);
        self
    }

    /// Number of linearized (surrogate-carrying) units.
    pub fn linear_units(&self) -> usize {
        self.ranges.as_ref().map_or(0, RangeTable::units)
    }

    /// `z = x·W_up + b_up` into `z` (`[rows, d_ff]`).
    pub fn preactivations_into(
        &self,
        pool: Option<&ThreadPool>,
        x: &[f32],
        rows: usize,
        z: &mut [f32],
    ) {
        matmul(pool, x, rows, &self.w_up_packed, Epilogue::Bias(&self.b_up), z);
    }

    /// In-place activation of one `[d_ff]` row: per-unit linear
    /// surrogate on linearized units inside their range, GELU everywhere
    /// else.
    pub fn activate_row(&self, row: &mut [f32]) {
        if let Some(t) = &self.ranges {
            let n = t.units();
            for (j, v) in row.iter_mut().take(n).enumerate() {
                *v = t.apply(j, *v);
            }
            for v in row.iter_mut().skip(n) {
                *v = gelu(*v);
            }
        } else {
            for v in row.iter_mut() {
                *v = gelu(*v);
            }
        }
    }

    /// In-place activation of `[rows, d_ff]`.
    pub fn activate(&self, z: &mut [f32]) {
        for row in z.chunks_mut(self.d_ff) {
            self.activate_row(row);
        }
    }

    /// `y = h·W_down + b_down` into `y` (`[rows, d_model]`).
    pub fn project_into(&self, pool: Option<&ThreadPool>, h: &[f32], rows: usize, y: &mut [f32]) {
        matmul(pool, h, rows, &self.w_down_packed, Epilogue::Bias(&self.b_down), y);
    }

    /// Full forward; the returned buffer comes from `scratch` (hand it
    /// back with [`Scratch::give`] for steady-state zero allocation).
    pub fn forward(
        &self,
        pool: Option<&ThreadPool>,
        scratch: &mut Scratch,
        x: &[f32],
        rows: usize,
    ) -> Vec<f32> {
        let mut z = scratch.take(rows * self.d_ff);
        if self.ranges.is_none() {
            // pure GELU: bias + activation fused into the tile store
            matmul(pool, x, rows, &self.w_up_packed, Epilogue::BiasGelu(&self.b_up), &mut z);
        } else {
            self.preactivations_into(pool, x, rows, &mut z);
            self.activate(&mut z);
        }
        let mut y = scratch.take(rows * self.d_model);
        self.project_into(pool, &z, rows, &mut y);
        scratch.give(z);
        y
    }

    pub fn param_count(&self) -> usize {
        2 * self.d_model * self.d_ff + self.d_ff + self.d_model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> DenseFfn {
        // d=2, h=3; w_up = [[1,0,1],[0,1,1]], w_down = [[1,0],[0,1],[1,1]]
        DenseFfn::new(
            Arc::new(vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0]),
            Arc::new(vec![0.0, 0.0, 0.5]),
            Arc::new(vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]),
            Arc::new(vec![0.1, -0.1]),
            2,
            3,
        )
    }

    #[test]
    fn forward_matches_hand_computation() {
        let f = tiny();
        let x = vec![1.0, 2.0];
        // z = [1, 2, 3.5]; h = gelu(z); y = [h0+h2+0.1, h1+h2-0.1]
        let (h0, h1, h2) = (gelu(1.0), gelu(2.0), gelu(3.5));
        let mut scratch = Scratch::new();
        let y = f.forward(None, &mut scratch, &x, 1);
        assert!((y[0] - (h0 + h2 + 0.1)).abs() < 1e-6);
        assert!((y[1] - (h1 + h2 - 0.1)).abs() < 1e-6);
    }

    #[test]
    fn linearization_fits_gelu_inside_range() {
        let lin = Linearization::fit_gelu(2.0, 6.0);
        // gelu is nearly the identity on [2, 6]
        assert!((lin.slope - 1.0).abs() < 0.05, "slope {}", lin.slope);
        for z in [2.0f32, 3.0, 4.5, 5.9] {
            assert!((lin.apply(z) - gelu(z)).abs() < 0.05);
        }
        // outside the range the true GELU applies exactly
        assert_eq!(lin.apply(-3.0), gelu(-3.0));
        assert_eq!(lin.apply(7.0), gelu(7.0));
    }

    #[test]
    fn linearized_units_use_the_surrogate() {
        let lin = Linearization::fit_gelu(-6.0, 6.0);
        let f = tiny().with_linearization(lin, 2);
        let mut z = vec![1.0, 1.0, 1.0];
        f.activate(&mut z);
        assert!((z[0] - lin.apply(1.0)).abs() < 1e-7);
        assert!((z[1] - lin.apply(1.0)).abs() < 1e-7);
        assert!((z[2] - gelu(1.0)).abs() < 1e-7); // unit 2 not linearized
        assert!((z[0] - z[2]).abs() > 1e-4, "surrogate differs from gelu");
    }

    #[test]
    fn fused_gelu_path_matches_unfused() {
        // the same weights with a no-op linearization boundary at 0
        // units run the unfused path; results must agree bitwise.
        let fused = tiny();
        let unfused = tiny().with_linearization(Linearization::fit_gelu(-1.0, 1.0), 0);
        let x = vec![0.3, -0.7, 1.4, 0.2];
        let mut scratch = Scratch::new();
        let a = fused.forward(None, &mut scratch, &x, 2);
        let b = unfused.forward(None, &mut scratch, &x, 2);
        assert_eq!(a, b);
    }

    #[test]
    fn param_count_is_dense_size() {
        assert_eq!(tiny().param_count(), 2 * 2 * 3 + 3 + 2);
    }

    #[test]
    fn per_neuron_table_applies_each_units_own_range() {
        // unit 0: z=1 in range [-2,2) -> surrogate; unit 1: z=1 outside
        // its range [3,5) -> true gelu.
        let t = RangeTable::from_calibration(&[-2.0, 3.0], &[2.0, 5.0], &[0.5, 1.0], &[0.1, 0.0]);
        assert_eq!(t.units(), 2);
        assert!(t.in_range(0, 1.0));
        assert!(!t.in_range(1, 1.0));
        assert!((t.apply(0, 1.0) - 0.6).abs() < 1e-7);
        assert_eq!(t.apply(1, 1.0), gelu(1.0));
        assert!((t.surrogate(1, 1.0) - 1.0).abs() < 1e-7);
        // exclusive upper bound: hi itself is out of range
        assert!(!t.in_range(0, 2.0));

        let f = tiny().with_ranges(t.clone());
        assert_eq!(f.linear_units(), 2);
        let mut z = vec![1.0, 1.0, 1.0];
        f.activate_row(&mut z);
        assert!((z[0] - 0.6).abs() < 1e-7);
        assert_eq!(z[1], gelu(1.0));
        assert_eq!(z[2], gelu(1.0)); // unit 2 not linearized
    }

    #[test]
    fn uniform_table_matches_scalar_linearization() {
        let lin = Linearization::fit_gelu(-6.0, 6.0);
        let t = RangeTable::uniform(lin, 3);
        for z in [-7.0f32, -1.0, 0.0, 2.5, 6.0, 9.0] {
            for j in 0..3 {
                assert_eq!(t.apply(j, z), lin.apply(z));
            }
        }
    }
}
