//! The TARDIS partially-linear FFN: constant-folded matrix + per-row
//! online outlier fallback (paper §5.2, Fig 3).
//!
//! With the activation of the first `folded_units` hidden units replaced
//! by its linear surrogate `a·z + c`, the FFN collapses by associativity:
//!
//! ```text
//! σ(x·W_up + b_up)·W_down + b_down
//!   ≈ x·(W_up_F · a · W_down_F)  +  (a·b_up_F + c)·W_down_F + b_down
//!     + gelu(x·W_up_K + b_up_K)·W_down_K
//!   = x·C + B + kept-unit path
//! ```
//!
//! `C` is `d×d` (vs `2·d·h` for the folded units), `B` absorbs the
//! intercepts and `b_down`, and the `K = d_ff - folded_units` kept units
//! run the original dense columns. Per batch row an
//! [`super::predictor::OutlierPredictor`] decides between this folded
//! path and the exact dense fallback ([`DenseFfn`] with the same partial
//! linearization).
//!
//! The batch split executes **in place**: each side runs the row-sparse
//! kernel over its row mask ([`matmul_sparse_rows`]) directly on the
//! input and output buffers — no gather/scatter copies, no per-call
//! allocation (masks are reused across calls, intermediates come from
//! the caller's [`Scratch`]). All matrices are pre-packed at fold time.
//! Fallback rows are bitwise equal to the reference; folded in-range
//! rows differ only by the fold's reassociation roundoff.

use crate::config::TardisFfnConfig;
use crate::util::threadpool::ThreadPool;

use super::FfnTelemetry;
use super::dense::{DenseFfn, Linearization};
use super::kernels::{matmul, matmul_sparse_rows, norm, Epilogue, PackedMatrix, Scratch};
use super::predictor::{OutlierPredictor, Route};

pub struct FoldedFfn {
    /// Dense path with the same linearization: semantic reference and
    /// per-row fallback executor.
    pub reference: DenseFfn,
    folded_units: usize,
    kept_units: usize,
    /// Packed `[d, d]` folded map `C`.
    c: PackedMatrix,
    /// `[d]` folded bias `B` (absorbs `b_down`).
    b: Vec<f32>,
    /// Packed kept-unit columns of `W_up`: `[d, kept]`.
    w_up_kept: PackedMatrix,
    /// `[kept]`.
    b_up_kept: Vec<f32>,
    /// Packed kept-unit rows of `W_down`: `[kept, d]`.
    w_down_kept: PackedMatrix,
    pub predictor: OutlierPredictor,
    pub telemetry: FfnTelemetry,
    /// Reusable routing state (no per-call allocation).
    norms: Vec<f32>,
    folded_mask: Vec<bool>,
    fallback_mask: Vec<bool>,
}

impl FoldedFfn {
    /// Fold `dense` at `cfg.fold_ratio`, linearizing the first
    /// `round(ratio·d_ff)` units on `[linear_lo, linear_hi)`. The fold is
    /// accumulated in f64 and packed once.
    pub fn new(dense: DenseFfn, cfg: &TardisFfnConfig) -> FoldedFfn {
        let (d, h) = (dense.d_model, dense.d_ff);
        let nf = ((cfg.fold_ratio * h as f64).round() as usize).min(h);
        assert!(nf >= 1, "fold_ratio {} folds no units", cfg.fold_ratio);
        let lin = Linearization::fit_gelu(cfg.linear_lo, cfg.linear_hi);
        let reference = dense.with_linearization(lin, nf);
        let (w_up, b_up) = (&reference.w_up, &reference.b_up);
        let (w_down, b_down) = (&reference.w_down, &reference.b_down);

        // C[l][m] = Σ_{j<nf} w_up[l][j] · a · w_down[j][m]
        let a64 = lin.slope as f64;
        let c64 = lin.intercept as f64;
        let mut c = vec![0f64; d * d];
        for l in 0..d {
            let row = &mut c[l * d..(l + 1) * d];
            for j in 0..nf {
                let scaled = w_up[l * h + j] as f64 * a64;
                for (cv, &wv) in row.iter_mut().zip(&w_down[j * d..(j + 1) * d]) {
                    *cv += scaled * wv as f64;
                }
            }
        }
        // B[m] = Σ_{j<nf} (a·b_up[j] + c) · w_down[j][m] + b_down[m]
        let mut b = vec![0f64; d];
        for j in 0..nf {
            let coef = a64 * b_up[j] as f64 + c64;
            for (bv, &wv) in b.iter_mut().zip(&w_down[j * d..(j + 1) * d]) {
                *bv += coef * wv as f64;
            }
        }
        for (bv, &bd) in b.iter_mut().zip(b_down.iter()) {
            *bv += bd as f64;
        }

        // Kept units: gather columns nf.. of W_up, rows nf.. of W_down.
        let kept = h - nf;
        let mut w_up_kept = Vec::with_capacity(d * kept);
        for l in 0..d {
            w_up_kept.extend_from_slice(&w_up[l * h + nf..(l + 1) * h]);
        }
        let b_up_kept = b_up[nf..].to_vec();
        let w_down_kept = w_down[nf * d..].to_vec();

        // Provable in-range radius: min_j slack_j / ‖w_up column j‖.
        let mut safe_radius = f32::INFINITY;
        for j in 0..nf {
            let slack = (cfg.linear_hi - b_up[j]).min(b_up[j] - cfg.linear_lo);
            if slack <= 0.0 {
                safe_radius = 0.0;
                break;
            }
            let col_norm = (0..d)
                .map(|l| {
                    let w = w_up[l * h + j] as f64;
                    w * w
                })
                .sum::<f64>()
                .sqrt() as f32;
            if col_norm > 1e-12 {
                safe_radius = safe_radius.min(slack / col_norm);
            }
        }
        if !safe_radius.is_finite() {
            // every folded column is zero: constant units, always in range
            safe_radius = f32::MAX;
        }

        let c_f32: Vec<f32> = c.into_iter().map(|v| v as f32).collect();
        FoldedFfn {
            folded_units: nf,
            kept_units: kept,
            c: PackedMatrix::pack(&c_f32, d, d),
            b: b.into_iter().map(|v| v as f32).collect(),
            w_up_kept: PackedMatrix::pack(&w_up_kept, d, kept),
            b_up_kept,
            w_down_kept: PackedMatrix::pack(&w_down_kept, kept, d),
            predictor: OutlierPredictor::new(safe_radius, cfg.predictor_threshold),
            telemetry: FfnTelemetry::default(),
            norms: Vec::new(),
            folded_mask: Vec::new(),
            fallback_mask: Vec::new(),
            reference,
        }
    }

    pub fn d_model(&self) -> usize {
        self.reference.d_model
    }

    pub fn folded_units(&self) -> usize {
        self.folded_units
    }

    /// Resident parameters of the folded deployment.
    pub fn param_count(&self) -> usize {
        let d = self.reference.d_model;
        d * d + d + self.kept_units * (2 * d + 1)
    }

    /// Fraction of dense FFN parameters eliminated by the fold.
    pub fn compression_ratio(&self) -> f64 {
        1.0 - self.param_count() as f64 / self.reference.param_count() as f64
    }

    /// Batch forward with per-row routing; `x` is `[rows, d_model]`. The
    /// returned buffer comes from `scratch` (hand it back with
    /// [`Scratch::give`] for steady-state zero allocation).
    pub fn forward(
        &mut self,
        pool: Option<&ThreadPool>,
        scratch: &mut Scratch,
        x: &[f32],
        rows: usize,
    ) -> Vec<f32> {
        let d = self.reference.d_model;
        debug_assert_eq!(x.len(), rows * d);
        self.norms.clear();
        self.folded_mask.clear();
        self.fallback_mask.clear();
        let mut n_folded = 0usize;
        for row in x.chunks_exact(d).take(rows) {
            let nrm = norm(row);
            let folded = matches!(self.predictor.classify(nrm), Route::Folded);
            self.norms.push(nrm);
            self.folded_mask.push(folded);
            self.fallback_mask.push(!folded);
            if folded {
                n_folded += 1;
            }
        }
        let n_fallback = rows - n_folded;
        let mut out = scratch.take(rows * d);

        if n_folded == rows {
            // whole batch folded: dense tiling, parallel when large
            matmul(pool, x, rows, &self.c, Epilogue::Bias(&self.b), &mut out);
            if self.kept_units > 0 {
                let mut hk = scratch.take(rows * self.kept_units);
                matmul(
                    pool,
                    x,
                    rows,
                    &self.w_up_kept,
                    Epilogue::BiasGelu(&self.b_up_kept),
                    &mut hk,
                );
                matmul(pool, &hk, rows, &self.w_down_kept, Epilogue::Add, &mut out);
                scratch.give(hk);
            }
        } else if n_folded > 0 {
            // mixed batch: folded rows execute in place over their mask
            matmul_sparse_rows(
                pool,
                x,
                rows,
                &self.c,
                Epilogue::Bias(&self.b),
                &self.folded_mask,
                &mut out,
            );
            if self.kept_units > 0 {
                let mut hk = scratch.take(rows * self.kept_units);
                matmul_sparse_rows(
                    pool,
                    x,
                    rows,
                    &self.w_up_kept,
                    Epilogue::BiasGelu(&self.b_up_kept),
                    &self.folded_mask,
                    &mut hk,
                );
                matmul_sparse_rows(
                    pool,
                    &hk,
                    rows,
                    &self.w_down_kept,
                    Epilogue::Add,
                    &self.folded_mask,
                    &mut out,
                );
                scratch.give(hk);
            }
        }

        if n_fallback > 0 {
            let h = self.reference.d_ff;
            let mut z = scratch.take(rows * h);
            if n_fallback == rows {
                self.reference.preactivations_into(pool, x, rows, &mut z);
            } else {
                matmul_sparse_rows(
                    pool,
                    x,
                    rows,
                    &self.reference.w_up_packed,
                    Epilogue::Bias(&self.reference.b_up),
                    &self.fallback_mask,
                    &mut z,
                );
            }
            let lin = self.reference.lin.expect("folded ffn has a linearization");
            for i in 0..rows {
                if !self.fallback_mask[i] {
                    continue;
                }
                let zrow = &mut z[i * h..(i + 1) * h];
                let in_range = zrow[..self.folded_units]
                    .iter()
                    .all(|zv| (lin.lo..lin.hi).contains(zv));
                self.predictor.observe(self.norms[i], in_range);
                self.reference.activate_row(zrow);
            }
            if n_fallback == rows {
                self.reference.project_into(pool, &z, rows, &mut out);
            } else {
                matmul_sparse_rows(
                    pool,
                    &z,
                    rows,
                    &self.reference.w_down_packed,
                    Epilogue::Bias(&self.reference.b_down),
                    &self.fallback_mask,
                    &mut out,
                );
            }
            scratch.give(z);
        }

        self.telemetry.folded_rows += n_folded as u64;
        self.telemetry.fallback_rows += n_fallback as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn random_dense(rng: &mut Rng, d: usize, h: usize, scale: f32) -> DenseFfn {
        let w_up: Vec<f32> = (0..d * h).map(|_| rng.normal() as f32 * scale).collect();
        let b_up: Vec<f32> = (0..h).map(|_| rng.normal() as f32 * 0.1).collect();
        let w_down: Vec<f32> = (0..h * d).map(|_| rng.normal() as f32 * scale).collect();
        let b_down: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
        DenseFfn::new(
            Arc::new(w_up),
            Arc::new(b_up),
            Arc::new(w_down),
            Arc::new(b_down),
            d,
            h,
        )
    }

    fn cfg(ratio: f64) -> TardisFfnConfig {
        TardisFfnConfig {
            fold_ratio: ratio,
            linear_lo: -6.0,
            linear_hi: 6.0,
            predictor_threshold: 1.0,
        }
    }

    #[test]
    fn folded_matches_reference_for_provably_safe_rows() {
        let mut rng = Rng::new(42);
        let dense = random_dense(&mut rng, 8, 16, 0.3);
        let mut f = FoldedFfn::new(dense, &cfg(0.75));
        let r = f.predictor.safe_radius();
        assert!(r > 0.0, "safe radius {r}");
        // rows scaled to 90% of the provable radius: folded on first call
        let rows = 5;
        let mut x = vec![0f32; rows * 8];
        for row in x.chunks_mut(8) {
            for v in row.iter_mut() {
                *v = rng.normal() as f32;
            }
            let n = norm(row);
            for v in row.iter_mut() {
                *v *= 0.9 * r / n;
            }
        }
        let mut scratch = Scratch::new();
        let got = f.forward(None, &mut scratch, &x, rows);
        let want = f.reference.forward(None, &mut scratch, &x, rows);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                "folded {g} vs reference {w}"
            );
        }
        assert_eq!(f.telemetry.folded_rows, rows as u64);
        assert_eq!(f.telemetry.fallback_rows, 0);
    }

    #[test]
    fn outlier_rows_fall_back_bitwise() {
        let mut rng = Rng::new(7);
        let dense = random_dense(&mut rng, 8, 16, 0.3);
        let mut f = FoldedFfn::new(dense, &cfg(0.5));
        let r = f.predictor.safe_radius();
        // one far-out row along folded column 0, one safe row
        let d = 8;
        let h = 16;
        let mut x = vec![0f32; 2 * d];
        for (l, v) in x[..d].iter_mut().enumerate() {
            *v = f.reference.w_up[l * h]; // column 0 direction
        }
        let n0 = norm(&x[..d]);
        let blow = 50.0 * r / n0;
        for v in x[..d].iter_mut() {
            *v *= blow;
        }
        for v in x[d..].iter_mut() {
            *v = 0.01 * r;
        }
        let mut scratch = Scratch::new();
        let got = f.forward(None, &mut scratch, &x, 2);
        let want = f.reference.forward(None, &mut scratch, &x, 2);
        // outlier row: routed dense, so exactly the reference
        assert_eq!(&got[..d], &want[..d]);
        // safe row: folded, within fold roundoff
        for (g, w) in got[d..].iter().zip(&want[d..]) {
            assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0));
        }
        assert_eq!(f.telemetry.fallback_rows, 1);
        assert_eq!(f.telemetry.folded_rows, 1);
        assert_eq!(f.predictor.stats.observed_out_of_range, 1);
    }

    #[test]
    fn online_predictor_learns_in_range_norms() {
        // w_up = 0.5·I with a wide range: safe radius 12/0.5 = 24, but
        // x = [15,15,15,15] (norm 30) has z_j = 7.5, well in range.
        let d = 4;
        let mut eye = vec![0f32; d * d];
        for i in 0..d {
            eye[i * d + i] = 0.5;
        }
        let dense = DenseFfn::new(
            Arc::new(eye.clone()),
            Arc::new(vec![0.0; d]),
            Arc::new(eye),
            Arc::new(vec![0.0; d]),
            d,
            d,
        );
        let mut f = FoldedFfn::new(
            dense,
            &TardisFfnConfig {
                fold_ratio: 1.0,
                linear_lo: -12.0,
                linear_hi: 12.0,
                predictor_threshold: 1.0,
            },
        );
        assert!((f.predictor.safe_radius() - 24.0).abs() < 1e-4);
        let x = vec![15.0f32; d];
        let mut scratch = Scratch::new();
        let first = f.forward(None, &mut scratch, &x, 1);
        assert_eq!(f.telemetry.fallback_rows, 1, "first sighting falls back");
        let second = f.forward(None, &mut scratch, &x, 1);
        assert_eq!(f.telemetry.folded_rows, 1, "second sighting folds");
        for (a, b) in first.iter().zip(&second) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
        }
    }

    #[test]
    fn compression_ratio_tracks_fold_ratio() {
        let mut rng = Rng::new(3);
        let dense = random_dense(&mut rng, 16, 64, 0.2);
        let full = FoldedFfn::new(random_dense(&mut rng, 16, 64, 0.2), &cfg(1.0));
        let half = FoldedFfn::new(dense, &cfg(0.5));
        assert!(full.compression_ratio() > half.compression_ratio());
        // h = 4d: folding everything removes 1 - (d²+d)/(2dh+h+d) ≈ 87%
        let r = full.compression_ratio();
        assert!(r > 0.8, "{r}");
        assert!(half.compression_ratio() > 0.3);
    }

    #[test]
    fn steady_state_forward_allocates_nothing() {
        let mut rng = Rng::new(99);
        let dense = random_dense(&mut rng, 8, 16, 0.3);
        let mut f = FoldedFfn::new(dense, &cfg(0.75));
        let r = f.predictor.safe_radius();
        let rows = 3;
        let mut x = vec![0f32; rows * 8];
        for row in x.chunks_mut(8) {
            for v in row.iter_mut() {
                *v = rng.normal() as f32;
            }
            let n = norm(row);
            for v in row.iter_mut() {
                *v *= 0.5 * r / n;
            }
        }
        let mut scratch = Scratch::new();
        let warm = f.forward(None, &mut scratch, &x, rows);
        scratch.give(warm);
        let misses = scratch.misses;
        for _ in 0..10 {
            let y = f.forward(None, &mut scratch, &x, rows);
            scratch.give(y);
        }
        assert_eq!(scratch.misses, misses, "steady-state decode must not allocate");
    }
}
