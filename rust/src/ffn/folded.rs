//! The TARDIS partially-linear FFN: constant-folded matrix + online
//! outlier fallback (paper §5.2, Fig 3).
//!
//! With the activation of the first `folded_units` hidden units replaced
//! by its per-unit linear surrogate `a_j·z + c_j` ([`RangeTable`]), the
//! FFN collapses by associativity:
//!
//! ```text
//! σ(x·W_up + b_up)·W_down + b_down
//!   ≈ x·(W_up_F · diag(a) · W_down_F)
//!     + (a ⊙ b_up_F + c)·W_down_F + b_down
//!     + gelu(x·W_up_K + b_up_K)·W_down_K
//!   = x·C + B + kept-unit path
//! ```
//!
//! `C` is `d×d` (vs `2·d·h` for the folded units), `B` absorbs the
//! intercepts and `b_down`, and the `K = d_ff - folded_units` kept units
//! run the original dense columns. The surrogate table is either
//! *uniform* (one configured `[lo, hi)` and one GELU fit, the
//! no-artifacts default) or *calibrated* per neuron from the python
//! pipeline ([`FoldedFfn::with_calibration`]).
//!
//! Routing around the fold is a configurable
//! [`PredictorKind`](crate::config::PredictorKind):
//!
//! * `norm` — the per-row 1-D input-norm gate
//!   ([`super::predictor::OutlierPredictor`]): whole rows fold or fall
//!   back to the exact dense path.
//! * `quantized` — the paper's k-bit `W_up` proxy
//!   ([`super::quant::QuantizedRouter`]): per-neuron in/out decisions
//!   against the calibrated ranges, top-K result fixing for rows with at
//!   most `top_k` flagged neurons, and the same per-row dense fallback
//!   beyond that capacity.
//!
//! Both routes execute the batch split **in place**: each side runs the
//! row-sparse kernel over its row mask ([`matmul_sparse_rows`]) directly
//! on the input and output buffers — no gather/scatter copies, no
//! per-call allocation (masks and fix lists are reused across calls,
//! intermediates come from the caller's [`Scratch`]). All matrices are
//! pre-packed at fold time. Fallback rows are bitwise equal to the
//! reference; folded in-range rows differ only by the fold's
//! reassociation roundoff; fixed neurons patch the folded output with
//! their exact pre-activation.

use crate::config::{PredictorKind, TardisFfnConfig};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

use super::dense::{DenseFfn, Linearization, RangeTable};
use super::kernels::{dot, gelu, matmul, matmul_sparse_rows, norm, Epilogue, PackedMatrix, Scratch};
use super::predictor::{OutlierPredictor, Route};
use super::quant::{
    synthetic_outlier_workload, QuantRoute, QuantizedProxy, QuantizedRouter,
    RoutingQuality,
};
use super::FfnTelemetry;

/// Number of folded (surrogate-carrying) units at `ratio` of `h` hidden
/// units.
pub fn folded_units_for(ratio: f64, h: usize) -> usize {
    ((ratio * h as f64).round() as usize).min(h)
}

pub struct FoldedFfn {
    /// Dense path with the same linearization table: semantic reference
    /// and per-row fallback executor.
    pub reference: DenseFfn,
    folded_units: usize,
    kept_units: usize,
    /// Packed `[d, d]` folded map `C`.
    c: PackedMatrix,
    /// `[d]` folded bias `B` (absorbs `b_down`).
    b: Vec<f32>,
    /// Packed kept-unit columns of `W_up`: `[d, kept]`.
    w_up_kept: PackedMatrix,
    /// `[kept]`.
    b_up_kept: Vec<f32>,
    /// Packed kept-unit rows of `W_down`: `[kept, d]`.
    w_down_kept: PackedMatrix,
    /// Folded columns of `W_up` transposed to `[nf, d]` row-major, so a
    /// top-K fix is one contiguous `d`-dot (empty for the norm router).
    w_up_f_t: Vec<f32>,
    /// Which predictor routes around the fold.
    kind: PredictorKind,
    /// The per-row norm gate (always constructed: its provable radius
    /// doubles as fold metadata, and the norm route uses it online).
    pub predictor: OutlierPredictor,
    /// The per-neuron quantized router (`kind == Quantized` only).
    pub quant: Option<QuantizedRouter>,
    pub telemetry: FfnTelemetry,
    /// Reusable routing state (no per-call allocation).
    norms: Vec<f32>,
    folded_mask: Vec<bool>,
    fallback_mask: Vec<bool>,
    fixes: Vec<(u32, u32)>,
}

impl FoldedFfn {
    /// Fold `dense` at `cfg.fold_ratio` with the *uniform* surrogate:
    /// the first `round(ratio·d_ff)` units linearized by one
    /// least-squares GELU fit on `[linear_lo, linear_hi)`.
    pub fn new(dense: DenseFfn, cfg: &TardisFfnConfig) -> FoldedFfn {
        let h = dense.d_ff;
        let nf = folded_units_for(cfg.fold_ratio, h);
        assert!(nf >= 1, "fold_ratio {} folds no units", cfg.fold_ratio);
        let lin = Linearization::fit_gelu(cfg.linear_lo, cfg.linear_hi);
        FoldedFfn::build(dense, cfg, RangeTable::uniform(lin, nf), None)
    }

    /// Fold `dense` with *per-neuron calibrated* ranges and fits:
    /// `lo`/`hi`/`slope`/`intercept` are full `[d_ff]` arrays from the
    /// python pipeline (the folded prefix `0..round(ratio·d_ff)` is
    /// used). `proxy_parts` optionally carries the pipeline's exported
    /// quantized `W_up` copy (row-major `[d, d_ff]` i8 codes and
    /// `[ceil(d/group), d_ff]` f32 scales); without it, a quantized
    /// predictor quantizes `W_up` at fold time.
    pub fn with_calibration(
        dense: DenseFfn,
        cfg: &TardisFfnConfig,
        lo: &[f32],
        hi: &[f32],
        slope: &[f32],
        intercept: &[f32],
        proxy_parts: Option<(&[i8], &[f32])>,
    ) -> FoldedFfn {
        let h = dense.d_ff;
        assert!(
            lo.len() == h && hi.len() == h && slope.len() == h && intercept.len() == h,
            "calibration arrays must cover all {h} hidden units"
        );
        let nf = folded_units_for(cfg.fold_ratio, h);
        assert!(nf >= 1, "fold_ratio {} folds no units", cfg.fold_ratio);
        let table = RangeTable::from_calibration(
            &lo[..nf],
            &hi[..nf],
            &slope[..nf],
            &intercept[..nf],
        );
        FoldedFfn::build(dense, cfg, table, proxy_parts)
    }

    /// Shared fold constructor: accumulate `C`/`B` in f64 with the
    /// table's per-unit slopes and pack once.
    fn build(
        dense: DenseFfn,
        cfg: &TardisFfnConfig,
        table: RangeTable,
        proxy_parts: Option<(&[i8], &[f32])>,
    ) -> FoldedFfn {
        let (d, h) = (dense.d_model, dense.d_ff);
        let nf = table.units();
        let reference = dense.with_ranges(table);
        let table = reference.ranges.as_ref().expect("just set");
        let (w_up, b_up) = (&reference.w_up, &reference.b_up);
        let (w_down, b_down) = (&reference.w_down, &reference.b_down);

        // C[l][m] = Σ_{j<nf} w_up[l][j] · a_j · w_down[j][m]
        let mut c = vec![0f64; d * d];
        for l in 0..d {
            let row = &mut c[l * d..(l + 1) * d];
            for j in 0..nf {
                let scaled = w_up[l * h + j] as f64 * table.slope[j] as f64;
                for (cv, &wv) in row.iter_mut().zip(&w_down[j * d..(j + 1) * d]) {
                    *cv += scaled * wv as f64;
                }
            }
        }
        // B[m] = Σ_{j<nf} (a_j·b_up[j] + c_j) · w_down[j][m] + b_down[m]
        let mut b = vec![0f64; d];
        for j in 0..nf {
            let coef = table.slope[j] as f64 * b_up[j] as f64 + table.intercept[j] as f64;
            for (bv, &wv) in b.iter_mut().zip(&w_down[j * d..(j + 1) * d]) {
                *bv += coef * wv as f64;
            }
        }
        for (bv, &bd) in b.iter_mut().zip(b_down.iter()) {
            *bv += bd as f64;
        }

        // Kept units: gather columns nf.. of W_up, rows nf.. of W_down.
        let kept = h - nf;
        let mut w_up_kept = Vec::with_capacity(d * kept);
        for l in 0..d {
            w_up_kept.extend_from_slice(&w_up[l * h + nf..(l + 1) * h]);
        }
        let b_up_kept = b_up[nf..].to_vec();
        let w_down_kept = w_down[nf * d..].to_vec();

        // Provable in-range radius: min_j slack_j / ‖w_up column j‖,
        // with per-neuron slack against the calibrated range.
        let mut safe_radius = f32::INFINITY;
        for j in 0..nf {
            let slack = (table.hi[j] - b_up[j]).min(b_up[j] - table.lo[j]);
            if slack <= 0.0 {
                safe_radius = 0.0;
                break;
            }
            let col_norm = (0..d)
                .map(|l| {
                    let w = w_up[l * h + j] as f64;
                    w * w
                })
                .sum::<f64>()
                .sqrt() as f32;
            if col_norm > 1e-12 {
                safe_radius = safe_radius.min(slack / col_norm);
            }
        }
        if !safe_radius.is_finite() {
            // every folded column is zero: constant units, always in range
            safe_radius = f32::MAX;
        }

        // The per-neuron router: packed k-bit proxy + transposed folded
        // columns for result fixing.
        let (quant, w_up_f_t) = if cfg.predictor == PredictorKind::Quantized {
            let proxy = match proxy_parts {
                Some((codes, scales)) => QuantizedProxy::from_parts(
                    codes,
                    scales,
                    d,
                    h,
                    nf,
                    cfg.predictor_bits,
                    cfg.predictor_group,
                ),
                None => QuantizedProxy::quantize(
                    w_up,
                    d,
                    h,
                    nf,
                    cfg.predictor_bits,
                    cfg.predictor_group,
                ),
            };
            let mut t = vec![0f32; nf * d];
            for l in 0..d {
                for j in 0..nf {
                    t[j * d + l] = w_up[l * h + j];
                }
            }
            (Some(QuantizedRouter::new(proxy, cfg.top_k)), t)
        } else {
            (None, Vec::new())
        };

        let c_f32: Vec<f32> = c.into_iter().map(|v| v as f32).collect();
        FoldedFfn {
            folded_units: nf,
            kept_units: kept,
            c: PackedMatrix::pack(&c_f32, d, d),
            b: b.into_iter().map(|v| v as f32).collect(),
            w_up_kept: PackedMatrix::pack(&w_up_kept, d, kept),
            b_up_kept,
            w_down_kept: PackedMatrix::pack(&w_down_kept, kept, d),
            w_up_f_t,
            kind: cfg.predictor,
            predictor: OutlierPredictor::new(safe_radius, cfg.predictor_threshold),
            quant,
            telemetry: FfnTelemetry::default(),
            norms: Vec::new(),
            folded_mask: Vec::new(),
            fallback_mask: Vec::new(),
            fixes: Vec::new(),
            reference,
        }
    }

    pub fn d_model(&self) -> usize {
        self.reference.d_model
    }

    pub fn folded_units(&self) -> usize {
        self.folded_units
    }

    pub fn predictor_kind(&self) -> PredictorKind {
        self.kind
    }

    /// The per-unit surrogate table of the folded prefix.
    pub fn range_table(&self) -> &RangeTable {
        self.reference.ranges.as_ref().expect("folded ffn has ranges")
    }

    /// Resident parameters of the folded deployment (f32 equivalents;
    /// the quantized proxy counts at `bits/32` per code plus f16
    /// scales).
    pub fn param_count(&self) -> usize {
        let d = self.reference.d_model;
        d * d + d + self.kept_units * (2 * d + 1)
    }

    /// Fraction of dense FFN parameters eliminated by the fold.
    pub fn compression_ratio(&self) -> f64 {
        let mut kept = self.param_count() as f64;
        if let Some(q) = &self.quant {
            kept += q.proxy.size_params_f32();
        }
        1.0 - kept / self.reference.param_count() as f64
    }

    /// Batch forward with routed execution; `x` is `[rows, d_model]`.
    /// The returned buffer comes from `scratch` (hand it back with
    /// [`Scratch::give`] for steady-state zero allocation).
    pub fn forward(
        &mut self,
        pool: Option<&ThreadPool>,
        scratch: &mut Scratch,
        x: &[f32],
        rows: usize,
    ) -> Vec<f32> {
        self.forward_forced(pool, scratch, x, rows, &[])
    }

    /// [`Self::forward`] with a per-row degraded-service mask (empty =
    /// nothing forced). A forced row folds unconditionally: the
    /// predictor is bypassed (no classification, no online observation)
    /// and the quantized router issues no fixes for it — the row runs
    /// the pure folded path, `--fix-k 0`. Because the row-sparse kernels
    /// are bitwise row-independent, a forced row's output is identical
    /// whatever mix of neighbors shares the batch.
    pub fn forward_forced(
        &mut self,
        pool: Option<&ThreadPool>,
        scratch: &mut Scratch,
        x: &[f32],
        rows: usize,
        forced: &[bool],
    ) -> Vec<f32> {
        let d = self.reference.d_model;
        debug_assert_eq!(x.len(), rows * d);
        debug_assert!(forced.is_empty() || forced.len() == rows);
        let nf = self.folded_units;
        self.norms.clear();
        self.folded_mask.clear();
        self.fallback_mask.clear();
        self.fixes.clear();
        let is_forced = |i: usize| forced.get(i).copied().unwrap_or(false);
        let mut n_folded = 0usize;
        match self.kind {
            PredictorKind::Norm => {
                for (i, row) in x.chunks_exact(d).take(rows).enumerate() {
                    if is_forced(i) {
                        // placeholder norm: never read (the row cannot
                        // reach the fallback/observe loop)
                        self.norms.push(0.0);
                        self.folded_mask.push(true);
                        self.fallback_mask.push(false);
                        n_folded += 1;
                        continue;
                    }
                    let nrm = norm(row);
                    let folded = matches!(self.predictor.classify(nrm), Route::Folded);
                    self.norms.push(nrm);
                    self.folded_mask.push(folded);
                    self.fallback_mask.push(!folded);
                    if folded {
                        n_folded += 1;
                    }
                }
            }
            PredictorKind::Quantized => {
                let mut z_hat = scratch.take(rows * nf);
                let table = self.reference.ranges.as_ref().expect("folded ffn has ranges");
                let quant = self.quant.as_mut().expect("quantized router");
                quant
                    .proxy
                    .forward_into(pool, x, rows, &self.reference.b_up[..nf], &mut z_hat);
                for i in 0..rows {
                    if is_forced(i) {
                        self.folded_mask.push(true);
                        self.fallback_mask.push(false);
                        n_folded += 1;
                        continue;
                    }
                    let route = quant.decide_row(
                        &z_hat[i * nf..(i + 1) * nf],
                        table,
                        i as u32,
                        &mut self.fixes,
                    );
                    let folded = !matches!(route, QuantRoute::Fallback);
                    self.folded_mask.push(folded);
                    self.fallback_mask.push(!folded);
                    if folded {
                        n_folded += 1;
                    }
                }
                scratch.give(z_hat);
            }
        }
        let n_fallback = rows - n_folded;
        let mut out = scratch.take(rows * d);

        if n_folded == rows {
            // whole batch folded: dense tiling, parallel when large
            matmul(pool, x, rows, &self.c, Epilogue::Bias(&self.b), &mut out);
            if self.kept_units > 0 {
                let mut hk = scratch.take(rows * self.kept_units);
                matmul(
                    pool,
                    x,
                    rows,
                    &self.w_up_kept,
                    Epilogue::BiasGelu(&self.b_up_kept),
                    &mut hk,
                );
                matmul(pool, &hk, rows, &self.w_down_kept, Epilogue::Add, &mut out);
                scratch.give(hk);
            }
        } else if n_folded > 0 {
            // mixed batch: folded rows execute in place over their mask
            matmul_sparse_rows(
                pool,
                x,
                rows,
                &self.c,
                Epilogue::Bias(&self.b),
                &self.folded_mask,
                &mut out,
            );
            if self.kept_units > 0 {
                let mut hk = scratch.take(rows * self.kept_units);
                matmul_sparse_rows(
                    pool,
                    x,
                    rows,
                    &self.w_up_kept,
                    Epilogue::BiasGelu(&self.b_up_kept),
                    &self.folded_mask,
                    &mut hk,
                );
                matmul_sparse_rows(
                    pool,
                    &hk,
                    rows,
                    &self.w_down_kept,
                    Epilogue::Add,
                    &self.folded_mask,
                    &mut out,
                );
                scratch.give(hk);
            }
        }

        if n_fallback > 0 {
            let h = self.reference.d_ff;
            let mut z = scratch.take(rows * h);
            if n_fallback == rows {
                self.reference.preactivations_into(pool, x, rows, &mut z);
            } else {
                matmul_sparse_rows(
                    pool,
                    x,
                    rows,
                    &self.reference.w_up_packed,
                    Epilogue::Bias(&self.reference.b_up),
                    &self.fallback_mask,
                    &mut z,
                );
            }
            let table = self.reference.ranges.as_ref().expect("folded ffn has ranges");
            for i in 0..rows {
                if !self.fallback_mask[i] {
                    continue;
                }
                let zrow = &mut z[i * h..(i + 1) * h];
                if self.kind == PredictorKind::Norm {
                    // every fallback row is an observation for the
                    // online norm gate
                    let in_range = (0..nf).all(|j| table.in_range(j, zrow[j]));
                    self.predictor.observe(self.norms[i], in_range);
                }
                self.reference.activate_row(zrow);
            }
            if n_fallback == rows {
                self.reference.project_into(pool, &z, rows, &mut out);
            } else {
                matmul_sparse_rows(
                    pool,
                    &z,
                    rows,
                    &self.reference.w_down_packed,
                    Epilogue::Bias(&self.reference.b_down),
                    &self.fallback_mask,
                    &mut out,
                );
            }
            scratch.give(z);
        }

        // Top-K result fixing: each flagged neuron of a still-folded row
        // recomputes its exact pre-activation (one contiguous d-dot) and
        // patches the folded output with the surrogate's residual.
        if !self.fixes.is_empty() {
            let table = self.reference.ranges.as_ref().expect("folded ffn has ranges");
            let quant = self.quant.as_mut().expect("quantized router");
            let mut applied = 0u64;
            for &(row, j) in &self.fixes {
                let (ri, ji) = (row as usize, j as usize);
                let z = dot(
                    &x[ri * d..(ri + 1) * d],
                    &self.w_up_f_t[ji * d..(ji + 1) * d],
                ) + self.reference.b_up[ji];
                if table.in_range(ji, z) {
                    // false flag: the folded surrogate was already exact
                    quant.stats.fixed_in_range += 1;
                    continue;
                }
                quant.stats.fixed_out_of_range += 1;
                applied += 1;
                let delta = gelu(z) - table.surrogate(ji, z);
                let orow = &mut out[ri * d..(ri + 1) * d];
                for (o, &wv) in orow
                    .iter_mut()
                    .zip(&self.reference.w_down[ji * d..(ji + 1) * d])
                {
                    *o += delta * wv;
                }
            }
            // only fixes that actually patched the output; false flags
            // are visible in QuantRouterStats::fixed_in_range
            self.telemetry.fixed_neurons += applied;
        }

        self.telemetry.folded_rows += n_folded as u64;
        self.telemetry.fallback_rows += n_fallback as u64;
        out
    }

    /// Evaluate this FFN's routing decisions against ground-truth range
    /// violations on `x` (`[rows, d_model]`), without mutating any
    /// online state. A (row, neuron) pair counts as *flagged* when it
    /// would execute on the dense path — through per-neuron fixing or a
    /// whole-row fallback.
    pub fn routing_quality(
        &self,
        scratch: &mut Scratch,
        x: &[f32],
        rows: usize,
    ) -> RoutingQuality {
        let d = self.reference.d_model;
        let h = self.reference.d_ff;
        let nf = self.folded_units;
        debug_assert_eq!(x.len(), rows * d);
        let table = self.reference.ranges.as_ref().expect("folded ffn has ranges");
        let mut z = scratch.take(rows * h);
        self.reference.preactivations_into(None, x, rows, &mut z);
        let (mut tp, mut flagged, mut truly) = (0u64, 0u64, 0u64);
        match self.kind {
            PredictorKind::Norm => {
                let radius = self.predictor.predicted_radius();
                for i in 0..rows {
                    let dense_row = norm(&x[i * d..(i + 1) * d]) > radius;
                    for j in 0..nf {
                        let oor = !table.in_range(j, z[i * h + j]);
                        if oor {
                            truly += 1;
                        }
                        if dense_row {
                            flagged += 1;
                            if oor {
                                tp += 1;
                            }
                        }
                    }
                }
            }
            PredictorKind::Quantized => {
                let quant = self.quant.as_ref().expect("quantized router");
                let mut z_hat = scratch.take(rows * nf);
                quant
                    .proxy
                    .forward_into(None, x, rows, &self.reference.b_up[..nf], &mut z_hat);
                for i in 0..rows {
                    let zh = &z_hat[i * nf..(i + 1) * nf];
                    let row_fallback = quant.count_flags(zh, table) > quant.top_k;
                    for j in 0..nf {
                        let oor = !table.in_range(j, z[i * h + j]);
                        if oor {
                            truly += 1;
                        }
                        if row_fallback || !table.in_range(j, zh[j]) {
                            flagged += 1;
                            if oor {
                                tp += 1;
                            }
                        }
                    }
                }
                scratch.give(z_hat);
            }
        }
        scratch.give(z);
        RoutingQuality::from_counts(tp, flagged, truly, (rows * nf) as u64)
    }
}

/// Result of [`compare_predictors`]: both routers folded over the same
/// dense weights and scored on the same seeded injected-outlier batch.
pub struct PredictorComparison {
    /// Norm-routed fold, warmed online on clean rows at the workload
    /// norm (its learned radius covers `norm_target`).
    pub norm_fold: FoldedFfn,
    /// Quantized-routed fold over the same dense weights.
    pub quant_fold: FoldedFfn,
    /// The evaluation batch (`rows` × d_model, every 4th row an aligned
    /// direction-dependent outlier).
    pub workload: Vec<f32>,
    pub rows: usize,
    /// Shared row norm: 1.25× the provable radius.
    pub norm_target: f32,
    pub norm: RoutingQuality,
    pub quantized: RoutingQuality,
}

/// The one evaluation harness behind the `bench-decode`/`variants`
/// routing-quality report **and** the `predictor_quality` regression
/// test, so the two can never drift apart: fold `dense` under both
/// [`PredictorKind`]s, warm the norm gate exactly as it would warm
/// online (8 clean rows at the shared norm, two passes: fall back +
/// observe, then fold), then score both routers with
/// [`FoldedFfn::routing_quality`] on a 64-row
/// [`synthetic_outlier_workload`] with every 4th row injected.
pub fn compare_predictors(
    dense: DenseFfn,
    cfg: &TardisFfnConfig,
    rng: &mut Rng,
) -> PredictorComparison {
    let mut norm_fold = FoldedFfn::new(
        dense.clone(),
        &TardisFfnConfig { predictor: PredictorKind::Norm, ..*cfg },
    );
    let quant_fold = FoldedFfn::new(
        dense,
        &TardisFfnConfig { predictor: PredictorKind::Quantized, ..*cfg },
    );
    let mut scratch = Scratch::new();
    let norm_target = 1.25 * norm_fold.predictor.safe_radius();
    let warm = synthetic_outlier_workload(
        rng,
        &norm_fold.reference,
        norm_fold.range_table(),
        norm_target,
        8,
        usize::MAX,
    );
    for _ in 0..2 {
        let y = norm_fold.forward(None, &mut scratch, &warm, 8);
        scratch.give(y);
    }
    let rows = 64;
    let workload = synthetic_outlier_workload(
        rng,
        &norm_fold.reference,
        norm_fold.range_table(),
        norm_target,
        rows,
        4,
    );
    let norm = norm_fold.routing_quality(&mut scratch, &workload, rows);
    let quantized = quant_fold.routing_quality(&mut scratch, &workload, rows);
    PredictorComparison {
        norm_fold,
        quant_fold,
        workload,
        rows,
        norm_target,
        norm,
        quantized,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn random_dense(rng: &mut Rng, d: usize, h: usize, scale: f32) -> DenseFfn {
        let w_up: Vec<f32> = (0..d * h).map(|_| rng.normal() as f32 * scale).collect();
        let b_up: Vec<f32> = (0..h).map(|_| rng.normal() as f32 * 0.1).collect();
        let w_down: Vec<f32> = (0..h * d).map(|_| rng.normal() as f32 * scale).collect();
        let b_down: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
        DenseFfn::new(Arc::new(w_up), Arc::new(b_up), Arc::new(w_down), Arc::new(b_down), d, h)
    }

    fn cfg(ratio: f64) -> TardisFfnConfig {
        TardisFfnConfig {
            fold_ratio: ratio,
            linear_lo: -6.0,
            linear_hi: 6.0,
            predictor_threshold: 1.0,
            ..TardisFfnConfig::default()
        }
    }

    #[test]
    fn folded_matches_reference_for_provably_safe_rows() {
        let mut rng = Rng::new(42);
        let dense = random_dense(&mut rng, 8, 16, 0.3);
        let mut f = FoldedFfn::new(dense, &cfg(0.75));
        let r = f.predictor.safe_radius();
        assert!(r > 0.0, "safe radius {r}");
        // rows scaled to 90% of the provable radius: folded on first call
        let rows = 5;
        let mut x = vec![0f32; rows * 8];
        for row in x.chunks_mut(8) {
            for v in row.iter_mut() {
                *v = rng.normal() as f32;
            }
            let n = norm(row);
            for v in row.iter_mut() {
                *v *= 0.9 * r / n;
            }
        }
        let mut scratch = Scratch::new();
        let got = f.forward(None, &mut scratch, &x, rows);
        let want = f.reference.forward(None, &mut scratch, &x, rows);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0), "folded {g} vs reference {w}");
        }
        assert_eq!(f.telemetry.folded_rows, rows as u64);
        assert_eq!(f.telemetry.fallback_rows, 0);
    }

    #[test]
    fn outlier_rows_fall_back_bitwise() {
        let mut rng = Rng::new(7);
        let dense = random_dense(&mut rng, 8, 16, 0.3);
        let mut f = FoldedFfn::new(dense, &cfg(0.5));
        let r = f.predictor.safe_radius();
        // one far-out row along folded column 0, one safe row
        let d = 8;
        let h = 16;
        let mut x = vec![0f32; 2 * d];
        for (l, v) in x[..d].iter_mut().enumerate() {
            *v = f.reference.w_up[l * h]; // column 0 direction
        }
        let n0 = norm(&x[..d]);
        let blow = 50.0 * r / n0;
        for v in x[..d].iter_mut() {
            *v *= blow;
        }
        for v in x[d..].iter_mut() {
            *v = 0.01 * r;
        }
        let mut scratch = Scratch::new();
        let got = f.forward(None, &mut scratch, &x, 2);
        let want = f.reference.forward(None, &mut scratch, &x, 2);
        // outlier row: routed dense, so exactly the reference
        assert_eq!(&got[..d], &want[..d]);
        // safe row: folded, within fold roundoff
        for (g, w) in got[d..].iter().zip(&want[d..]) {
            assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0));
        }
        assert_eq!(f.telemetry.fallback_rows, 1);
        assert_eq!(f.telemetry.folded_rows, 1);
        assert_eq!(f.predictor.stats.observed_out_of_range, 1);
    }

    #[test]
    fn forced_rows_take_pure_folded_path_bitwise() {
        let mut rng = Rng::new(11);
        let dense = random_dense(&mut rng, 8, 16, 0.3);
        let mut mixed = FoldedFfn::new(dense.clone(), &cfg(0.5));
        let mut all = FoldedFfn::new(dense, &cfg(0.5));
        let r = mixed.predictor.safe_radius();
        let (d, h) = (8, 16);
        // two copies of a far outlier along folded column 0: the norm
        // gate would route both dense
        let mut x = vec![0f32; 2 * d];
        for (l, v) in x[..d].iter_mut().enumerate() {
            *v = mixed.reference.w_up[l * h];
        }
        let n0 = norm(&x[..d]);
        let blow = 50.0 * r / n0;
        for v in x[..d].iter_mut() {
            *v *= blow;
        }
        let (head, tail) = x.split_at_mut(d);
        tail.copy_from_slice(head);
        let mut scratch = Scratch::new();
        // Degrade only row 0 in one call, both rows in the other: the
        // forced row must come out bitwise identical — the pure folded
        // path, independent of what its batch neighbors do.
        let got = mixed.forward_forced(None, &mut scratch, &x, 2, &[true, false]);
        let want = all.forward_forced(None, &mut scratch, &x, 2, &[true, true]);
        assert_eq!(&got[..d], &want[..d], "forced row output depends on batch mask");
        // The unforced copy still routes dense (bitwise the reference),
        // so forcing genuinely changed row 0's path.
        let reference = mixed.reference.forward(None, &mut scratch, &x, 2);
        assert_eq!(&got[d..], &reference[d..]);
        assert_ne!(&got[..d], &reference[..d], "outlier fold must differ from dense");
        // Forced rows bypass the predictor entirely: only the unforced
        // outlier was observed, and the all-forced run observed nothing.
        assert_eq!(mixed.telemetry.folded_rows, 1);
        assert_eq!(mixed.telemetry.fallback_rows, 1);
        assert_eq!(mixed.predictor.stats.observed_out_of_range, 1);
        assert_eq!(all.telemetry.folded_rows, 2);
        assert_eq!(all.telemetry.fallback_rows, 0);
        assert_eq!(all.predictor.stats.observed_out_of_range, 0);
    }

    #[test]
    fn forced_rows_skip_quantized_fixes() {
        let d = 16;
        let mut f = FoldedFfn::new(orthogonal_dense(d), &quant_cfg(0.75, 4));
        // unit 1 far out of range: normally one top-K fix would land
        let mut x = vec![0f32; d];
        x[1] = 20.0;
        let mut scratch = Scratch::new();
        let y = f.forward_forced(None, &mut scratch, &x, 1, &[true]);
        scratch.give(y);
        assert_eq!(f.telemetry.folded_rows, 1);
        assert_eq!(f.telemetry.fallback_rows, 0);
        assert_eq!(f.telemetry.fixed_neurons, 0, "degraded rows run fix-k 0");
        let q = f.quant.as_ref().unwrap();
        assert_eq!(
            q.stats.rows_fixed + q.stats.rows_clean + q.stats.rows_fallback,
            0,
            "forced rows never consult the router"
        );
    }

    #[test]
    fn online_predictor_learns_in_range_norms() {
        // w_up = 0.5·I with a wide range: safe radius 12/0.5 = 24, but
        // x = [15,15,15,15] (norm 30) has z_j = 7.5, well in range.
        let d = 4;
        let mut eye = vec![0f32; d * d];
        for i in 0..d {
            eye[i * d + i] = 0.5;
        }
        let dense = DenseFfn::new(
            Arc::new(eye.clone()),
            Arc::new(vec![0.0; d]),
            Arc::new(eye),
            Arc::new(vec![0.0; d]),
            d,
            d,
        );
        let mut f = FoldedFfn::new(
            dense,
            &TardisFfnConfig {
                fold_ratio: 1.0,
                linear_lo: -12.0,
                linear_hi: 12.0,
                predictor_threshold: 1.0,
                ..TardisFfnConfig::default()
            },
        );
        assert!((f.predictor.safe_radius() - 24.0).abs() < 1e-4);
        let x = vec![15.0f32; d];
        let mut scratch = Scratch::new();
        let first = f.forward(None, &mut scratch, &x, 1);
        assert_eq!(f.telemetry.fallback_rows, 1, "first sighting falls back");
        let second = f.forward(None, &mut scratch, &x, 1);
        assert_eq!(f.telemetry.folded_rows, 1, "second sighting folds");
        for (a, b) in first.iter().zip(&second) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
        }
    }

    #[test]
    fn compression_ratio_tracks_fold_ratio() {
        let mut rng = Rng::new(3);
        let dense = random_dense(&mut rng, 16, 64, 0.2);
        let full = FoldedFfn::new(random_dense(&mut rng, 16, 64, 0.2), &cfg(1.0));
        let half = FoldedFfn::new(dense, &cfg(0.5));
        assert!(full.compression_ratio() > half.compression_ratio());
        // h = 4d: folding everything removes 1 - (d²+d)/(2dh+h+d) ≈ 87%
        let r = full.compression_ratio();
        assert!(r > 0.8, "{r}");
        assert!(half.compression_ratio() > 0.3);
    }

    #[test]
    fn quantized_proxy_counts_against_compression() {
        let mut rng = Rng::new(31);
        let dense = random_dense(&mut rng, 16, 64, 0.2);
        let norm_fold = FoldedFfn::new(dense.clone(), &cfg(0.8));
        let quant_fold = FoldedFfn::new(
            dense,
            &TardisFfnConfig {
                predictor: PredictorKind::Quantized,
                predictor_group: 8,
                ..cfg(0.8)
            },
        );
        let (rn, rq) = (norm_fold.compression_ratio(), quant_fold.compression_ratio());
        assert!(rq < rn, "proxy must cost something: {rq} vs {rn}");
        assert!(rq > 0.3, "but only bits/32 of the folded columns: {rq}");
    }

    #[test]
    fn steady_state_forward_allocates_nothing() {
        let mut rng = Rng::new(99);
        let dense = random_dense(&mut rng, 8, 16, 0.3);
        let mut f = FoldedFfn::new(dense, &cfg(0.75));
        let r = f.predictor.safe_radius();
        let rows = 3;
        let mut x = vec![0f32; rows * 8];
        for row in x.chunks_mut(8) {
            for v in row.iter_mut() {
                *v = rng.normal() as f32;
            }
            let n = norm(row);
            for v in row.iter_mut() {
                *v *= 0.5 * r / n;
            }
        }
        let mut scratch = Scratch::new();
        let warm = f.forward(None, &mut scratch, &x, rows);
        scratch.give(warm);
        let misses = scratch.misses;
        for _ in 0..10 {
            let y = f.forward(None, &mut scratch, &x, rows);
            scratch.give(y);
        }
        assert_eq!(scratch.misses, misses, "steady-state decode must not allocate");
    }

    // -- quantized per-neuron routing -----------------------------------

    fn quant_cfg(ratio: f64, top_k: usize) -> TardisFfnConfig {
        TardisFfnConfig {
            fold_ratio: ratio,
            linear_lo: -6.0,
            linear_hi: 6.0,
            predictor_threshold: 1.0,
            predictor: PredictorKind::Quantized,
            predictor_bits: 4,
            predictor_group: 8,
            top_k,
        }
    }

    /// `d == h` FFN with orthogonal folded columns (`w_up = 0.5·I`):
    /// hidden unit `j` listens to input coordinate `j` alone, so a row
    /// along `e_j` is a pure direction-dependent outlier for unit `j`.
    /// One-hot columns also quantize exactly (absmax maps to the top
    /// code), making the proxy's decisions deterministic.
    fn orthogonal_dense(d: usize) -> DenseFfn {
        let mut eye = vec![0f32; d * d];
        for i in 0..d {
            eye[i * d + i] = 0.5;
        }
        let mut rng = Rng::new(123);
        let w_down: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32 * 0.3).collect();
        DenseFfn::new(
            Arc::new(eye),
            Arc::new(vec![0.1; d]),
            Arc::new(w_down),
            Arc::new(vec![0.0; d]),
            d,
            d,
        )
    }

    #[test]
    fn quantized_router_fixes_single_neuron_outliers() {
        let d = 16;
        let mut f = FoldedFfn::new(orthogonal_dense(d), &quant_cfg(0.75, 4));
        assert_eq!(f.folded_units(), 12);
        // row 0: z_1 = 20·0.5 + 0.1 = 10.1, out of [-6, 6) — every other
        // unit sits at its bias; row 1: uniformly tiny, all in range.
        let mut x = vec![0f32; 2 * d];
        x[1] = 20.0;
        for v in x[d..].iter_mut() {
            *v = 0.01;
        }
        let mut scratch = Scratch::new();
        let got = f.forward(None, &mut scratch, &x, 2);
        let want = f.reference.forward(None, &mut scratch, &x, 2);
        // both rows stay folded (the outlier is fixed per neuron, not
        // routed away) and the fixed output tracks the exact reference
        assert_eq!(f.telemetry.folded_rows, 2);
        assert_eq!(f.telemetry.fallback_rows, 0);
        assert_eq!(f.telemetry.fixed_neurons, 1, "exactly the outlier neuron");
        let q = f.quant.as_ref().unwrap();
        assert_eq!(q.stats.rows_fixed, 1);
        assert_eq!(q.stats.rows_clean, 1);
        assert_eq!(q.stats.fixed_out_of_range, 1);
        assert_eq!(q.stats.fixed_in_range, 0);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                "elem {i}: fixed {g} vs reference {w}"
            );
        }
        // the norm proxy would have missed this row entirely once its
        // learned radius covers ‖x‖ — the quantized route catches it
        // regardless of the row's norm.
    }

    #[test]
    fn quantized_router_falls_back_beyond_capacity() {
        let d = 16;
        // top_k = 0: any flagged neuron forces the row onto the exact
        // dense path.
        let mut f = FoldedFfn::new(orthogonal_dense(d), &quant_cfg(0.75, 0));
        let mut x = vec![0f32; d];
        x[0] = 30.0;
        let mut scratch = Scratch::new();
        let got = f.forward(None, &mut scratch, &x, 1);
        let want = f.reference.forward(None, &mut scratch, &x, 1);
        assert_eq!(f.telemetry.fallback_rows, 1);
        assert_eq!(f.telemetry.fixed_neurons, 0);
        assert_eq!(f.quant.as_ref().unwrap().stats.rows_fallback, 1);
        assert_eq!(got, want, "fallback rows are bitwise dense");
    }

    #[test]
    fn calibrated_fold_uses_per_neuron_slopes() {
        let mut rng = Rng::new(57);
        let (d, h) = (8, 16);
        let dense = random_dense(&mut rng, d, h, 0.3);
        // Per-neuron tables: unit j gets range [-4-j*0.1, 4+j*0.1) and
        // its own least-squares fit on that range.
        let mut lo = Vec::new();
        let mut hi = Vec::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        for j in 0..h {
            let (l, r) = (-4.0 - 0.1 * j as f32, 4.0 + 0.1 * j as f32);
            let fit = Linearization::fit_gelu(l, r);
            lo.push(l);
            hi.push(r);
            a.push(fit.slope);
            b.push(fit.intercept);
        }
        let c = cfg(0.75);
        let mut f = FoldedFfn::with_calibration(dense, &c, &lo, &hi, &a, &b, None);
        assert_eq!(f.range_table().units(), 12);
        assert!((f.range_table().lo[3] + 4.3).abs() < 1e-6);
        // in-range rows reproduce the per-neuron reference
        let r = f.predictor.safe_radius();
        assert!(r > 0.0);
        let rows = 3;
        let mut x = vec![0f32; rows * d];
        for row in x.chunks_mut(d) {
            for v in row.iter_mut() {
                *v = rng.normal() as f32;
            }
            let n = norm(row);
            for v in row.iter_mut() {
                *v *= 0.9 * r / n;
            }
        }
        let mut scratch = Scratch::new();
        let got = f.forward(None, &mut scratch, &x, rows);
        let want = f.reference.forward(None, &mut scratch, &x, rows);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= 1e-3 * w.abs().max(1.0), "{g} vs {w}");
        }
        assert_eq!(f.telemetry.fallback_rows, 0);
    }

    #[test]
    fn routing_quality_scores_perfect_predictor_on_clean_rows() {
        let mut rng = Rng::new(58);
        let dense = random_dense(&mut rng, 8, 16, 0.3);
        let f = FoldedFfn::new(dense, &cfg(0.75));
        let r = f.predictor.safe_radius();
        let rows = 4;
        let mut x = vec![0f32; rows * 8];
        for row in x.chunks_mut(8) {
            for v in row.iter_mut() {
                *v = rng.normal() as f32;
            }
            let n = norm(row);
            for v in row.iter_mut() {
                *v *= 0.5 * r / n;
            }
        }
        let mut scratch = Scratch::new();
        let q = f.routing_quality(&mut scratch, &x, rows);
        // nothing is truly out of range and nothing is flagged
        assert_eq!(q.true_oor_rate, 0.0);
        assert_eq!(q.flag_rate, 0.0);
        assert_eq!(q.precision, 0.0);
        assert_eq!(q.recall, 0.0);
    }
}
