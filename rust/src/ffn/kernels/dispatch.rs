//! Runtime ISA dispatch for the kernel tier.
//!
//! The micro-kernel family is selected **once** per process: the first
//! call to [`KernelDispatch::active`] probes the CPU (via
//! `is_x86_feature_detected!`) and caches the result, so the hot loops
//! carry no per-call feature branches beyond one enum compare that the
//! branch predictor retires for free. Every GEMM entry point also has a
//! `*_with` variant taking an explicit [`KernelDispatch`], which is how
//! the equivalence tests force both paths in one process.
//!
//! **Numerics contract.** Within one dispatch path, results are bitwise
//! deterministic and thread-count invariant (see the `gemm` module
//! docs). *Across* paths the portable tiles round every multiply and add
//! separately while the AVX2/FMA tiles contract them into fused
//! multiply-adds, so the two paths agree only to rounding — the
//! fold-tolerance bound (`FOLD_TOL = 1e-3` relative, documented in
//! `tests/fold_invariant.rs`) is the repo-wide budget for exactly this
//! kind of reassociation/contraction noise, and the SIMD-vs-portable
//! equivalence tests assert it.
//!
//! Setting `TARDIS_FORCE_SCALAR=1` (also `true`/`yes`) pins dispatch to
//! the portable tiles regardless of hardware — the escape hatch for
//! bit-exact cross-machine reproduction and the lane CI uses to keep the
//! fallback path exercised on SIMD-capable runners.

use std::sync::OnceLock;

/// Which micro-kernel family the GEMM drivers hand their tiles to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelDispatch {
    /// The portable `MR`×`NR` tiles: fixed-size-array accumulators
    /// autovectorized by stable Rust. Always available; bit-exact across
    /// machines and the reference the SIMD paths are tested against.
    Portable,
    /// Explicit AVX2 + FMA micro-kernels (x86-64 only, runtime-detected).
    Avx2Fma,
}

impl KernelDispatch {
    /// Probe the CPU and the `TARDIS_FORCE_SCALAR` override. Prefer
    /// [`KernelDispatch::active`], which caches this answer.
    pub fn detect() -> KernelDispatch {
        if force_scalar() {
            return KernelDispatch::Portable;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return KernelDispatch::Avx2Fma;
            }
        }
        KernelDispatch::Portable
    }

    /// The process-wide dispatch decision, made once on first use.
    pub fn active() -> KernelDispatch {
        static ACTIVE: OnceLock<KernelDispatch> = OnceLock::new();
        *ACTIVE.get_or_init(KernelDispatch::detect)
    }

    /// Every path executable on this machine, portable first. Reflects
    /// hardware only — `TARDIS_FORCE_SCALAR` pins [`Self::active`] but
    /// does not hide paths from tests that enumerate this list.
    pub fn available() -> Vec<KernelDispatch> {
        let mut paths = vec![KernelDispatch::Portable];
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                paths.push(KernelDispatch::Avx2Fma);
            }
        }
        paths
    }

    /// Stable identifier for bench output and JSON reports.
    pub fn name(self) -> &'static str {
        match self {
            KernelDispatch::Portable => "portable",
            KernelDispatch::Avx2Fma => "avx2+fma",
        }
    }
}

fn force_scalar() -> bool {
    matches!(
        std::env::var("TARDIS_FORCE_SCALAR").ok().as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn available_starts_portable_and_contains_active_hardware_path() {
        let paths = KernelDispatch::available();
        assert_eq!(paths[0], KernelDispatch::Portable);
        // detect() without the env override must be one of the
        // executable paths (active() may be pinned by the env).
        assert!(paths.contains(&KernelDispatch::detect()) || force_scalar());
    }

    #[test]
    fn names_are_stable() {
        assert_eq!(KernelDispatch::Portable.name(), "portable");
        assert_eq!(KernelDispatch::Avx2Fma.name(), "avx2+fma");
    }
}
