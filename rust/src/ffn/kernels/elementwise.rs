//! Elementwise / reduction primitives shared by the kernel subsystem:
//! GELU, dot, norm, and single-pass (Welford) LayerNorm.

/// tanh-approximation GELU (the activation of the `TINY_GELU` shape).
pub fn gelu(z: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    const CUBIC: f32 = 0.044_715;
    0.5 * z * (1.0 + (SQRT_2_OVER_PI * (z + CUBIC * z * z * z)).tanh())
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// Euclidean norm of one row.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// LayerNorm over the last dimension, written into `out`: per row,
/// subtract the mean, divide by the standard deviation (eps 1e-5),
/// scale and shift. Mean and variance come from a single Welford pass
/// (numerically stabler than the old two-pass sum-of-squares and one
/// fewer sweep over the row).
pub fn layernorm_into(
    x: &[f32],
    rows: usize,
    d: usize,
    gain: &[f32],
    bias: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(out.len(), rows * d);
    for (xi, yi) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)).take(rows) {
        let mut mean = 0f32;
        let mut m2 = 0f32;
        let mut count = 0f32;
        for &v in xi {
            count += 1.0;
            let delta = v - mean;
            mean += delta / count;
            m2 += delta * (v - mean);
        }
        let var = m2 / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (((yv, &xv), &g), &b) in yi.iter_mut().zip(xi).zip(gain).zip(bias) {
            *yv = (xv - mean) * inv * g + b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_reference_points() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
        // asymptotes: identity for large z, zero for very negative z
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
    }

    #[test]
    fn layernorm_normalizes_rows() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let gain = vec![1.0; 4];
        let bias = vec![0.0; 4];
        let mut y = vec![0f32; 8];
        layernorm_into(&x, 2, 4, &gain, &bias, &mut y);
        for row in y.chunks(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
        // both rows are affine images of [1,2,3,4]: identical post-norm
        for (a, b) in y[..4].iter().zip(&y[4..]) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn welford_matches_two_pass() {
        let mut rng = crate::util::rng::Rng::new(9);
        let (rows, d) = (3, 64);
        let x: Vec<f32> = (0..rows * d).map(|_| (rng.normal() * 3.0) as f32).collect();
        let gain: Vec<f32> = (0..d).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
        let bias: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut got = vec![0f32; rows * d];
        layernorm_into(&x, rows, d, &gain, &bias, &mut got);
        for r in 0..rows {
            let xi = &x[r * d..(r + 1) * d];
            let mean: f32 = xi.iter().sum::<f32>() / d as f32;
            let var: f32 = xi.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for i in 0..d {
                let want = (xi[i] - mean) * inv * gain[i] + bias[i];
                let g = got[r * d + i];
                assert!((g - want).abs() <= 1e-3 * want.abs().max(1.0), "{g} vs {want}");
            }
        }
    }
}
