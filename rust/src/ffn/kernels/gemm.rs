//! Register-blocked GEMM over [`PackedMatrix`] panels.
//!
//! The hot loop is an `MR`×`NR` micro-kernel. Two families implement it,
//! selected once per process by [`KernelDispatch`]: the portable tiles
//! (`MR` accumulator rows of `NR` floats in fixed-size arrays,
//! autovectorized by stable Rust — no nightly `std::simd`) and the
//! explicit AVX2/FMA tiles in the `x86` module. Each step broadcasts
//! `MR` input values and streams one packed panel row. Bias and
//! bias+GELU epilogues are fused into the tile store, so the dense path
//! never re-reads its output.
//!
//! **Determinism.** Every output element is produced by exactly one tile
//! job, and the `k`-accumulation order inside a tile is fixed and
//! identical for every row-block width. Serial, row-parallel,
//! column-parallel and row-sparse execution are therefore bitwise
//! identical for any worker count *within one dispatch path* — the
//! parallel drivers only partition *which* tiles a worker computes (a
//! deterministic contiguous schedule over row blocks or column-panel
//! segments), never the arithmetic inside one. Across paths, portable
//! and SIMD results agree to rounding only (FMA contraction; see the
//! `dispatch` module docs for the documented `FOLD_TOL` contract).
//!
//! The pre-PR scalar kernel is kept as [`matmul_naive`]: it is the
//! correctness reference for the property tests and the baseline the
//! bench reports the blocked kernel's speedup against.

use std::sync::Mutex;

use crate::util::threadpool::ThreadPool;

use super::dispatch::KernelDispatch;
use super::elementwise::gelu;
use super::pack::{PackedMatrix, MR, NR};
#[cfg(target_arch = "x86_64")]
use super::x86;

/// Below this many multiply-adds the pool dispatch overhead dominates
/// and the serial kernel wins.
pub const PARALLEL_THRESHOLD_OPS: usize = 1 << 18;

/// Fused tail applied to each output tile as it leaves the accumulator
/// registers. Applied per element, so it preserves the kernel's
/// thread-count and tile-schedule invariance.
#[derive(Debug, Clone, Copy)]
pub enum Epilogue<'a> {
    /// `out = acc`
    Store,
    /// `out = acc + bias[col]`
    Bias(&'a [f32]),
    /// `out = gelu(acc + bias[col])` — the dense-path fusion.
    BiasGelu(&'a [f32]),
    /// `out += acc` — accumulate into existing output (the folded
    /// path's kept-unit contribution).
    Add,
}

// ---------------------------------------------------------------------------
// Portable micro-kernels: R×NR accumulator tiles in registers.
// ---------------------------------------------------------------------------

#[inline]
fn micro1(x0: &[f32], panel: &[f32]) -> [[f32; NR]; 1] {
    let k = x0.len();
    let mut a0 = [0f32; NR];
    for (kk, prow) in panel.chunks_exact(NR).take(k).enumerate() {
        let v0 = x0[kk];
        for (a, &p) in a0.iter_mut().zip(prow) {
            *a += v0 * p;
        }
    }
    [a0]
}

#[inline]
fn micro2(x0: &[f32], x1: &[f32], panel: &[f32]) -> [[f32; NR]; 2] {
    let k = x0.len();
    let mut a0 = [0f32; NR];
    let mut a1 = [0f32; NR];
    for (kk, prow) in panel.chunks_exact(NR).take(k).enumerate() {
        let (v0, v1) = (x0[kk], x1[kk]);
        for (a, &p) in a0.iter_mut().zip(prow) {
            *a += v0 * p;
        }
        for (a, &p) in a1.iter_mut().zip(prow) {
            *a += v1 * p;
        }
    }
    [a0, a1]
}

#[inline]
fn micro3(x0: &[f32], x1: &[f32], x2: &[f32], panel: &[f32]) -> [[f32; NR]; 3] {
    let k = x0.len();
    let mut a0 = [0f32; NR];
    let mut a1 = [0f32; NR];
    let mut a2 = [0f32; NR];
    for (kk, prow) in panel.chunks_exact(NR).take(k).enumerate() {
        let (v0, v1, v2) = (x0[kk], x1[kk], x2[kk]);
        for (a, &p) in a0.iter_mut().zip(prow) {
            *a += v0 * p;
        }
        for (a, &p) in a1.iter_mut().zip(prow) {
            *a += v1 * p;
        }
        for (a, &p) in a2.iter_mut().zip(prow) {
            *a += v2 * p;
        }
    }
    [a0, a1, a2]
}

#[inline]
fn micro4(x0: &[f32], x1: &[f32], x2: &[f32], x3: &[f32], panel: &[f32]) -> [[f32; NR]; 4] {
    let k = x0.len();
    let mut a0 = [0f32; NR];
    let mut a1 = [0f32; NR];
    let mut a2 = [0f32; NR];
    let mut a3 = [0f32; NR];
    for (kk, prow) in panel.chunks_exact(NR).take(k).enumerate() {
        let (v0, v1, v2, v3) = (x0[kk], x1[kk], x2[kk], x3[kk]);
        for (a, &p) in a0.iter_mut().zip(prow) {
            *a += v0 * p;
        }
        for (a, &p) in a1.iter_mut().zip(prow) {
            *a += v1 * p;
        }
        for (a, &p) in a2.iter_mut().zip(prow) {
            *a += v2 * p;
        }
        for (a, &p) in a3.iter_mut().zip(prow) {
            *a += v3 * p;
        }
    }
    [a0, a1, a2, a3]
}

// ---------------------------------------------------------------------------
// Dispatch: one tile on the selected ISA path.
// ---------------------------------------------------------------------------

#[inline]
fn tile1(disp: KernelDispatch, x: &[f32], k: usize, panel: &[f32]) -> [[f32; NR]; 1] {
    #[cfg(target_arch = "x86_64")]
    if disp == KernelDispatch::Avx2Fma {
        // SAFETY: `Avx2Fma` is only constructed after runtime feature
        // detection (see dispatch.rs), so AVX2 and FMA are present.
        return unsafe { x86::micro::<1>(x, k, panel) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = disp;
    micro1(&x[..k], panel)
}

#[inline]
fn tile2(disp: KernelDispatch, x: &[f32], k: usize, panel: &[f32]) -> [[f32; NR]; 2] {
    #[cfg(target_arch = "x86_64")]
    if disp == KernelDispatch::Avx2Fma {
        // SAFETY: as in `tile1`.
        return unsafe { x86::micro::<2>(x, k, panel) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = disp;
    micro2(&x[..k], &x[k..2 * k], panel)
}

#[inline]
fn tile3(disp: KernelDispatch, x: &[f32], k: usize, panel: &[f32]) -> [[f32; NR]; 3] {
    #[cfg(target_arch = "x86_64")]
    if disp == KernelDispatch::Avx2Fma {
        // SAFETY: as in `tile1`.
        return unsafe { x86::micro::<3>(x, k, panel) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = disp;
    micro3(&x[..k], &x[k..2 * k], &x[2 * k..3 * k], panel)
}

#[inline]
fn tile4(disp: KernelDispatch, x: &[f32], k: usize, panel: &[f32]) -> [[f32; NR]; 4] {
    #[cfg(target_arch = "x86_64")]
    if disp == KernelDispatch::Avx2Fma {
        // SAFETY: as in `tile1`.
        return unsafe { x86::micro::<4>(x, k, panel) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = disp;
    micro4(&x[..k], &x[k..2 * k], &x[2 * k..3 * k], &x[3 * k..4 * k], panel)
}

// ---------------------------------------------------------------------------
// Tile stores, shared with the fused quant GEMM (`qgemm`).
// ---------------------------------------------------------------------------

/// Write one accumulator row into `out` (`out.len() <= NR`), applying
/// the epilogue. `col0` is the global column of `out[0]` (bias offset).
#[inline]
pub(super) fn finish_row(acc: &[f32; NR], out: &mut [f32], col0: usize, epi: Epilogue<'_>) {
    let n = out.len();
    match epi {
        Epilogue::Store => out.copy_from_slice(&acc[..n]),
        Epilogue::Bias(bias) => {
            let b = &bias[col0..col0 + n];
            for ((o, &a), &bv) in out.iter_mut().zip(acc.iter()).zip(b) {
                *o = a + bv;
            }
        }
        Epilogue::BiasGelu(bias) => {
            let b = &bias[col0..col0 + n];
            for ((o, &a), &bv) in out.iter_mut().zip(acc.iter()).zip(b) {
                *o = gelu(a + bv);
            }
        }
        Epilogue::Add => {
            for (o, &a) in out.iter_mut().zip(acc.iter()) {
                *o += a;
            }
        }
    }
}

/// Store one `R`-row accumulator tile at (`row0`, `col0`) of `out`.
#[inline]
pub(super) fn store_acc<const R: usize>(
    acc: &[[f32; NR]; R],
    row0: usize,
    m: usize,
    col0: usize,
    ncols: usize,
    out: &mut [f32],
    epi: Epilogue<'_>,
) {
    for (rr, arow) in acc.iter().enumerate() {
        let base = (row0 + rr) * m + col0;
        finish_row(arow, &mut out[base..base + ncols], col0, epi);
    }
}

/// Store one `R`-row tile into per-row column-segment views: row `r0+rr`
/// of the tile goes to `segs[r0+rr][lcol..lcol+ncols]`, whose global
/// column offset is `col0`.
#[inline]
pub(super) fn store_segs<const R: usize>(
    acc: &[[f32; NR]; R],
    r0: usize,
    lcol: usize,
    col0: usize,
    ncols: usize,
    segs: &mut [&mut [f32]],
    epi: Epilogue<'_>,
) {
    for (rr, arow) in acc.iter().enumerate() {
        finish_row(arow, &mut segs[r0 + rr][lcol..lcol + ncols], col0, epi);
    }
}

/// Compute `r` (1..=MR) consecutive input rows (`x` holds exactly
/// `r * w.k()` floats) across all panels, writing output rows
/// `row0..row0+r` of `out` (stride `w.m()`).
fn block_rows(
    disp: KernelDispatch,
    r: usize,
    x: &[f32],
    w: &PackedMatrix,
    row0: usize,
    out: &mut [f32],
    epi: Epilogue<'_>,
) {
    let (k, m) = (w.k(), w.m());
    for p in 0..w.n_panels() {
        let col0 = p * NR;
        let ncols = (m - col0).min(NR);
        let panel = w.panel(p);
        match r {
            4 => store_acc(&tile4(disp, x, k, panel), row0, m, col0, ncols, out, epi),
            3 => store_acc(&tile3(disp, x, k, panel), row0, m, col0, ncols, out, epi),
            2 => store_acc(&tile2(disp, x, k, panel), row0, m, col0, ncols, out, epi),
            _ => store_acc(&tile1(disp, x, k, panel), row0, m, col0, ncols, out, epi),
        }
    }
}

/// The column-segment walk of [`block_rows`]: all `rows` (blocked `MR`
/// wide) over panels `p0..`, writing into per-row segment views handed
/// out by [`fan_out_col_segments`]. Per-element arithmetic is identical
/// to the serial kernel — only the panel range is restricted.
fn block_rows_segments(
    disp: KernelDispatch,
    x: &[f32],
    rows: usize,
    w: &PackedMatrix,
    p0: usize,
    segs: &mut [&mut [f32]],
    epi: Epilogue<'_>,
) {
    let (k, m) = (w.k(), w.m());
    let seg_len = segs[0].len();
    let mut r0 = 0;
    while r0 < rows {
        let r = (rows - r0).min(MR);
        let xb = &x[r0 * k..(r0 + r) * k];
        let mut lcol = 0;
        let mut p = p0;
        while lcol < seg_len {
            let col0 = p * NR;
            let ncols = (m - col0).min(NR).min(seg_len - lcol);
            let panel = w.panel(p);
            match r {
                4 => store_segs(&tile4(disp, xb, k, panel), r0, lcol, col0, ncols, segs, epi),
                3 => store_segs(&tile3(disp, xb, k, panel), r0, lcol, col0, ncols, segs, epi),
                2 => store_segs(&tile2(disp, xb, k, panel), r0, lcol, col0, ncols, segs, epi),
                _ => store_segs(&tile1(disp, xb, k, panel), r0, lcol, col0, ncols, segs, epi),
            }
            lcol += ncols;
            p += 1;
        }
        r0 += r;
    }
}

// ---------------------------------------------------------------------------
// Fan-out helpers: disjoint output views over the pool, shared with
// `qgemm`. Both hand each broadcast job a deterministic contiguous span
// of the output, so worker count never changes what any job computes.
// ---------------------------------------------------------------------------

/// One disjoint output span handed to one broadcast job.
type ChunkSlot<'a> = Mutex<Option<(usize, &'a mut [f32])>>;
type SegSlot<'a> = Mutex<Option<&'a mut [f32]>>;

/// Partition `out` (`rows` × `m`) into contiguous `MR`-aligned row
/// chunks and run `body(row0, n_rows, chunk)` for each across the pool.
pub(super) fn fan_out_row_blocks<F>(
    pool: &ThreadPool,
    rows: usize,
    m: usize,
    out: &mut [f32],
    body: F,
) where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let n_blocks = rows.div_ceil(MR);
    let jobs = pool.size().min(n_blocks);
    let rows_per_job = n_blocks.div_ceil(jobs) * MR;
    let slots: Vec<ChunkSlot<'_>> = out
        .chunks_mut(rows_per_job * m)
        .enumerate()
        .map(|(i, c)| Mutex::new(Some((i * rows_per_job, c))))
        .collect();
    pool.broadcast(slots.len(), |i| {
        let (row0, chunk) = slots[i]
            .lock()
            .expect("tile slot")
            .take()
            .expect("tile taken once");
        let nr = chunk.len() / m;
        body(row0, nr, chunk);
    });
}

/// Partition the columns of `out` (`rows` × `m`) into contiguous
/// panel-aligned segments and run `body(p0, segs)` for each across the
/// pool, where `segs[r]` is row `r`'s view of the job's columns and
/// `p0` its first panel. Every row splits into the same segment
/// pattern, so each job sees all `rows` rows of its column span — the
/// schedule that keeps 2..7-row decode batches parallel when there are
/// too few row blocks to split.
pub(super) fn fan_out_col_segments<F>(
    pool: &ThreadPool,
    rows: usize,
    m: usize,
    n_panels: usize,
    out: &mut [f32],
    body: F,
) where
    F: Fn(usize, &mut [&mut [f32]]) + Sync,
{
    let jobs = pool.size().min(n_panels);
    let panels_per_job = n_panels.div_ceil(jobs);
    let n_jobs = n_panels.div_ceil(panels_per_job);
    let span = panels_per_job * NR;
    // Row-major slot grid: slot r*n_jobs + i = row r's columns of job i.
    let mut slots: Vec<SegSlot<'_>> = Vec::with_capacity(rows * n_jobs);
    for row_out in out.chunks_mut(m) {
        for c in row_out.chunks_mut(span) {
            slots.push(Mutex::new(Some(c)));
        }
    }
    debug_assert_eq!(slots.len(), rows * n_jobs);
    pool.broadcast(n_jobs, |i| {
        let mut segs: Vec<&mut [f32]> = Vec::with_capacity(rows);
        for r in 0..rows {
            let seg = slots[r * n_jobs + i]
                .lock()
                .expect("tile slot")
                .take()
                .expect("tile taken once");
            segs.push(seg);
        }
        body(i * panels_per_job, &mut segs);
    });
}

// ---------------------------------------------------------------------------
// Drivers.
// ---------------------------------------------------------------------------

/// Serial blocked GEMM: `out[rows, m] = epi(x[rows, k] · w)`.
fn matmul_serial(
    disp: KernelDispatch,
    x: &[f32],
    rows: usize,
    w: &PackedMatrix,
    epi: Epilogue<'_>,
    out: &mut [f32],
) {
    let k = w.k();
    let mut r0 = 0;
    while r0 < rows {
        let r = (rows - r0).min(MR);
        block_rows(disp, r, &x[r0 * k..(r0 + r) * k], w, r0, out, epi);
        r0 += r;
    }
}

/// `out[rows, m] = epi(x[rows, k] · w)` on the active dispatch path.
///
/// With a pool and enough work the tiles fan out over a deterministic
/// contiguous schedule (row blocks for full batches, column-panel
/// segments for 1..7-row decode batches); results are bitwise identical
/// to the serial kernel for any worker count within one dispatch path.
pub fn matmul(
    pool: Option<&ThreadPool>,
    x: &[f32],
    rows: usize,
    w: &PackedMatrix,
    epi: Epilogue<'_>,
    out: &mut [f32],
) {
    matmul_with(KernelDispatch::active(), pool, x, rows, w, epi, out);
}

/// [`matmul`] on an explicit dispatch path (tests force both in one
/// process; the bench measures them side by side).
pub fn matmul_with(
    disp: KernelDispatch,
    pool: Option<&ThreadPool>,
    x: &[f32],
    rows: usize,
    w: &PackedMatrix,
    epi: Epilogue<'_>,
    out: &mut [f32],
) {
    let (k, m) = (w.k(), w.m());
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * m);
    if let Some(pool) = pool {
        if rows * k * m >= PARALLEL_THRESHOLD_OPS && pool.size() > 1 {
            if rows >= 2 * MR {
                return rows_parallel(disp, pool, x, rows, w, epi, out);
            }
            if w.n_panels() >= 2 {
                return cols_parallel_rows(disp, pool, x, rows, w, epi, out);
            }
            if rows.div_ceil(MR) >= 2 {
                return rows_parallel(disp, pool, x, rows, w, epi, out);
            }
        }
    }
    matmul_serial(disp, x, rows, w, epi, out);
}

fn rows_parallel(
    disp: KernelDispatch,
    pool: &ThreadPool,
    x: &[f32],
    rows: usize,
    w: &PackedMatrix,
    epi: Epilogue<'_>,
    out: &mut [f32],
) {
    let k = w.k();
    fan_out_row_blocks(pool, rows, w.m(), out, |row0, nr, chunk| {
        matmul_serial(disp, &x[row0 * k..(row0 + nr) * k], nr, w, epi, chunk);
    });
}

/// Column-parallel schedule for small-row batches (1..=2*MR-1 rows):
/// each job computes *all* rows over its contiguous panel span. Covers
/// the single-row decode case and the 2..7-row mixed decode batches
/// that used to fall back to the serial kernel.
fn cols_parallel_rows(
    disp: KernelDispatch,
    pool: &ThreadPool,
    x: &[f32],
    rows: usize,
    w: &PackedMatrix,
    epi: Epilogue<'_>,
    out: &mut [f32],
) {
    fan_out_col_segments(pool, rows, w.m(), w.n_panels(), out, |p0, segs| {
        block_rows_segments(disp, x, rows, w, p0, segs, epi);
    });
}

/// Row-sparse GEMM: compute only the rows with `active[r]` (consecutive
/// active rows are blocked up to `MR` wide); inactive rows of `out` are
/// left untouched.
///
/// This is the explicit sparsity-aware entry point — used where the
/// outlier predictor has split a batch into folded/fallback row subsets,
/// so each side executes in place on the full batch without
/// gather/scatter copies. With a pool and enough *active* work the row
/// blocks fan out like [`matmul`]. Per-row results are bitwise identical
/// to the dense kernel for any worker count, because neither row
/// blocking nor the chunk boundaries change a row's accumulation order.
pub fn matmul_sparse_rows(
    pool: Option<&ThreadPool>,
    x: &[f32],
    rows: usize,
    w: &PackedMatrix,
    epi: Epilogue<'_>,
    active: &[bool],
    out: &mut [f32],
) {
    matmul_sparse_rows_with(KernelDispatch::active(), pool, x, rows, w, epi, active, out);
}

/// [`matmul_sparse_rows`] on an explicit dispatch path.
#[allow(clippy::too_many_arguments)]
pub fn matmul_sparse_rows_with(
    disp: KernelDispatch,
    pool: Option<&ThreadPool>,
    x: &[f32],
    rows: usize,
    w: &PackedMatrix,
    epi: Epilogue<'_>,
    active: &[bool],
    out: &mut [f32],
) {
    let (k, m) = (w.k(), w.m());
    debug_assert_eq!(active.len(), rows);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * m);
    if let Some(pool) = pool {
        let n_active = active.iter().filter(|&&a| a).count();
        if n_active * k * m >= PARALLEL_THRESHOLD_OPS
            && pool.size() > 1
            && rows.div_ceil(MR) >= 2
        {
            return fan_out_row_blocks(pool, rows, m, out, |row0, nr, chunk| {
                sparse_rows_serial(
                    disp,
                    &x[row0 * k..(row0 + nr) * k],
                    nr,
                    w,
                    epi,
                    &active[row0..row0 + nr],
                    chunk,
                );
            });
        }
    }
    sparse_rows_serial(disp, x, rows, w, epi, active, out);
}

fn sparse_rows_serial(
    disp: KernelDispatch,
    x: &[f32],
    rows: usize,
    w: &PackedMatrix,
    epi: Epilogue<'_>,
    active: &[bool],
    out: &mut [f32],
) {
    let k = w.k();
    let mut r0 = 0;
    while r0 < rows {
        if !active[r0] {
            r0 += 1;
            continue;
        }
        let mut r = 1;
        while r < MR && r0 + r < rows && active[r0 + r] {
            r += 1;
        }
        block_rows(disp, r, &x[r0 * k..(r0 + r) * k], w, r0, out, epi);
        r0 += r;
    }
}

// ---------------------------------------------------------------------------
// Pre-PR scalar reference.
// ---------------------------------------------------------------------------

/// The pre-packing scalar kernel (row-times-row, bias pre-initialized,
/// per-element `xv != 0.0` skip branch), verbatim from the PR-2
/// `matmul_serial` this module replaced in PR 3. Kept as the
/// property-test reference and the bench baseline; not used on any hot
/// path.
pub fn matmul_naive(
    x: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    m: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(w.len(), k * m);
    let mut y = vec![0f32; rows * m];
    for (xi, yi) in x.chunks_exact(k).zip(y.chunks_exact_mut(m)).take(rows) {
        if let Some(b) = bias {
            yi.copy_from_slice(b);
        }
        for (&xv, wrow) in xi.iter().zip(w.chunks_exact(m)) {
            if xv != 0.0 {
                for (yv, &wv) in yi.iter_mut().zip(wrow) {
                    *yv += xv * wv;
                }
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * b.abs().max(1.0)
    }

    #[test]
    fn matmul_known_values() {
        // x = [[1,2],[3,4]], w = [[5,6],[7,8]] -> [[19,22],[43,50]]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = PackedMatrix::pack(&[5.0, 6.0, 7.0, 8.0], 2, 2);
        let mut y = vec![0f32; 4];
        matmul(None, &x, 2, &w, Epilogue::Store, &mut y);
        assert_eq!(y, vec![19.0, 22.0, 43.0, 50.0]);
        let b = vec![1.0, -1.0];
        matmul(None, &x, 2, &w, Epilogue::Bias(&b), &mut y);
        assert_eq!(y, vec![20.0, 21.0, 44.0, 49.0]);
    }

    #[test]
    fn packed_matches_naive_across_blocking_widths() {
        let mut rng = Rng::new(5);
        for (rows, k, m) in [(1, 3, 2), (2, 7, 5), (3, 16, NR), (5, 9, NR + 1), (7, 33, 2 * NR + 3)]
        {
            let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
            let wr: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
            let w = PackedMatrix::pack(&wr, k, m);
            let want = matmul_naive(&x, rows, k, &wr, m, Some(&b));
            let mut got = vec![0f32; rows * m];
            matmul(None, &x, rows, &w, Epilogue::Bias(&b), &mut got);
            for (g, wv) in got.iter().zip(&want) {
                assert!(close(*g, *wv, 1e-4), "{g} vs {wv} (rows={rows} k={k} m={m})");
            }
        }
    }

    #[test]
    fn pooled_matches_serial_bitwise() {
        let mut rng = Rng::new(11);
        let (rows, k, m) = (64, 96, 128);
        let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
        let wr: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
        let w = PackedMatrix::pack(&wr, k, m);
        let mut serial = vec![0f32; rows * m];
        matmul(None, &x, rows, &w, Epilogue::Bias(&b), &mut serial);
        // rows*k*m = 786k ops, above the threshold: takes the pooled path.
        let pool = ThreadPool::new(3);
        let mut pooled = vec![0f32; rows * m];
        matmul(Some(&pool), &x, rows, &w, Epilogue::Bias(&b), &mut pooled);
        assert_eq!(serial, pooled);
    }

    #[test]
    fn single_row_pooled_matches_serial_bitwise() {
        let mut rng = Rng::new(13);
        let (k, m) = (512, 512); // 262144 ops: at the parallel threshold
        let x: Vec<f32> = (0..k).map(|_| rng.normal() as f32).collect();
        let wr: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
        let w = PackedMatrix::pack(&wr, k, m);
        let mut serial = vec![0f32; m];
        matmul(None, &x, 1, &w, Epilogue::Store, &mut serial);
        let pool = ThreadPool::new(4);
        let mut pooled = vec![0f32; m];
        matmul(Some(&pool), &x, 1, &w, Epilogue::Store, &mut pooled);
        assert_eq!(serial, pooled);
    }

    #[test]
    fn small_batch_pooled_matches_serial_bitwise() {
        // 2..7 rows with >= 2 panels: the column-segment schedule.
        let mut rng = Rng::new(19);
        let (k, m) = (256, 4 * NR + 11);
        for rows in 2..2 * MR {
            let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
            let wr: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
            let w = PackedMatrix::pack(&wr, k, m);
            let mut serial = vec![0f32; rows * m];
            matmul(None, &x, rows, &w, Epilogue::Bias(&b), &mut serial);
            for workers in [2, 3, 5] {
                let pool = ThreadPool::new(workers);
                let mut pooled = vec![0f32; rows * m];
                matmul(Some(&pool), &x, rows, &w, Epilogue::Bias(&b), &mut pooled);
                assert_eq!(serial, pooled, "rows={rows} workers={workers}");
            }
        }
    }

    #[test]
    fn sparse_rows_leave_inactive_rows_untouched() {
        let mut rng = Rng::new(17);
        let (rows, k, m) = (6, 10, NR + 5);
        let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
        let wr: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
        let w = PackedMatrix::pack(&wr, k, m);
        let mut dense = vec![0f32; rows * m];
        matmul(None, &x, rows, &w, Epilogue::Bias(&b), &mut dense);
        let active = [true, false, true, true, false, true];
        let mut sparse = vec![-7.0f32; rows * m];
        matmul_sparse_rows(None, &x, rows, &w, Epilogue::Bias(&b), &active, &mut sparse);
        for r in 0..rows {
            for j in 0..m {
                let want = if active[r] { dense[r * m + j] } else { -7.0 };
                assert_eq!(sparse[r * m + j], want, "row {r} col {j}");
            }
        }
        // empty split: nothing written
        let mut untouched = vec![3.0f32; rows * m];
        matmul_sparse_rows(None, &x, rows, &w, Epilogue::Store, &[false; 6], &mut untouched);
        assert!(untouched.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn fused_gelu_and_add_epilogues() {
        let mut rng = Rng::new(23);
        let (rows, k, m) = (3, 8, 9);
        let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
        let wr: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..m).map(|_| rng.normal() as f32).collect();
        let w = PackedMatrix::pack(&wr, k, m);
        let mut biased = vec![0f32; rows * m];
        matmul(None, &x, rows, &w, Epilogue::Bias(&b), &mut biased);
        // BiasGelu == gelu applied after Bias, bitwise
        let mut fused = vec![0f32; rows * m];
        matmul(None, &x, rows, &w, Epilogue::BiasGelu(&b), &mut fused);
        for (f, bv) in fused.iter().zip(&biased) {
            assert_eq!(*f, gelu(*bv));
        }
        // Add into a bias-preloaded buffer == Bias, bitwise
        let mut added: Vec<f32> = Vec::new();
        for _ in 0..rows {
            added.extend_from_slice(&b);
        }
        matmul(None, &x, rows, &w, Epilogue::Add, &mut added);
        assert_eq!(added, biased);
    }
}
