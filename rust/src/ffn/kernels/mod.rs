//! Blocked, pre-packed matmul kernels and the zero-alloc forward-pass
//! substrate of the native backend.
//!
//! * [`pack`]        — [`PackedMatrix`]: weights repacked once at load
//!   time into `NR`-wide column panels (layout diagram in the module
//!   docs)
//! * [`dispatch`]    — [`KernelDispatch`]: the runtime ISA decision
//!   (portable tiles vs explicit AVX2/FMA), made once per process and
//!   overridable with `TARDIS_FORCE_SCALAR=1`
//! * [`gemm`]        — the `MR`×`NR` register-blocked micro-kernel,
//!   serial/row-parallel/column-parallel drivers with a deterministic
//!   tile schedule (bitwise identical results for any worker count
//!   within one dispatch path), fused bias / bias+GELU / accumulate
//!   epilogues, the explicit row-sparse variant
//!   [`matmul_sparse_rows`], and the pre-PR scalar reference
//!   [`matmul_naive`]
//! * [`qgemm`]       — [`QuantPanels`] and the fused k-bit dequant GEMM
//!   ([`matmul_q`]): codes and group scales consumed in their packed
//!   panel layout, dequantized in-register inside the micro-kernel (no
//!   widened f32 matrix is ever materialized)
//! * `x86`           — the AVX2/FMA micro-kernel family (x86-64 only,
//!   reached through [`KernelDispatch`])
//! * [`scratch`]     — [`Scratch`], the reusable buffer arena threaded
//!   through the forward pass (steady-state decode allocates nothing)
//! * [`elementwise`] — GELU, dot, norm, single-pass Welford LayerNorm

pub mod dispatch;
pub mod elementwise;
pub mod gemm;
pub mod pack;
pub mod qgemm;
pub mod scratch;
#[cfg(target_arch = "x86_64")]
mod x86;

pub use dispatch::KernelDispatch;
pub use elementwise::{dot, gelu, layernorm_into, norm};
pub use gemm::{
    matmul, matmul_naive, matmul_sparse_rows, matmul_sparse_rows_with, matmul_with, Epilogue,
    PARALLEL_THRESHOLD_OPS,
};
pub use pack::{PackedMatrix, MR, NR};
pub use qgemm::{
    matmul_q, matmul_q_sparse_rows, matmul_q_sparse_rows_with, matmul_q_with, QuantPanels,
};
pub use scratch::Scratch;
