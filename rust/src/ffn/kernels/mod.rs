//! Blocked, pre-packed matmul kernels and the zero-alloc forward-pass
//! substrate of the native backend.
//!
//! * [`pack`]        — [`PackedMatrix`]: weights repacked once at load
//!   time into `NR`-wide column panels (layout diagram in the module
//!   docs)
//! * [`gemm`]        — the `MR`×`NR` register-blocked micro-kernel,
//!   serial/row-parallel/column-parallel drivers with a deterministic
//!   tile schedule (bitwise identical results for any worker count),
//!   fused bias / bias+GELU / accumulate epilogues, the explicit
//!   row-sparse variant [`matmul_sparse_rows`], and the pre-PR scalar
//!   reference [`matmul_naive`]
//! * [`scratch`]     — [`Scratch`], the reusable buffer arena threaded
//!   through the forward pass (steady-state decode allocates nothing)
//! * [`elementwise`] — GELU, dot, norm, single-pass Welford LayerNorm

pub mod elementwise;
pub mod gemm;
pub mod pack;
pub mod scratch;

pub use elementwise::{dot, gelu, layernorm_into, norm};
pub use gemm::{matmul, matmul_naive, matmul_sparse_rows, Epilogue, PARALLEL_THRESHOLD_OPS};
pub use pack::{PackedMatrix, MR, NR};
pub use scratch::Scratch;
