//! Cache-friendly pre-packed weight layout for the blocked GEMM kernels.
//!
//! A row-major `[k, m]` weight matrix is repacked **once at load time**
//! into column panels of [`NR`] columns. Panel `p` holds columns
//! `p*NR .. p*NR + NR` contiguously, as `k` rows of `NR` floats:
//!
//! ```text
//! w (row-major [k, m])            packed (panel-major)
//! ┌────────────┬────────────┐     panel 0        panel 1
//! │ w[0][0..NR]│w[0][NR..2NR]│    ┌───────────┐  ┌───────────┐
//! │ w[1][0..NR]│w[1][NR..2NR]│ →  │w[0][0..NR]│  │w[0][NR..] │
//! │     ⋮      │      ⋮      │    │w[1][0..NR]│  │w[1][NR..] │
//! └────────────┴────────────┘    │    ⋮      │  │    ⋮      │
//!                                 └───────────┘  └───────────┘
//! panel[kk*NR + j] = w[kk*m + p*NR + j]   (zero-padded past column m)
//! ```
//!
//! The micro-kernel streams one panel linearly (unit stride, one cache
//! line per [`NR`]/16 rows) while broadcasting input values, instead of
//! striding through `w` row-by-row once per output row as the old scalar
//! kernel did. Both micro-kernel families consume this layout unchanged:
//! the portable tiles walk it with fixed-size-array accumulators, the
//! AVX2/FMA tiles (see the `dispatch` module) load each panel row as
//! four ymm vectors and issue prefetch hints a few rows ahead — one
//! `NR`-wide f32 row is exactly two cache lines. The quantized sibling
//! of this layout (`i8` codes + group scales, same panel walk) lives in
//! `qgemm::QuantPanels`.

use std::sync::Arc;

/// Rows per register tile (input rows one micro-kernel call carries).
pub const MR: usize = 4;
/// Columns per register tile (panel width). `MR`×`NR` f32 accumulators
/// are held in fixed-size arrays so stable Rust autovectorizes them;
/// `NR = 32` amortizes each input-value broadcast over 8 SSE (or 4 AVX)
/// vectors, which measured fastest for the tiny-GELU shapes. The
/// explicit AVX2 tier keeps the same width: one panel row is 4 ymm
/// loads, and its half-width variant two 16-column passes.
pub const NR: usize = 32;

/// A weight matrix pre-packed into [`NR`]-wide column panels.
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    k: usize,
    m: usize,
    /// `ceil(m/NR)` panels of `k*NR` floats each. Shared so cloning a
    /// layer (e.g. the bench's dense baseline) never copies weights.
    data: Arc<Vec<f32>>,
}

impl PackedMatrix {
    /// Pack row-major `w[k, m]`. Zero-sized matrices are allowed (a
    /// fully-folded FFN keeps no units) and pack to zero panels.
    pub fn pack(w: &[f32], k: usize, m: usize) -> PackedMatrix {
        assert_eq!(w.len(), k * m, "pack: weight shape mismatch");
        let n_panels = m.div_ceil(NR);
        let mut data = vec![0f32; n_panels * k * NR];
        for p in 0..n_panels {
            let col0 = p * NR;
            let ncols = (m - col0).min(NR);
            let dst = &mut data[p * k * NR..(p + 1) * k * NR];
            for kk in 0..k {
                dst[kk * NR..kk * NR + ncols]
                    .copy_from_slice(&w[kk * m + col0..kk * m + col0 + ncols]);
            }
        }
        PackedMatrix {
            k,
            m,
            data: Arc::new(data),
        }
    }

    /// Input (reduction) dimension.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output (column) dimension.
    pub fn m(&self) -> usize {
        self.m
    }

    pub fn n_panels(&self) -> usize {
        self.m.div_ceil(NR)
    }

    /// Panel `p`: `k` rows of [`NR`] columns, zero-padded past `m`.
    #[inline]
    pub fn panel(&self, p: usize) -> &[f32] {
        &self.data[p * self.k * NR..(p + 1) * self.k * NR]
    }

    /// Bytes held by the packed representation (padding included).
    pub fn resident_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packs_with_zero_padded_tail() {
        // k=2, m = NR + 3: two panels, second mostly padding
        let m = NR + 3;
        let w: Vec<f32> = (0..2 * m).map(|i| i as f32).collect();
        let p = PackedMatrix::pack(&w, 2, m);
        assert_eq!(p.n_panels(), 2);
        assert_eq!(p.k(), 2);
        assert_eq!(p.m(), m);
        // panel 0 row 1 starts at w[1*m + 0]
        assert_eq!(p.panel(0)[NR], m as f32);
        // panel 1 holds columns NR..NR+3 then zeros
        assert_eq!(p.panel(1)[0], NR as f32);
        assert_eq!(p.panel(1)[2], (NR + 2) as f32);
        assert_eq!(p.panel(1)[3], 0.0);
        assert_eq!(p.panel(1)[NR + 1], (m + NR + 1) as f32);
        assert_eq!(p.resident_bytes(), 2 * 2 * NR * 4);
    }

    #[test]
    fn packs_empty_matrix() {
        let p = PackedMatrix::pack(&[], 3, 0);
        assert_eq!(p.n_panels(), 0);
        assert_eq!(p.resident_bytes(), 0);
    }
}
