//! Fused k-bit dequant GEMM over packed code panels.
//!
//! [`QuantPanels`] is the quantized sibling of
//! [`PackedMatrix`](super::pack::PackedMatrix): a `[k, m]` matrix stored
//! as `i8` codes in [`NR`]-wide column panels (nibble-packed two per
//! byte at `bits <= 4`) with one f32 scale per (reduction-group, column)
//! panel-major alongside. The fused micro-kernels consume that layout
//! *directly* — each panel row is decoded and scaled in registers
//! (`w = code as f32 * scale`) and immediately multiply-accumulated, so
//! no widened f32 proxy matrix is ever materialized and the weight-side
//! memory traffic is the code bytes plus scales, `~bits/32` of the f32
//! GEMM's.
//!
//! **Numerics.** The portable fused tile performs, per output element,
//! exactly the multiply/add sequence of `dequantize()` followed by the
//! portable f32 `matmul` — same widening, same products, same ascending
//! `k` order — so fused-vs-dequantized equality is **bitwise** on the
//! portable path (property-tested in `tests/kernel_equivalence.rs`).
//! The AVX2 path adds only FMA contraction on top, bounded by the same
//! `FOLD_TOL` contract as the f32 tiles (see the `dispatch` module
//! docs). Drivers reuse the deterministic tile schedules of `gemm`, so
//! thread-count invariance is bitwise within each dispatch path.
//!
//! Used today by `QuantizedProxy` (the §5.3 out-of-range predictor);
//! the entry points take any [`QuantPanels`], so a fully-quantized `W1`
//! path can reuse them unchanged.

use crate::util::threadpool::ThreadPool;

use super::dispatch::KernelDispatch;
use super::gemm::{
    fan_out_col_segments, fan_out_row_blocks, store_acc, store_segs, Epilogue,
    PARALLEL_THRESHOLD_OPS,
};
use super::pack::{MR, NR};
#[cfg(target_arch = "x86_64")]
use super::x86;

/// Physical storage of the panel-major code stream. Codes at `bits <= 4`
/// fit a signed nibble, so they bit-pack **two per byte** (low nibble =
/// even column, high nibble = odd column within the panel row — [`NR`]
/// is even, so rows never straddle a byte); wider codes stay one `i8`
/// each. Packing halves the resident weight traffic, which is the whole
/// point of the low-bit predictor (§5.3).
#[derive(Debug, Clone)]
enum CodeStore {
    /// One `i8` per code (`bits > 4`).
    Wide(Vec<i8>),
    /// Two 4-bit codes per byte (`bits <= 4`).
    Packed(Vec<u8>),
}

/// Sign-extend the low nibble of `byte`.
#[inline]
pub(crate) fn nibble_lo(byte: u8) -> i8 {
    ((byte << 4) as i8) >> 4
}

/// Sign-extend the high nibble of `byte`.
#[inline]
pub(crate) fn nibble_hi(byte: u8) -> i8 {
    (byte as i8) >> 4
}

impl CodeStore {
    /// Pack a panel-major `i8` stream for the given bit width.
    fn pack(codes: Vec<i8>, bits: u8) -> CodeStore {
        if bits > 4 {
            return CodeStore::Wide(codes);
        }
        debug_assert!(codes.len() % 2 == 0, "NR is even");
        let packed = codes
            .chunks_exact(2)
            .map(|pair| {
                debug_assert!((-8..=7).contains(&pair[0]));
                debug_assert!((-8..=7).contains(&pair[1]));
                ((pair[0] as u8) & 0x0F) | ((pair[1] as u8) << 4)
            })
            .collect();
        CodeStore::Packed(packed)
    }

    /// Code at flat panel-major index `idx` (`p*k*NR + kk*NR + j`).
    #[inline]
    fn code(&self, idx: usize) -> i8 {
        match self {
            CodeStore::Wide(c) => c[idx],
            CodeStore::Packed(c) => {
                let byte = c[idx / 2];
                if idx % 2 == 0 {
                    nibble_lo(byte)
                } else {
                    nibble_hi(byte)
                }
            }
        }
    }

    fn resident_bytes(&self) -> usize {
        match self {
            CodeStore::Wide(c) => c.len(),
            CodeStore::Packed(c) => c.len(),
        }
    }
}

/// Borrowed view of one panel's code rows, in whichever physical layout
/// the store uses — what the micro-kernels decode from.
#[derive(Clone, Copy)]
pub(super) enum PanelCodes<'a> {
    /// `k * NR` codes, one `i8` each.
    Wide(&'a [i8]),
    /// `k * NR / 2` bytes, two nibble codes each.
    Packed(&'a [u8]),
}

/// A `[k, m]` matrix quantized to `bits` with one f32 scale per
/// (`group` reduction rows, column), packed into [`NR`]-wide column
/// panels mirroring [`PackedMatrix`](super::pack::PackedMatrix).
///
/// Panel `p` holds columns `p*NR..p*NR+NR`: `k` rows of `NR` codes
/// (zero-padded past column `m`; bit-packed 2-per-byte at `bits <= 4`,
/// see `CodeStore`), plus `n_groups` rows of `NR` f32 scales.
/// `w[kk][col] ≈ codes[kk][col] · scales[kk/group][col]`.
#[derive(Debug, Clone)]
pub struct QuantPanels {
    k: usize,
    m: usize,
    group: usize,
    bits: u8,
    /// `n_panels * k * NR` codes, panel-major (possibly nibble-packed).
    codes: CodeStore,
    /// `n_panels * n_groups * NR` scales, panel-major.
    scales: Vec<f32>,
}

impl QuantPanels {
    /// Take ownership of a panel-major `i8` code stream
    /// (`n_panels * k * NR`, zero-padded past column `m`) and its
    /// panel-major scales (`n_panels * ceil(k/group) * NR`), bit-packing
    /// the codes when they fit a nibble.
    pub fn pack(
        codes: Vec<i8>,
        scales: Vec<f32>,
        k: usize,
        m: usize,
        group: usize,
        bits: u8,
    ) -> QuantPanels {
        assert!((2..=8).contains(&bits), "code bits {bits} not in 2..=8");
        assert!(group >= 1, "reduction group must be >= 1");
        let n_panels = m.div_ceil(NR);
        let n_groups = k.div_ceil(group);
        assert_eq!(codes.len(), n_panels * k * NR, "panel-major code stream shape");
        assert_eq!(scales.len(), n_panels * n_groups * NR, "panel-major scale shape");
        QuantPanels { k, m, group, bits, codes: CodeStore::pack(codes, bits), scales }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn group(&self) -> usize {
        self.group
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn n_panels(&self) -> usize {
        self.m.div_ceil(NR)
    }

    pub fn n_groups(&self) -> usize {
        self.k.div_ceil(self.group)
    }

    /// Whether the codes are stored two per byte.
    pub fn is_bitpacked(&self) -> bool {
        matches!(self.codes, CodeStore::Packed(_))
    }

    /// Resident bytes of the packed representation (padding included;
    /// codes at `bits <= 4` occupy half a byte each).
    pub fn resident_bytes(&self) -> usize {
        self.codes.resident_bytes() + self.scales.len() * std::mem::size_of::<f32>()
    }

    /// Code at panel-major position (panel `p`, reduction row `kk`,
    /// panel column `j`), unpacking nibbles as needed.
    pub(crate) fn code_at(&self, p: usize, kk: usize, j: usize) -> i8 {
        self.codes.code(p * self.k * NR + kk * NR + j)
    }

    /// Scale of (panel `p`, group `g`, panel column `j`).
    pub(crate) fn scale_at(&self, p: usize, g: usize, j: usize) -> f32 {
        self.scales[p * self.n_groups() * NR + g * NR + j]
    }

    /// Reconstructed row-major `[k, m]` f32 matrix (tests, error bounds,
    /// and the bitwise reference of the fused kernels: the fused portable
    /// path performs exactly `code as f32 * scale` per element).
    pub fn dequantize(&self) -> Vec<f32> {
        let (k, m, group) = (self.k, self.m, self.group);
        let n_groups = self.n_groups();
        let mut w = vec![0f32; k * m];
        for p in 0..self.n_panels() {
            let col0 = p * NR;
            let ncols = (m - col0).min(NR);
            let spanel = &self.scales[p * n_groups * NR..(p + 1) * n_groups * NR];
            for kk in 0..k {
                let g = kk / group;
                for j in 0..ncols {
                    w[kk * m + col0 + j] = self.code_at(p, kk, j) as f32 * spanel[g * NR + j];
                }
            }
        }
        w
    }

    /// Panel `p`'s code rows in their physical layout.
    #[inline]
    pub(super) fn codes_panel(&self, p: usize) -> PanelCodes<'_> {
        match &self.codes {
            CodeStore::Wide(c) => PanelCodes::Wide(&c[p * self.k * NR..(p + 1) * self.k * NR]),
            CodeStore::Packed(c) => {
                PanelCodes::Packed(&c[p * self.k * (NR / 2)..(p + 1) * self.k * (NR / 2)])
            }
        }
    }

    /// Panel `p`'s scale rows (`n_groups * NR` floats).
    #[inline]
    pub(super) fn scales_panel(&self, p: usize) -> &[f32] {
        let n_groups = self.n_groups();
        &self.scales[p * n_groups * NR..(p + 1) * n_groups * NR]
    }

    /// Test helper: the same panels with codes widened to one `i8` each
    /// (the pre-packing layout), for layout-equivalence checks.
    #[cfg(test)]
    pub(crate) fn unpacked_clone(&self) -> QuantPanels {
        let n = self.n_panels() * self.k * NR;
        let wide: Vec<i8> = (0..n).map(|i| self.codes.code(i)).collect();
        QuantPanels { codes: CodeStore::Wide(wide), ..self.clone() }
    }
}

// ---------------------------------------------------------------------------
// Micro-kernels.
// ---------------------------------------------------------------------------

/// Portable fused dequant tile: decode one panel row into a register
/// weight row (`w = code as f32 * scale`), then multiply-accumulate —
/// per output element the exact op sequence of dequantize-then-portable
/// `matmul`, so the two are bitwise equal.
fn qmicro<const R: usize>(
    x: &[f32],
    k: usize,
    group: usize,
    codes: PanelCodes<'_>,
    spanel: &[f32],
) -> [[f32; NR]; R] {
    let mut acc = [[0f32; NR]; R];
    let mut g = 0;
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + group).min(k);
        let srow = &spanel[g * NR..(g + 1) * NR];
        for kk in k0..k1 {
            let mut wrow = [0f32; NR];
            match codes {
                PanelCodes::Wide(c) => {
                    let crow = &c[kk * NR..(kk + 1) * NR];
                    for ((w, &cv), &s) in wrow.iter_mut().zip(crow).zip(srow) {
                        *w = cv as f32 * s;
                    }
                }
                PanelCodes::Packed(c) => {
                    let crow = &c[kk * (NR / 2)..(kk + 1) * (NR / 2)];
                    for ((pair, spair), &byte) in
                        wrow.chunks_exact_mut(2).zip(srow.chunks_exact(2)).zip(crow)
                    {
                        pair[0] = nibble_lo(byte) as f32 * spair[0];
                        pair[1] = nibble_hi(byte) as f32 * spair[1];
                    }
                }
            }
            for rr in 0..R {
                let v = x[rr * k + kk];
                for (a, &wv) in acc[rr].iter_mut().zip(&wrow) {
                    *a += v * wv;
                }
            }
        }
        k0 = k1;
        g += 1;
    }
    acc
}

/// One `R`-row fused tile of panel `p`, routed to the active ISA path.
#[inline]
fn qtile<const R: usize>(
    disp: KernelDispatch,
    x: &[f32],
    w: &QuantPanels,
    p: usize,
) -> [[f32; NR]; R] {
    let codes = w.codes_panel(p);
    let spanel = w.scales_panel(p);
    #[cfg(target_arch = "x86_64")]
    if disp == KernelDispatch::Avx2Fma {
        // SAFETY: `Avx2Fma` is only constructed after runtime feature
        // detection (see dispatch.rs), so AVX2 and FMA are present.
        return unsafe { x86::qmicro::<R>(x, w.k, w.group, codes, spanel) };
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = disp;
    qmicro::<R>(x, w.k, w.group, codes, spanel)
}

/// Compute `r` (1..=MR) consecutive input rows across all panels,
/// writing output rows `row0..row0+r` of `out` (stride `w.m()`).
fn qblock_rows(
    disp: KernelDispatch,
    r: usize,
    x: &[f32],
    w: &QuantPanels,
    row0: usize,
    out: &mut [f32],
    epi: Epilogue<'_>,
) {
    let m = w.m;
    for p in 0..w.n_panels() {
        let col0 = p * NR;
        let ncols = (m - col0).min(NR);
        match r {
            4 => store_acc(&qtile::<4>(disp, x, w, p), row0, m, col0, ncols, out, epi),
            3 => store_acc(&qtile::<3>(disp, x, w, p), row0, m, col0, ncols, out, epi),
            2 => store_acc(&qtile::<2>(disp, x, w, p), row0, m, col0, ncols, out, epi),
            _ => store_acc(&qtile::<1>(disp, x, w, p), row0, m, col0, ncols, out, epi),
        }
    }
}

/// The column-segment walk of `qblock_rows`: all `rows` over panels
/// `p0..`, writing into per-row segment views (see
/// `gemm::fan_out_col_segments`).
fn qblock_rows_segments(
    disp: KernelDispatch,
    x: &[f32],
    rows: usize,
    w: &QuantPanels,
    p0: usize,
    segs: &mut [&mut [f32]],
    epi: Epilogue<'_>,
) {
    let (k, m) = (w.k, w.m);
    let seg_len = segs[0].len();
    let mut r0 = 0;
    while r0 < rows {
        let r = (rows - r0).min(MR);
        let xb = &x[r0 * k..(r0 + r) * k];
        let mut lcol = 0;
        let mut p = p0;
        while lcol < seg_len {
            let col0 = p * NR;
            let ncols = (m - col0).min(NR).min(seg_len - lcol);
            match r {
                4 => store_segs(&qtile::<4>(disp, xb, w, p), r0, lcol, col0, ncols, segs, epi),
                3 => store_segs(&qtile::<3>(disp, xb, w, p), r0, lcol, col0, ncols, segs, epi),
                2 => store_segs(&qtile::<2>(disp, xb, w, p), r0, lcol, col0, ncols, segs, epi),
                _ => store_segs(&qtile::<1>(disp, xb, w, p), r0, lcol, col0, ncols, segs, epi),
            }
            lcol += ncols;
            p += 1;
        }
        r0 += r;
    }
}

// ---------------------------------------------------------------------------
// Drivers: same deterministic schedules as the f32 GEMM.
// ---------------------------------------------------------------------------

/// Serial fused GEMM: `out[rows, m] = epi(x[rows, k] · deq(w))`, never
/// materializing `deq(w)`.
fn matmul_q_serial(
    disp: KernelDispatch,
    x: &[f32],
    rows: usize,
    w: &QuantPanels,
    epi: Epilogue<'_>,
    out: &mut [f32],
) {
    let k = w.k;
    let mut r0 = 0;
    while r0 < rows {
        let r = (rows - r0).min(MR);
        qblock_rows(disp, r, &x[r0 * k..(r0 + r) * k], w, r0, out, epi);
        r0 += r;
    }
}

/// `out[rows, m] = epi(x[rows, k] · deq(w))` on the active dispatch
/// path, fusing dequantization into the tiles. Parallel schedules and
/// their bitwise thread-count invariance mirror
/// [`matmul`](super::gemm::matmul).
pub fn matmul_q(
    pool: Option<&ThreadPool>,
    x: &[f32],
    rows: usize,
    w: &QuantPanels,
    epi: Epilogue<'_>,
    out: &mut [f32],
) {
    matmul_q_with(KernelDispatch::active(), pool, x, rows, w, epi, out);
}

/// [`matmul_q`] on an explicit dispatch path (tests force both in one
/// process).
pub fn matmul_q_with(
    disp: KernelDispatch,
    pool: Option<&ThreadPool>,
    x: &[f32],
    rows: usize,
    w: &QuantPanels,
    epi: Epilogue<'_>,
    out: &mut [f32],
) {
    let (k, m) = (w.k, w.m);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * m);
    if let Some(pool) = pool {
        if rows * k * m >= PARALLEL_THRESHOLD_OPS && pool.size() > 1 {
            if rows >= 2 * MR {
                return fan_out_row_blocks(pool, rows, m, out, |row0, nr, chunk| {
                    matmul_q_serial(disp, &x[row0 * k..(row0 + nr) * k], nr, w, epi, chunk);
                });
            }
            if w.n_panels() >= 2 {
                return fan_out_col_segments(pool, rows, m, w.n_panels(), out, |p0, segs| {
                    qblock_rows_segments(disp, x, rows, w, p0, segs, epi);
                });
            }
            if rows.div_ceil(MR) >= 2 {
                return fan_out_row_blocks(pool, rows, m, out, |row0, nr, chunk| {
                    matmul_q_serial(disp, &x[row0 * k..(row0 + nr) * k], nr, w, epi, chunk);
                });
            }
        }
    }
    matmul_q_serial(disp, x, rows, w, epi, out);
}

/// Row-sparse fused GEMM: compute only rows with `active[r]` (runs of
/// active rows blocked up to `MR` wide); inactive rows of `out` are left
/// untouched. Mirrors
/// [`matmul_sparse_rows`](super::gemm::matmul_sparse_rows) — per-row
/// results are bitwise identical to [`matmul_q`] for any worker count.
pub fn matmul_q_sparse_rows(
    pool: Option<&ThreadPool>,
    x: &[f32],
    rows: usize,
    w: &QuantPanels,
    epi: Epilogue<'_>,
    active: &[bool],
    out: &mut [f32],
) {
    matmul_q_sparse_rows_with(KernelDispatch::active(), pool, x, rows, w, epi, active, out);
}

/// [`matmul_q_sparse_rows`] on an explicit dispatch path.
#[allow(clippy::too_many_arguments)]
pub fn matmul_q_sparse_rows_with(
    disp: KernelDispatch,
    pool: Option<&ThreadPool>,
    x: &[f32],
    rows: usize,
    w: &QuantPanels,
    epi: Epilogue<'_>,
    active: &[bool],
    out: &mut [f32],
) {
    let (k, m) = (w.k, w.m);
    debug_assert_eq!(active.len(), rows);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * m);
    if let Some(pool) = pool {
        let n_active = active.iter().filter(|&&a| a).count();
        if n_active * k * m >= PARALLEL_THRESHOLD_OPS
            && pool.size() > 1
            && rows.div_ceil(MR) >= 2
        {
            return fan_out_row_blocks(pool, rows, m, out, |row0, nr, chunk| {
                q_sparse_rows_serial(
                    disp,
                    &x[row0 * k..(row0 + nr) * k],
                    nr,
                    w,
                    epi,
                    &active[row0..row0 + nr],
                    chunk,
                );
            });
        }
    }
    q_sparse_rows_serial(disp, x, rows, w, epi, active, out);
}

fn q_sparse_rows_serial(
    disp: KernelDispatch,
    x: &[f32],
    rows: usize,
    w: &QuantPanels,
    epi: Epilogue<'_>,
    active: &[bool],
    out: &mut [f32],
) {
    let k = w.k;
    let mut r0 = 0;
    while r0 < rows {
        if !active[r0] {
            r0 += 1;
            continue;
        }
        let mut r = 1;
        while r < MR && r0 + r < rows && active[r0 + r] {
            r += 1;
        }
        qblock_rows(disp, r, &x[r0 * k..(r0 + r) * k], w, r0, out, epi);
        r0 += r;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn nibble_sign_extension() {
        for v in -8i8..=7 {
            let hi = -v - 1; // also spans -8..=7
            let byte = ((v as u8) & 0x0F) | ((hi as u8) << 4);
            assert_eq!(nibble_lo(byte), v);
            assert_eq!(nibble_hi(byte), hi);
        }
    }

    /// Hand-built 2-column panel: fused output must equal the scaled
    /// integer dot products exactly.
    #[test]
    fn fused_known_values() {
        let (k, m, group) = (2, 2, 2);
        // codes [[1, -2], [3, 4]], scale 0.5 per (group, col)
        let mut codes = vec![0i8; k * NR];
        codes[0] = 1;
        codes[1] = -2;
        codes[NR] = 3;
        codes[NR + 1] = 4;
        let mut scales = vec![0f32; NR];
        scales[0] = 0.5;
        scales[1] = 0.5;
        let w = QuantPanels::pack(codes, scales, k, m, group, 4);
        assert!(w.is_bitpacked());
        let x = vec![2.0f32, 1.0]; // row · deq(w) = [2*0.5 + 1*1.5, 2*-1.0 + 1*2.0]
        let mut out = vec![0f32; m];
        matmul_q_with(KernelDispatch::Portable, None, &x, 1, &w, Epilogue::Store, &mut out);
        assert_eq!(out, vec![2.5, 0.0]);
    }

    #[test]
    fn serial_matches_dequantized_matmul_bitwise_on_portable_path() {
        let mut rng = Rng::new(41);
        let (rows, k, m, group) = (5, 23, NR + 9, 7);
        let wf: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32 * 0.3).collect();
        // quantize via the proxy-style symmetric scheme, inline
        let qmax = 7.0f32;
        let n_panels = m.div_ceil(NR);
        let n_groups = k.div_ceil(group);
        let mut codes = vec![0i8; n_panels * k * NR];
        let mut scales = vec![0f32; n_panels * n_groups * NR];
        for p in 0..n_panels {
            let col0 = p * NR;
            let ncols = (m - col0).min(NR);
            for g in 0..n_groups {
                let (k0, k1) = (g * group, (g * group + group).min(k));
                for j in 0..ncols {
                    let col = col0 + j;
                    let mut absmax = 0f32;
                    for kk in k0..k1 {
                        absmax = absmax.max(wf[kk * m + col].abs());
                    }
                    let scale = (absmax / qmax).max(1e-12);
                    scales[p * n_groups * NR + g * NR + j] = scale;
                    for kk in k0..k1 {
                        codes[p * k * NR + kk * NR + j] =
                            (wf[kk * m + col] / scale).round_ties_even().clamp(-qmax, qmax) as i8;
                    }
                }
            }
        }
        let w = QuantPanels::pack(codes, scales, k, m, group, 4);
        let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
        let mut fused = vec![0f32; rows * m];
        matmul_q_with(KernelDispatch::Portable, None, &x, rows, &w, Epilogue::Store, &mut fused);
        let deq = crate::ffn::kernels::PackedMatrix::pack(&w.dequantize(), k, m);
        let mut want = vec![0f32; rows * m];
        crate::ffn::kernels::gemm::matmul_with(
            KernelDispatch::Portable,
            None,
            &x,
            rows,
            &deq,
            Epilogue::Store,
            &mut want,
        );
        assert_eq!(
            fused.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }
}
