//! Reusable forward-pass buffers: the zero-alloc arena.
//!
//! Every native forward pass used to allocate a fresh `Vec` per
//! intermediate (pre-activations, attention projections, gather/scatter
//! copies…). A [`Scratch`] is threaded through
//! `NativeModel::forward` → `FfnBackend::forward` instead: `take` pops a
//! recycled buffer from a free-list and `give` returns it, so once warm
//! the forward pass's intermediates perform no heap allocation — buffers
//! keep their capacity across calls and `take` degenerates to a memset.
//! (The logits output buffer, which leaves the forward pass, is the one
//! remaining per-call allocation.)
//!
//! `take` re-zeroes deliberately: most consumers fully overwrite their
//! buffer and could skip it, but the memset is a few KB against the
//! megaflop GEMMs it sits between, and handing out deterministic zeroed
//! buffers keeps accumulate-style consumers (`Epilogue::Add` targets,
//! the attention context) safe by construction — the arena itself needs
//! no `unsafe` (the kernel tier's only `unsafe` is the feature-gated
//! SIMD in `x86.rs` and the scoped borrow erasure in the thread pool).

/// Free-list of `f32` buffers.
#[derive(Debug, Default)]
pub struct Scratch {
    free: Vec<Vec<f32>>,
    /// `take` calls whose recycled buffer (if any) had to grow — i.e.
    /// heap allocations. Steady-state decode should hold this constant;
    /// the native bench asserts as much.
    pub misses: u64,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// A zeroed buffer of exactly `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        if v.capacity() < len {
            self.misses += 1;
        }
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer to the free-list for reuse.
    pub fn give(&mut self, v: Vec<f32>) {
        self.free.push(v);
    }

    /// Buffers currently parked in the free-list.
    pub fn pooled(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zeroes_and_reuses_capacity() {
        let mut s = Scratch::new();
        let mut a = s.take(8);
        assert_eq!(a, vec![0.0; 8]);
        assert_eq!(s.misses, 1);
        a[3] = 5.0;
        s.give(a);
        assert_eq!(s.pooled(), 1);
        // same-or-smaller takes reuse the buffer without allocating
        let b = s.take(8);
        assert_eq!(b, vec![0.0; 8], "recycled buffer is re-zeroed");
        assert_eq!(s.misses, 1);
        s.give(b);
        let c = s.take(4);
        assert_eq!(c.len(), 4);
        assert_eq!(s.misses, 1);
        s.give(c);
        // growth is counted as a miss
        let d = s.take(100);
        assert_eq!(d.len(), 100);
        assert_eq!(s.misses, 2);
    }
}
