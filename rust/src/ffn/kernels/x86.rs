//! Explicit AVX2/FMA micro-kernels (x86-64 only).
//!
//! Same `R`×[`NR`] tile shape and the same ascending-`k` per-element
//! accumulation order as the portable micros in `gemm`/`qgemm`, so every
//! driver-level invariant (deterministic tile schedule, thread-count
//! invariance) carries over unchanged. The arithmetic differs in exactly
//! one way: `_mm256_fmadd_ps` contracts each multiply-add into one
//! rounding, so results diverge from the portable tiles by rounding
//! noise only (bounded well inside `FOLD_TOL`; see the dispatch module
//! docs and `tests/kernel_equivalence.rs`).
//!
//! Two f32 tile variants cover the register-pressure trade-off:
//!
//! * **full-width** — all 4 ymm column vectors of a panel row live at
//!   once (`R*4` accumulators); best at `R <= 2` where accumulators fit
//!   the 16 architectural ymm registers with room for the panel loads.
//! * **half-width** — two independent 16-column passes (`R*2`
//!   accumulators each); best at `R >= 3` where the full-width variant
//!   would spill.
//!
//! The two are bitwise identical (per output element both execute the
//! same FMA chain over `kk`), so [`micro`] picks per `R` freely.
//!
//! The fused dequant micro [`qmicro`] consumes `QuantPanels` codes in
//! their packed form: nibbles are decoded to sign-extended i8 lanes with
//! a mask/shift/unpack sequence, widened to f32 in-register, scaled by
//! the group's scale vector and FMA'd — the widened weight row never
//! exists in memory.
//!
//! # Safety
//! Every function here is `unsafe fn` with
//! `#[target_feature(enable = "avx2", enable = "fma")]`: callers must
//! guarantee both features are present. The only callers are the
//! `KernelDispatch::Avx2Fma` arms in `gemm`/`qgemm`, and that variant is
//! only ever selected after `is_x86_feature_detected!` succeeds.

use core::arch::x86_64::*;

use super::pack::NR;
use super::qgemm::PanelCodes;

/// Panel rows to prefetch ahead of the current `kk` step. One `NR`-wide
/// f32 panel row is two cache lines; staying a few rows ahead hides the
/// stream's L2 latency without thrashing the L1 fill buffers.
const PREFETCH_ROWS: usize = 4;

/// `R`×`NR` f32 tile over one packed panel: the AVX2/FMA counterpart of
/// the portable `micro1..micro4`.
///
/// # Safety
/// AVX2 and FMA must be available, `x` must hold at least `R * k`
/// floats and `panel` at least `k * NR`.
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn micro<const R: usize>(x: &[f32], k: usize, panel: &[f32]) -> [[f32; NR]; R] {
    debug_assert!((1..=4).contains(&R));
    debug_assert!(x.len() >= R * k);
    debug_assert!(panel.len() >= k * NR);
    if R <= 2 {
        micro_full::<R>(x, k, panel)
    } else {
        micro_half::<R>(x, k, panel)
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn micro_full<const R: usize>(x: &[f32], k: usize, panel: &[f32]) -> [[f32; NR]; R] {
    let xp = x.as_ptr();
    let pp = panel.as_ptr();
    let mut acc = [[_mm256_setzero_ps(); 4]; R];
    for kk in 0..k {
        let prow = pp.add(kk * NR);
        // wrapping_add: the hint may point past the final panel row.
        _mm_prefetch::<_MM_HINT_T0>(pp.wrapping_add((kk + PREFETCH_ROWS) * NR) as *const i8);
        let p0 = _mm256_loadu_ps(prow);
        let p1 = _mm256_loadu_ps(prow.add(8));
        let p2 = _mm256_loadu_ps(prow.add(16));
        let p3 = _mm256_loadu_ps(prow.add(24));
        for rr in 0..R {
            let v = _mm256_set1_ps(*xp.add(rr * k + kk));
            acc[rr][0] = _mm256_fmadd_ps(v, p0, acc[rr][0]);
            acc[rr][1] = _mm256_fmadd_ps(v, p1, acc[rr][1]);
            acc[rr][2] = _mm256_fmadd_ps(v, p2, acc[rr][2]);
            acc[rr][3] = _mm256_fmadd_ps(v, p3, acc[rr][3]);
        }
    }
    let mut out = [[0f32; NR]; R];
    for rr in 0..R {
        for (q, &a) in acc[rr].iter().enumerate() {
            _mm256_storeu_ps(out[rr].as_mut_ptr().add(q * 8), a);
        }
    }
    out
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn micro_half<const R: usize>(x: &[f32], k: usize, panel: &[f32]) -> [[f32; NR]; R] {
    let xp = x.as_ptr();
    let mut out = [[0f32; NR]; R];
    for half in 0..2 {
        let pp = panel.as_ptr().add(half * (NR / 2));
        let mut acc = [[_mm256_setzero_ps(); 2]; R];
        for kk in 0..k {
            let prow = pp.add(kk * NR);
            _mm_prefetch::<_MM_HINT_T0>(pp.wrapping_add((kk + PREFETCH_ROWS) * NR) as *const i8);
            let p0 = _mm256_loadu_ps(prow);
            let p1 = _mm256_loadu_ps(prow.add(8));
            for rr in 0..R {
                let v = _mm256_set1_ps(*xp.add(rr * k + kk));
                acc[rr][0] = _mm256_fmadd_ps(v, p0, acc[rr][0]);
                acc[rr][1] = _mm256_fmadd_ps(v, p1, acc[rr][1]);
            }
        }
        for rr in 0..R {
            let optr = out[rr].as_mut_ptr().add(half * (NR / 2));
            _mm256_storeu_ps(optr, acc[rr][0]);
            _mm256_storeu_ps(optr.add(8), acc[rr][1]);
        }
    }
    out
}

/// Fused dequant `R`×`NR` tile over one quantized panel: decode codes,
/// scale by the group's scales and FMA, all in registers. Half-width
/// passes (one 16-code decode feeds two ymm weight vectors).
///
/// # Safety
/// AVX2 and FMA must be available, `x` must hold at least `R * k`
/// floats, `codes` one full panel (`k` rows of `NR` codes, nibble-packed
/// or wide) and `spanel` all `ceil(k/group) * NR` scales of the panel.
#[target_feature(enable = "avx2", enable = "fma")]
pub(super) unsafe fn qmicro<const R: usize>(
    x: &[f32],
    k: usize,
    group: usize,
    codes: PanelCodes<'_>,
    spanel: &[f32],
) -> [[f32; NR]; R] {
    debug_assert!((1..=4).contains(&R));
    debug_assert!(x.len() >= R * k);
    debug_assert!(spanel.len() >= k.div_ceil(group) * NR);
    let xp = x.as_ptr();
    let mut out = [[0f32; NR]; R];
    for half in 0..2 {
        let mut acc = [[_mm256_setzero_ps(); 2]; R];
        let mut g = 0;
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + group).min(k);
            let srow = spanel.as_ptr().add(g * NR + half * (NR / 2));
            let s0 = _mm256_loadu_ps(srow);
            let s1 = _mm256_loadu_ps(srow.add(8));
            for kk in k0..k1 {
                let (c0, c1) = decode16(codes, kk, half);
                let w0 = _mm256_mul_ps(c0, s0);
                let w1 = _mm256_mul_ps(c1, s1);
                for rr in 0..R {
                    let v = _mm256_set1_ps(*xp.add(rr * k + kk));
                    acc[rr][0] = _mm256_fmadd_ps(v, w0, acc[rr][0]);
                    acc[rr][1] = _mm256_fmadd_ps(v, w1, acc[rr][1]);
                }
            }
            k0 = k1;
            g += 1;
        }
        for rr in 0..R {
            let optr = out[rr].as_mut_ptr().add(half * (NR / 2));
            _mm256_storeu_ps(optr, acc[rr][0]);
            _mm256_storeu_ps(optr.add(8), acc[rr][1]);
        }
    }
    out
}

/// Decode the 16 codes at columns `half*16 .. half*16+16` of panel row
/// `kk` into two f32 ymm vectors (exact integer-to-float conversion, so
/// the values are identical to the portable `code as f32` widening).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn decode16(codes: PanelCodes<'_>, kk: usize, half: usize) -> (__m256, __m256) {
    let bytes16 = match codes {
        // Wide codes: 16 i8 loaded directly.
        PanelCodes::Wide(c) => {
            _mm_loadu_si128(c.as_ptr().add(kk * NR + half * (NR / 2)) as *const __m128i)
        }
        // Nibble-packed: 8 bytes hold 16 codes. Split nibbles (low =
        // even column, high = odd), interleave back into column order,
        // then sign-extend 4-bit two's-complement via (v ^ 8) - 8.
        PanelCodes::Packed(c) => {
            let b = _mm_loadl_epi64(c.as_ptr().add(kk * (NR / 2) + half * (NR / 4)) as *const __m128i);
            let mask = _mm_set1_epi8(0x0F);
            let lo = _mm_and_si128(b, mask);
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(b), mask);
            let inter = _mm_unpacklo_epi8(lo, hi);
            let eight = _mm_set1_epi8(8);
            _mm_sub_epi8(_mm_xor_si128(inter, eight), eight)
        }
    };
    let lo = _mm256_cvtepi8_epi32(bytes16);
    let hi = _mm256_cvtepi8_epi32(_mm_srli_si128::<8>(bytes16));
    (_mm256_cvtepi32_ps(lo), _mm256_cvtepi32_ps(hi))
}
