//! Row-major f32 linear algebra for the native backend.
//!
//! The only heavy primitive is [`matmul`]: `y = x · w (+ bias)` with `x`
//! `[rows, k]` and `w` `[k, m]`, both row-major. Small problems run
//! serially; above [`PARALLEL_THRESHOLD_OPS`] multiply-adds the rows are
//! split into blocks and fanned out over a
//! [`crate::util::threadpool::ThreadPool`]. Weights are held in `Arc`s so
//! blocks can be shipped to workers without copying the matrix; each
//! row's result is computed independently, so serial and parallel
//! execution are bitwise identical.

use std::sync::Arc;

use crate::util::threadpool::ThreadPool;

/// Below this many multiply-adds the pool dispatch overhead dominates and
/// the serial kernel wins.
pub const PARALLEL_THRESHOLD_OPS: usize = 1 << 18;

/// tanh-approximation GELU (the activation of the `TINY_GELU` shape).
pub fn gelu(z: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    const CUBIC: f32 = 0.044_715;
    0.5 * z * (1.0 + (SQRT_2_OVER_PI * (z + CUBIC * z * z * z)).tanh())
}

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

/// Euclidean norm of one row.
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// `y[rows, m] = x[rows, k] · w[k, m] (+ bias[m])`, all row-major.
pub fn matmul(
    pool: Option<&ThreadPool>,
    x: &[f32],
    rows: usize,
    k: usize,
    w: &Arc<Vec<f32>>,
    m: usize,
    bias: Option<&Arc<Vec<f32>>>,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(w.len(), k * m);
    if let Some(pool) = pool {
        if rows >= 2 && rows * k * m >= PARALLEL_THRESHOLD_OPS {
            return matmul_pooled(pool, x, rows, k, w, m, bias);
        }
    }
    matmul_serial(x, rows, k, w, m, bias.map(|b| b.as_slice()))
}

fn matmul_serial(
    x: &[f32],
    rows: usize,
    k: usize,
    w: &[f32],
    m: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    let mut y = vec![0f32; rows * m];
    for (xi, yi) in x.chunks_exact(k).zip(y.chunks_exact_mut(m)).take(rows) {
        if let Some(b) = bias {
            yi.copy_from_slice(b);
        }
        for (&xv, wrow) in xi.iter().zip(w.chunks_exact(m)) {
            if xv != 0.0 {
                for (yv, &wv) in yi.iter_mut().zip(wrow) {
                    *yv += xv * wv;
                }
            }
        }
    }
    y
}

fn matmul_pooled(
    pool: &ThreadPool,
    x: &[f32],
    rows: usize,
    k: usize,
    w: &Arc<Vec<f32>>,
    m: usize,
    bias: Option<&Arc<Vec<f32>>>,
) -> Vec<f32> {
    let jobs = pool.size().min(rows).max(1);
    let per = rows.div_ceil(jobs);
    let blocks: Vec<Vec<f32>> = x.chunks(per * k).map(|c| c.to_vec()).collect();
    let w = Arc::clone(w);
    let bias = bias.cloned();
    let outs = pool.map(blocks, move |xb| {
        let r = xb.len() / k;
        matmul_serial(&xb, r, k, &w, m, bias.as_ref().map(|b| b.as_slice()))
    });
    let mut y = Vec::with_capacity(rows * m);
    for o in outs {
        y.extend_from_slice(&o);
    }
    y
}

/// Standard LayerNorm over the last dimension: per row, subtract the
/// mean, divide by the standard deviation (eps 1e-5), scale and shift.
pub fn layernorm(x: &[f32], rows: usize, d: usize, gain: &[f32], bias: &[f32]) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * d);
    let mut y = vec![0f32; rows * d];
    for (xi, yi) in x.chunks_exact(d).zip(y.chunks_exact_mut(d)).take(rows) {
        let mean = xi.iter().sum::<f32>() / d as f32;
        let var = xi.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (((yv, &xv), &g), &b) in yi.iter_mut().zip(xi).zip(gain).zip(bias) {
            *yv = (xv - mean) * inv * g + b;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arc(v: Vec<f32>) -> Arc<Vec<f32>> {
        Arc::new(v)
    }

    #[test]
    fn matmul_known_values() {
        // x = [[1,2],[3,4]], w = [[5,6],[7,8]] -> [[19,22],[43,50]]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = arc(vec![5.0, 6.0, 7.0, 8.0]);
        let y = matmul(None, &x, 2, 2, &w, 2, None);
        assert_eq!(y, vec![19.0, 22.0, 43.0, 50.0]);
        let b = arc(vec![1.0, -1.0]);
        let y = matmul(None, &x, 2, 2, &w, 2, Some(&b));
        assert_eq!(y, vec![20.0, 21.0, 44.0, 49.0]);
    }

    #[test]
    fn pooled_matches_serial_bitwise() {
        let mut rng = crate::util::rng::Rng::new(11);
        let (rows, k, m) = (64, 96, 128);
        let x: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
        let w = arc((0..k * m).map(|_| rng.normal() as f32).collect());
        let b = arc((0..m).map(|_| rng.normal() as f32).collect());
        let serial = matmul(None, &x, rows, k, &w, m, Some(&b));
        let pool = ThreadPool::new(3);
        // rows*k*m = 786k ops, above the threshold: takes the pooled path.
        let pooled = matmul(Some(&pool), &x, rows, k, &w, m, Some(&b));
        assert_eq!(serial, pooled);
    }

    #[test]
    fn gelu_reference_points() {
        assert_eq!(gelu(0.0), 0.0);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
        // asymptotes: identity for large z, zero for very negative z
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
    }

    #[test]
    fn layernorm_normalizes_rows() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0];
        let gain = vec![1.0; 4];
        let bias = vec![0.0; 4];
        let y = layernorm(&x, 2, 4, &gain, &bias);
        for row in y.chunks(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
        // both rows are affine images of [1,2,3,4]: identical post-norm
        for (a, b) in y[..4].iter().zip(&y[4..]) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
