//! Native partially-linear FFN kernels (the paper's core contribution,
//! executed in pure std-only Rust).
//!
//! * [`kernels`]   — blocked GEMM over pre-packed weights with fused
//!   epilogues, runtime ISA dispatch ([`KernelDispatch`]: portable vs
//!   explicit AVX2/FMA tiles), the fused k-bit dequant GEMM over
//!   [`kernels::QuantPanels`], deterministic parallel tile schedules,
//!   the explicit row-sparse variant, and the [`kernels::Scratch`]
//!   zero-alloc arena
//! * [`dense`]     — the dense FFN with optional per-unit linearized
//!   activation ([`dense::RangeTable`]: uniform or per-neuron
//!   calibrated; reference + fallback path)
//! * [`folded`]    — the constant-folded `W' = W_down·A·W_up` map with
//!   per-range bias and kept-unit columns
//! * [`predictor`] — the online per-row norm-proxy outlier predictor
//! * [`quant`]     — the paper's k-bit quantized `W_up` proxy: per-neuron
//!   in/out decisions + top-K result fixing
//!
//! See `rust/src/ffn/README.md` for the fold math, the two predictors
//! and how to read the routing statistics.
//!
//! [`FfnBackend`] is the per-layer executor
//! [`crate::coordinator::model::NativeModel`] dispatches through; its
//! cumulative [`FfnTelemetry`] feeds the engine's fallback-rate stats.

pub mod dense;
pub mod folded;
pub mod kernels;
pub mod predictor;
pub mod quant;

pub use dense::{DenseFfn, Linearization, RangeTable};
pub use folded::{
    compare_predictors, folded_units_for, FoldedFfn, PredictorComparison,
};
pub use kernels::{KernelDispatch, PackedMatrix, Scratch};
pub use predictor::{OutlierPredictor, PredictorStats, Route};
pub use quant::{
    QuantRoute, QuantRouterStats, QuantizedProxy, QuantizedRouter, RoutingQuality,
};

use crate::util::threadpool::ThreadPool;

/// Cumulative row-routing counters of a partially-linear FFN.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FfnTelemetry {
    /// Rows executed on the folded path.
    pub folded_rows: u64,
    /// Rows routed to the dense fallback path.
    pub fallback_rows: u64,
    /// (row, neuron) pairs actually patched by the quantized router's
    /// top-K result fixing (false flags are exact no-ops and counted
    /// only in `QuantRouterStats::fixed_in_range`; 0 under the norm
    /// predictor).
    pub fixed_neurons: u64,
}

impl FfnTelemetry {
    pub fn total_rows(&self) -> u64 {
        self.folded_rows + self.fallback_rows
    }

    /// Fraction of rows that took the dense fallback path; `None` until
    /// any row has been routed.
    pub fn fallback_rate(&self) -> Option<f64> {
        let total = self.total_rows();
        if total == 0 {
            None
        } else {
            Some(self.fallback_rows as f64 / total as f64)
        }
    }

    pub fn accumulate(&mut self, other: FfnTelemetry) {
        self.folded_rows += other.folded_rows;
        self.fallback_rows += other.fallback_rows;
        self.fixed_neurons += other.fixed_neurons;
    }
}

/// The FFN executor of one native transformer layer.
pub enum FfnBackend {
    Dense(DenseFfn),
    Folded(Box<FoldedFfn>),
}

impl FfnBackend {
    /// The returned buffer comes from `scratch`; hand it back with
    /// [`Scratch::give`] for steady-state zero allocation.
    pub fn forward(
        &mut self,
        pool: Option<&ThreadPool>,
        scratch: &mut Scratch,
        x: &[f32],
        rows: usize,
    ) -> Vec<f32> {
        match self {
            FfnBackend::Dense(f) => f.forward(pool, scratch, x, rows),
            FfnBackend::Folded(f) => f.forward(pool, scratch, x, rows),
        }
    }

    /// [`Self::forward`] with a per-row degraded-service mask: rows with
    /// `forced[i]` set bypass the outlier predictor and run the pure
    /// folded path (no fallback, no fixes — `--fix-k 0` for that row).
    /// A dense layer has nothing to degrade and ignores the mask.
    pub fn forward_forced(
        &mut self,
        pool: Option<&ThreadPool>,
        scratch: &mut Scratch,
        x: &[f32],
        rows: usize,
        forced: &[bool],
    ) -> Vec<f32> {
        match self {
            FfnBackend::Dense(f) => f.forward(pool, scratch, x, rows),
            FfnBackend::Folded(f) => f.forward_forced(pool, scratch, x, rows, forced),
        }
    }

    pub fn telemetry(&self) -> FfnTelemetry {
        match self {
            FfnBackend::Dense(_) => FfnTelemetry::default(),
            FfnBackend::Folded(f) => f.telemetry,
        }
    }

    /// Fraction of dense FFN parameters the deployment eliminated
    /// (`None` for a dense layer).
    pub fn compression_ratio(&self) -> Option<f64> {
        match self {
            FfnBackend::Dense(_) => None,
            FfnBackend::Folded(f) => Some(f.compression_ratio()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn telemetry_rate() {
        let mut t = FfnTelemetry::default();
        assert_eq!(t.fallback_rate(), None);
        let step = FfnTelemetry {
            folded_rows: 3,
            fallback_rows: 1,
            fixed_neurons: 2,
        };
        t.accumulate(step);
        assert_eq!(t.total_rows(), 4);
        assert!((t.fallback_rate().unwrap() - 0.25).abs() < 1e-12);
    }
}
