//! Online out-of-range predictor (paper §5.3, specialised to per-row
//! routing).
//!
//! The fold is only valid while every folded unit's pre-activation
//! `z_j = w_j·x + b_j` stays inside its approximated linear range.
//! Checking that exactly would require the very `x·W_up` matmul folding
//! eliminated, so the predictor routes on a cheap per-row proxy: the
//! input norm `‖x‖₂`.
//!
//! Two gates decide the route:
//!  * **provable** — by Cauchy–Schwarz, `|z_j - b_j| ≤ ‖w_j‖·‖x‖`, so any
//!    row with `‖x‖ ≤ safe_radius = min_j slack_j / ‖w_j‖` is guaranteed
//!    in-range. Computed offline from the fold's weights.
//!  * **learned** — the fallback path computes the true pre-activations
//!    anyway, so every fallback row is an observation: the predictor
//!    grows its radius toward the largest norm seen fully in-range
//!    (scaled by the configured `threshold` margin) and clamps it below
//!    the smallest norm seen out-of-range. A steady in-range workload
//!    pays for one fallback per new high-water mark, then folds.
//!
//! The proxy is one-dimensional, so it can misroute direction-dependent
//! outliers; `threshold` trades that risk against fallback rate
//! (`< 1.0` never folds beyond direct observations, `> 1.0`
//! extrapolates). The paper's full predictor — per-neuron decisions
//! from a k-bit quantized `W_up` proxy with top-K result fixing — lives
//! in [`super::quant`] and is selected with
//! [`PredictorKind::Quantized`](crate::config::PredictorKind);
//! `bench-decode` reports both predictors' precision/recall against
//! ground-truth range violations.
//!
//! The resulting batch split executes in place: [`super::FoldedFfn`]
//! turns the per-row decisions into folded/fallback row masks for the
//! row-sparse kernels (`kernels::matmul_sparse_rows`), so routing costs
//! no gather/scatter copies and no per-call allocation.

/// Where one batch row is executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// In-range: the folded `d×d` map.
    Folded,
    /// Possible outlier: the dense fallback path.
    Fallback,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PredictorStats {
    /// Rows routed to the folded path.
    pub folded: u64,
    /// Rows routed to the dense fallback path.
    pub fallback: u64,
    /// Fallback rows whose true pre-activations were all in range
    /// (conservative mispredictions the online gate learns from).
    pub observed_in_range: u64,
    /// Fallback rows confirmed out of range (true outliers).
    pub observed_out_of_range: u64,
}

#[derive(Debug, Clone)]
pub struct OutlierPredictor {
    /// Rows with `‖x‖` at or below this are provably in-range.
    safe_radius: f32,
    /// Largest `‖x‖` observed with every folded pre-activation in range.
    learned_in: f32,
    /// Smallest `‖x‖` observed out of range; the learned gate never
    /// extrapolates past it.
    out_floor: f32,
    /// Margin multiplier on `learned_in` (config
    /// [`crate::config::TardisFfnConfig::predictor_threshold`]).
    threshold: f32,
    pub stats: PredictorStats,
}

impl OutlierPredictor {
    pub fn new(safe_radius: f32, threshold: f32) -> OutlierPredictor {
        OutlierPredictor {
            safe_radius: safe_radius.max(0.0),
            learned_in: 0.0,
            out_floor: f32::INFINITY,
            threshold: threshold.max(0.0),
            stats: PredictorStats::default(),
        }
    }

    /// The provable (offline) in-range radius.
    pub fn safe_radius(&self) -> f32 {
        self.safe_radius
    }

    /// The radius the next row is judged against. The learned gate stays
    /// strictly below `out_floor`: a norm already proven out-of-range
    /// must never route folded again.
    #[inline]
    pub fn predicted_radius(&self) -> f32 {
        let cap = self.out_floor * (1.0 - f32::EPSILON);
        let learned = (self.learned_in * self.threshold).min(cap);
        self.safe_radius.max(learned)
    }

    /// Route one row by its input norm, recording the decision.
    #[inline]
    pub fn classify(&mut self, x_norm: f32) -> Route {
        if x_norm <= self.predicted_radius() {
            self.stats.folded += 1;
            Route::Folded
        } else {
            self.stats.fallback += 1;
            Route::Fallback
        }
    }

    /// Feed back the ground truth for a fallback row: `in_range` is
    /// whether every folded unit's pre-activation was inside its range.
    pub fn observe(&mut self, x_norm: f32, in_range: bool) {
        if in_range {
            self.stats.observed_in_range += 1;
            self.learned_in = self.learned_in.max(x_norm);
        } else {
            self.stats.observed_out_of_range += 1;
            self.out_floor = self.out_floor.min(x_norm);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn provable_radius_folds_immediately() {
        let mut p = OutlierPredictor::new(2.0, 1.0);
        assert_eq!(p.classify(1.5), Route::Folded);
        assert_eq!(p.classify(2.0), Route::Folded);
        assert_eq!(p.classify(2.5), Route::Fallback);
        assert_eq!(p.stats.folded, 2);
        assert_eq!(p.stats.fallback, 1);
    }

    #[test]
    fn learns_from_in_range_fallbacks() {
        let mut p = OutlierPredictor::new(1.0, 1.0);
        assert_eq!(p.classify(5.0), Route::Fallback);
        p.observe(5.0, true);
        // same norm now folds; slightly larger still falls back
        assert_eq!(p.classify(5.0), Route::Folded);
        assert_eq!(p.classify(5.1), Route::Fallback);
        assert_eq!(p.stats.observed_in_range, 1);
    }

    #[test]
    fn threshold_extrapolates_beyond_observations() {
        let mut p = OutlierPredictor::new(1.0, 1.1);
        p.observe(10.0, true);
        assert_eq!(p.classify(10.9), Route::Folded);
        assert_eq!(p.classify(11.5), Route::Fallback);
    }

    #[test]
    fn out_of_range_observation_caps_the_radius() {
        let mut p = OutlierPredictor::new(1.0, 2.0);
        p.observe(10.0, true);
        p.observe(12.0, false);
        // learned_in * threshold = 20 but the out floor clamps the gate
        // strictly below 12: the proven-bad norm itself must fall back.
        assert!(p.predicted_radius() < 12.0);
        assert!(p.predicted_radius() > 10.0);
        assert_eq!(p.classify(12.0), Route::Fallback);
        assert_eq!(p.classify(15.0), Route::Fallback);
        // the provable radius survives any observation
        p.observe(0.5, false);
        assert!(p.predicted_radius() >= 1.0);
    }
}
